import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("EXTRA_XLA", ""))
import json, sys, traceback
from repro.launch.dryrun import lower_cell, _cfg_for_cell
from repro.configs import ARCH_IDS, SHAPES, get, shape_applicable

OUT = "/root/repo/dryrun_multi_pod.json"
results = []
if os.path.exists(OUT):
    results = json.load(open(OUT))
done = {(r["arch"], r["shape"]) for r in results}

cells = []
for arch in ARCH_IDS:
    for shape in SHAPES:
        cells.append((arch, shape))
cells.sort(key=lambda c: (c[0] == "internvl2_76b" and c[1] == "train_4k",
                          c[1] == "train_4k"))
for arch, shape in cells:
    cfg = _cfg_for_cell(arch, shape)
    if (cfg.name, shape) in done or (arch, shape) in done:
        continue
    run, why = shape_applicable(cfg, SHAPES[shape])
    if not run:
        results.append({"arch": cfg.name, "shape": shape,
                        "mesh": "2x16x16", "skipped": True, "reason": why})
        print(f"[skip] {arch} x {shape}", flush=True)
    else:
        try:
            # Multi-pod cells are the COMPILE + MEMORY proof: skip the HLO
            # text dump (hundreds of MB at 512 devices) and cost analysis —
            # roofline terms are single-pod per the assignment.
            compiled, meta = lower_cell(cfg, shape, True)
        except Exception as e:
            traceback.print_exc()
            results.append({"arch": cfg.name, "shape": shape,
                            "mesh": "2x16x16",
                            "error": f"{type(e).__name__}: {e}"})
            print(f"[FAIL] {arch} x {shape}: {e}", flush=True)
            compiled = None
        if compiled is not None:
            mem = compiled.memory_analysis()
            peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
            results.append({
                "arch": cfg.name, "shape": shape, "mesh": "2x16x16",
                "kind": meta["kind"], "compile_s": round(meta["compile_s"], 1),
                "bytes_per_device": {
                    "args": mem.argument_size_in_bytes,
                    "out": mem.output_size_in_bytes,
                    "temp": mem.temp_size_in_bytes,
                    "alias": mem.alias_size_in_bytes,
                    "peak_est": peak},
                "proof_only": True,
            })
            print(f"[ ok ] {arch} x {shape} x 2x16x16: "
                  f"compile={meta['compile_s']:.1f}s "
                  f"peak={peak/2**30:.2f}GiB", flush=True)
    with open(OUT + ".tmp", "w") as f:
        json.dump(results, f, indent=1)
    os.replace(OUT + ".tmp", OUT)
print("done", len(results))
