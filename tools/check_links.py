#!/usr/bin/env python3
"""Check that intra-repo markdown links resolve to real files.

Scans every tracked ``*.md`` under the repo root (skipping VCS/venv
directories) for inline links/images ``[text](target)`` and reference
definitions ``[ref]: target``, and verifies that each *relative* target
exists on disk. External links (``http(s)://``, ``mailto:``), pure
anchors (``#section``) and targets that resolve outside the repository
(e.g. GitHub web paths like ``../../actions/...``) are ignored — this is
a filesystem check, not a crawler. Anchors on existing files
(``file.md#section``) are checked for the file part only.

Exit status 1 lists every broken link; used by the CI ``docs`` job and
``tests/test_docs.py``.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

SKIP_DIRS = {".git", ".venv", "node_modules", "__pycache__", ".ruff_cache",
             ".pytest_cache", "build", "dist"}

# Machine-extracted reference material (arxiv retrieval artifacts), not
# authored docs — their figure refs were never part of this repo.
SKIP_FILES = {"PAPER.md", "PAPERS.md", "SNIPPETS.md"}

# Inline links/images: [text](target "title"); ignores ](... inside code
# spans well enough for docs written by humans.
_INLINE = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")
# Reference definitions: [ref]: target
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+<?(\S+?)>?\s*$", re.MULTILINE)


def _strip_code(text: str) -> str:
    """Drop fenced code blocks and inline code spans (no links in code)."""
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return re.sub(r"`[^`\n]*`", "", text)


def iter_md_files(root: Path):
    for path in sorted(root.rglob("*.md")):
        if any(part in SKIP_DIRS for part in path.parts):
            continue
        if path.parent == root and path.name in SKIP_FILES:
            continue
        yield path


def check_file(path: Path, root: Path) -> list:
    """Return (line_hint, target) for every broken relative link."""
    text = _strip_code(path.read_text(encoding="utf-8"))
    targets = _INLINE.findall(text) + _REFDEF.findall(text)
    broken = []
    for t in targets:
        if re.match(r"^[a-zA-Z][a-zA-Z0-9+.-]*:", t):  # http:, mailto:, ...
            continue
        if t.startswith("#") or not t:
            continue
        file_part = t.split("#", 1)[0]
        if not file_part:
            continue
        if file_part.startswith("/"):
            # GitHub resolves leading-slash links against the repo root.
            resolved = (root / file_part.lstrip("/")).resolve()
        else:
            resolved = (path.parent / file_part).resolve()
        try:
            resolved.relative_to(root.resolve())
        except ValueError:
            # Escapes the repo (e.g. GitHub badge web paths) — not ours.
            continue
        if not resolved.exists():
            broken.append((path.relative_to(root), t))
    return broken


def main(argv=None) -> int:
    root = Path(argv[1]) if argv and len(argv) > 1 else \
        Path(__file__).resolve().parent.parent
    n_files = 0
    n_links_broken = 0
    for md in iter_md_files(root):
        n_files += 1
        for rel, target in check_file(md, root):
            n_links_broken += 1
            print(f"BROKEN {rel}: ({target})", file=sys.stderr)
    if n_links_broken:
        print(f"{n_links_broken} broken link(s) across {n_files} markdown "
              f"file(s)", file=sys.stderr)
        return 1
    print(f"ok: all intra-repo links resolve across {n_files} markdown "
          f"file(s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
