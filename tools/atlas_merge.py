#!/usr/bin/env python3
"""Merge per-host anomaly-atlas shard files into one canonical atlas.

A sharded adaptive sweep (``python -m repro.core.sweep --mode adaptive
--shard k/n``, see ``repro.core.adaptive``) leaves one
``atlas-…-shardK.jsonl`` file per host, each carrying the full sweep
configuration in its header. This tool reconciles them::

    python tools/atlas_merge.py --out atlas-merged.jsonl shards/atlas-*-shard*.jsonl

Contract (per Peise & Bientinesi, arXiv:1409.8602, measurements are only
comparable under matching hardware/cache conditions):

* every shard's header must agree on schema version, spec name, threshold
  and hardware fingerprint — any mismatch aborts the merge (exit 1);
* duplicate points are deduplicated deterministically: the first writer
  in command-line shard order wins, and the drop is reported (duplicates
  whose payloads actually differ are reported separately as conflicts);
* a torn final line in any shard (a host killed mid-write) is tolerated
  and counted, exactly like ``AnomalyAtlas._load``;
* the output is a canonical atlas: the shared header without the shard
  identity, then one record per point sorted by point — byte-stable for
  a given input set, resumable by ``AnomalyAtlas``, written atomically.

Standalone on purpose (stdlib only): runs without PYTHONPATH=src so ops
hosts that only collect shard files need nothing installed.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple


class MergeError(RuntimeError):
    """Shard files disagree on the sweep configuration (or are not shards)."""


def _canonical(header: dict) -> dict:
    """Header identity that must match across shards (shard id stripped)."""
    return {k: v for k, v in header.items() if k != "shard"}


def load_shard(path: Path) -> Tuple[dict, List[Tuple[tuple, dict]], int]:
    """One shard file -> (header, [(point, record), ...], torn_lines).

    Tolerates a torn tail (undecodable or field-incomplete line) the same
    way the atlas loader does; a missing/torn *header* is a MergeError —
    a shard whose configuration cannot be read must not be merged.
    """
    with path.open() as f:
        first = f.readline()
        try:
            header = json.loads(first)
        except json.JSONDecodeError:
            raise MergeError(f"{path}: unreadable header line")
        if not isinstance(header, dict) or header.get("kind") != "header":
            raise MergeError(f"{path}: first line is not an atlas header")
        records: List[Tuple[tuple, dict]] = []
        torn = 0
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
                point = tuple(int(x) for x in rec["point"])
                # Field presence check mirrors what a resuming atlas
                # needs; a torn line missing fields is dropped, not kept.
                for field in ("is_anomaly", "times", "flops",
                              "cheapest", "fastest"):
                    rec[field]
            except (json.JSONDecodeError, KeyError, TypeError, ValueError):
                torn += 1
                continue
            records.append((point, rec))
    return header, records, torn


@dataclasses.dataclass
class MergeReport:
    out_path: Optional[Path]
    header: dict
    n_shards: int
    n_records: int
    n_duplicates: int              # dropped, first-writer kept
    n_conflicts: int               # duplicates whose payloads differed
    n_torn: int
    duplicates: List[Tuple[tuple, str, str]]  # (point, kept-in, dropped-in)

    def summary(self) -> str:
        lines = [
            f"merged {self.n_shards} shard(s): {self.n_records} instances"
            + (f" -> {self.out_path}" if self.out_path else ""),
            f"duplicates dropped (first writer wins): {self.n_duplicates}"
            + (f" ({self.n_conflicts} with conflicting payloads)"
               if self.n_conflicts else ""),
        ]
        if self.n_torn:
            lines.append(f"torn tail lines tolerated: {self.n_torn}")
        for point, kept, dropped in self.duplicates[:10]:
            lines.append(f"  dup {point}: kept {kept}, dropped {dropped}")
        if len(self.duplicates) > 10:
            lines.append(f"  ... {len(self.duplicates) - 10} more")
        return "\n".join(lines)


def merge_shards(paths: List[Path],
                 out_path: Optional[Path] = None) -> MergeReport:
    """Merge shard files (in the given order) into one canonical atlas.

    ``out_path=None`` validates and reports without writing (dry run).
    """
    if not paths:
        raise MergeError("no shard files given")
    canon: Optional[dict] = None
    canon_src: Optional[Path] = None
    merged: Dict[tuple, dict] = {}
    kept_in: Dict[tuple, str] = {}
    duplicates: List[Tuple[tuple, str, str]] = []
    n_conflicts = 0
    n_torn = 0
    for path in paths:
        path = Path(path)
        header, records, torn = load_shard(path)
        n_torn += torn
        ident = _canonical(header)
        if canon is None:
            canon, canon_src = ident, path
        elif ident != canon:
            diff = sorted(k for k in set(canon) | set(ident)
                          if canon.get(k) != ident.get(k))
            raise MergeError(
                f"{path} disagrees with {canon_src} on {diff} — refusing "
                f"to merge measurements from different sweep "
                f"configurations")
        for point, rec in records:
            if point in merged:
                duplicates.append((point, kept_in[point], path.name))
                if merged[point] != rec:
                    n_conflicts += 1
                continue
            merged[point] = rec
            kept_in[point] = path.name
    if out_path is not None:
        out_path = Path(out_path)
        out_path.parent.mkdir(parents=True, exist_ok=True)
        tmp = out_path.with_suffix(out_path.suffix + ".tmp")
        with tmp.open("w") as f:
            f.write(json.dumps(canon, sort_keys=True) + "\n")
            for point in sorted(merged):
                f.write(json.dumps(merged[point], sort_keys=True) + "\n")
        tmp.replace(out_path)
    return MergeReport(
        out_path=out_path,
        header=canon,
        n_shards=len(paths),
        n_records=len(merged),
        n_duplicates=len(duplicates),
        n_conflicts=n_conflicts,
        n_torn=n_torn,
        duplicates=duplicates,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python tools/atlas_merge.py",
        description="Merge per-host atlas shard files into one canonical "
                    "atlas (first-writer-wins dedup; mismatched "
                    "fingerprint/spec/threshold headers are rejected).")
    ap.add_argument("shards", nargs="+", type=Path,
                    help="shard files in precedence order (first writer "
                         "wins on duplicate points)")
    ap.add_argument("--out", "-o", type=Path, default=None,
                    help="canonical atlas to write (omit for a dry-run "
                         "validation + report)")
    args = ap.parse_args(argv)
    try:
        report = merge_shards(args.shards, args.out)
    except (MergeError, OSError) as e:
        print(f"atlas merge failed: {e}", file=sys.stderr)
        return 1
    print(report.summary())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
