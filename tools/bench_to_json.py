#!/usr/bin/env python3
"""Convert the benchmark CSV contract into a perf-trajectory JSON artifact.

``python -m benchmarks.run`` prints ``name,value,derived`` rows to
stdout; CI pipes them here to produce the ``BENCH_<n>.json`` artifact that
seeds the repo's perf trajectory — one self-describing document per run,
so regressions can be plotted across PRs without re-running anything.

Usage::

    python tools/bench_to_json.py bench.csv BENCH_5.json

``value`` is microseconds-per-call by default; rows whose derived field
carries a ``unit=<u>`` token (the discriminant scoreboard emits
``unit=percent`` accuracy/regret rows) are tagged with that unit instead,
so quality metrics ride the same trajectory as latency metrics without
being misread as times. Each row lands as ``{"name", "value", "unit",
"us_per_call", "derived"}`` (``us_per_call`` mirrors ``value`` for
consumers of the original schema).

The converter is strict about the row shape (a malformed emit() should
fail CI, not silently drop a metric) but tolerant of comment lines
(``# ...``) and blank lines.
"""

from __future__ import annotations

import json
import platform
import sys
from datetime import datetime, timezone
from pathlib import Path


def parse_rows(text: str) -> list:
    rows = []
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split(",", 2)
        if len(parts) < 2:
            raise SystemExit(
                f"line {lineno}: expected 'name,us_per_call[,derived]', "
                f"got {line!r}")
        name, us = parts[0].strip(), parts[1].strip()
        try:
            us_val = float(us)
        except ValueError:
            raise SystemExit(
                f"line {lineno}: value is not a number: {us!r}")
        derived = parts[2].strip() if len(parts) > 2 else ""
        unit = "us"
        for token in derived.split(";"):
            if token.startswith("unit="):
                unit = token[len("unit="):].strip() or "us"
        rows.append({
            "name": name,
            "value": us_val,
            "unit": unit,
            "us_per_call": us_val,
            "derived": derived,
        })
    if not rows:
        raise SystemExit("no benchmark rows found — did the run fail?")
    return rows


def main(argv) -> int:
    if len(argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    src, dst = Path(argv[1]), Path(argv[2])
    rows = parse_rows(src.read_text())
    doc = {
        "schema": 1,
        "generated_utc": datetime.now(timezone.utc).isoformat(),
        "host": {"machine": platform.machine(),
                 "python": platform.python_version()},
        "n_rows": len(rows),
        "rows": rows,
    }
    dst.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    print(f"wrote {dst} ({len(rows)} rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
