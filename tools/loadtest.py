#!/usr/bin/env python3
"""Load-test the serving plan cache: concurrent synthetic request storms.

Drives thousands of lookups from a thread pool through
:class:`repro.serve.plan_cache.PlanService` and reports the numbers the
serving story stands on (docs/serving.md):

* **steady-state selection latency** — p50/p99 of cache-*hit* lookups,
  the per-request planner cost once shapes are warm (the CI-gated
  number: regressions here are lock convoys or key-build bloat);
* **cold selection latency** — first-touch misses (enumeration +
  ranking), the cost ``plan_warmup`` hides from first requests;
* **cache hit rate** over the storm;
* **coalescing effectiveness** — a barrier-synchronised burst of
  same-shape misses should run ONE enumeration; effectiveness is the
  fraction of the burst that waited instead of duplicating work.

Usage::

    PYTHONPATH=src python tools/loadtest.py --requests 2000 --threads 8
    PYTHONPATH=src python tools/loadtest.py --gate-p99-us 5000   # CI gate

Exit status is non-zero iff a ``--gate-p99-us`` bound is violated — the
serve-smoke CI job runs exactly that, so a steady-state regression fails
the build instead of drifting into the trajectory unnoticed.
"""

from __future__ import annotations

import argparse
import dataclasses
import random
import sys
import threading
import time
from typing import Dict, List, Sequence, Tuple

#: The default synthetic shape pool: decode-regime instances of the
#: serving zoo families (a small model's worth of distinct shapes).
DEFAULT_SHAPES: Tuple[Tuple[str, Tuple[int, ...]], ...] = (
    ("decproj", (1, 256, 768)),
    ("decproj", (1, 256, 1024)),
    ("decproj", (8, 256, 768)),
    ("decattn", (1, 512, 64, 256)),
    ("decattn", (1, 1024, 64, 256)),
    ("decmlp", (1, 256, 1024)),
    ("decmlp", (8, 256, 1024)),
    ("decmlp", (1, 512, 2048)),
)


@dataclasses.dataclass
class LoadReport:
    requests: int
    threads: int
    wall_s: float
    hit_p50_us: float
    hit_p99_us: float
    miss_p50_us: float
    miss_p99_us: float
    hit_rate: float            # 0..1 over the storm phase
    throughput_rps: float
    coalesce_effectiveness: float   # 0..1 over the burst phase
    burst_misses: int          # enumerations actually run in the burst
    stats: Dict[str, int]      # final service counters


def _percentile(sorted_vals: Sequence[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def _storm(service, schedule: List[Tuple[str, Tuple[int, ...]]],
           threads: int) -> Tuple[List[float], float]:
    """Run the schedule across a thread pool; returns (latencies_us, wall)."""
    chunks = [schedule[i::threads] for i in range(threads)]
    lat: List[List[float]] = [[] for _ in range(threads)]
    start = threading.Barrier(threads + 1)

    def worker(tid: int) -> None:
        mine, out = chunks[tid], lat[tid]
        start.wait()
        for family, dims in mine:
            t0 = time.perf_counter_ns()
            service.lookup(family, dims)
            out.append((time.perf_counter_ns() - t0) / 1e3)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(threads)]
    for t in ts:
        t.start()
    start.wait()
    t0 = time.perf_counter()
    for t in ts:
        t.join()
    wall = time.perf_counter() - t0
    return [v for chunk in lat for v in chunk], wall


def coalescing_burst(make_service, threads: int = 16,
                     shape: Tuple[str, Tuple[int, ...]] = ("decmlp",
                                                           (3, 96, 384))
                     ) -> Tuple[float, int, int]:
    """Barrier-aligned same-shape miss burst on a FRESH service.

    Returns (effectiveness, misses, coalesced). With no coalescing every
    thread would enumerate; effectiveness is the fraction of potential
    duplicate enumerations avoided, ``(threads - misses)/(threads - 1)``
    — 1.0 means exactly one enumeration ran, whether the other threads
    parked on the in-flight marker (``coalesced``) or arrived after
    publication (lock-free hits). Both avoid the duplicate work.
    """
    service = make_service()
    family, dims = shape
    start = threading.Barrier(threads)

    def worker() -> None:
        start.wait()
        service.lookup(family, dims)

    ts = [threading.Thread(target=worker) for _ in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    stats = service.cache.stats()
    misses, coalesced = stats["misses"], stats["coalesced"]
    eff = (threads - misses) / max(1, threads - 1)
    return eff, misses, coalesced


def run_loadtest(service, *, requests: int = 2000, threads: int = 8,
                 shapes: Sequence[Tuple[str, Tuple[int, ...]]] =
                 DEFAULT_SHAPES, seed: int = 0,
                 make_service=None) -> LoadReport:
    """Cold phase + concurrent storm + coalescing burst → LoadReport.

    The cold phase touches every shape once single-threaded (those are
    the miss latencies); the storm then runs ``requests`` lookups over
    ``threads`` threads, all steady-state hits. ``make_service`` (a
    zero-arg factory) is used for the burst phase, which needs a fresh,
    cold cache; defaults to ``type(service)()``.
    """
    rng = random.Random(seed)
    shapes = list(shapes)

    miss_us: List[float] = []
    for family, dims in shapes:           # cold: one miss per shape
        t0 = time.perf_counter_ns()
        service.lookup(family, dims)
        miss_us.append((time.perf_counter_ns() - t0) / 1e3)
    miss_us.sort()

    base = dict(service.cache.stats())
    schedule = [shapes[rng.randrange(len(shapes))] for _ in range(requests)]
    hit_us, wall = _storm(service, schedule, threads)
    hit_us.sort()
    after = service.cache.stats()
    storm_hits = after["hits"] - base["hits"]
    storm_lookups = after["lookups"] - base["lookups"]
    hit_rate = storm_hits / max(1, storm_lookups)

    if make_service is None:
        make_service = type(service)
    eff, burst_misses, _ = coalescing_burst(make_service, threads=threads)

    return LoadReport(
        requests=requests, threads=threads, wall_s=wall,
        hit_p50_us=_percentile(hit_us, 0.50),
        hit_p99_us=_percentile(hit_us, 0.99),
        miss_p50_us=_percentile(miss_us, 0.50),
        miss_p99_us=_percentile(miss_us, 0.99),
        hit_rate=hit_rate,
        throughput_rps=requests / max(wall, 1e-9),
        coalesce_effectiveness=eff,
        burst_misses=burst_misses,
        stats=after,
    )


def main(argv: Sequence[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="loadtest", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--threads", type=int, default=8)
    ap.add_argument("--discriminant", default="perfmodel")
    ap.add_argument("--backend", default="numpy")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--gate-p99-us", type=float, default=None,
                    help="fail (exit 1) if steady-state cache-hit "
                         "selection p99 exceeds this many microseconds")
    args = ap.parse_args(argv)

    from repro.serve.plan_cache import PlanService

    def make_service() -> PlanService:
        return PlanService(discriminant=args.discriminant,
                           backend=args.backend)

    rep = run_loadtest(make_service(), requests=args.requests,
                       threads=args.threads, seed=args.seed,
                       make_service=make_service)
    print(f"requests={rep.requests} threads={rep.threads} "
          f"wall={rep.wall_s:.3f}s throughput={rep.throughput_rps:,.0f} rps",
          file=sys.stderr)
    print(f"selection hit   p50={rep.hit_p50_us:.1f}us "
          f"p99={rep.hit_p99_us:.1f}us (hit rate {rep.hit_rate:.1%})",
          file=sys.stderr)
    print(f"selection miss  p50={rep.miss_p50_us:.1f}us "
          f"p99={rep.miss_p99_us:.1f}us", file=sys.stderr)
    print(f"coalescing      effectiveness={rep.coalesce_effectiveness:.1%} "
          f"(burst enumerations: {rep.burst_misses})", file=sys.stderr)
    if args.gate_p99_us is not None and rep.hit_p99_us > args.gate_p99_us:
        print(f"GATE FAILED: cache-hit selection p99 {rep.hit_p99_us:.1f}us "
              f"> bound {args.gate_p99_us:.1f}us", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
