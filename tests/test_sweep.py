"""Sweep engine: atlas resumability (kill mid-sweep, restart, no duplicate
instances), sharding equivalence (serial vs process-pool sweeps agree),
region clustering on synthetic masks, batched kernel dedup, and the CLI
(ISSUE 2). Crash/restart interleavings over adaptive shard files and
region-ordering determinism ride along from ISSUE 7."""

import json
import random
import tempfile
from pathlib import Path

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.adaptive import adaptive_sweep
from repro.core.anomaly import cluster_regions, region_summary
from repro.core.profile_store import HardwareFingerprint
from repro.core.perfmodel import AnalyticalTPUProfile, TableProfile
from repro.core.sweep import (
    GRAM_AATB,
    REGISTRY,
    SWEEP_GRIDS,
    AnomalyAtlas,
    AtlasError,
    GridSpec,
    atlas_path,
    atlas_shard_path,
    benchmark_unique_calls,
    cluster_sweep,
    collect_unique_calls,
    main as sweep_main,
    measure_instance,
    predict_classifications,
    sweep,
)
from repro.core.synthetic import BlobMask, MaskRunner, PlantedSpec, dense_oracle
from repro.core.experiments import experiment1_random_search
from repro.core.flops import gemm, syrk

FP = HardwareFingerprint(backend="blas", device="testdev", dtype="float64")

GRID = GridSpec.uniform((32, 64, 96), GRAM_AATB.ndims, name="test")


class DeterministicRunner:
    """FLOP-proportional fake timer with a SYRK cliff at m >= 64.

    For AAᵀB this makes the FLOP-cheapest (SYRK-based) algorithms slower
    than the GEMM-based ones exactly when d0 >= 64 — so every grid point
    with d0 >= 64 is an anomaly, deterministically, with zero noise.
    Top-level class: instances/factories pickle across the process pool.
    """

    def make_operands(self, alg):
        return {}

    def time_algorithm(self, alg, operands=None):
        t = 0.0
        for call in alg.calls:
            t += call.flops * 1e-9
            if call.kind == "syrk" and call.dims[0] >= 64:
                t += call.flops * 3e-9
            if call.kind == "tri2full":
                t += 1e-6
        return t


def _expected_anomaly(point):
    """First-principles oracle: anomalous iff the FLOP-cheapest algorithms
    all use SYRK (pure FLOP arithmetic, no timing) and the runner's SYRK
    cliff applies (d0 >= 64)."""
    algos = GRAM_AATB.algorithms(point)
    fmin = min(a.flops for a in algos)
    cheapest = [a for a in algos if a.flops == fmin]
    all_syrk = all(any(c.kind == "syrk" for c in a.calls) for a in cheapest)
    return all_syrk and point[0] >= 64


# ------------------------------------------------------------------ grids --

def test_grid_spec_points_and_named_grids():
    g = GridSpec.uniform((64, 32), 3)
    assert g.axes == ((32, 64),) * 3
    assert g.n_points == 8
    pts = g.points()
    assert len(pts) == 8 and len(set(pts)) == 8
    assert pts[0] == (32, 32, 32) and pts[-1] == (64, 64, 64)
    for name in SWEEP_GRIDS:
        assert GridSpec.named(name, 2).n_points == len(SWEEP_GRIDS[name]) ** 2
    with pytest.raises(ValueError):
        GridSpec(name="bad", axes=((64, 32),))  # unsorted
    with pytest.raises(ValueError):
        GridSpec.named("nope", 3)


# ------------------------------------------------------------ measurement --

def test_sweep_serial_classifies_deterministically(tmp_path):
    atlas = AnomalyAtlas(tmp_path / "a.jsonl", FP, GRAM_AATB.name, 0.10)
    res = sweep(GRAM_AATB, GRID.points(), runner=DeterministicRunner(),
                threshold=0.10, atlas=atlas)
    assert res.n_measured == GRID.n_points and res.n_skipped == 0
    assert len(res.records) == GRID.n_points
    for r in res.records:
        assert r.cls.is_anomaly == _expected_anomaly(r.point), r.point
        # engine result matches a direct measure_instance
        direct = measure_instance(GRAM_AATB, r.point,
                                  DeterministicRunner(), 0.10)
        assert direct.cls == r.cls
        assert direct.times == r.times
    assert (tmp_path / "a.jsonl").is_file()


def test_sweep_result_preserves_requested_order(tmp_path):
    pts = list(reversed(GRID.points()))
    res = sweep(GRAM_AATB, pts, runner=DeterministicRunner())
    assert [r.point for r in res.records] == pts


@pytest.mark.parametrize("expr", sorted(REGISTRY))
def test_every_registered_expression_sweeps_and_resumes(expr, tmp_path):
    """Registry gate: a family that breaks sweeping (mis-shaped grid,
    enumeration error, unserializable spec) must fail here, not in a
    user's overnight run. Measure the smoke grid, then resume: 0 new."""
    spec = REGISTRY[expr]
    grid = spec.grid("smoke")
    path = tmp_path / f"{expr}.jsonl"
    atlas = AnomalyAtlas(path, FP, spec.name, 0.10)
    res = sweep(spec, grid.points(), runner=DeterministicRunner(),
                atlas=atlas)
    assert res.n_measured == grid.n_points and res.n_skipped == 0
    atlas2 = AnomalyAtlas(path, FP, spec.name, 0.10)
    res2 = sweep(spec, grid.points(), runner=DeterministicRunner(),
                 atlas=atlas2)
    assert res2.n_measured == 0 and res2.n_skipped == grid.n_points


# ------------------------------------------------------------ resumability --

def test_killed_sweep_resumes_without_duplicates(tmp_path):
    path = tmp_path / "atlas.jsonl"
    # First run dies after 10 instances (budget cap stands in for a kill
    # right after a chunk flush).
    atlas = AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10, chunk_size=5)
    res1 = sweep(GRAM_AATB, GRID.points(), runner=DeterministicRunner(),
                 atlas=atlas, max_instances=10)
    assert res1.n_measured == 10 and len(atlas) == 10

    # Restart from disk: only the remaining 17 are measured.
    atlas2 = AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10)
    assert len(atlas2) == 10
    res2 = sweep(GRAM_AATB, GRID.points(), runner=DeterministicRunner(),
                 atlas=atlas2)
    assert res2.n_skipped == 10
    assert res2.n_measured == GRID.n_points - 10
    assert len(atlas2) == GRID.n_points

    # No duplicate instances on disk: header + one line per point.
    lines = path.read_text().strip().splitlines()
    assert len(lines) == 1 + GRID.n_points
    pts = [tuple(json.loads(li)["point"]) for li in lines[1:]]
    assert len(set(pts)) == GRID.n_points

    # A third run measures nothing at all.
    atlas3 = AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10)
    res3 = sweep(GRAM_AATB, GRID.points(), runner=DeterministicRunner(),
                 atlas=atlas3)
    assert res3.n_measured == 0 and res3.n_skipped == GRID.n_points


def test_unflushed_chunk_is_lost_but_flushed_chunks_survive(tmp_path):
    path = tmp_path / "atlas.jsonl"
    atlas = AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10, chunk_size=100)
    pts = GRID.points()
    for p in pts[:5]:
        atlas.append(measure_instance(GRAM_AATB, p, DeterministicRunner(),
                                      0.10))
    atlas.flush()  # chunk boundary
    for p in pts[5:8]:
        atlas.append(measure_instance(GRAM_AATB, p, DeterministicRunner(),
                                      0.10))
    # no flush: the process "dies" here — at most one chunk is lost
    resumed = AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10)
    assert len(resumed) == 5
    assert all(p in resumed for p in pts[:5])


def test_torn_tail_line_is_tolerated(tmp_path):
    path = tmp_path / "atlas.jsonl"
    atlas = AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10)
    res = sweep(GRAM_AATB, GRID.points()[:6], runner=DeterministicRunner(),
                atlas=atlas)
    assert res.n_measured == 6
    with path.open("a") as f:
        f.write('{"point": [128, 128,')  # killed mid-write, no newline
    resumed = AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10)
    assert len(resumed) == 6
    assert resumed.skipped_lines == 1
    # and the resumed atlas still appends cleanly after the torn tail
    res2 = sweep(GRAM_AATB, GRID.points()[:8], runner=DeterministicRunner(),
                 atlas=resumed)
    assert res2.n_measured == 2 and res2.n_skipped == 6
    # records appended after the torn tail survive the next load — the
    # flush restores the newline the torn line lost, instead of merging
    # the first new record into the garbage
    assert len(AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10)) == 8


def test_torn_header_recovers_with_sidecar(tmp_path):
    path = tmp_path / "atlas.jsonl"
    path.write_text('{"kind": "head')  # killed mid-write of line 1
    atlas = AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10)
    assert len(atlas) == 0
    assert atlas.recovered_from is not None
    assert atlas.recovered_from.read_text() == '{"kind": "head'
    # ...and the fresh atlas works end to end
    res = sweep(GRAM_AATB, GRID.points()[:3], runner=DeterministicRunner(),
                atlas=atlas)
    assert res.n_measured == 3
    assert len(AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10)) == 3


def test_sweep_rejects_atlas_threshold_mismatch(tmp_path):
    atlas = AnomalyAtlas(tmp_path / "a.jsonl", FP, GRAM_AATB.name, 0.10)
    with pytest.raises(ValueError, match="threshold"):
        sweep(GRAM_AATB, GRID.points()[:2], runner=DeterministicRunner(),
              threshold=0.05, atlas=atlas)


def test_sweep_rejects_runner_on_sharded_backends():
    # a runner's config (reps, cache flushing) would be silently dropped
    with pytest.raises(ValueError, match="serial"):
        sweep(GRAM_AATB, GRID.points()[:2], runner=DeterministicRunner(),
              backend="process", runner_factory=DeterministicRunner)
    with pytest.raises(ValueError, match="serial"):
        sweep(GRAM_AATB, GRID.points()[:2], runner=DeterministicRunner(),
              backend="jax")


def test_atlas_rejects_wrong_fingerprint_and_config(tmp_path):
    path = tmp_path / "atlas.jsonl"
    with AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10) as atlas:
        atlas.append(measure_instance(GRAM_AATB, (32, 32, 32),
                                      DeterministicRunner(), 0.10))
    other = HardwareFingerprint(backend="jax", device="TPU v5e",
                                dtype="bfloat16")
    with pytest.raises(AtlasError):
        AnomalyAtlas(path, other, GRAM_AATB.name, 0.10)
    with pytest.raises(AtlasError):
        AnomalyAtlas(path, FP, "ABCD", 0.10)
    with pytest.raises(AtlasError):
        AnomalyAtlas(path, FP, GRAM_AATB.name, 0.05)
    # the honest configuration still opens
    assert len(AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10)) == 1


# ----------------------------------------------------- sharding equivalence --

def test_process_sharded_sweep_equals_serial(tmp_path):
    serial_atlas = AnomalyAtlas(tmp_path / "serial.jsonl", FP,
                                GRAM_AATB.name, 0.10)
    serial = sweep(GRAM_AATB, GRID.points(), runner=DeterministicRunner(),
                   atlas=serial_atlas)
    sharded_atlas = AnomalyAtlas(tmp_path / "sharded.jsonl", FP,
                                 GRAM_AATB.name, 0.10)
    sharded = sweep(GRAM_AATB, GRID.points(), backend="process", shards=2,
                    runner_factory=DeterministicRunner, chunk_size=4,
                    atlas=sharded_atlas)
    assert sharded.n_measured == serial.n_measured == GRID.n_points
    a = {r.point: (r.cls, r.times, r.flops) for r in serial.records}
    b = {r.point: (r.cls, r.times, r.flops) for r in sharded.records}
    assert a == b  # deterministic runner -> identical atlases, exactly

    # the two atlases re-open to identical contents too
    ra = AnomalyAtlas(tmp_path / "serial.jsonl", FP, GRAM_AATB.name, 0.10)
    rb = AnomalyAtlas(tmp_path / "sharded.jsonl", FP, GRAM_AATB.name, 0.10)
    assert {r.point: r.cls for r in ra.records()} == \
        {r.point: r.cls for r in rb.records()}


def test_jax_backend_smoke(tmp_path):
    # Real timing on however many JAX devices exist (1 in CI): just the
    # contract — everything measured once, records complete.
    g = GridSpec.uniform((8, 16), GRAM_AATB.ndims)
    atlas = AnomalyAtlas(tmp_path / "jax.jsonl", FP, GRAM_AATB.name, 0.10)
    res = sweep(GRAM_AATB, g.points(), backend="jax", reps=1, atlas=atlas)
    assert res.n_measured == g.n_points
    assert all(set(r.times) == set(r.flops) for r in res.records)
    assert len(atlas) == g.n_points


# ------------------------------------------------------------- clustering --

def test_cluster_regions_synthetic_mask():
    axes = [(10, 20, 30, 40), (10, 20, 30, 40)]
    scores = {
        # L-shaped component of three points...
        (10, 10): (0.30, 0.10),
        (20, 10): (0.50, 0.20),
        (20, 20): (0.10, 0.30),
        # ...and an isolated singleton across the grid.
        (40, 40): (0.90, 0.40),
    }
    regions = cluster_regions(scores, axes)
    assert [r.size for r in regions] == [3, 1]
    big, small = regions
    assert set(big.points) == {(10, 10), (20, 10), (20, 20)}
    assert big.lo == (10, 10) and big.hi == (20, 20)
    assert big.max_time_score == pytest.approx(0.50)
    assert big.mean_time_score == pytest.approx(0.30)
    assert big.mean_flop_score == pytest.approx(0.20)
    assert small.points == ((40, 40),)
    assert small.max_flop_score == pytest.approx(0.40)


def test_cluster_regions_positional_adjacency_not_metric():
    # (64, 128) are adjacent grid positions even though they differ by 64.
    axes = [(32, 64, 128)]
    regions = cluster_regions({(64,): (0.2, 0.1), (128,): (0.2, 0.1)}, axes)
    assert len(regions) == 1 and regions[0].size == 2


def test_cluster_sweep_matches_expected_region(tmp_path):
    res = sweep(GRAM_AATB, GRID.points(), runner=DeterministicRunner())
    regions = cluster_sweep(res.records, GRID)
    expected = {p for p in GRID.points() if _expected_anomaly(p)}
    assert expected  # the cliff must actually produce anomalies
    # they form one contiguous region covering exactly the expected set
    assert len(regions) == 1
    assert set(regions[0].points) == expected
    assert regions[0].lo == (64, 64, 32) and regions[0].hi == (96, 96, 96)


def test_cluster_sweep_ignores_off_grid_records():
    res = sweep(GRAM_AATB, [(64, 32, 32), (65, 32, 32)],
                runner=DeterministicRunner())
    regions = cluster_sweep(res.records, GRID)  # (65,..) is off-grid
    assert sum(r.size for r in regions) <= 1


# ------------------------------------------------- batched kernel benching --

class CountingRunner:
    def __init__(self):
        self.calls = []

    def benchmark_call(self, call, reps=None):
        self.calls.append(call)
        return 1e-6 * max(1, call.flops) ** 0.5


def test_benchmark_unique_calls_dedups_and_reuses_profile():
    runner = CountingRunner()
    calls = [gemm(64, 64, 64), gemm(64, 64, 64), syrk(64, 64),
             gemm(64, 64, 64), syrk(64, 64)]
    profile = TableProfile(1e11, table={("syrk", (64, 64)): 5e-5})
    profile, n_meas, n_reused = benchmark_unique_calls(
        runner, calls, profile=profile)
    assert n_meas == 1 and n_reused == 1            # 2 unique, 1 cached
    assert len(runner.calls) == 1                   # duplicates never timed
    assert profile.table[("syrk", (64, 64))] == 5e-5  # cache untouched
    # a second pass over the same stream measures nothing
    _, n_meas2, n_reused2 = benchmark_unique_calls(runner, calls,
                                                   profile=profile)
    assert n_meas2 == 0 and n_reused2 == 2


def test_benchmark_unique_calls_raises_cached_profile_peak():
    class FastRunner:
        def benchmark_call(self, call, reps=None):
            return 1e-9  # absurdly fast -> throughput far above old peak

    profile = TableProfile(1e3, table={("syrk", (64, 64)): 5e-5})
    call = gemm(64, 64, 64)
    benchmark_unique_calls(FastRunner(), [call], profile=profile)
    assert profile.peak() >= call.flops / 1e-9  # stale peak was raised
    assert profile.efficiency(call) <= 1.0


def test_collect_unique_calls_shrinks_grid_call_stream():
    pts = GRID.points()
    unique = collect_unique_calls(GRAM_AATB, pts)
    total = sum(len(a.calls) for p in pts for a in GRAM_AATB.algorithms(p))
    assert len(unique) == len(set(unique))
    assert len(unique) < total / 2  # the dedup is what makes predict cheap


def test_predict_classifications_covers_every_point():
    pts = GRID.points()[:6]
    out = predict_classifications(GRAM_AATB, pts, AnalyticalTPUProfile(),
                                  threshold=0.05)
    assert set(out) == set(pts)
    for cls in out.values():
        assert cls.cheapest and cls.fastest


# ------------------------------------------------- experiments on the engine --

def test_experiment1_runs_through_engine_and_resumes(tmp_path):
    atlas = AnomalyAtlas(tmp_path / "e1.jsonl", FP, GRAM_AATB.name, 0.10)
    r1 = experiment1_random_search(
        GRAM_AATB, DeterministicRunner(), box=(32, 96), n_anomalies=5,
        max_samples=50, threshold=0.10, seed=3, atlas=atlas)
    assert r1.anomalies and r1.samples <= 50
    for inst in r1.anomalies:
        assert inst.cls.is_anomaly
    # identical re-run is served from the atlas: nothing new on disk
    atlas2 = AnomalyAtlas(tmp_path / "e1.jsonl", FP, GRAM_AATB.name, 0.10)
    before = len(atlas2)
    r2 = experiment1_random_search(
        GRAM_AATB, DeterministicRunner(), box=(32, 96), n_anomalies=5,
        max_samples=50, threshold=0.10, seed=3, atlas=atlas2)
    assert len(atlas2) == before
    assert [i.point for i in r2.anomalies] == [i.point for i in r1.anomalies]


# -------------------------------------------------------------------- CLI --

def test_cli_sweep_writes_resumable_atlas(tmp_path, capsys):
    args = ["--expr", "aatb", "--grid", "smoke", "--reps", "1",
            "--no-flush", "--atlas-dir", str(tmp_path), "--quiet"]
    assert sweep_main(args) == 0
    out1 = capsys.readouterr().out
    assert "measured=8" in out1 and "skipped=0" in out1

    files = list(tmp_path.glob("atlas-aatb-*.jsonl"))
    assert len(files) == 1  # named by expr + threshold + fingerprint

    # re-run: every instance is served from the atlas
    assert sweep_main(args) == 0
    out2 = capsys.readouterr().out
    assert "measured=0" in out2 and "skipped=8" in out2


def test_cli_predict_mode_feeds_profile_cache(tmp_path, monkeypatch,
                                              capsys):
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path / "profiles"))
    args = ["--expr", "aatb", "--grid", "smoke", "--reps", "1",
            "--no-flush", "--mode", "predict",
            "--atlas-dir", str(tmp_path), "--quiet"]
    assert sweep_main(args) == 0
    out = capsys.readouterr().out
    assert "predicted anomalies=" in out
    profiles = list((tmp_path / "profiles").glob("profile-*.json"))
    assert len(profiles) == 1  # batched benchmarks landed in the cache
    n_entries = len(json.loads(profiles[0].read_text())["entries"])
    assert n_entries > 0
    # second predict run reuses every cached kernel timing
    assert sweep_main(args) == 0
    out2 = capsys.readouterr().out
    assert "measured=0" in out2


def test_atlas_path_is_fingerprint_keyed(tmp_path):
    p = atlas_path("AATB", FP, 0.10, tmp_path)
    assert p.name == "atlas-aatb-t0p1-blas-testdev-float64.jsonl"


# ----------------------------------- budgeted sweeps vs the atlas (ISSUE 7) --

def test_max_instances_budget_is_not_consumed_by_cached_points(tmp_path):
    """Atlas-cached points are excluded before the max_instances cut, so
    the budget buys new measurements only."""
    path = tmp_path / "a.jsonl"
    sweep(GRAM_AATB, GRID.points()[:10], runner=DeterministicRunner(),
          atlas=AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10))
    atlas = AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10)
    res = sweep(GRAM_AATB, GRID.points(), runner=DeterministicRunner(),
                atlas=atlas, max_instances=5)
    assert res.n_skipped == 10      # every cached point still served
    assert res.n_measured == 5      # the budget bought 5 *new* points
    assert len(atlas) == 15
    # the 5 new points are the first 5 uncached ones, in request order
    cached = set(GRID.points()[:10])
    new = [r.point for r in res.records if r.point not in cached]
    assert new == GRID.points()[10:15]


# ----------------------- adaptive shard crash/restart interleaving (ISSUE 7) --

PLANTED = PlantedSpec()
PGRID = GridSpec.uniform(tuple(range(10, 110, 10)), 2, name="planted10")
PMASK = BlobMask(center=(50, 50), radius=24.0)


class RecordingMaskRunner:
    """MaskRunner that records which points it timed and can crash."""

    def __init__(self, mask, fail_after=None):
        self.inner = MaskRunner(mask)
        self.fail_after = fail_after
        self.count = 0
        self.timed = set()

    def make_operands(self, alg):
        return {}

    def time_algorithm(self, alg, operands=None):
        self.count += 1
        if self.fail_after is not None and self.count > self.fail_after:
            raise RuntimeError("simulated crash")
        self.timed.add(alg.point)
        return self.inner.time_algorithm(alg, operands)


@settings(max_examples=8, deadline=None)
@given(kill_a=st.integers(min_value=1, max_value=40),
       kill_b=st.integers(min_value=1, max_value=40),
       tear=st.sampled_from((False, True)))
def test_shard_crash_restart_never_loses_or_double_measures(
        kill_a, kill_b, tear):
    """Arbitrary crash/restart interleavings over the per-host shard files
    never lose a completed (flushed) measurement and never re-measure a
    point any host already persisted — the torn-tail fixtures of the dense
    engine, replayed through the sharded adaptive trajectory."""
    budget = 60
    with tempfile.TemporaryDirectory() as td:
        paths = [atlas_shard_path(PLANTED.name, FP, 0.10, k, Path(td))
                 for k in (0, 1)]

        def persisted():
            out = []
            for k, p in enumerate(paths):
                if p.is_file():
                    a = AnomalyAtlas(p, FP, PLANTED.name, 0.10,
                                     shard=(k, 2))
                    out.append({r.point for r in a.records()})
                else:
                    out.append(set())
            return out

        last = {}

        def step(host, kill=None):
            before = persisted()
            runner = RecordingMaskRunner(PMASK, kill)
            atlas = AnomalyAtlas(paths[host], FP, PLANTED.name, 0.10,
                                 chunk_size=3, shard=(host, 2))
            stopped = None
            try:
                last[host] = adaptive_sweep(
                    PLANTED, PGRID, budget, atlas=atlas, shard=(host, 2),
                    runner=runner)
                stopped = last[host].stopped
            except RuntimeError:
                pass                      # the simulated crash
            after = persisted()
            for b, a in zip(before, after):
                assert b <= a             # completed measurements survive
            # nothing persisted anywhere is ever re-measured
            assert not (runner.timed & (before[0] | before[1]))
            if tear and paths[host].is_file():
                with paths[host].open("a") as f:
                    f.write('{"point": [70, 7')   # kill mid-write
            return stopped

        step(0, kill_a)                   # both hosts crash once...
        step(1, kill_b)
        for _ in range(30):               # ...then clean lockstep reruns
            r0 = step(0)
            r1 = step(1)
            if r0 != "awaiting-siblings" and r1 != "awaiting-siblings":
                break
        else:
            pytest.fail("shard lockstep did not converge after crashes")

        # both hosts agree on the full trajectory, the shard files union
        # to it exactly, and every verdict matches the planted oracle
        union = set().union(*persisted())
        assert union == set(last[0].known) == set(last[1].known)
        oracle = dense_oracle(PMASK, PGRID)
        for p, inst in last[0].known.items():
            assert inst.cls.is_anomaly == oracle[p], p


# ------------------------------- region ordering determinism (ISSUE 7) --

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n=st.integers(min_value=1, max_value=20))
def test_cluster_regions_ordering_is_deterministic(seed, n):
    """Region order is a pure function of the point set — (-size, first
    member) with sorted members — regardless of insertion order."""
    rng = random.Random(seed)
    axes = [tuple(range(10, 60, 10))] * 2
    cells = [(x, y) for x in axes[0] for y in axes[1]]
    scores = {p: (rng.random(), rng.random()) for p in rng.sample(cells, n)}
    regions = cluster_regions(scores, axes)
    items = list(scores.items())
    rng.shuffle(items)
    again = cluster_regions(dict(items), axes)   # permuted insertion order
    assert regions == again
    keys = [(-r.size, r.points[0]) for r in regions]
    assert keys == sorted(keys)
    for r in regions:
        assert list(r.points) == sorted(r.points)
        assert r.lo == tuple(min(p[d] for p in r.points) for d in (0, 1))
        assert r.hi == tuple(max(p[d] for p in r.points) for d in (0, 1))
    assert sum(r.size for r in regions) == n
    assert region_summary(regions, len(cells)) == \
        region_summary(again, len(cells))


def test_region_ordering_ties_single_point_and_full_grid():
    axes = [(1, 2, 3, 4), (1, 2, 3, 4)]
    # equal-size regions tie-break on the smallest member point
    tied = cluster_regions({(3, 3): (.2, .2), (1, 1): (.1, .1)}, axes)
    assert [r.points for r in tied] == [((1, 1),), ((3, 3),)]
    # single point: degenerate bbox, mean == max
    [r] = cluster_regions({(2, 3): (.5, .6)}, axes)
    assert r.size == 1 and r.lo == r.hi == (2, 3)
    assert r.mean_time_score == r.max_time_score == .5
    assert r.mean_flop_score == r.max_flop_score == .6
    # full grid: one region spanning the whole bbox
    full = {(x, y): (.1, .2) for x in axes[0] for y in axes[1]}
    [r] = cluster_regions(full, axes)
    assert r.size == 16 and r.lo == (1, 1) and r.hi == (4, 4)
    assert "16/16 (100.0%) in 1 region(s)" in region_summary([r], 16)
