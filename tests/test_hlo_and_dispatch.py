"""HLO parser edge cases + MoE dispatch equivalence property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.launch.hlo import Roofline, collective_stats, top_collectives
from repro.models import moe as moe_lib
from repro.models.moe import MoEConfig


def test_collective_stats_tuple_results():
    hlo = """
ENTRY e {
  %ar = (f32[4,4]{1,0}, bf16[8]{0}) all-reduce(%a, %b), to_apply=%s
}
"""
    st_ = collective_stats(hlo)
    assert st_.counts["all-reduce"] == 1
    assert st_.bytes_["all-reduce"] == 4 * 4 * 4 + 8 * 2


def test_collective_stats_async_pairs_counted_once():
    hlo = """
ENTRY e {
  %s = f32[16]{0} all-gather-start(%x), dimensions={0}
  %d = f32[16]{0} all-gather-done(%s)
}
"""
    st_ = collective_stats(hlo)
    assert st_.counts.get("all-gather", 0) == 1


def test_top_collectives_ranked():
    hlo = """
ENTRY e {
  %a = f32[1024]{0} all-reduce(%x), to_apply=%s
  %b = f32[8]{0} all-reduce(%y), to_apply=%s
}
"""
    top = top_collectives(hlo, 2)
    assert top[0][0] >= top[1][0]


def test_roofline_collective_bound():
    r = Roofline(flops_per_device=1e12, bytes_per_device=1e9,
                 collective_bytes=50e9 * 3, chips=4)
    assert r.bottleneck == "collective"
    assert r.t_collective == 3.0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10 ** 6), tokens=st.sampled_from([8, 16, 32]))
def test_moe_gather_equals_einsum_dispatch(seed, tokens):
    """Property: under ample capacity the two dispatch implementations are
    numerically identical for any routing realization."""
    cfg_e = MoEConfig(d_model=8, d_ff=16, n_experts=4, top_k=2,
                      capacity_factor=8.0, dispatch="einsum")
    cfg_g = cfg_e._replace(dispatch="gather", group_size=8)
    params, _ = moe_lib.init(jax.random.PRNGKey(1), cfg_e)
    x = jnp.asarray(np.random.default_rng(seed).standard_normal(
        (1, tokens, 8)).astype(np.float32))
    ye, auxe = moe_lib.apply(params, cfg_e, x)
    yg, auxg = moe_lib.apply(params, cfg_g, x)
    np.testing.assert_allclose(np.asarray(ye), np.asarray(yg),
                               rtol=1e-5, atol=1e-5)
    assert abs(float(auxe) - float(auxg)) < 1e-6


def test_moe_gather_grad_matches_einsum():
    cfg_e = MoEConfig(d_model=8, d_ff=16, n_experts=2, top_k=1,
                      capacity_factor=8.0, dispatch="einsum")
    cfg_g = cfg_e._replace(dispatch="gather", group_size=8)
    params, _ = moe_lib.init(jax.random.PRNGKey(0), cfg_e)
    x = jnp.asarray(np.random.default_rng(3).standard_normal(
        (1, 8, 8)).astype(np.float32))

    def loss(p, cfg):
        y, aux = moe_lib.apply(p, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    ge = jax.grad(lambda p: loss(p, cfg_e))(params)
    gg = jax.grad(lambda p: loss(p, cfg_g))(params)
    for a, b in zip(jax.tree.leaves(ge), jax.tree.leaves(gg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
