"""Discriminant registry + atlas-replay evaluation (ISSUE 5).

Covers: the registry protocol and capability flags, the selector shim's
argument validation, deduplicated measurement ranking, the new
``roofline``/``rankk`` policies, the evaluation scoreboard (top-1
accuracy / time regret / anomaly recall-precision), legacy-atlas
normalization, and the `anomaly.classify` edge cases the scoreboard's
metrics lean on.
"""

from __future__ import annotations

import warnings
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import classify
from repro.core.anomaly import pick_regret
from repro.core.discriminants import (
    _REGISTRY,
    Discriminant,
    DiscriminantContext,
    RankKDiscriminant,
    get_discriminant,
    register_discriminant,
    registered_discriminants,
    shared_runner,
    validate_arguments,
)
from repro.core.expressions import GRAM_AATB, find_spec
from repro.core.perfmodel import RooflineProfile, TableProfile
from repro.core.selector import rank_by_measurement, select
from repro.core.sweep import Instance

FIXTURES = Path(__file__).parent / "fixtures"

POINT = (16, 8, 12)


def _algos():
    return GRAM_AATB.algorithms(POINT)


class _CountingRunner:
    """Stub execution backend: records every isolated kernel benchmark."""

    def __init__(self):
        self.benched = []

    def benchmark_call(self, call, reps=None):
        self.benched.append((call.kind, call.dims))
        # Deterministic, flops-monotone fake seconds (plus a constant so
        # zero-FLOP tri2full still costs time).
        return 1e-9 * call.flops + 1e-6


# ------------------------------------------------------------- registry ----


def test_registry_ships_six_policies():
    names = registered_discriminants()
    assert {"flops", "perfmodel", "hybrid", "roofline", "measured",
            "rankk"} <= set(names)
    assert len(names) >= 6


def test_capability_flags():
    assert not get_discriminant("flops").requires_profile
    assert not get_discriminant("flops").requires_measurement
    assert get_discriminant("perfmodel").requires_profile
    assert not get_discriminant("perfmodel").requires_measurement
    assert not get_discriminant("roofline").requires_profile
    assert get_discriminant("measured").requires_measurement
    d = get_discriminant("rankk")
    assert d.requires_profile and d.requires_measurement


def test_get_unknown_discriminant_lists_registry():
    with pytest.raises(KeyError, match="registered"):
        get_discriminant("nope")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_discriminant(RankKDiscriminant(), name="flops")


def test_register_custom_discriminant_recipe():
    """The docs' recipe: a policy registered once is selectable by name."""

    class Antimodel(Discriminant):
        name = "antimodel"

        def predict_times(self, algos, ctx):
            return {a.name: -float(a.flops) for a in algos}

    register_discriminant(Antimodel())
    try:
        ranked = select(_algos(), "antimodel")
        assert ranked[0].flops == max(a.flops for a in _algos())
        assert ranked[-1].flops == min(a.flops for a in _algos())
        assert "antimodel" in registered_discriminants()
    finally:
        _REGISTRY.pop("antimodel")


def test_discriminants_tuple_deprecated():
    import repro.core.selector as selector

    with pytest.warns(DeprecationWarning, match="registered_discriminants"):
        legacy = selector.DISCRIMINANTS
    assert set(legacy) == set(registered_discriminants())


# ------------------------------------------- capability-flag validation ----


def test_select_rejects_profile_for_profile_free_policies():
    prof = TableProfile(1e11)
    for disc in ("flops", "measured", "roofline"):
        with pytest.raises(ValueError, match="requires_profile"):
            select(_algos(), disc, profile=prof)


def test_select_rejects_runner_for_measurement_free_policies():
    for disc in ("flops", "perfmodel", "hybrid", "roofline"):
        with pytest.raises(ValueError, match="requires_measurement"):
            select(_algos(), disc, backend="numpy")
    with pytest.raises(ValueError, match="requires_measurement"):
        select(_algos(), "perfmodel", runner=_CountingRunner())


def test_select_rejects_runner_and_backend_together():
    with pytest.raises(ValueError, match="not both"):
        select(_algos(), "measured", runner=_CountingRunner(),
               backend="numpy")
    with pytest.raises(ValueError, match="not both"):
        validate_arguments(get_discriminant("measured"),
                           runner=_CountingRunner(), backend="numpy")


def test_select_unknown_discriminant_is_value_error():
    with pytest.raises(ValueError, match="unknown discriminant"):
        select(_algos(), "nope")


# ------------------------------------------------ measurement + rankk ------


def test_rank_by_measurement_dedups_shared_calls():
    """Shared kernel prefixes are benchmarked once, not per algorithm."""
    runner = _CountingRunner()
    ranked = rank_by_measurement(_algos(), runner=runner)
    assert {a.name for a in ranked} == {a.name for a in _algos()}
    # every distinct (kind, dims) at most once...
    assert len(runner.benched) == len(set(runner.benched))
    # ...and strictly fewer benchmarks than the naive per-algorithm stream
    naive = sum(len(a.calls) for a in _algos())
    assert len(runner.benched) < naive


def test_shared_default_runner_is_cached():
    assert shared_runner("numpy") is shared_runner("numpy")


def test_rankk_times_only_top_k_flops_candidates():
    runner = _CountingRunner()
    k = 2
    d = RankKDiscriminant(k=k)
    ctx = DiscriminantContext(runner=runner)
    ranked = d.rank(_algos(), ctx)
    assert {a.name for a in ranked} == {a.name for a in _algos()}
    top = sorted(_algos(), key=lambda a: (a.flops, a.name))[:k]
    budget = {(c.kind, c.dims) for a in top for c in a.calls}
    assert set(runner.benched) == budget


def test_rankk_fingerprint_carries_budget():
    assert RankKDiscriminant(k=5).fingerprint() == "rankk(k=5)"
    with pytest.raises(ValueError, match="k >= 1"):
        RankKDiscriminant(k=0)


def test_roofline_is_distinct_from_perfmodel():
    """Pure-traffic roofline and the MXU-quantized model must be able to
    disagree — otherwise the registry entry adds nothing."""
    roof = select(_algos(), "roofline")
    prof = RooflineProfile()
    # roofline charges the zero-FLOP tri2full copy for its traffic
    from repro.core.flops import tri2full
    assert prof.time(tri2full(512), dtype_bytes=8) > 0
    assert [a.name for a in roof] == [a.name for a in select(
        _algos(), "roofline")]  # deterministic


# ----------------------------------------------------------- planner -------


def test_planner_rejects_unknown_discriminant_at_construction():
    from repro.core.planner import Planner

    with pytest.raises(ValueError, match="unknown discriminant"):
        Planner(discriminant="nope", backend="numpy")


def test_planner_accepts_any_registry_key_and_pins_profile_free_memo():
    from repro.core.expr import gram_times
    from repro.core.planner import Planner

    planner = Planner(discriminant="roofline", backend="numpy",
                      profile=TableProfile(1e11), record=True)
    c = gram_times(24, 16, 8)
    plan1 = planner.plan(c)
    planner.observe(plan1, seconds=0.1)  # bumps the table generation
    # roofline never reads the profile: the memo slot must survive
    assert planner.plan(c) is plan1


def test_planner_memo_keyed_by_policy_fingerprint():
    from repro.core.expr import gram_times
    from repro.core.planner import Planner

    p = Planner(discriminant="rankk", backend="numpy")
    key = p._key(gram_times(24, 16, 8), None)
    assert key[-1] == "rankk(k=3)"


# ------------------------------------------------- classify edge cases -----


def test_classify_all_tied_times_is_never_anomalous():
    times = {"a": 1.0, "b": 1.0, "c": 1.0}
    flops = {"a": 10, "b": 20, "c": 30}
    cls = classify(times, flops, threshold=0.0)
    assert cls.fastest == ("a", "b", "c")
    assert not cls.is_anomaly and cls.time_score == 0.0


def test_classify_rel_tol_boundary_membership():
    times = {"a": 1.0, "b": 1.0 + 5e-10, "c": 1.1}
    flops = {"a": 2, "b": 1, "c": 1}
    cls = classify(times, flops, rel_tol=1e-9)
    assert "b" in cls.fastest          # within the tie tolerance
    assert "c" not in cls.fastest
    # b is both cheapest and (tied-)fastest -> no anomaly
    assert not cls.is_anomaly


def test_classify_zero_time_denominator():
    times = {"a": 0.0, "b": 0.0}
    flops = {"a": 5, "b": 1}
    cls = classify(times, flops)
    assert cls.time_score == 0.0 and not cls.is_anomaly


def test_classify_zero_flop_denominator():
    times = {"a": 2.0, "b": 1.0}
    flops = {"a": 0, "b": 0}
    cls = classify(times, flops, threshold=0.0)
    assert cls.flop_score == 0.0
    # both are FLOP-cheapest, so the sets intersect: no anomaly
    assert not cls.is_anomaly


def test_classify_threshold_exactly_met_is_not_anomaly():
    # (1.0 - 0.875) / 1.0 == 0.125 exactly in binary floating point
    times = {"cheap": 1.0, "fast": 0.875}
    flops = {"cheap": 1, "fast": 2}
    at = classify(times, flops, threshold=0.125)
    assert at.time_score == 0.125 and not at.is_anomaly
    below = classify(times, flops, threshold=0.124)
    assert below.is_anomaly


def test_pick_regret():
    times = {"a": 2.0, "b": 1.0}
    assert pick_regret(times, "a") == 1.0
    assert pick_regret(times, "b") == 0.0
    assert pick_regret({"a": 0.0, "b": 0.0}, "a") == 0.0


# ------------------------------------------------------- evaluation --------


def _records(seed: int, points=((16, 8, 12), (24, 12, 8))):
    """Synthetic fully measured records with random (positive) times."""
    rng = np.random.default_rng(seed)
    out = []
    for p in points:
        algos = GRAM_AATB.algorithms(p)
        times = {a.name: float(t) for a, t in
                 zip(algos, rng.uniform(1e-4, 1e-2, len(algos)))}
        flops = {a.name: a.flops for a in algos}
        out.append(Instance(tuple(p), times, flops,
                            classify(times, flops, threshold=0.10)))
    return out


def test_evaluate_scores_every_requested_policy():
    from repro.core.evaluate import evaluate_discriminants

    records = _records(0)
    res = evaluate_discriminants(GRAM_AATB, records,
                                 ["flops", "perfmodel", "measured"],
                                 threshold=0.10)
    assert set(res.scores) == {"flops", "perfmodel", "measured"}
    assert res.n_instances == len(records)
    for s in res.scores.values():
        assert 0.0 <= s.top1_accuracy <= 1.0
        assert s.mean_regret >= 0.0 and s.p95_regret >= s.mean_regret * 0 \
            and all(r >= 0 for r in s.regrets)
    assert "top1=" in res.summary()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_measured_has_zero_regret_on_its_own_atlas(seed):
    """Round-trip property: replaying the recorded times through the
    `measured` policy reproduces the ground truth exactly — 100 % top-1,
    0 regret, a diagonal confusion matrix."""
    from repro.core.evaluate import evaluate_discriminants

    res = evaluate_discriminants(GRAM_AATB, _records(seed), ["measured"],
                                 threshold=0.10)
    s = res.scores["measured"]
    assert s.top1_accuracy == 1.0
    assert s.mean_regret == 0.0 and s.p95_regret == 0.0
    assert s.confusion.fp == 0 and s.confusion.fn == 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10 ** 6))
def test_flops_never_predicts_an_anomaly(seed):
    """FLOPs-as-time makes predicted fastest == cheapest by construction,
    so its predicted classification can never be anomalous (recall 0
    whenever ground truth has anomalies)."""
    from repro.core.evaluate import evaluate_discriminants

    res = evaluate_discriminants(GRAM_AATB, _records(seed), ["flops"],
                                 threshold=0.10)
    cm = res.scores["flops"].confusion
    assert cm.tp == 0 and cm.fp == 0
    if res.n_anomalies:
        assert res.scores["flops"].recall == 0.0


def test_evaluate_rejects_records_from_older_enumerations():
    from repro.core.evaluate import evaluate_discriminants

    rec = _records(1)[0]
    rec.times.pop(sorted(rec.times)[0])
    with pytest.raises(ValueError, match="lacks times"):
        evaluate_discriminants(GRAM_AATB, [rec], ["flops"])


def test_evaluate_rejects_records_with_unknown_algorithms():
    """A superset record (atlas swept with a *newer* enumeration) gets the
    curated diagnostic too, not classify's generic ValueError."""
    from repro.core.evaluate import evaluate_discriminants

    rec = _records(1)[0]
    rec.times["alg99[future]"] = 1e-3
    with pytest.raises(ValueError, match="unknown.*different enumeration"):
        evaluate_discriminants(GRAM_AATB, [rec], ["flops"])


def test_evaluate_isolates_per_policy_failures():
    """A partial calibration KeyErrors `perfmodel`; its row carries the
    error while the other policies still score (review fix — and the
    scoreboard shares one enumeration pass across all policies)."""
    from repro.core.evaluate import evaluate_discriminants
    from repro.core.flops import gemm

    prof = TableProfile(1e11)
    prof.record(gemm(8, 8, 8), 1e-6)   # gemm-only: no syrk/symm entries
    res = evaluate_discriminants(GRAM_AATB, _records(4),
                                 ["perfmodel", "hybrid", "flops"],
                                 profile=prof)
    assert res.scores["perfmodel"].error is not None
    assert "KeyError" in res.scores["perfmodel"].error
    assert "failed:" in res.scores["perfmodel"].row()
    assert res.scores["hybrid"].error is None
    assert res.scores["flops"].error is None
    assert 0.0 <= res.scores["hybrid"].top1_accuracy <= 1.0


def test_evaluate_scores_the_policy_rank_not_the_argsort():
    """Accuracy/regret must follow the policy's own rank() — the ordering
    the planner executes — even when it also exposes predict_times."""
    from repro.core.evaluate import evaluate_discriminants

    class Contrarian(Discriminant):
        name = "contrarian"

        def predict_times(self, algos, ctx):
            return {a.name: float(a.flops) for a in algos}

        def rank(self, algos, ctx):   # NOT the argsort of predict_times
            return sorted(algos, key=lambda a: (-a.flops, a.name))

    register_discriminant(Contrarian())
    try:
        records = _records(5)
        res = evaluate_discriminants(GRAM_AATB, records, ["contrarian"])
        s = res.scores["contrarian"]
        # regret of the max-FLOPs pick per record, not the flops-argsort's
        expected = []
        for inst in records:
            algos = GRAM_AATB.algorithms(inst.point)
            pick = sorted(algos, key=lambda a: (-a.flops, a.name))[0]
            expected.append(pick_regret(inst.times, pick.name))
        assert s.regrets == tuple(expected)
    finally:
        _REGISTRY.pop("contrarian")


def test_star_import_does_not_warn():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        exec("from repro.core import *", {})


def test_evaluate_dedupes_repeated_discriminant_names():
    """Shared per-name counters must not double-count: a repeated name
    once reported top-1 accuracy of 2.0."""
    from repro.core.evaluate import evaluate_discriminants

    res = evaluate_discriminants(GRAM_AATB, _records(3),
                                 ["measured", "measured"])
    assert list(res.scores) == ["measured"]
    s = res.scores["measured"]
    assert s.top1_accuracy == 1.0
    assert len(s.regrets) == res.n_instances


def test_experiment3_reproduces_through_evaluate_path():
    """The paper harness is a thin shim over the scoreboard: its confusion
    matrix equals evaluating `perfmodel` with the benched profile."""
    from repro.core.evaluate import evaluate_discriminants
    from repro.core.experiments import experiment3_predict_from_benchmarks
    from repro.core.sweep import benchmark_unique_calls, collect_unique_calls

    records = _records(2)
    classified = {r.point: r for r in records}
    runner = _CountingRunner()
    res = experiment3_predict_from_benchmarks(
        GRAM_AATB, runner, classified, threshold=0.05)
    profile, _, _ = benchmark_unique_calls(
        _CountingRunner(), collect_unique_calls(GRAM_AATB, classified))
    ref = evaluate_discriminants(GRAM_AATB, records, ["perfmodel"],
                                 profile=profile, threshold=0.05)
    cm_ref = ref.scores["perfmodel"].confusion
    assert (res.confusion.tp, res.confusion.fp, res.confusion.fn,
            res.confusion.tn) == (cm_ref.tp, cm_ref.fp, cm_ref.fn,
                                  cm_ref.tn)
    assert res.n_calls_measured == len(runner.benched)


# ------------------------------------------------ legacy atlas replay ------


def test_legacy_atlas_fixture_normalizes_and_evaluates():
    """Atlases written before the backend registry (no `backend` key in
    the fingerprint) load for replay instead of crashing; the torn tail
    is skipped and counted."""
    from repro.core.evaluate import evaluate_atlas, load_atlas_records

    replay = load_atlas_records(FIXTURES / "legacy_atlas_aatb.jsonl")
    assert replay.legacy
    assert replay.fingerprint.backend == "blas"
    assert replay.fingerprint.dtype == "float64"
    assert replay.spec_name == "AATB"
    assert len(replay.records) == 4
    assert replay.skipped_lines == 1    # the checked-in torn tail
    assert find_spec(replay.spec_name) is GRAM_AATB

    res = evaluate_atlas(replay, ["flops", "measured"])
    assert res.n_instances == 4
    assert res.scores["measured"].top1_accuracy == 1.0
    assert res.scores["measured"].mean_regret == 0.0


def test_strict_atlas_loader_still_rejects_legacy_headers(tmp_path):
    """The resumable (append) loader stays strict — normalization is a
    replay-only affordance; appending under a guessed fingerprint would
    mix machines."""
    import shutil

    from repro.core.profile_store import current_fingerprint
    from repro.core.sweep import AnomalyAtlas

    p = tmp_path / "legacy.jsonl"
    shutil.copy(FIXTURES / "legacy_atlas_aatb.jsonl", p)
    with pytest.raises(Exception):
        AnomalyAtlas(p, current_fingerprint(), "AATB", 0.10)


# ------------------------------------------------------------- CLI ---------


def _cli_measure(tmp_path, extra=()):
    from repro.core.sweep import main as sweep_main

    args = ["--expr", "aatb", "--grid", "8,16", "--backend", "numpy",
            "--reps", "1", "--atlas-dir", str(tmp_path), "--quiet",
            *extra]
    return sweep_main(args)


def test_cli_mode_evaluate_prints_scoreboard(tmp_path, capsys):
    from repro.core.sweep import main as sweep_main

    assert _cli_measure(tmp_path) == 0
    capsys.readouterr()
    args = ["--expr", "aatb", "--grid", "8,16", "--backend", "numpy",
            "--mode", "evaluate", "--atlas-dir", str(tmp_path),
            "--discriminants", "flops,perfmodel,hybrid", "--quiet"]
    assert sweep_main(args) == 0
    out = capsys.readouterr().out
    assert "evaluate AATB" in out
    for name in ("flops", "perfmodel", "hybrid"):
        assert name in out
    assert "top1=" in out and "mean_regret=" in out
    assert "measured" not in out      # only the requested policies print


def test_cli_mode_evaluate_survives_partial_calibration(tmp_path, capsys,
                                                        monkeypatch):
    """A gemm-only cached calibration makes `perfmodel` KeyError on AAᵀB's
    syrk/symm calls; the CLI must report that row as failed and still
    score the other policies (review fix)."""
    from repro.core.flops import gemm
    from repro.core.perfmodel import TableProfile
    from repro.core.profile_store import current_fingerprint, save_profile
    from repro.core.sweep import main as sweep_main

    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path / "profiles"))
    assert _cli_measure(tmp_path) == 0
    prof = TableProfile(1e11)
    prof.record(gemm(8, 8, 8), 1e-6)
    save_profile(prof, current_fingerprint(backend="numpy",
                                           dtype="float64"))
    capsys.readouterr()
    args = ["--expr", "aatb", "--grid", "8,16", "--backend", "numpy",
            "--mode", "evaluate", "--atlas-dir", str(tmp_path),
            "--discriminants", "perfmodel,hybrid,flops", "--quiet"]
    assert sweep_main(args) == 0
    out = capsys.readouterr().out
    assert "perfmodel  failed: KeyError" in out
    assert "hybrid" in out and "flops" in out and "top1=" in out
    assert "profile=cached" in out


def test_cli_mode_evaluate_rejects_unknown_discriminant(tmp_path, capsys):
    from repro.core.sweep import main as sweep_main

    assert _cli_measure(tmp_path) == 0
    args = ["--expr", "aatb", "--grid", "8,16", "--backend", "numpy",
            "--mode", "evaluate", "--atlas-dir", str(tmp_path),
            "--discriminants", "flops,nope"]
    assert sweep_main(args) == 2


def test_cli_mode_evaluate_requires_ground_truth(tmp_path, capsys):
    from repro.core.sweep import main as sweep_main

    args = ["--expr", "aatb", "--grid", "8,16", "--backend", "numpy",
            "--mode", "evaluate", "--atlas-dir", str(tmp_path)]
    assert sweep_main(args) == 2
    assert "sweep ground truth first" in capsys.readouterr().err


def test_cli_discriminants_flag_requires_evaluate_mode(tmp_path):
    from repro.core.sweep import main as sweep_main

    with pytest.raises(SystemExit):
        sweep_main(["--expr", "aatb", "--grid", "8,16",
                    "--atlas-dir", str(tmp_path),
                    "--discriminants", "flops"])


def test_cli_mode_evaluate_reads_legacy_atlas(tmp_path, capsys):
    """A legacy atlas dropped at any name in the atlas dir is picked up
    (single spec/threshold match) and scored end to end."""
    import shutil

    from repro.core.sweep import main as sweep_main

    shutil.copy(FIXTURES / "legacy_atlas_aatb.jsonl",
                tmp_path / "atlas-aatb-t0p1-legacy.jsonl")
    args = ["--expr", "aatb", "--grid", "8,16", "--backend", "blas",
            "--mode", "evaluate", "--atlas-dir", str(tmp_path),
            "--discriminants", "flops,measured", "--quiet"]
    assert sweep_main(args) == 0
    out = capsys.readouterr().out
    assert "legacy-fingerprint" in out and "top1=" in out


def test_deprecation_suppressed_in_normal_import():
    """Importing the package must not emit the DISCRIMINANTS warning —
    only *touching* the deprecated alias does."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        import repro.core  # noqa: F401
        import repro.core.selector  # noqa: F401
