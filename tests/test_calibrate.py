"""Calibration subsystem: persistence round-trips, fingerprint gating,
hybrid fallback, online refinement, and the tri2full nearest-neighbour
regression (ISSUE 1)."""

import json

import numpy as np
import pytest

from repro.core import (
    AnalyticalTPUProfile,
    FingerprintMismatchError,
    HardwareFingerprint,
    HybridProfile,
    Planner,
    ProfileStoreError,
    TableProfile,
    current_fingerprint,
    default_planner,
    gram_times,
    load_default_profile,
    load_profile,
    profile_path,
    reset_default_planner,
    save_profile,
    select,
    sweep_kernels,
)
from repro.core.algorithms import enumerate_algorithms
from repro.core.calibrate import (
    GRIDS,
    calibrate,
    grid_calls,
    main as calibrate_main,
)
from repro.core.flops import gemm, symm, syrk, tri2full
from repro.core.runners import BlasRunner


FP = HardwareFingerprint(backend="blas", device="testdev", dtype="float64")


def _sample_profile() -> TableProfile:
    return TableProfile(peak_flops=5e10, table={
        ("gemm", (128, 128, 128)): 1.1e-4,
        ("gemm", (256, 64, 128)): 9.0e-5,
        ("syrk", (128, 128)): 7.5e-5,
        ("symm", (128, 64)): 6.0e-5,
        ("tri2full", (64,)): 1.0e-5,
        ("tri2full", (1024,)): 1.3e-3,
    })


# ------------------------------------------------------------ persistence --

def test_roundtrip_identical_predictions(tmp_path):
    prof = _sample_profile()
    path = save_profile(prof, FP, directory=tmp_path)
    loaded, fp = load_profile(path, expected_fingerprint=FP)
    assert fp == FP
    assert loaded.table == prof.table
    assert loaded.peak() == prof.peak()
    # identical predictions on exact hits AND nearest-neighbour queries
    queries = [gemm(128, 128, 128), gemm(200, 100, 128), syrk(96, 128),
               symm(130, 70), tri2full(100)]
    for call in queries:
        assert loaded.time(call) == pytest.approx(prof.time(call), rel=0,
                                                  abs=0)


def test_fingerprint_mismatch_rejected(tmp_path):
    path = save_profile(_sample_profile(), FP, directory=tmp_path)
    other = HardwareFingerprint(backend="jax", device="TPU v5e",
                                dtype="bfloat16")
    with pytest.raises(FingerprintMismatchError):
        load_profile(path, expected_fingerprint=other)
    # without an expectation, the stored fingerprint is simply returned
    _, fp = load_profile(path)
    assert fp == FP


def test_schema_version_and_corruption_rejected(tmp_path):
    path = save_profile(_sample_profile(), FP, directory=tmp_path)
    doc = json.loads(path.read_text())
    doc["version"] = 999
    path.write_text(json.dumps(doc))
    with pytest.raises(ProfileStoreError):
        load_profile(path)
    path.write_text("{not json")
    with pytest.raises(ProfileStoreError):
        load_profile(path)


def test_load_default_profile_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_NO_PROFILE_CACHE", raising=False)
    assert load_default_profile() is None  # empty cache
    fp = current_fingerprint()
    save_profile(_sample_profile(), fp, directory=tmp_path)
    loaded = load_default_profile()
    assert loaded is not None
    assert loaded.table == _sample_profile().table
    # kill switch
    monkeypatch.setenv("REPRO_NO_PROFILE_CACHE", "1")
    assert load_default_profile() is None


def test_corrupt_default_cache_degrades_to_none(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path))
    fp = current_fingerprint()
    profile_path(fp).parent.mkdir(parents=True, exist_ok=True)
    profile_path(fp).write_text("garbage")
    assert load_default_profile() is None


# ----------------------------------------------------------------- hybrid --

def test_hybrid_uses_table_near_and_analytical_far():
    prof = _sample_profile()
    hy = HybridProfile(prof)
    near = gemm(130, 130, 130)        # within tolerance of (128,128,128)
    far = gemm(8192, 8192, 8192)      # far outside the calibrated grid
    assert hy.source(near) == "table"
    assert hy.source(far) == "analytical"
    assert hy.time(near) == pytest.approx(prof.time(near))
    assert hy.time(far) == pytest.approx(
        AnalyticalTPUProfile().time(far))
    # a kind with no entries at all falls back too
    empty = HybridProfile(TableProfile(1e11))
    assert empty.source(syrk(100, 100)) == "analytical"


def test_hybrid_discriminant_select():
    algos = enumerate_algorithms(gram_times(300, 200, 100))
    ranked = select(algos, discriminant="hybrid", profile=_sample_profile())
    assert len(ranked) == len(algos)
    # deterministic, complete ranking (no algorithm lost to KeyError)
    assert {a.name for a in ranked} == {a.name for a in algos}


def test_hybrid_empty_table_matches_analytical_ranking():
    algos = enumerate_algorithms(gram_times(300, 200, 100))
    analytical = select(algos, discriminant="perfmodel")
    hybrid = select(algos, discriminant="hybrid",
                    profile=HybridProfile(TableProfile(
                        AnalyticalTPUProfile().peak())))
    assert [a.name for a in analytical] == [a.name for a in hybrid]


# ----------------------------------------------- tri2full NN (regression) --

def test_tri2full_nearest_neighbour_picks_closest_dim():
    # Far entry first in insertion order: the old code scaled from the
    # first table hit, yielding a wildly wrong estimate for small dims.
    prof = TableProfile(1e11, table={
        ("tri2full", (1024,)): 1.3e-3,
        ("tri2full", (64,)): 1.0e-5,
    })
    t = prof.time(tri2full(100))
    assert t == pytest.approx(1.0e-5 * 100 ** 2 / 64 ** 2)
    t_big = prof.time(tri2full(900))
    assert t_big == pytest.approx(1.3e-3 * 900 ** 2 / 1024 ** 2)


# ------------------------------------------------------------ calibration --

def test_grid_calls_cover_all_kernels():
    calls = grid_calls(GRIDS["small"])
    kinds = {c.kind for c in calls}
    assert kinds == {"gemm", "syrk", "symm", "tri2full"}
    n = len(GRIDS["small"])
    assert len(calls) == n ** 3 + 2 * n ** 2 + n
    assert len(set(calls)) == len(calls)


def test_sweep_and_calibrate_blas(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path))
    res = calibrate(backend="blas", grid="small", reps=1)
    assert res.path is not None and res.path.is_file()
    assert res.n_calls == len(grid_calls(GRIDS["small"]))
    assert all(t >= 0 for t in res.profile.table.values())
    assert res.profile.peak() > 1.0
    # ...and default_planner() auto-loads it
    reset_default_planner()
    try:
        p = default_planner()
        assert isinstance(p.profile, HybridProfile)
        assert p.profile.table_profile.table == res.profile.table
    finally:
        reset_default_planner()


def test_calibrate_cli_writes_profile(tmp_path, capsys):
    rc = calibrate_main(["--grid", "small", "--reps", "1",
                         "--out", str(tmp_path), "--quiet"])
    assert rc == 0
    files = list(tmp_path.glob("profile-*.json"))
    assert len(files) == 1
    doc = json.loads(files[0].read_text())
    assert doc["version"] == 1
    assert doc["meta"]["grid"] == "small"
    assert len(doc["entries"]) == len(grid_calls(GRIDS["small"]))
    out = capsys.readouterr().out
    assert "profile written to" in out


def test_sweep_kernels_with_tiny_custom_runner():
    class FakeRunner(BlasRunner):
        def benchmark_call(self, call, reps=None):
            return 1e-6 * max(1, call.flops) ** 0.5

    prof = sweep_kernels(FakeRunner(reps=1), (64, 128))
    assert ("gemm", (64, 128, 64)) in prof.table
    assert prof.peak() > 1.0


# ------------------------------------------------------ online refinement --

def test_planner_online_refinement_records_and_blends():
    table = TableProfile(1e11)
    planner = Planner(discriminant="hybrid", profile=HybridProfile(table),
                      record=True, dtype_bytes=4)
    c = gram_times(96, 64, 32)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((96, 64)).astype(np.float32)
    b = rng.standard_normal((96, 32)).astype(np.float32)
    out = planner(c, a, a, b)
    assert out.shape == (96, 32)
    assert len(table.table) > 0
    first = dict(table.table)
    planner(c, a, a, b)
    # The second call re-ranks under the refined profile (the generation
    # counter invalidated the memoised plan), so it may execute — and
    # record — a different algorithm's calls; the first call's entries
    # survive and every entry stays positive (EMA blend).
    assert set(first) <= set(table.table)
    assert all(v > 0 for v in table.table.values())


def test_planner_bootstrap_from_empty_table():
    """Regression: record=True on an empty TableProfile must record its
    first entries (analytical weights), not die with KeyError."""
    table = TableProfile(1e11)
    planner = Planner(discriminant="flops", profile=table, record=True)
    c = gram_times(64, 32, 16)
    rng = np.random.default_rng(2)
    a = rng.standard_normal((64, 32)).astype(np.float32)
    b = rng.standard_normal((64, 16)).astype(np.float32)
    out = planner(c, a, a, b)
    assert out.shape == (64, 16)
    assert len(table.table) > 0


def test_planner_observe_noop_on_analytical_profile():
    planner = Planner(profile=AnalyticalTPUProfile(), record=True)
    c = gram_times(64, 32, 16)
    rng = np.random.default_rng(1)
    a = rng.standard_normal((64, 32)).astype(np.float32)
    b = rng.standard_normal((64, 16)).astype(np.float32)
    out = planner(c, a, a, b)  # must not raise despite no table
    assert out.shape == (64, 16)


def test_planner_save_roundtrip(tmp_path):
    table = TableProfile(2e10, table={("gemm", (64, 64, 64)): 3e-6})
    planner = Planner(discriminant="hybrid", profile=HybridProfile(table))
    path = planner.save(directory=tmp_path)
    assert path is not None
    loaded, _ = load_profile(path)
    assert loaded.table == table.table


def test_planner_save_key_matches_resolve_key(tmp_path, monkeypatch):
    """Regression: save() must persist under the same fingerprint that
    resolve_profile() loads from, or refinements are never reloaded."""
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path))
    monkeypatch.delenv("REPRO_NO_PROFILE_CACHE", raising=False)
    table = TableProfile(2e10, table={("gemm", (64, 64, 64)): 3e-6})
    Planner(profile=HybridProfile(table)).save()
    fresh = Planner()  # new process, same machine: must see the save
    assert isinstance(fresh.profile, HybridProfile)
    assert fresh.profile.table_profile.table == table.table


def test_refinement_flipping_ranking_yields_new_plan():
    """Regression (ISSUE 4 satellite): the plan memo used to ignore
    profile state, so a record=True planner froze its first ranking
    forever. The profile generation counter (bumped by every record,
    including observe()) must invalidate the cached plan — and when the
    refined table flips the ranking, the new plan must follow it."""
    from repro.core import KernelCall

    table = TableProfile(1e11)
    # Seed: every kernel cheap, SYRK cheapest -> the SYRK algorithm wins.
    for kind, dims, t in [("gemm", (96, 32, 64), 1e-4),
                          ("gemm", (96, 64, 96), 1e-4),
                          ("gemm", (96, 32, 96), 1e-4),
                          ("gemm", (64, 32, 96), 1e-4),
                          ("gemm", (96, 96, 64), 1e-4),
                          ("syrk", (96, 64), 1e-6),
                          ("symm", (96, 32), 1e-6),
                          ("tri2full", (96,), 1e-6)]:
        table.record(KernelCall(kind, dims), t)
    planner = Planner(discriminant="hybrid", profile=HybridProfile(table))
    c = gram_times(96, 64, 32)
    plan1 = planner.plan(c)
    assert "syrk" in {cl.kind for cl in plan1.algorithm.calls}
    # Unchanged profile: the memoised plan object is served back.
    assert planner.plan(c) is plan1
    # Online refinement discovers SYRK is actually catastrophic here.
    table.record(KernelCall("syrk", (96, 64)), 1.0)
    plan2 = planner.plan(c)
    assert plan2 is not plan1
    assert "syrk" not in {cl.kind for cl in plan2.algorithm.calls}


def test_observe_bumps_generation_and_replans():
    """observe() routes through table.record, so a recorded execution
    alone (no direct table access) must already invalidate the memo."""
    table = TableProfile(1e11)
    planner = Planner(discriminant="hybrid", profile=HybridProfile(table),
                      record=True)
    c = gram_times(64, 32, 16)
    plan1 = planner.plan(c)
    gen0 = table.generation
    planner.observe(plan1, seconds=0.25)
    assert table.generation > gen0
    assert planner.plan(c) is not plan1


def test_observe_mixed_sources_does_not_poison_table():
    """Regression: apportioning weights come from one consistent model,
    so a measured-ms entry can't starve an analytical-µs call's share."""
    table = TableProfile(1e11, table={("syrk", (96, 64)): 5e-3})
    hy = HybridProfile(table, max_log_dist=1e-9)  # symm call -> analytical
    planner = Planner(discriminant="hybrid", profile=hy, record=True)
    plan = planner.plan(gram_times(96, 64, 32))
    planner.observe(plan, seconds=1.0)
    # every call in the winning algorithm got a non-negligible share
    for call in plan.algorithm.calls:
        t = table.table[(call.kind, call.dims)]
        assert t > 1e-4, (call, t)
