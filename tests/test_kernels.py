"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def randf(*shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype) * scale)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------- gemm ---

@pytest.mark.parametrize("m,n,k", [
    (128, 128, 128), (256, 128, 384), (200, 150, 300), (64, 64, 64),
    (129, 257, 130), (1, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_matches_ref(m, n, k, dtype):
    a = randf(m, k).astype(dtype)
    b = randf(k, n).astype(dtype)
    out = ops.gemm(a, b)
    expect = ref.gemm(a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **tol(dtype))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 300), n=st.integers(1, 300), k=st.integers(1, 300))
def test_gemm_hypothesis_shapes(m, n, k):
    a = randf(m, k)
    b = randf(k, n)
    np.testing.assert_allclose(
        np.asarray(ops.gemm(a, b)), np.asarray(ref.gemm(a, b)),
        rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------- syrk ---

@pytest.mark.parametrize("m,k", [
    (128, 128), (256, 128), (384, 256), (130, 70), (257, 511),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_syrk_matches_ref(m, k, dtype):
    a = randf(m, k).astype(dtype)
    out = ops.syrk(a)
    expect = ref.syrk(a)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **tol(dtype))


def test_syrk_strictly_upper_is_zero():
    a = randf(256, 64)
    out = np.asarray(ops.syrk(a))
    assert np.all(np.triu(out, 1) == 0.0)


# ---------------------------------------------------------------- symm ---

@pytest.mark.parametrize("m,n", [
    (128, 128), (256, 64), (300, 120), (129, 33),
])
def test_symm_matches_ref(m, n):
    s_low = jnp.asarray(np.tril(RNG.standard_normal((m, m))).astype(
        np.float32))
    b = randf(m, n)
    np.testing.assert_allclose(
        np.asarray(ops.symm(s_low, b)), np.asarray(ref.symm(s_low, b)),
        rtol=1e-4, atol=1e-3)


def test_symm_ignores_strict_upper_garbage():
    m, n = 192, 64
    low = np.tril(RNG.standard_normal((m, m)))
    garbage = low + np.triu(RNG.standard_normal((m, m)) * 100, 1)
    b = randf(m, n)
    out = ops.symm(jnp.asarray(garbage.astype(np.float32)), b)
    expect = ref.symm(jnp.asarray(low.astype(np.float32)), b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------- chain gemm ---

@pytest.mark.parametrize("m,k,l,n", [
    (128, 128, 128, 128), (130, 70, 150, 60), (256, 512, 128, 384),
])
def test_chain_gemm_matches_ref(m, k, l, n):
    a, b, c = randf(m, k), randf(k, l), randf(l, n)
    np.testing.assert_allclose(
        np.asarray(ops.chain_gemm(a, b, c)),
        np.asarray(ref.chain_gemm(a, b, c)), rtol=1e-4, atol=1e-2)


def test_chain_gemm_falls_back_above_vmem_bound():
    # Big enough that the fused kernel would exceed the VMEM bound.
    a, b, c = randf(64, 4096), randf(4096, 4096), randf(4096, 64)
    np.testing.assert_allclose(
        np.asarray(ops.chain_gemm(a, b, c)),
        np.asarray(ref.chain_gemm(a, b, c)), rtol=1e-3, atol=5e-2)


# ----------------------------------------------------------- gemm+syrk ---

@pytest.mark.parametrize("m,k,l", [
    (128, 128, 128), (130, 70, 64), (256, 384, 128), (65, 200, 130),
])
def test_gemm_syrk_matches_ref(m, k, l):
    a, b = randf(m, k), randf(k, l)
    m1 = np.asarray(a) @ np.asarray(b)
    expect = np.tril(m1 @ m1.T)
    np.testing.assert_allclose(
        np.asarray(ops.gemm_syrk(a, b)), expect, rtol=1e-4, atol=1e-2)


def test_gemm_syrk_strictly_upper_is_zero():
    a, b = randf(200, 64), randf(64, 96)
    out = np.asarray(ops.gemm_syrk(a, b))
    assert np.all(np.triu(out, 1) == 0.0)


def test_gemm_syrk_falls_back_above_vmem_bound():
    a, b = randf(64, 4096), randf(4096, 4096)
    # f64 oracle + relative tolerance: entries here are ~1e5, and the f32
    # accumulation-order difference between the fused and fallback paths
    # is itself ~rtol-sized at this contraction depth.
    m1 = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
    np.testing.assert_allclose(
        np.asarray(ops.gemm_syrk(a, b), np.float64), np.tril(m1 @ m1.T),
        rtol=1e-2, atol=1e-1)


# --------------------------------------- pad/unpad path, every kernel ---
# Non-multiple-of-128 dims exercise the _pad_to → kernel → slice path in
# ops.py against the numpy oracle (interpret mode on this CPU container).

@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 300), k=st.integers(1, 300))
def test_syrk_hypothesis_pad_unpad(m, k):
    a = randf(m, k)
    expect = np.tril(np.asarray(a) @ np.asarray(a).T)
    np.testing.assert_allclose(np.asarray(ops.syrk(a)), expect,
                               rtol=1e-4, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 300), n=st.integers(1, 300))
def test_symm_hypothesis_pad_unpad(m, n):
    low = np.tril(RNG.standard_normal((m, m))).astype(np.float32)
    b = randf(m, n)
    full = low + np.tril(low, -1).T
    np.testing.assert_allclose(
        np.asarray(ops.symm(jnp.asarray(low), b)),
        full @ np.asarray(b), rtol=1e-4, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 200), k=st.integers(1, 200),
       l=st.integers(1, 200), n=st.integers(1, 200))
def test_chain_gemm_hypothesis_pad_unpad(m, k, l, n):
    a, b, c = randf(m, k), randf(k, l), randf(l, n)
    expect = (np.asarray(a) @ np.asarray(b)) @ np.asarray(c)
    np.testing.assert_allclose(np.asarray(ops.chain_gemm(a, b, c)),
                               expect, rtol=1e-4, atol=1e-2)


@settings(max_examples=8, deadline=None)
@given(m=st.integers(1, 200), k=st.integers(1, 200), l=st.integers(1, 200))
def test_gemm_syrk_hypothesis_pad_unpad(m, k, l):
    a, b = randf(m, k), randf(k, l)
    m1 = np.asarray(a) @ np.asarray(b)
    np.testing.assert_allclose(np.asarray(ops.gemm_syrk(a, b)),
                               np.tril(m1 @ m1.T), rtol=1e-4, atol=1e-2)


# ------------------------------------------- shape validation (no -O) ---

def test_kernels_raise_valueerror_naming_dim_and_block():
    from repro.kernels.chain_gemm import chain_gemm_pallas, gemm_syrk_pallas
    from repro.kernels.gemm import gemm_pallas
    from repro.kernels.symm import symm_pallas
    from repro.kernels.syrk import syrk_pallas
    z = jnp.zeros
    with pytest.raises(ValueError, match=r"m=130.*bm=128"):
        gemm_pallas(z((130, 128)), z((128, 128)), interpret=True)
    with pytest.raises(ValueError, match="contraction dim k"):
        gemm_pallas(z((128, 64)), z((128, 128)), interpret=True)
    with pytest.raises(ValueError, match=r"k=100.*bk=128"):
        syrk_pallas(z((128, 100)), interpret=True)
    with pytest.raises(ValueError, match=r"n=100.*bn=128"):
        symm_pallas(z((128, 128)), z((128, 100)), interpret=True)
    with pytest.raises(ValueError, match=r"l=100.*bl=128"):
        chain_gemm_pallas(z((128, 128)), z((128, 100)), z((100, 128)),
                          interpret=True)
    with pytest.raises(ValueError, match=r"m=130.*bm=128"):
        gemm_syrk_pallas(z((130, 128)), z((128, 128)), interpret=True)


def test_chain_gemm_vmem_bytes_requires_dtype_bytes():
    from repro.kernels.chain_gemm import chain_gemm_vmem_bytes
    with pytest.raises(TypeError):
        chain_gemm_vmem_bytes(128, 128, 128, 128)  # no dtype_bytes
    f32 = chain_gemm_vmem_bytes(128, 256, 256, 128, dtype_bytes=4)
    bf16 = chain_gemm_vmem_bytes(128, 256, 256, 128, dtype_bytes=2)
    assert f32 > bf16  # the old hard-coded 2 halved the f32 footprint


# ------------------------------------------- fused dispatch (walker) ---

def _pallas_backend(reps=1):
    from repro.core.backends.jax_backend import PallasBackend
    return PallasBackend(reps=reps, tuning=None)


@pytest.mark.parametrize("kind,dims", [
    ("chain_gemm", (130, 70, 64, 150)),
    ("chain_gemm", (128, 128, 128, 128)),
    ("gemm_syrk", (130, 70, 64)),
    ("gemm_syrk", (256, 128, 128)),
])
def test_fused_vs_unfused_parity(kind, dims, monkeypatch):
    from repro.core.backends.base import synthetic_fused_algorithm
    backend = _pallas_backend()
    alg = synthetic_fused_algorithm(kind, dims)
    operands = backend.make_operands(alg)
    monkeypatch.delenv("REPRO_NO_FUSION", raising=False)
    assert backend.ops().fused_kinds()  # fusion on: fused launch
    fused = np.asarray(backend.execute(alg, operands))
    monkeypatch.setenv("REPRO_NO_FUSION", "1")
    assert not backend.ops().fused_kinds()  # fusion off: two kernels
    unfused = np.asarray(backend.execute(alg, operands))
    np.testing.assert_allclose(fused, unfused, rtol=1e-4, atol=1e-2)


def test_fusable_pattern_detection():
    from repro.core.backends.base import (
        fusable_pattern,
        synthetic_fused_algorithm,
    )
    chain = synthetic_fused_algorithm("chain_gemm", (128, 128, 128, 128))
    assert fusable_pattern(chain.steps[0], chain.steps[1], ()) == "gemm+gemm"
    gs = synthetic_fused_algorithm("gemm_syrk", (128, 128, 128))
    assert fusable_pattern(gs.steps[0], gs.steps[1], ()) == "gemm+syrk"
    # A later step consuming the intermediate vetoes the fusion.
    assert fusable_pattern(chain.steps[0], chain.steps[1],
                           (chain.steps[1],)) is None
    # C·(A·B) — the intermediate on the rhs — is not the chain_gemm
    # shape and must not match.
    import dataclasses
    s2 = chain.steps[1]
    swapped = dataclasses.replace(s2, lhs=s2.rhs, rhs=s2.lhs)
    assert fusable_pattern(chain.steps[0], swapped, ()) is None


def test_enumerated_gram_algorithms_fuse_and_stay_correct():
    # The real DAGs (not synthetic ones): every enumerated algorithm of a
    # gram family must produce identical results with fusion on and off.
    from repro.core import enumerate_algorithms, gram_times
    backend = _pallas_backend()
    A = randf(130, 100)
    B = randf(130, 64)
    for alg in enumerate_algorithms(gram_times(130, 100, 64)):
        fn = backend.build(alg)
        out = np.asarray(fn(A, A, B))
        expect = (np.asarray(A) @ np.asarray(A).T) @ np.asarray(B)
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-2)


# ------------------------------------------------------ flash attention ---

@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, logit_softcap=30.0),
    dict(causal=True, window=128),
    dict(causal=True, window=64, logit_softcap=20.0),
])
def test_flash_attention_variants(kwargs):
    B, H, Hkv, S, D = 2, 4, 2, 256, 64
    q = randf(B, H, S, D, scale=0.3)
    k = randf(B, Hkv, S, D, scale=0.3)
    v = randf(B, Hkv, S, D)
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v, **kwargs)),
        np.asarray(ref.flash_attention(q, k, v, **kwargs)),
        rtol=1e-4, atol=1e-4)


def test_flash_attention_mha_no_gqa():
    B, H, S, D = 1, 2, 384, 32
    q = randf(B, H, S, D, scale=0.3)
    k = randf(B, H, S, D, scale=0.3)
    v = randf(B, H, S, D)
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v)),
        np.asarray(ref.flash_attention(q, k, v)), rtol=1e-4, atol=1e-4)


def test_flash_attention_odd_seq_falls_back():
    B, H, S, D = 1, 2, 100, 32   # not block divisible → reference path
    q = randf(B, H, S, D, scale=0.3)
    k = randf(B, H, S, D, scale=0.3)
    v = randf(B, H, S, D)
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v)),
        np.asarray(ref.flash_attention(q, k, v)), rtol=1e-4, atol=1e-4)


# ------------------------------------------- chunked attention (train) ---

def test_chunked_attention_value_and_grad_match_dense():
    from repro.models import attention
    from repro.models.attention import AttnConfig
    B, H, Hkv, S, D = 1, 4, 2, 1024, 32
    q = randf(B, S, H, D, scale=0.3)
    k = randf(B, S, Hkv, D, scale=0.3)
    v = randf(B, S, Hkv, D)
    for kwargs in (dict(), dict(window=256), dict(logit_softcap=40.0)):
        acfg = AttnConfig(d_model=H * D, n_heads=H, n_kv_heads=Hkv,
                          head_dim=D, **kwargs)

        def f_c(q, k, v):
            return jnp.sum(attention.chunked_attention(acfg, q, k, v) ** 2)

        def f_d(q, k, v):
            return jnp.sum(attention._dense_attention(acfg, q, k, v) ** 2)

        v1, g1 = jax.value_and_grad(f_c, argnums=(0, 1, 2))(q, k, v)
        v2, g2 = jax.value_and_grad(f_d, argnums=(0, 1, 2))(q, k, v)
        assert abs(v1 - v2) / abs(v2) < 1e-5
        # dq accumulates in f32 (strict); dk/dv partials are emitted bf16
        # per block (the collective-halving §Perf trade) → loose tolerance.
        np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]),
                                   rtol=1e-3, atol=1e-4)
        for a, b in zip(g1[1:], g2[1:]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-2, atol=3e-2)


# -------------------------------------------------------- planner+pallas --

def test_jax_runner_pallas_path_matches_jnp_path():
    from repro.core import enumerate_algorithms, gram_times
    from repro.core.runners import JaxRunner
    algos = enumerate_algorithms(gram_times(128, 192, 64))
    A = randf(128, 192)
    B = randf(128, 64)
    for a in algos:
        fn_ref = JaxRunner(use_pallas=False).build(a)
        fn_pl = JaxRunner(use_pallas=True).build(a)
        np.testing.assert_allclose(
            np.asarray(fn_pl(A, A, B)), np.asarray(fn_ref(A, A, B)),
            rtol=1e-4, atol=1e-2)
