"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def randf(*shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype) * scale)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------- gemm ---

@pytest.mark.parametrize("m,n,k", [
    (128, 128, 128), (256, 128, 384), (200, 150, 300), (64, 64, 64),
    (129, 257, 130), (1, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_matches_ref(m, n, k, dtype):
    a = randf(m, k).astype(dtype)
    b = randf(k, n).astype(dtype)
    out = ops.gemm(a, b)
    expect = ref.gemm(a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **tol(dtype))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 300), n=st.integers(1, 300), k=st.integers(1, 300))
def test_gemm_hypothesis_shapes(m, n, k):
    a = randf(m, k)
    b = randf(k, n)
    np.testing.assert_allclose(
        np.asarray(ops.gemm(a, b)), np.asarray(ref.gemm(a, b)),
        rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------- syrk ---

@pytest.mark.parametrize("m,k", [
    (128, 128), (256, 128), (384, 256), (130, 70), (257, 511),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_syrk_matches_ref(m, k, dtype):
    a = randf(m, k).astype(dtype)
    out = ops.syrk(a)
    expect = ref.syrk(a)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **tol(dtype))


def test_syrk_strictly_upper_is_zero():
    a = randf(256, 64)
    out = np.asarray(ops.syrk(a))
    assert np.all(np.triu(out, 1) == 0.0)


# ---------------------------------------------------------------- symm ---

@pytest.mark.parametrize("m,n", [
    (128, 128), (256, 64), (300, 120), (129, 33),
])
def test_symm_matches_ref(m, n):
    s_low = jnp.asarray(np.tril(RNG.standard_normal((m, m))).astype(
        np.float32))
    b = randf(m, n)
    np.testing.assert_allclose(
        np.asarray(ops.symm(s_low, b)), np.asarray(ref.symm(s_low, b)),
        rtol=1e-4, atol=1e-3)


def test_symm_ignores_strict_upper_garbage():
    m, n = 192, 64
    low = np.tril(RNG.standard_normal((m, m)))
    garbage = low + np.triu(RNG.standard_normal((m, m)) * 100, 1)
    b = randf(m, n)
    out = ops.symm(jnp.asarray(garbage.astype(np.float32)), b)
    expect = ref.symm(jnp.asarray(low.astype(np.float32)), b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------- chain gemm ---

@pytest.mark.parametrize("m,k,l,n", [
    (128, 128, 128, 128), (130, 70, 150, 60), (256, 512, 128, 384),
])
def test_chain_gemm_matches_ref(m, k, l, n):
    a, b, c = randf(m, k), randf(k, l), randf(l, n)
    np.testing.assert_allclose(
        np.asarray(ops.chain_gemm(a, b, c)),
        np.asarray(ref.chain_gemm(a, b, c)), rtol=1e-4, atol=1e-2)


def test_chain_gemm_falls_back_above_vmem_bound():
    # Big enough that the fused kernel would exceed the VMEM bound.
    a, b, c = randf(64, 4096), randf(4096, 4096), randf(4096, 64)
    np.testing.assert_allclose(
        np.asarray(ops.chain_gemm(a, b, c)),
        np.asarray(ref.chain_gemm(a, b, c)), rtol=1e-3, atol=5e-2)


# ------------------------------------------------------ flash attention ---

@pytest.mark.parametrize("kwargs", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, logit_softcap=30.0),
    dict(causal=True, window=128),
    dict(causal=True, window=64, logit_softcap=20.0),
])
def test_flash_attention_variants(kwargs):
    B, H, Hkv, S, D = 2, 4, 2, 256, 64
    q = randf(B, H, S, D, scale=0.3)
    k = randf(B, Hkv, S, D, scale=0.3)
    v = randf(B, Hkv, S, D)
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v, **kwargs)),
        np.asarray(ref.flash_attention(q, k, v, **kwargs)),
        rtol=1e-4, atol=1e-4)


def test_flash_attention_mha_no_gqa():
    B, H, S, D = 1, 2, 384, 32
    q = randf(B, H, S, D, scale=0.3)
    k = randf(B, H, S, D, scale=0.3)
    v = randf(B, H, S, D)
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v)),
        np.asarray(ref.flash_attention(q, k, v)), rtol=1e-4, atol=1e-4)


def test_flash_attention_odd_seq_falls_back():
    B, H, S, D = 1, 2, 100, 32   # not block divisible → reference path
    q = randf(B, H, S, D, scale=0.3)
    k = randf(B, H, S, D, scale=0.3)
    v = randf(B, H, S, D)
    np.testing.assert_allclose(
        np.asarray(ops.flash_attention(q, k, v)),
        np.asarray(ref.flash_attention(q, k, v)), rtol=1e-4, atol=1e-4)


# ------------------------------------------- chunked attention (train) ---

def test_chunked_attention_value_and_grad_match_dense():
    from repro.models import attention
    from repro.models.attention import AttnConfig
    B, H, Hkv, S, D = 1, 4, 2, 1024, 32
    q = randf(B, S, H, D, scale=0.3)
    k = randf(B, S, Hkv, D, scale=0.3)
    v = randf(B, S, Hkv, D)
    for kwargs in (dict(), dict(window=256), dict(logit_softcap=40.0)):
        acfg = AttnConfig(d_model=H * D, n_heads=H, n_kv_heads=Hkv,
                          head_dim=D, **kwargs)

        def f_c(q, k, v):
            return jnp.sum(attention.chunked_attention(acfg, q, k, v) ** 2)

        def f_d(q, k, v):
            return jnp.sum(attention._dense_attention(acfg, q, k, v) ** 2)

        v1, g1 = jax.value_and_grad(f_c, argnums=(0, 1, 2))(q, k, v)
        v2, g2 = jax.value_and_grad(f_d, argnums=(0, 1, 2))(q, k, v)
        assert abs(v1 - v2) / abs(v2) < 1e-5
        # dq accumulates in f32 (strict); dk/dv partials are emitted bf16
        # per block (the collective-halving §Perf trade) → loose tolerance.
        np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]),
                                   rtol=1e-3, atol=1e-4)
        for a, b in zip(g1[1:], g2[1:]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-2, atol=3e-2)


# -------------------------------------------------------- planner+pallas --

def test_jax_runner_pallas_path_matches_jnp_path():
    from repro.core import enumerate_algorithms, gram_times
    from repro.core.runners import JaxRunner
    algos = enumerate_algorithms(gram_times(128, 192, 64))
    A = randf(128, 192)
    B = randf(128, 64)
    for a in algos:
        fn_ref = JaxRunner(use_pallas=False).build(a)
        fn_pl = JaxRunner(use_pallas=True).build(a)
        np.testing.assert_allclose(
            np.asarray(fn_pl(A, A, B)), np.asarray(fn_ref(A, A, B)),
            rtol=1e-4, atol=1e-2)
