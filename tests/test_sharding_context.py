"""Sharding rules + activation-sharding context + vocab padding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sharding import context as ctx
from repro.sharding.rules import pick_param_policy, rules_for


def test_helpers_are_identity_outside_context():
    x3 = jnp.ones((2, 8, 4))
    x4 = jnp.ones((2, 8, 4, 4))
    assert ctx.shard_seq(x3) is x3
    assert ctx.shard_logits(x3) is x3
    assert ctx.shard_heads(x4) is x4
    assert ctx.shard_moe_groups(x3) is x3


def test_context_applies_and_restores():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices")
    mesh = jax.make_mesh((jax.device_count() // 2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    x = jnp.ones((4, 8, 4))
    with ctx.activation_sharding(mesh):
        y = jax.jit(ctx.shard_seq)(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert ctx.shard_seq(x) is x  # restored


def test_heads_toggle():
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices")
    mesh = jax.make_mesh((jax.device_count() // 2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    x = jnp.ones((2, 4, 4, 2))
    with ctx.activation_sharding(mesh, heads=False):
        assert ctx.shard_heads(x) is x
    with ctx.activation_sharding(mesh, heads=True):
        y = ctx.shard_heads(x)
        assert y is not x


def test_param_policy_picker():
    mesh16 = type("M", (), {"shape": {"model": 16}})()
    # 9B fp32 params+grads on a 16-way TP shard: 4.7 GB → zero1
    assert pick_param_policy(9_400_000_000, mesh16) == "zero1"
    # 76B: 38 GB → fsdp
    assert pick_param_policy(76_000_000_000, mesh16) == "fsdp"
    assert rules_for("zero1")["embed"] is None
    assert rules_for("fsdp")["embed"] == ("pod", "data")


def test_padded_vocab_rules():
    from repro.configs import get
    assert get("mamba2_370m").padded_vocab == 50304     # 50280 → pad
    assert get("whisper_tiny").padded_vocab == 51968    # 51865 → pad
    assert get("glm4_9b").padded_vocab == 151552        # divisible → keep
    for arch in ("mamba2_370m", "whisper_tiny"):
        assert get(arch).padded_vocab % 16 == 0


def test_padded_vocab_logits_masked():
    """Pad columns must never win the argmax / carry softmax mass."""
    import dataclasses
    from repro.configs import get_smoke
    from repro.models import api
    cfg = dataclasses.replace(get_smoke("yi_9b"), vocab=250)  # 250 % 16 != 0
    assert cfg.padded_vocab == 256
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (1, 8), dtype=np.int32))
    logits, _ = api.forward_train(params, cfg, {"tokens": tokens,
                                                "labels": tokens})
    assert logits.shape[-1] == 256
    pad = np.asarray(logits[..., cfg.vocab:])
    assert np.all(pad <= -1e29)
    assert int(jnp.argmax(logits[0, -1])) < cfg.vocab
