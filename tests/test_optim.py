"""Optimizers: AdamW/Muon convergence, NS orthogonalization, planner
selection, gradient compression error-feedback."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, grad_compress, muon, schedule


def quad_problem(dim=16, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((dim, dim)).astype(np.float32)
    A = A @ A.T / dim + np.eye(dim, dtype=np.float32)
    target = rng.standard_normal((dim, dim)).astype(np.float32)

    def loss(p):
        W = p["w"]
        r = (W - jnp.asarray(target))
        return jnp.trace(r.T @ jnp.asarray(A) @ r)

    return loss, {"w": jnp.zeros((dim, dim), jnp.float32)}


def test_adamw_converges_on_quadratic():
    loss, params = quad_problem()
    state = adamw.init(params)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw.update(g, state, params,
                                     lr=jnp.asarray(0.05),
                                     weight_decay=0.0)
    assert float(loss(params)) < 0.01 * l0


def test_muon_converges_on_quadratic():
    loss, params = quad_problem(seed=1)
    state = muon.init(params)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = muon.update(g, state, params, lr=jnp.asarray(0.05))
    assert float(loss(params)) < 0.05 * l0


def test_newton_schulz_orthogonalizes():
    rng = np.random.default_rng(0)
    for mode in ("gram", "gram_gemm", "right"):
        x = jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32))
        y = muon.newton_schulz(x, mode=mode)
        gram = np.asarray(y @ y.T)
        # quintic NS in bf16: singular values within ~0.3 of 1
        sv = np.linalg.svd(np.asarray(y), compute_uv=False)
        assert np.all(sv < 1.6)
        assert np.all(sv > 0.4)


def test_newton_schulz_modes_agree():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((48, 96)).astype(np.float32))
    outs = [np.asarray(muon.newton_schulz(x, mode=m))
            for m in ("gram_gemm", "right")]
    np.testing.assert_allclose(outs[0], outs[1], rtol=0.15, atol=0.15)


def test_plan_ns_mode_prefers_gram_for_wide():
    """The paper's selection: Gram-first is FLOP-cheaper when m << k."""
    mode_wide = muon.plan_ns_mode(128, 8192, discriminant="flops")
    assert mode_wide in ("gram", "gram_gemm")  # 3·m²k-ish < k²m-ish


def test_ns_algorithm_calls_flops_ordering():
    # For m << k, the Gram association must be FLOP-cheaper than 'right'.
    gram = sum(c.flops for c in muon.ns_algorithm_calls("gram", 128, 8192))
    right = sum(c.flops for c in muon.ns_algorithm_calls("right", 128, 8192))
    assert gram < right


def test_schedules_shapes():
    for name, fn in schedule.SCHEDULES.items():
        lr0 = float(fn(jnp.asarray(0), 1e-3, 10, 100))
        lr_peak = float(fn(jnp.asarray(10), 1e-3, 10, 100))
        assert lr0 <= lr_peak <= 1e-3 + 1e-9


# ------------------------------------------------------- compression -----

def test_grad_compress_roundtrip_small_error():
    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.standard_normal((300,)).astype(
        np.float32)), "b": jnp.asarray(rng.standard_normal(
            (17, 31)).astype(np.float32))}
    st = grad_compress.init_state(grads)
    comp, st = grad_compress.compress(grads, st)
    deq = grad_compress.decompress(comp)
    for k in grads:
        err = np.abs(np.asarray(deq[k]) - np.asarray(grads[k])).max()
        scale = np.abs(np.asarray(grads[k])).max()
        assert err < scale / 64  # int8 blockwise quantization error bound


def test_grad_compress_error_feedback_unbiased_over_steps():
    """With error feedback, the *sum* of dequantized grads tracks the sum
    of true grads (bias cancels) — the convergence-critical property."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros((64,), np.float32)
    deq_sum = np.zeros((64,), np.float32)
    g0 = {"w": None}
    st = None
    for t in range(50):
        g = rng.standard_normal(64).astype(np.float32) * 0.1
        true_sum += g
        grads = {"w": jnp.asarray(g)}
        if st is None:
            st = grad_compress.init_state(grads)
        comp, st = grad_compress.compress(grads, st)
        deq_sum += np.asarray(grad_compress.decompress(comp)["w"])
    # residual is bounded → sums converge
    assert np.abs(deq_sum - true_sum).max() < 0.02


def test_muon_treats_vectors_with_adamw():
    params = {"w": jnp.zeros((16, 16)), "b": jnp.zeros((16,))}
    state = muon.init(params)
    flat = jax.tree.leaves(state.momentum)
    # vector param has no muon momentum slot
    assert state.momentum["b"] is None
    assert state.momentum["w"] is not None
