"""Docs tree: the three pages exist and every intra-repo markdown link
resolves (same check the CI ``docs`` job runs via tools/check_links.py)."""

import importlib.util
import pathlib

REPO = pathlib.Path(__file__).resolve().parent.parent


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_links", REPO / "tools" / "check_links.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_pages_exist():
    for page in ("analysis.md", "architecture.md", "calibration.md",
                 "discriminants.md", "serving.md", "sweeping.md"):
        path = REPO / "docs" / page
        assert path.is_file(), page
        assert path.read_text().strip().startswith("#"), page


def test_readme_links_into_docs():
    text = (REPO / "README.md").read_text()
    for page in ("docs/analysis.md", "docs/architecture.md",
                 "docs/calibration.md", "docs/discriminants.md",
                 "docs/serving.md", "docs/sweeping.md"):
        assert page in text, page
    assert "repro.core.sweep" in text  # quickstart runs the sweep engine
    assert "tools/loadtest.py" in text  # serving quickstart
    assert "--mode adaptive" in text  # adaptive quickstart


def test_sweeping_guide_covers_the_contracts():
    """docs/sweeping.md documents what the adaptive engine enforces."""
    text = (REPO / "docs" / "sweeping.md").read_text()
    for needle in (
        "--mode adaptive",          # the CLI entry point
        "--budget",                 # the budget contract
        "--seed-stride",            # tuning knob + its caveat...
        "missed entirely",          # ...regions narrower than the stride
        "--shard",                  # multi-host fan-out
        "awaiting-siblings",        # exit-3 rerun protocol
        "tools/atlas_merge.py",     # shard reconciliation
        "first writer",             # merge dedup rule
        "torn final line",          # crash tolerance
        "synthetic.py",             # planted ground truth
    ):
        assert needle in text, needle


def test_serving_guide_covers_the_contracts():
    """docs/serving.md documents what the code actually enforces."""
    text = (REPO / "docs" / "serving.md").read_text()
    for needle in (
        "profile generation",       # cache-key + invalidation rule
        "coalescing",               # miss semantics
        "drop",                     # queue backpressure: drop-oldest
        "REPRO_SERVE_PLANNER",      # kill-switch
        "plan_cache",               # the module the guide narrates
        "tools/loadtest.py",        # quickstart command
    ):
        assert needle in text, needle


def test_analysis_guide_covers_the_contracts():
    """docs/analysis.md documents what the verifier actually enforces.

    (Rule-catalog completeness — every registered rule id and mutant
    name appears — is pinned in tests/test_analysis.py, next to the
    registries it reads.)
    """
    text = (REPO / "docs" / "analysis.md").read_text()
    for needle in (
        "repro.core.analysis",      # the CLI entry point
        "--mutants",                # the mutation gate
        "8/8 caught",               # what CI greps for
        "REPRO_VERIFY_ENUMERATION", # the enumeration hook env var
        "verify_plans",             # the serving publish guard
        "register_kernel_shape",    # extending to a new kernel kind
        "register_rule",            # extending with a custom rule
        "AnalysisError",            # the raising contract
    ):
        assert needle in text, needle


def test_planner_doctests_execute():
    """The Planner class example in core/planner.py runs as shown."""
    import doctest

    import repro.core.planner as planner_mod
    results = doctest.testmod(planner_mod)
    assert results.attempted >= 5
    assert results.failed == 0


def test_all_intra_repo_markdown_links_resolve(capsys):
    checker = _load_checker()
    rc = checker.main(["check_links", str(REPO)])
    err = capsys.readouterr().err
    assert rc == 0, f"broken links:\n{err}"


def test_checker_catches_broken_links(tmp_path):
    (tmp_path / "a.md").write_text("see [missing](nope/gone.md) "
                                   "and [ok](b.md)")
    (tmp_path / "b.md").write_text("# b\n[external](https://x.test/y) "
                                   "[anchor](#top) [badge](../../escape.md)")
    checker = _load_checker()
    assert checker.main(["check_links", str(tmp_path)]) == 1
    (tmp_path / "nope").mkdir()
    (tmp_path / "nope" / "gone.md").write_text("# found")
    assert checker.main(["check_links", str(tmp_path)]) == 0
    # leading-slash links resolve against the repo root (GitHub-style)
    (tmp_path / "nope" / "deep.md").write_text("[abs](/b.md)")
    assert checker.main(["check_links", str(tmp_path)]) == 0
    (tmp_path / "nope" / "deep.md").write_text("[abs](/missing.md)")
    assert checker.main(["check_links", str(tmp_path)]) == 1
