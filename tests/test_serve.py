"""Serving plan-cache tests: concurrency, invalidation, drain, epilogs.

Pins the contracts docs/serving.md documents: lock-free reads return
consistent plans under thread-pool stress, concurrent same-shape misses
run exactly one enumeration (coalescing), a profile generation bump
invalidates and *flips* a stale plan, the refinement queue drops-oldest
without blocking, and shutdown drains the worker deterministically.
"""

from __future__ import annotations

import importlib.util
import pathlib
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.discriminants import (
    registered_discriminants,
)
from repro.core.backends import registered_backends
from repro.core.expressions import get_spec, registered_names
from repro.core.perfmodel import TableProfile
from repro.core.planner import Planner
from repro.runtime.supervisor import BackgroundWorker
from repro.serve.plan_cache import (
    PlanCache,
    PlanService,
    RefinementQueue,
    planner_enabled,
    reset_default_plan_service,
)

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _fresh_default_service():
    reset_default_plan_service()
    yield
    reset_default_plan_service()


def _table_planner(entries=None) -> Planner:
    table = TableProfile(peak_flops=1e12)
    for call, seconds in (entries or []):
        table.record(call, seconds)
    return Planner(discriminant="perfmodel", backend="numpy", profile=table)


def _seed_decmlp(table_planner: Planner, dims, fast_idx: int):
    """Record exact call times making algorithm ``fast_idx`` cheapest."""
    algs = get_spec("decmlp").algorithms(dims)
    table = table_planner.profile
    for i, alg in enumerate(algs):
        for call in alg.calls:
            table.record(call, 1e-6 if i == fast_idx else 1e-3)
    return algs


# ------------------------------------------------------------ concurrency --


def test_stress_no_torn_reads_and_single_enumeration():
    svc = PlanService(discriminant="flops", backend="numpy")
    calls = []
    lock = threading.Lock()
    inner = svc.planner.plan

    def slow_plan(chain, env=None):
        with lock:
            calls.append(chain)
        time.sleep(0.02)            # widen the race window
        return inner(chain, env)

    svc.planner.plan = slow_plan
    threads, per_thread = 16, 20
    shapes = [("decmlp", (1, 64, 256)), ("decproj", (1, 64, 128)),
              ("decattn", (1, 128, 32, 64))]
    start = threading.Barrier(threads)
    results = [[] for _ in range(threads)]
    errors = []

    def worker(tid):
        try:
            start.wait()
            for i in range(per_thread):
                fam, dims = shapes[(tid + i) % len(shapes)]
                results[tid].append((fam, svc.lookup(fam, dims)))
        except BaseException as e:   # noqa: BLE001
            errors.append(e)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors
    # exactly one enumeration per distinct shape, however many threads
    assert len(calls) == len(shapes)
    # no torn reads: every thread saw the same Plan object per family
    by_family = {}
    for chunk in results:
        for fam, plan in chunk:
            assert plan is by_family.setdefault(fam, plan)
    stats = svc.cache.stats()
    assert stats["misses"] == len(shapes)
    assert stats["hits"] + stats["coalesced"] == \
        threads * per_thread - len(shapes)


def test_coalesced_waiters_share_one_plan():
    svc = PlanService(discriminant="flops", backend="numpy")
    inner = svc.planner.plan
    svc.planner.plan = lambda c, env=None: (time.sleep(0.05),
                                            inner(c, env))[1]
    n = 12
    start = threading.Barrier(n)
    seen = []
    lock = threading.Lock()

    def worker():
        start.wait()
        p = svc.lookup("decmlp", (2, 96, 384))
        with lock:
            seen.append(p)

    ts = [threading.Thread(target=worker) for _ in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert len({id(p) for p in seen}) == 1
    stats = svc.cache.stats()
    assert stats["misses"] == 1
    assert stats["coalesced"] == n - 1


def test_first_lookup_double_check_hit_does_not_deadlock():
    # Regression: a thread's FIRST lookup whose lock-free probe misses
    # but whose double-check under the lock hits (another thread
    # published in between) used to call _slot() while holding the
    # non-reentrant lock — self-deadlock. Simulate that interleaving
    # deterministically with a dict whose first probe misses.
    cache = PlanCache()
    plan = object()
    key = ("k", 0)

    class RacingDict(dict):
        def __init__(self):
            super().__init__()
            self.probes = 0

        def get(self, k, default=None):
            self.probes += 1
            if self.probes == 1:
                return None          # lock-free probe: miss
            return super().get(k, default)

    racing = RacingDict()
    racing[key] = plan
    cache._plans = racing
    result = []
    t = threading.Thread(target=lambda: result.append(
        cache.get(key, lambda: pytest.fail("must not compute"))),
        daemon=True)                 # a regression must not wedge pytest
    t.start()
    t.join(timeout=5.0)
    assert not t.is_alive(), "first-lookup double-check hit deadlocked"
    assert result == [plan]
    assert cache.stats()["hits"] == 1


def test_miss_error_propagates_and_shape_retries():
    cache = PlanCache()
    boom = [True]

    def compute():
        if boom[0]:
            raise RuntimeError("enumeration failed")
        return "plan"

    with pytest.raises(RuntimeError):
        cache.get(("k", 0), compute)
    boom[0] = False
    assert cache.get(("k", 0), compute) == "plan"
    assert cache.stats()["errors"] == 1


# ------------------------------------------------------------ invalidation --


def test_generation_bump_flips_stale_plan():
    planner = _table_planner()
    dims = (4, 64, 256)
    algs = _seed_decmlp(planner, dims, fast_idx=0)
    svc = PlanService(planner=planner)
    first = svc.lookup("decmlp", dims)
    assert first.algorithm.name == algs[0].name
    gen0 = planner.profile_generation()

    # refinement moves the table: the other association is now cheaper
    _seed_decmlp(planner, dims, fast_idx=1)
    assert planner.profile_generation() > gen0
    second = svc.lookup("decmlp", dims)
    assert second.algorithm.name == algs[1].name
    # the stale same-shape entry was purged, not leaked
    assert svc.cache.stats()["size"] == 1


def test_cache_key_components():
    svc = PlanService(discriminant="flops", backend="numpy", dtype="bf16")
    key = svc.key("decproj", (1, 8, 8))
    assert key[0] == "decproj" and key[1] == (1, 8, 8)
    assert key[2] == "bf16" and key[3] == "numpy"
    assert key[4] == svc.planner.policy_fingerprint()
    assert key[5] == svc.planner.profile_generation()


# ------------------------------------------------------- refinement queue --


def test_refinement_queue_drops_oldest_without_blocking():
    q = RefinementQueue(maxlen=4)
    for i in range(10):
        q.put(i)
    assert q.enqueued == 10
    assert q.dropped == 6
    assert len(q) == 4
    assert [q.pop() for _ in range(4)] == [6, 7, 8, 9]  # oldest went first
    assert q.pop() is None


def test_execute_refines_asynchronously_and_shutdown_drains():
    planner = _table_planner()
    dims = (4, 64, 256)
    _seed_decmlp(planner, dims, fast_idx=0)
    svc = PlanService(planner=planner, refine=True, queue_maxlen=256)
    table = planner.profile
    gen0 = table.generation
    x = np.ones((4, 64), np.float32)
    wu = np.ones((64, 256), np.float32)
    wd = np.ones((256, 64), np.float32)
    n = 32
    for _ in range(n):
        svc.execute("decmlp", dims, x, wu, wd)
    assert svc.queue.enqueued == n
    assert svc.shutdown(drain=True)            # deterministic drain
    assert len(svc.queue) == 0
    assert svc.worker.steps >= n               # every timing processed
    assert table.generation > gen0             # observations landed
    # post-shutdown executions still run, but no longer enqueue
    svc.execute("decmlp", dims, x, wu, wd)
    assert svc.queue.enqueued == n


def test_shutdown_folds_straggler_timing_enqueued_during_race():
    # A producer already past the _accepting check can enqueue after the
    # worker observes an empty queue and exits; shutdown re-drains
    # inline so that timing is folded, not silently lost.
    planner = _table_planner()
    dims = (4, 64, 256)
    _seed_decmlp(planner, dims, fast_idx=0)
    svc = PlanService(planner=planner, refine=True)
    plan = svc.lookup("decmlp", dims)
    assert svc.worker.stop(drain=True)          # worker exits, queue empty
    gen0 = planner.profile.generation
    svc.queue.put((plan, 1e-4))                 # the racing straggler
    assert svc.shutdown(drain=True)
    assert len(svc.queue) == 0
    assert planner.profile.generation > gen0    # straggler was folded


def test_background_worker_drain_is_deterministic():
    import collections
    items = collections.deque(range(100))
    done = []

    def step():
        if not items:
            return False
        done.append(items.popleft())
        return True

    w = BackgroundWorker(step, idle_wait_s=0.01).start()
    assert w.stop(drain=True)
    assert done == list(range(100))
    assert not w.running


def test_background_worker_poisoned_step_does_not_wedge_drain():
    import collections
    items = collections.deque(range(10))
    caught = []

    def step():
        if not items:
            return False
        v = items.popleft()
        if v % 3 == 0:
            raise ValueError(v)
        return True

    w = BackgroundWorker(step, on_error=caught.append,
                         idle_wait_s=0.01).start()
    assert w.stop(drain=True)
    assert not items
    assert w.errors == len(caught) == 4        # 0, 3, 6, 9


# ------------------------------------------------------------- model path --


def test_pv_wo_output_orders_agree():
    import jax.numpy as jnp
    from repro.models import attention

    rng = np.random.default_rng(0)
    b, h, s, dh, d = 2, 4, 16, 8, 32
    p_attn = jnp.asarray(rng.standard_normal((b, h, 1, s)), jnp.float32)
    vq = jnp.asarray(rng.standard_normal((b, s, h, dh)), jnp.float32)
    wo = {"w": jnp.asarray(rng.standard_normal((h * dh, d)), jnp.float32)}
    orig = attention.planned_pv_right_first
    try:
        attention.planned_pv_right_first = lambda *a: False
        left = attention.pv_wo_output(p_attn, vq, wo, h, dh, jnp.float32)
        attention.planned_pv_right_first = lambda *a: True
        right = attention.pv_wo_output(p_attn, vq, wo, h, dh, jnp.float32)
    finally:
        attention.planned_pv_right_first = orig
    assert left.shape == right.shape == (b, 1, d)
    np.testing.assert_allclose(np.asarray(left), np.asarray(right),
                               rtol=2e-4, atol=2e-4)


def test_planner_consult_picks_left_at_decode(monkeypatch):
    from repro.models import attention
    monkeypatch.setenv("REPRO_SERVE_DISCRIMINANT", "flops")
    # q=1 decode: left association is strictly cheaper under any cost
    # model, so the consult must return False (keep the classic order).
    assert attention.planned_pv_right_first(1, 512, 64, 256) is False


def test_planner_kill_switch(monkeypatch):
    from repro.models import attention
    from repro.serve import decode as sdecode
    from repro.models.transformer import ModelConfig
    monkeypatch.setenv("REPRO_SERVE_PLANNER", "0")
    assert planner_enabled() is False
    assert attention.planned_pv_right_first(1, 512, 64, 256) is False
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      vocab=128, n_heads=2, n_kv_heads=2, head_dim=32,
                      d_ff=128)
    assert sdecode.plan_warmup(cfg, 64) == []


def test_plan_warmup_populates_default_service(monkeypatch):
    from repro.serve import decode as sdecode
    from repro.serve.plan_cache import default_plan_service
    from repro.models.transformer import ModelConfig
    monkeypatch.setenv("REPRO_SERVE_DISCRIMINANT", "flops")
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      vocab=128, n_heads=2, n_kv_heads=2, head_dim=32,
                      d_ff=128)
    shapes = sdecode.plan_warmup(cfg, max_s=64)
    assert ("decattn", (1, 64, 32, 64)) in shapes
    assert ("decmlp", (1, 64, 128)) in shapes
    stats = default_plan_service().cache.stats()
    assert stats["size"] == len(set(shapes))
    # a decode-shape lookup is now a pure hit
    default_plan_service().lookup("decattn", (1, 64, 32, 64))
    assert default_plan_service().cache.stats()["hits"] >= 1


# ---------------------------------------------------------------- loadtest --


def _load_tool(name: str):
    spec = importlib.util.spec_from_file_location(
        name, REPO / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    spec.loader.exec_module(mod)
    return mod


def test_loadtest_harness_reports_sane_numbers():
    lt = _load_tool("loadtest")

    def make_service():
        return PlanService(discriminant="flops", backend="numpy")

    rep = lt.run_loadtest(make_service(), requests=400, threads=4,
                          make_service=make_service)
    assert rep.requests == 400
    assert rep.hit_rate > 0.99          # storm runs entirely on warm shapes
    assert rep.hit_p50_us > 0
    assert rep.hit_p99_us >= rep.hit_p50_us
    assert rep.miss_p50_us > 0
    assert rep.burst_misses == 1        # coalescing: one enumeration
    assert rep.coalesce_effectiveness == 1.0
    assert rep.stats["errors"] == 0


def test_loadtest_cli_gate(capsys):
    lt = _load_tool("loadtest")
    assert lt.main(["--requests", "100", "--threads", "2",
                    "--discriminant", "flops",
                    "--gate-p99-us", "1000000"]) == 0
    assert lt.main(["--requests", "100", "--threads", "2",
                    "--discriminant", "flops",
                    "--gate-p99-us", "0.000001"]) == 1


# ------------------------------------------------------------ CLI epilogs --


def test_sweep_epilog_lists_all_registries():
    from repro.core.sweep import _registry_epilog
    text = _registry_epilog()
    for name in registered_names():
        assert name in text
    for name in registered_discriminants():
        assert name in text
    for name in registered_backends():
        assert name in text


def test_calibrate_help_lists_registries(capsys):
    from repro.core import calibrate
    with pytest.raises(SystemExit) as exc:
        calibrate.main(["--help"])
    assert exc.value.code == 0
    out = capsys.readouterr().out
    for name in registered_discriminants():
        assert name in out
    for name in registered_backends():
        assert name in out
