"""Expression zoo: registry, enumeration counts, intermediate-Gram SYRK,
canonical dedup, ndims-validated grids, and the numerical correctness gate
(ISSUE 3)."""

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.algorithms import (
    Leaf,
    Step,
    canonical_key,
    enumerate_algorithms,
)
from repro.core.expr import gram_of_product, gram_times
from repro.core.expressions import (
    REGISTRY,
    SWEEP_GRIDS,
    ExpressionSpec,
    GridSpec,
    get_spec,
    register,
)
from repro.core.flops import gemm, syrk
from repro.core.runners import BlasRunner, reference_execute
from repro.core.sweep import sweep

#: Pinned algorithm counts per registered family — the regression gate for
#: the enumeration layer. The first two are the paper's published sets
#: (§3.2.1, §3.2.2); the rest were verified by hand (see each builder's
#: docstring) and against the numerical gate below.
EXPECTED_COUNTS = {
    "abcd": 6,
    "aatb": 5,
    "abcde": 24,
    "abtb": 5,
    "btsb": 4,
    "atab": 5,
    "abab": 13,
}


def _dims_for(spec, lo=8, hi=40, seed=0):
    rng = np.random.default_rng(seed)
    return tuple(int(rng.integers(lo, hi)) for _ in range(spec.ndims))


# ---------------------------------------------------------------- registry --

def test_registry_contains_the_zoo():
    assert set(EXPECTED_COUNTS) <= set(REGISTRY)
    assert len(REGISTRY) >= 6  # 2 paper families + >= 4 zoo families


def test_get_spec_case_insensitive_and_helpful_error():
    assert get_spec("AATB") is REGISTRY["aatb"]
    with pytest.raises(KeyError, match="registered"):
        get_spec("nope")


def test_register_rejects_duplicates():
    spec = REGISTRY["aatb"]
    with pytest.raises(ValueError, match="already registered"):
        register(spec, cli="aatb")


def test_every_spec_has_description_and_builder():
    for name, spec in REGISTRY.items():
        assert spec.description, name
        c = spec.chain(_dims_for(spec))
        assert len(c.ops) >= 2


# ------------------------------------------------------------ enumeration --

@pytest.mark.parametrize("name", sorted(EXPECTED_COUNTS))
def test_algorithm_counts_pinned(name):
    spec = REGISTRY[name]
    algos = spec.algorithms(_dims_for(spec))
    assert len(algos) == EXPECTED_COUNTS[name], \
        [a.name for a in algos]


def test_intermediate_gram_pair_enumerates_syrk():
    """(AB)(AB)ᵀ must yield GEMM+SYRK(+TRI2FULL) with the transpose twin
    pruned — leaf-adjacency Gram detection never generated this one."""
    d0, d1, d2 = 24, 16, 32
    algos = enumerate_algorithms(gram_of_product(d0, d1, d2))
    smart = [a for a in algos
             if tuple(c.kind for c in a.calls) == ("gemm", "syrk", "tri2full")]
    assert len(smart) == 1
    (a,) = smart
    g, s, t = a.steps
    # SYRK consumes the GEMM intermediate (an int ref), not a leaf, and
    # the never-materialized (BᵀAᵀ) twin left no step behind.
    assert s.lhs == g.out and s.rhs is None
    assert a.flops == 2 * d0 * d1 * d2 + (d0 + 1) * d0 * d2


def test_symm_side_r_attributed():
    """A·Bᵀ·B routes the symmetric intermediate in from the right."""
    algos = REGISTRY["abtb"].algorithms((24, 16, 32))
    sides = {s.symm_side for a in algos for s in a.steps
             if s.call.kind == "symm"}
    assert sides == {"R"}
    # ...and Aᵀ·A·B from the left.
    algos = REGISTRY["atab"].algorithms((40, 16, 24))
    sides = {s.symm_side for a in algos for s in a.steps
             if s.call.kind == "symm"}
    assert sides == {"L"}


def test_canonical_key_invariant_under_step_id_renumbering():
    """The old dedup keyed on raw (lhs, rhs) step ids, which a global
    counter makes search-path dependent: identical sequences reached via
    different interleavings carried different ids and both survived. The
    canonical key must erase the numbering."""
    la = Leaf(index=0, base=0, transposed=False, rows=8, cols=4)
    lb = Leaf(index=1, base=1, transposed=False, rows=4, cols=6)

    def seq(base):
        s1 = Step(call=gemm(8, 6, 4), lhs=la, rhs=lb, out=base,
                  out_rows=8, out_cols=6, out_storage="full",
                  out_symmetric=False)
        s2 = Step(call=syrk(8, 6), lhs=base, rhs=None, out=base + 7,
                  out_rows=8, out_cols=8, out_storage="tri",
                  out_symmetric=True)
        return (s1, s2)

    a, b = seq(100), seq(2000)
    assert canonical_key(a) == canonical_key(b)
    # the naive key the old dedup used distinguishes them:
    assert [(s.lhs, s.rhs) for s in a] != [(s.lhs, s.rhs) for s in b]


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_no_duplicate_algorithms_survive_dedup(name):
    spec = REGISTRY[name]
    algos = spec.algorithms(_dims_for(spec, seed=3))
    keys = [canonical_key(a.steps) for a in algos]
    assert len(keys) == len(set(keys))


def test_paper_counts_stable_across_distinct_dims():
    """Counts must not depend on the concrete sizes (no accidental
    dim-coincidence dedup)."""
    assert len(enumerate_algorithms(gram_times(64, 64, 64))) == 5
    assert len(enumerate_algorithms(gram_of_product(32, 32, 32))) == 13


# ----------------------------------------------------------------- grids ---

def test_named_grids_are_ndims_parametric():
    for name, spec in REGISTRY.items():
        g = spec.grid("smoke")
        assert g.ndims == spec.ndims
        for p in g.points():
            assert len(p) == spec.ndims


def test_per_spec_grid_overrides():
    abcde = REGISTRY["abcde"]
    assert abcde.grid("small").axes == ((32, 64, 96),) * 6
    # families without an override fall back to the shared table
    assert REGISTRY["aatb"].grid("small").axes == \
        (SWEEP_GRIDS["small"],) * 3
    with pytest.raises(ValueError, match="unknown grid"):
        abcde.grid("nope")


def test_mis_shaped_points_raise_not_mis_sweep():
    """A wrong-ndims grid must fail loudly: matrix_chain(*dims) happily
    builds a *different* expression from 4 dims, so silence here would
    corrupt the atlas with mislabeled instances."""
    spec = REGISTRY["aatb"]
    bad = GridSpec.uniform((32, 64), spec.ndims + 1)
    with pytest.raises(ValueError, match="takes 3|3 —|ndims"):
        sweep(spec, bad.points(), runner=_NullRunner())
    with pytest.raises(ValueError, match="takes 3"):
        spec.chain((32, 64, 96, 128))
    with pytest.raises(ValueError, match="takes 5"):
        REGISTRY["abcd"].algorithms((32, 64, 96))


class _NullRunner:
    def make_operands(self, alg):
        return {}

    def time_algorithm(self, alg, operands=None):
        return 1.0


# ----------------------------------------------- numerical correctness gate --

@settings(max_examples=8, deadline=None)
@given(name=st.sampled_from(sorted(REGISTRY)), seed=st.integers(0, 10 ** 6))
def test_every_algorithm_of_every_family_is_numerically_identical(name, seed):
    """The zoo's correctness gate, now backend-wide (ISSUE 4): at random
    dims, every enumerated algorithm of every registered expression, on
    **every registered execution backend**, equals the direct operand
    product. float64 backends (blas/numpy) are held to float64
    tolerances; float32 backends (jax, and pallas in interpret mode on
    this CPU container) to float32 tolerances scaled by the result
    magnitude."""
    from repro.core.backends import make_backend, registered_backends

    spec = REGISTRY[name]
    rng = np.random.default_rng(seed)
    point = tuple(int(rng.integers(4, 48)) for _ in range(spec.ndims))
    algos = spec.algorithms(point)
    runner = BlasRunner(reps=1, flush_cache=False,
                        rng=np.random.default_rng(seed + 1))
    operands = {}
    for a in algos:
        for k, v in runner.make_operands(a).items():
            operands.setdefault(k, v)
    expected = spec.reference_value(point, operands)
    scale = max(1.0, float(np.abs(expected).max()))
    for a in algos:
        np.testing.assert_allclose(
            reference_execute(a, operands), expected, rtol=1e-9, atol=1e-8,
            err_msg=f"{name} {a.name} (numpy reference)")
    for backend_name in registered_backends():
        be = make_backend(backend_name, reps=1, flush_cache=False,
                          rng=np.random.default_rng(seed + 1))
        ops = {k: be._asarray(np.asarray(v)) for k, v in operands.items()}
        f64 = be.dtype == "float64"
        rtol, atol = (1e-9, 1e-8) if f64 else (5e-4, 5e-4 * scale)
        for a in algos:
            np.testing.assert_allclose(
                np.asarray(be.execute(a, ops)), expected,
                rtol=rtol, atol=atol,
                err_msg=f"{name} {a.name} ({backend_name})")


def test_two_gram_pairs_mirror_each_consumed_triangle():
    """A chain with TWO Gram pairs (A·Aᵀ·B·Bᵀ) produces pairs where the
    tri-stored SYRK output sits on the *right* of a symmetric lhs — the
    pre-fix enumeration consumed it raw (upper-triangle zeros) in SYMM/
    GEMM products. Every algorithm must now be numerically exact, with
    tri2full pre-steps on each consumed triangle."""
    from repro.core.expr import Chain, Matrix

    A = Matrix("A", 12, 20)
    B = Matrix("B", 12, 16)
    algos = enumerate_algorithms(Chain((A, A.T(), B, B.T())))
    runner = BlasRunner(reps=1, flush_cache=False,
                        rng=np.random.default_rng(7))
    operands = {}
    for a in algos:
        for k, v in runner.make_operands(a).items():
            operands.setdefault(k, v)
    expected = operands[0] @ operands[0].T @ operands[2] @ operands[2].T
    for a in algos:
        np.testing.assert_allclose(reference_execute(a, operands), expected,
                                   rtol=1e-9, atol=1e-8, err_msg=a.name)
        np.testing.assert_allclose(runner.execute(a, operands), expected,
                                   rtol=1e-9, atol=1e-8, err_msg=a.name)


def test_symmetric_leaves_are_synthesized_symmetric():
    spec = REGISTRY["btsb"]
    algos = spec.algorithms((24, 16))
    runner = BlasRunner(reps=1, flush_cache=False)
    operands = {}
    for a in algos:
        for k, v in runner.make_operands(a).items():
            operands.setdefault(k, v)
    # base 1 is S (chain is Bᵀ·S·B: B at base 0, S at base 1)
    s = operands[1]
    np.testing.assert_allclose(s, s.T)


# --------------------------------------------------------- spec extension ---

def test_registering_a_new_family_flows_through(monkeypatch):
    """A spec registered at runtime enumerates, grids, and sweeps with no
    further wiring (the docs/architecture.md recipe)."""
    from repro.core import expressions as ex

    monkeypatch.setattr(ex, "REGISTRY", dict(ex.REGISTRY))
    spec = register(ExpressionSpec(
        name="AB", ndims=3, build=_build_plain_ab,
        description="2-operand chain"), cli="ab_test")
    assert get_spec("ab_test") is spec
    algos = spec.algorithms((8, 6, 4))
    assert len(algos) == 1 and algos[0].calls[0].kind == "gemm"
    res = sweep(spec, spec.grid("smoke").points(), runner=_NullRunner())
    assert res.n_measured == spec.grid("smoke").n_points


def _build_plain_ab(dims):
    from repro.core.expr import matrix_chain
    return matrix_chain(*dims)


def test_dataclass_replace_keeps_symm_side():
    s = Step(call=gemm(4, 4, 4), lhs=0, rhs=1, out=2, out_rows=4,
             out_cols=4, out_storage="full", out_symmetric=False,
             symm_side="R")
    assert dataclasses.replace(s, rhs=None).symm_side == "R"
