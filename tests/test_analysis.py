"""Static plan verifier: zoo-clean properties, mutation catches, wiring.

Four layers of assurance, mirroring how the analysis package is wired
into the repo:

1. **Soundness on valid input** — every algorithm of every registered
   family, at randomized valid dims (hypothesis, or the deterministic
   shim), verifies with ZERO findings. This is the acceptance bar the
   ``analysis-smoke`` CI job enforces over the named grids.
2. **Completeness on known-bad input** — each of the 8 mutation classes
   is caught with its *expected* rule id, and the harness's outcomes
   agree with running the mutators by hand.
3. **Wiring** — the ``enumerate_algorithms`` debug hook, the
   ``PlanService`` publish guard (an invalid plan must never enter the
   cache), the ``ExpressionSpec.verify`` convenience, and the lazy
   ``repro.core`` exports.
4. **Pins** — the CLI epilogs and docs rule catalog list every
   registered rule, so registry additions surface everywhere at once.
"""

import dataclasses
import pathlib
import subprocess
import sys

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.algorithms import enumerate_algorithms
from repro.core.analysis import (
    MUTANT_CLASSES,
    AnalysisError,
    Finding,
    RULES,
    errors_only,
    format_findings,
    mutant_names,
    register_rule,
    registered_rules,
    run_mutation_suite,
    verify_algorithm,
    verify_algorithms,
    verify_family,
    verify_zoo,
)
from repro.core.analysis.flopcheck import recount_call
from repro.core.expressions import get_spec, registered_names
from repro.core.flops import KernelCall, gemm, symm, syrk, tri2full

REPO = pathlib.Path(__file__).resolve().parent.parent


# ------------------------------------------------ soundness on valid input --


@settings(max_examples=20, deadline=None)
@given(
    family=st.sampled_from(sorted(registered_names())),
    d0=st.integers(min_value=2, max_value=96),
    d1=st.integers(min_value=2, max_value=96),
    d2=st.integers(min_value=2, max_value=96),
    d3=st.integers(min_value=2, max_value=96),
    d4=st.integers(min_value=2, max_value=96),
    d5=st.integers(min_value=2, max_value=96),
)
def test_every_family_verifies_clean_at_random_dims(family, d0, d1, d2,
                                                    d3, d4, d5):
    spec = get_spec(family)
    point = (d0, d1, d2, d3, d4, d5)[: spec.ndims]
    findings = verify_family(spec, point)
    assert findings == [], format_findings(findings)


def test_zoo_smoke_grid_is_clean():
    lint = verify_zoo(grids=("smoke",))
    assert lint.findings == [], format_findings(lint.findings)
    assert lint.algorithms > 0 and lint.instances > 0
    assert lint.rules_run == len(RULES)


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(min_value=1, max_value=512),
    n=st.integers(min_value=1, max_value=512),
    k=st.integers(min_value=1, max_value=512),
)
def test_recount_agrees_with_kernel_flops(m, n, k):
    """The independent derivations coincide with flops.py on every kind."""
    for call in (gemm(m, n, k), syrk(m, k), symm(m, n), tri2full(m)):
        assert recount_call(call) == call.flops


# ------------------------------------------- completeness on known-bad DAGs --


def test_mutation_suite_catches_all_classes():
    outcomes = run_mutation_suite()
    missed = [o for o in outcomes if not o.caught]
    assert not missed, f"uncaught mutants: {missed}"
    assert len(outcomes) == 8


@pytest.mark.parametrize("mutant", MUTANT_CLASSES, ids=mutant_names())
def test_each_mutant_flagged_with_expected_rule(mutant):
    spec = get_spec("aatb")
    point = (96, 64, 48)
    algos = spec.algorithms(point)
    chain = spec.chain(point)
    assert verify_algorithms(algos, chain=chain) == []
    mutated = mutant.apply(algos)
    fired = {f.rule_id for f in verify_algorithms(mutated, chain=chain)}
    assert mutant.expected_rule in fired, (
        f"{mutant.name}: expected {mutant.expected_rule}, fired {fired}")


def test_mutant_expected_rules_are_registered():
    for mutant in MUTANT_CLASSES:
        assert mutant.expected_rule in RULES


def test_redundant_tri2full_is_warning_not_error():
    """A wasteful (but correct) mirror is a warning, not an error."""
    from repro.core.algorithms import Algorithm, Leaf, Step

    leaf = Leaf(index=0, base=0, transposed=False, rows=8, cols=8,
                symmetric=True, storage="full")
    algo = Algorithm(
        name="wasteful-mirror",
        steps=(Step(call=tri2full(8), lhs=leaf, rhs=None, out=0,
                    out_rows=8, out_cols=8, out_storage="full",
                    out_symmetric=True),))
    findings = verify_algorithm(algo)
    assert [f.rule_id for f in findings] == ["redundant-tri2full"]
    assert findings[0].severity == "warning"
    assert errors_only(findings) == []


# ----------------------------------------------------------------- wiring --


def test_enumerate_verify_hook_explicit_and_env(monkeypatch):
    spec = get_spec("atab")
    c = spec.chain((24, 36, 12))
    ok = enumerate_algorithms(c, verify=True)
    assert ok
    monkeypatch.setenv("REPRO_VERIFY_ENUMERATION", "1")
    assert [a.name for a in enumerate_algorithms(c)] == [a.name for a in ok]


def test_expression_spec_verify_convenience():
    assert get_spec("abtb").verify((16, 24, 8)) == []


def test_plan_service_guard_blocks_invalid_plan():
    """An invalid plan raises pre-publication and never enters the cache."""
    from repro.core.planner import Planner
    from repro.serve.plan_cache import PlanService

    class _CorruptingPlanner:
        def __init__(self):
            self.inner = Planner(discriminant="flops", backend="numpy")

        def __getattr__(self, name):
            return getattr(self.inner, name)

        def plan(self, chain, env=None):
            good = self.inner.plan(chain, env)
            steps = list(good.algorithm.steps)
            steps[-1] = dataclasses.replace(
                steps[-1], out_rows=steps[-1].out_rows + 1)
            return dataclasses.replace(
                good, algorithm=dataclasses.replace(
                    good.algorithm, steps=tuple(steps)))

    svc = PlanService(planner=_CorruptingPlanner())
    with pytest.raises(AnalysisError) as exc:
        svc.lookup("atab", (16, 24, 8))
    assert any(f.rule_id == "bad-result" for f in exc.value.findings)
    assert svc.cache.stats()["size"] == 0
    # The in-flight marker was uninstalled: the shape is retryable.
    with pytest.raises(AnalysisError):
        svc.lookup("atab", (16, 24, 8))


def test_plan_service_verify_on_by_default_and_optional():
    from repro.serve.plan_cache import PlanService
    assert PlanService().verify_plans is True
    svc = PlanService(discriminant="flops", verify_plans=True)
    plan = svc.lookup("aatb", (16, 24, 8))
    assert plan is svc.lookup("aatb", (16, 24, 8))  # published + cached


def test_core_lazy_exports():
    import repro.core as core
    assert core.verify_algorithm is verify_algorithm
    assert core.AnalysisError is AnalysisError
    assert core.Finding is Finding


# ---------------------------------------------------------- rule registry --


def test_rule_registry_rejects_duplicates_and_bad_severity():
    with pytest.raises(ValueError):
        register_rule("raw-tri-read", "error", "dup")
    with pytest.raises(ValueError):
        register_rule("brand-new-rule-bad-sev", "fatal", "nope")


def test_collector_rejects_unregistered_rule():
    from repro.core.analysis import Collector
    with pytest.raises(KeyError):
        Collector(algorithm="x").emit("no-such-rule", "msg")


def test_analysis_error_carries_findings():
    from repro.core.analysis import assert_algorithms_valid
    spec = get_spec("aatb")
    algos = spec.algorithms((16, 24, 8))
    bad = [dataclasses.replace(a, steps=()) for a in algos[:1]]
    with pytest.raises(AnalysisError) as exc:
        assert_algorithms_valid(bad)
    assert exc.value.findings
    assert all(f.severity == "error" for f in exc.value.findings)


def test_off_by_one_flops_subclass_detected():
    """A KernelCall subclass lying through .flops trips flop-mismatch."""

    class _Lying(KernelCall):
        @property
        def flops(self):
            return super().flops + 1

    spec = get_spec("abcd")
    algo = spec.algorithms((8, 9, 10, 11, 12))[0]
    step = algo.steps[0]
    lying = _Lying(kind=step.call.kind, dims=step.call.dims,
                   operands=step.call.operands)
    bad = dataclasses.replace(
        algo, steps=(dataclasses.replace(step, call=lying),)
        + algo.steps[1:])
    fired = {f.rule_id for f in verify_algorithm(bad)}
    assert "flop-mismatch" in fired


# -------------------------------------------------------------- CLI + pins --


def test_cli_main_zoo_and_mutants(capsys):
    from repro.core.analysis.__main__ import main
    assert main(["--expr", "aatb,abtb", "--grid", "smoke"]) == 0
    out = capsys.readouterr().out
    assert "0 finding(s)" in out
    assert main(["--mutants"]) == 0
    out = capsys.readouterr().out
    assert "8/8 caught" in out


def test_cli_module_exit_status_zero_on_clean_zoo():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.core.analysis",
         "--expr", "btsb", "--grid", "smoke"],
        cwd=REPO, capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr


def test_analysis_epilog_lists_every_rule():
    from repro.core.cli_help import analysis_rules_epilog
    text = analysis_rules_epilog()
    for rule_id in registered_rules():
        assert rule_id in text


def test_sweep_and_analysis_cli_epilogs_include_rules():
    from repro.core.analysis.__main__ import build_parser
    from repro.core.sweep import _registry_epilog
    assert "static analysis rules" in _registry_epilog()
    epilog = build_parser().epilog
    for rule_id in registered_rules():
        assert rule_id in epilog


def test_docs_rule_catalog_covers_registry():
    """docs/analysis.md documents every registered rule (and no ghosts)."""
    text = (REPO / "docs" / "analysis.md").read_text()
    for rule_id in registered_rules():
        assert f"`{rule_id}`" in text, f"rule {rule_id} missing from docs"
    for mutant in mutant_names():
        assert mutant in text, f"mutant {mutant} missing from docs"
