"""Core LAMP planner: enumeration, FLOP counts, anomaly machinery."""

import numpy as np
import pytest

from repro.core import (
    GRAM_AATB,
    AnalyticalTPUProfile,
    BlasRunner,
    TableProfile,
    classify,
    enumerate_algorithms,
    gram_times,
    matrix_chain,
    measure_instance,
    optimal_chain_order,
    plan,
    predict_algorithm_time,
    scan_line,
)
from repro.core.flops import gemm, symm, syrk, tri2full


# ----------------------------------------------------------- enumeration --

def test_abcd_has_six_algorithms():
    """Paper §3.2.1: 3! = 6 orderings for the 4-operand chain."""
    algos = enumerate_algorithms(matrix_chain(100, 200, 50, 300, 80))
    assert len(algos) == 6
    assert all(len(a.calls) == 3 for a in algos)
    assert all(c.kind == "gemm" for a in algos for c in a.calls)


def test_abcd_flop_formulas_match_paper():
    """Paper's six FLOP-count formulas, checked exhaustively."""
    d = (101, 203, 57, 311, 83)
    algos = enumerate_algorithms(matrix_chain(*d))
    d0, d1, d2, d3, d4 = d
    expected = sorted([
        2 * d0 * (d1 * d2 + d2 * d3 + d3 * d4),      # alg 1
        2 * d2 * (d0 * d1 + d0 * d4 + d3 * d4),      # alg 2
        2 * d3 * (d0 * d1 + d0 * d4 + d1 * d2),      # alg 3
        2 * d1 * (d0 * d4 + d2 * d3 + d3 * d4),      # alg 4
        2 * d2 * (d0 * d1 + d0 * d4 + d3 * d4),      # alg 5 (= alg 2)
        2 * d4 * (d0 * d1 + d1 * d2 + d2 * d3),      # alg 6
    ])
    assert sorted(a.flops for a in algos) == expected


def test_aatb_has_five_algorithms_with_paper_flops():
    """Paper §3.2.2: SYRK/SYMM/GEMM variants, five total."""
    d0, d1, d2 = 120, 260, 70
    algos = enumerate_algorithms(gram_times(d0, d1, d2))
    assert len(algos) == 5
    kinds = sorted(tuple(c.kind for c in a.calls) for a in algos)
    assert kinds == sorted([
        ("syrk", "symm"),
        ("syrk", "tri2full", "gemm"),
        ("gemm", "symm"),
        ("gemm", "gemm"),
        ("gemm", "gemm"),
    ])
    fl = sorted(set(a.flops for a in algos))
    assert fl == sorted({
        d0 * ((d0 + 1) * d1 + 2 * d0 * d2),   # algs 1, 2
        2 * d0 * d0 * (d1 + d2),              # algs 3, 4
        4 * d0 * d1 * d2,                     # alg 5
    })


def test_dp_chain_order_optimal():
    flops, tree = optimal_chain_order([10, 1000, 10, 1000, 10])
    # ((A·B)·(C·D)) is wildly suboptimal; (A·B)C then ·D etc — DP must find
    # the min over all 5 parenthesizations; verify against brute force.
    algos = enumerate_algorithms(matrix_chain(10, 1000, 10, 1000, 10))
    assert flops == min(a.flops for a in algos)


def test_kernel_flop_conventions():
    assert gemm(3, 5, 7).flops == 2 * 3 * 5 * 7
    assert syrk(4, 9).flops == 5 * 4 * 9
    assert symm(6, 11).flops == 2 * 36 * 11
    assert tri2full(8).flops == 0


# -------------------------------------------------------------- anomaly --

def test_classify_non_anomaly_when_cheapest_is_fastest():
    c = classify({"a": 1.0, "b": 2.0}, {"a": 10, "b": 20})
    assert not c.is_anomaly
    assert c.time_score == 0.0


def test_classify_anomaly_with_scores():
    times = {"cheap": 2.0, "fast": 1.0}
    flops = {"cheap": 100, "fast": 145}
    c = classify(times, flops, threshold=0.10)
    assert c.is_anomaly
    assert c.cheapest == ("cheap",)
    assert c.fastest == ("fast",)
    assert c.time_score == pytest.approx(0.5)
    assert c.flop_score == pytest.approx(45 / 145)


def test_classify_tie_in_flops_not_anomaly_if_any_fast():
    times = {"a": 2.0, "b": 1.0}
    flops = {"a": 100, "b": 100}
    c = classify(times, flops)
    assert not c.is_anomaly  # cheapest set = {a,b} intersects fastest {b}


def test_classify_threshold_suppresses_marginal():
    times = {"cheap": 1.05, "fast": 1.0}
    flops = {"cheap": 100, "fast": 150}
    assert not classify(times, flops, threshold=0.10).is_anomaly
    assert classify(times, flops, threshold=0.01).is_anomaly


def test_scan_line_region_and_holes():
    # anomalous region = coords [100, 200] with a 1-point hole at 150
    def classify_at(pt):
        x = pt[0]
        anom = 100 <= x <= 200 and x != 150
        return classify({"c": 2.0 if anom else 1.0, "f": 1.0},
                        {"c": 10, "f": 20}, threshold=0.1)

    scan = scan_line(classify_at, origin=(140,), dim=0, lo_bound=20,
                     hi_bound=1200, step=10)
    assert scan.lo == 100
    assert scan.hi == 200
    assert scan.thickness == 101


# ------------------------------------------------------------ perfmodel --

def test_analytical_profile_syrk_cheaper_than_gemm():
    prof = AnalyticalTPUProfile()
    m, k = 1024, 1024
    t_syrk = prof.time(syrk(m, k), 2)
    t_gemm = prof.time(gemm(m, m, k), 2)
    assert t_syrk < t_gemm  # triangular block grid halves MXU work


def test_analytical_profile_quantization_cliff():
    prof = AnalyticalTPUProfile()
    # At 128³ the model is overhead/memory-bound; the MXU quantization
    # cliff shows where compute dominates: 1025³ pays for 1152-padded
    # tiles (+42 % block work for +0.3 % useful FLOPs).
    t1024 = prof.time(gemm(1024, 1024, 1024), 2)
    t1025 = prof.time(gemm(1025, 1025, 1025), 2)
    assert t1025 > t1024 * 1.25


def test_table_profile_exact_and_nn_fallback():
    prof = TableProfile(peak_flops=1e12)
    prof.record(gemm(100, 100, 100), 1e-3)
    assert prof.time(gemm(100, 100, 100)) == 1e-3
    # unseen shape: nearest neighbour scaled by FLOP ratio
    t = prof.time(gemm(200, 200, 200))
    assert t == pytest.approx(8e-3)


def test_predict_algorithm_time_additive():
    prof = TableProfile(peak_flops=1e12)
    prof.record(syrk(64, 32), 2e-3)
    prof.record(symm(64, 16), 3e-3)
    algos = enumerate_algorithms(gram_times(64, 32, 16))
    a1 = next(a for a in algos if a.name.endswith("[syrk+symm]"))
    assert predict_algorithm_time(a1.calls, prof) == pytest.approx(5e-3)


# ----------------------------------------------------------- execution ---

def test_blas_runner_executes_all_aatb_algorithms_identically():
    rng = np.random.default_rng(0)
    runner = BlasRunner(reps=1, flush_cache=False,
                        rng=np.random.default_rng(1))
    algos = enumerate_algorithms(gram_times(60, 90, 40))
    operands = runner.make_operands(algos[0])
    for a in algos:
        for kk, vv in runner.make_operands(a).items():
            operands.setdefault(kk, vv)
    ref = None
    for a in algos:
        out = runner.execute(a, operands)
        if ref is None:
            ref = out
        else:
            np.testing.assert_allclose(out, ref, rtol=1e-10, atol=1e-8)


def test_blas_runner_abcd_algorithms_agree():
    runner = BlasRunner(reps=1, flush_cache=False)
    algos = enumerate_algorithms(matrix_chain(30, 50, 20, 60, 40))
    operands = runner.make_operands(algos[0])
    ref = None
    for a in algos:
        out = runner.execute(a, operands)
        if ref is None:
            ref = out
        else:
            np.testing.assert_allclose(out, ref, rtol=1e-9, atol=1e-7)


def test_measure_instance_returns_consistent_classification():
    runner = BlasRunner(reps=2, flush_cache=False)
    inst = measure_instance(GRAM_AATB, (96, 160, 64), runner, threshold=0.1)
    assert set(inst.times) == set(inst.flops)
    assert len(inst.times) == 5


# -------------------------------------------------------------- planner --

def test_planner_executes_correctly_both_discriminants():
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((96, 160)).astype(np.float32))
    B = jnp.asarray(rng.standard_normal((96, 48)).astype(np.float32))
    ref = np.asarray(A @ A.T @ B)
    for disc in ("flops", "perfmodel"):
        p = plan(gram_times(96, 160, 48), discriminant=disc)
        out = np.asarray(p.fn(A, A, B))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-3)


def test_planner_chain_execution():
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    mats = [jnp.asarray(rng.standard_normal(s).astype(np.float32))
            for s in [(40, 60), (60, 30), (30, 70), (70, 20)]]
    p = plan(matrix_chain(40, 60, 30, 70, 20))
    ref = np.asarray(mats[0] @ mats[1] @ mats[2] @ mats[3])
    out = np.asarray(p.fn(*mats))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-3)
