"""Fault tolerance: checkpoint save/restore/resharding, supervisor restart,
straggler detection, preemption, end-to-end crash-resume training."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, store
from repro.runtime.supervisor import (
    Heartbeat,
    RestartPolicy,
    StragglerMonitor,
    Supervisor,
)


def tree_eq(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


# ------------------------------------------------------------- store -----

def test_store_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16) * 2,
                       "c": None}}
    store.save(str(tmp_path), 7, tree)
    assert store.latest_step(str(tmp_path)) == 7
    out = store.restore(str(tmp_path), 7, tree)
    assert tree_eq(tree, out)


def test_store_atomicity_tmp_dir_ignored(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    store.save(str(tmp_path), 1, tree)
    # a crashed save leaves a .tmp dir — must not count as a checkpoint
    os.makedirs(tmp_path / "step_9.tmp")
    assert store.latest_step(str(tmp_path)) == 1
    # incomplete dir without manifest also ignored
    os.makedirs(tmp_path / "step_5")
    assert store.latest_step(str(tmp_path)) == 1


def test_store_integrity_check(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3)}
    d = store.save(str(tmp_path), 2, tree)
    # corrupt: replace file with wrong shape
    np.save(os.path.join(d, "a.npy"), np.zeros((4, 4)))
    with pytest.raises(ValueError, match="integrity|shape"):
        store.restore(str(tmp_path), 2, tree)


def test_store_retention(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        store.save(str(tmp_path), s, tree)
    store.retain(str(tmp_path), keep=2)
    left = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert left == ["step_3", "step_4"]


def test_elastic_restore_to_different_mesh(tmp_path):
    """Save on a (2,) data mesh, restore onto (2, 2) data×model — the
    elastic-scaling path: specs recorded at save time are re-filtered to
    the new mesh and device_put reshards."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >= 2 devices (run under forced host devices)")
    mesh1 = jax.make_mesh((2,), ("data",),
                          axis_types=(jax.sharding.AxisType.Auto,))
    arr = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
    sharded = jax.device_put(arr, NamedSharding(mesh1, P("data", None)))
    tree = {"w": sharded}
    specs = {"w": P("data", None)}
    store.save(str(tmp_path), 3, tree, specs=specs,
               mesh_shape={"data": 2})
    mesh2 = jax.make_mesh((1, 2), ("data", "model"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    out = store.restore(str(tmp_path), 3, tree, mesh=mesh2)
    assert np.array_equal(np.asarray(out["w"]), np.asarray(arr))


# ------------------------------------------------------------ manager ----

def test_manager_async_save_and_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((4, 4))}
    for s in (1, 2, 3):
        mgr.save(s, jax.tree.map(lambda x: x * s, tree))
    mgr.wait()
    assert mgr.latest_step() == 3
    out = mgr.restore(tree)
    assert float(np.asarray(out["w"])[0, 0]) == 3.0
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_2", "step_3"]  # retention


def test_manager_preemption_flag(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert not mgr.preempted.is_set()
    mgr.preempted.set()
    assert mgr.preempted.is_set()


# ---------------------------------------------------------- supervisor ---

def test_supervisor_retries_until_success():
    calls = []

    def flaky(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise RuntimeError("boom")
        return "done"

    sup = Supervisor(RestartPolicy(max_restarts=5, backoff_s=0),
                     sleep=lambda s: None)
    assert sup.run(flaky) == "done"
    assert calls == [0, 1, 2]
    assert sup.restarts == 2


def test_supervisor_budget_exhaustion():
    sup = Supervisor(RestartPolicy(max_restarts=2, backoff_s=0),
                     sleep=lambda s: None)
    with pytest.raises(RuntimeError, match="restart budget"):
        sup.run(lambda attempt: (_ for _ in ()).throw(RuntimeError("x")))
    assert sup.restarts == 3


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(alpha=0.5, threshold=2.0, warmup_steps=2)
    for i in range(5):
        assert not mon.observe(i, 0.1)
    assert mon.observe(5, 0.5)       # 5× the EMA → flagged
    assert mon.flagged == [5]
    assert not mon.observe(6, 0.1)   # EMA not poisoned by the straggler


def test_heartbeat_detects_death():
    hb = Heartbeat(interval_s=0.05, miss_limit=2)
    hb.start()
    import time
    time.sleep(0.12)
    assert hb.is_alive()
    hb.stop()
    last = hb.last_beat
    assert not hb.is_alive(now=last + 1.0)


# ------------------------------------------------- end-to-end resume -----

def test_train_crash_and_resume_deterministic(tmp_path):
    """Train 6 steps; crash at 3 (after a save at 2); supervisor restarts;
    resumed run must land on the exact same final loss as an uninterrupted
    run (determinism contract of pipeline + checkpoint)."""
    from repro.configs import get_smoke
    from repro.data.pipeline import SyntheticLM
    from repro.train import loop as train_loop

    cfg = get_smoke("glm4_9b")
    src = SyntheticLM(cfg.vocab, 32, 4, seed=0)
    logs_a = []

    # uninterrupted reference
    state_ref = train_loop.train(
        cfg, src, 6, ckpt_dir=str(tmp_path / "ref"), save_every=2,
        log_every=1, log_fn=logs_a.append)

    # crash-and-resume run
    crash_dir = str(tmp_path / "crash")
    sup = Supervisor(RestartPolicy(max_restarts=1, backoff_s=0),
                     sleep=lambda s: None)

    def run(attempt):
        return train_loop.train(
            cfg, src, 6, ckpt_dir=crash_dir, save_every=2, log_every=1,
            fail_at_step=3 if attempt == 0 else None,
            log_fn=lambda m: None)

    state_resumed = sup.run(run)
    assert sup.restarts == 1
    np.testing.assert_allclose(
        np.asarray(jax.device_get(state_ref.params["final_norm"]["g"])),
        np.asarray(jax.device_get(
            state_resumed.params["final_norm"]["g"])),
        rtol=1e-5, atol=1e-6)
