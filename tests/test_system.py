"""End-to-end behaviour tests: examples run, train loop improves loss,
serve generates, benchmark conventions hold, cell accounting is exact."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_quickstart_example_runs():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    p = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "quickstart.py")],
        capture_output=True, text=True, timeout=600, env=env)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "6 algorithms" in p.stdout
    assert "plan output max err" in p.stdout


def test_train_loop_improves_loss(tmp_path):
    from repro.configs import get_smoke
    from repro.data.pipeline import SyntheticLM
    from repro.train import loop as train_loop

    cfg = get_smoke("phi3_mini")
    src = SyntheticLM(cfg.vocab, 32, 4, seed=0)
    losses = []

    def log(msg):
        if "loss=" in msg:
            losses.append(float(msg.split("loss=")[1].split()[0]))

    train_loop.train(cfg, src, 30, ckpt_dir=str(tmp_path), save_every=10,
                     log_every=1, peak_lr=1e-3, log_fn=log)
    assert len(losses) >= 30
    assert losses[-1] < losses[0], (losses[0], losses[-1])


def test_serve_generate_end_to_end():
    from repro.configs import get_smoke
    from repro.models import api
    from repro.serve.decode import generate

    cfg = get_smoke("zamba2_1p2b")
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    prompt = jnp.asarray([[3, 1, 4]], jnp.int32)
    out = generate(params, cfg, prompt, max_new=5, max_s=16)
    assert out.shape == (1, 8)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab).all())


def test_muon_trains_transformer_smoke():
    from repro.configs import get_smoke
    from repro.data.pipeline import SyntheticLM
    from repro.models import api
    from repro.optim import muon

    cfg = get_smoke("yi_9b")
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    state = muon.init(params)
    src = SyntheticLM(cfg.vocab, 32, 4, seed=0)
    losses = []
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(i).items()}
        loss, g = jax.value_and_grad(
            lambda p: api.loss_fn(p, cfg, batch)[0])(params)
        params, state = muon.update(g, state, params, lr=jnp.asarray(5e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_bench_csv_convention():
    """Benchmark emit() rows parse as name,us,derived."""
    import io
    from contextlib import redirect_stdout
    sys.path.insert(0, REPO)
    try:
        from benchmarks.common import emit
        buf = io.StringIO()
        with redirect_stdout(buf):
            emit("x", 12.5, "k=v")
        assert buf.getvalue().strip() == "x,12.500,k=v"
    finally:
        sys.path.remove(REPO)


def test_cell_table_accounting():
    from repro.configs import all_cells
    cells = all_cells()
    assert len(cells) == 40
    skipped = [(a, s) for a, s, run, _ in cells if not run]
    # long_500k skipped exactly for the 8 non-SSM archs
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    runnable = [c for c in cells if c[2]]
    assert len(runnable) == 32
