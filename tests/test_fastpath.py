"""Measurement fast path (ISSUE 10): operand arena, executable memo,
algorithm-enumeration LRU, and the pipelined serial sweep — all gated on
bit-for-bit parity with the legacy measurement path (identical Instance
records, byte-identical atlas files) plus kill/resume cleanliness."""

import os

import numpy as np
import pytest

from repro.core.arena import (
    FastPathStats,
    OperandArena,
    algorithm_structural_key,
    arena_for,
    order_points_for_locality,
)
from repro.core.backends import NumpyBackend, make_backend
from repro.core.expressions import (
    algorithm_cache_stats,
    clear_algorithm_cache,
)
from repro.core import expressions as expressions_mod
from repro.core.profile_store import HardwareFingerprint
from repro.core.sweep import (
    FASTPATH_ENV,
    GRAM_AATB,
    AnomalyAtlas,
    GridSpec,
    benchmark_unique_calls,
    fastpath_enabled,
    main as sweep_main,
    measure_instance,
    sweep,
)
from repro.core.synthetic import MaskRunner, PlantedSpec, planted_masks
from repro.core.flops import gemm, syrk

FP = HardwareFingerprint(backend="blas", device="testdev", dtype="float64")

GRID = GridSpec.uniform((32, 64, 96), GRAM_AATB.ndims, name="test")


class CliffRunner:
    """Deterministic FLOP-proportional timer with a SYRK cliff at m >= 64.

    Reported seconds are a pure function of the algorithm — identical in
    fast and legacy modes, so the two must agree byte for byte.
    """

    def make_operands(self, alg):
        return {}

    def time_algorithm(self, alg, operands=None):
        t = 0.0
        for call in alg.calls:
            t += call.flops * 1e-9
            if call.kind == "syrk" and call.dims[0] >= 64:
                t += call.flops * 3e-9
        return t


class SeededFakeTimeNumpy(NumpyBackend):
    """Real (seeded) operand synthesis, deterministic reported time.

    Unlike :class:`CliffRunner` this drives genuine buffers through the
    arena, so the parity check also covers operand plumbing.
    """

    def time_algorithm(self, alg, operands=None, reps=None):
        assert operands, f"operands never reached the runner for {alg.name}"
        skew = 1.5 if any(c.kind == "syrk" for c in alg.calls) else 1.0
        return 1e-12 * alg.flops * skew


def _sweep_bytes(tmp_path, tag, spec, points, runner, fp_on):
    path = tmp_path / f"{tag}.jsonl"
    atlas = AnomalyAtlas(path, FP, spec.name, 0.10)
    res = sweep(spec, points, runner=runner, atlas=atlas, fastpath=fp_on)
    atlas.flush()
    return res, path.read_bytes()


# ------------------------------------------------------------------ parity --

def test_fastpath_matches_legacy_on_planted_masks(tmp_path):
    """Planted-mask oracles: identical records and atlas bytes per mask."""
    spec = PlantedSpec()
    grid = GridSpec.uniform(tuple(range(10, 110, 10)), spec.ndims,
                            name="planted")
    for name, mask in sorted(planted_masks(grid).items()):
        fast, fast_b = _sweep_bytes(tmp_path, f"{name}-fast", spec,
                                    grid.points(), MaskRunner(mask), True)
        legacy, legacy_b = _sweep_bytes(tmp_path, f"{name}-legacy", spec,
                                        grid.points(), MaskRunner(mask),
                                        False)
        assert fast.n_measured == legacy.n_measured == grid.n_points
        a = [(r.point, r.times, r.flops, r.cls) for r in fast.records]
        b = [(r.point, r.times, r.flops, r.cls) for r in legacy.records]
        assert a == b, name
        assert fast_b == legacy_b, name           # atlas parity, bytewise
        assert fast.fastpath is not None and legacy.fastpath is None


def test_fastpath_matches_legacy_with_real_operands(tmp_path):
    """Seeded numpy operands through the arena: byte-identical atlases."""
    pts = GRID.points()
    fast, fast_b = _sweep_bytes(
        tmp_path, "fast", GRAM_AATB, pts,
        SeededFakeTimeNumpy(reps=1, flush_cache=False, seed=11), True)
    legacy, legacy_b = _sweep_bytes(
        tmp_path, "legacy", GRAM_AATB, pts,
        SeededFakeTimeNumpy(reps=1, flush_cache=False, seed=11), False)
    assert fast_b == legacy_b
    st = fast.fastpath
    assert st is not None
    assert st.arena_hits > 0          # leaf shapes shared across points
    assert st.points_pipelined == len(pts) - 1
    assert 0.0 <= st.overlap_fraction <= 1.0
    assert "arena" in st.summary() and "pipelined" in st.summary()


def test_fastpath_preserves_requested_order():
    pts = list(reversed(GRID.points()))
    res = sweep(GRAM_AATB, pts, runner=CliffRunner(), fastpath=True)
    assert [r.point for r in res.records] == pts


def test_direct_measure_instance_with_arena_matches_legacy():
    runner = SeededFakeTimeNumpy(reps=1, flush_cache=False, seed=3)
    arena = OperandArena(runner)
    for p in GRID.points()[:4]:
        via_arena = measure_instance(GRAM_AATB, p, runner, 0.10, arena=arena)
        plain = measure_instance(GRAM_AATB, p, runner, 0.10)
        assert via_arena == plain


# ------------------------------------------------------------- kill/resume --

def test_killed_fastpath_sweep_resumes_to_legacy_identical_atlas(tmp_path):
    """Kill after 10 points, resume with a *fresh* runner (fresh arena):
    the stitched atlas is byte-identical to an uninterrupted legacy sweep
    — no arena or memo state leaks into resumed results."""
    path = tmp_path / "fast.jsonl"
    atlas = AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10, chunk_size=5)
    res1 = sweep(GRAM_AATB, GRID.points(), runner=CliffRunner(),
                 atlas=atlas, max_instances=10, fastpath=True)
    assert res1.n_measured == 10

    atlas2 = AnomalyAtlas(path, FP, GRAM_AATB.name, 0.10)
    res2 = sweep(GRAM_AATB, GRID.points(), runner=CliffRunner(),
                 atlas=atlas2, fastpath=True)
    assert res2.n_skipped == 10
    assert res2.n_measured == GRID.n_points - 10

    _, legacy_b = _sweep_bytes(tmp_path, "legacy", GRAM_AATB, GRID.points(),
                               CliffRunner(), False)
    assert path.read_bytes() == legacy_b


def test_fastpath_budget_buys_first_points_in_request_order(tmp_path):
    """max_instances applies to the request-order todo *before* locality
    reordering — the budget semantics are unchanged by the fast path."""
    pts = list(reversed(GRID.points()))
    res = sweep(GRAM_AATB, pts, runner=CliffRunner(), max_instances=5,
                fastpath=True)
    assert [r.point for r in res.records] == pts[:5]


# ------------------------------------------------------------- kill-switch --

def test_fastpath_enabled_flag_and_env(monkeypatch):
    monkeypatch.delenv(FASTPATH_ENV, raising=False)
    assert fastpath_enabled() is True
    assert fastpath_enabled(False) is False
    monkeypatch.setenv(FASTPATH_ENV, "1")
    assert fastpath_enabled() is False
    assert fastpath_enabled(True) is True        # explicit flag wins
    res = sweep(GRAM_AATB, GRID.points()[:2], runner=CliffRunner())
    assert res.fastpath is None                  # env took the legacy path


def test_cli_no_fastpath_flag(tmp_path, monkeypatch, capsys):
    monkeypatch.setenv(FASTPATH_ENV, "")         # registered for teardown
    args = ["--expr", "aatb", "--grid", "smoke", "--reps", "1",
            "--no-flush", "--atlas-dir", str(tmp_path / "a"), "--quiet",
            "--no-fastpath"]
    assert sweep_main(args) == 0
    out = capsys.readouterr().out
    assert "fastpath:" not in out
    assert os.environ[FASTPATH_ENV] == "1"       # pool workers inherit it

    os.environ[FASTPATH_ENV] = ""                # re-arm for the second run
    args2 = ["--expr", "aatb", "--grid", "smoke", "--reps", "1",
             "--no-flush", "--atlas-dir", str(tmp_path / "b"), "--quiet"]
    assert sweep_main(args2) == 0
    out2 = capsys.readouterr().out
    assert "fastpath:" in out2


# --------------------------------------------------------- enumeration LRU --

def test_algorithms_memo_enumerates_once_per_point(monkeypatch):
    calls = []
    real = expressions_mod.enumerate_algorithms

    def counting(expr):
        calls.append(expr)
        return real(expr)

    monkeypatch.setattr(expressions_mod, "enumerate_algorithms", counting)
    clear_algorithm_cache()
    before = algorithm_cache_stats()
    for _ in range(3):
        a = GRAM_AATB.algorithms((32, 48, 64))
    assert len(calls) == 1                       # memoised after first
    b = GRAM_AATB.algorithms((32, 48, 64))
    assert [x.name for x in a] == [x.name for x in b]
    GRAM_AATB.algorithms((48, 48, 64))
    assert len(calls) == 2                       # distinct point, new entry
    hits, misses = algorithm_cache_stats()
    assert hits - before[0] == 3
    assert misses - before[1] == 2


def test_algorithms_memo_returns_fresh_lists():
    clear_algorithm_cache()
    a = GRAM_AATB.algorithms((32, 32, 32))
    a.clear()                                    # caller-side mutation
    b = GRAM_AATB.algorithms((32, 32, 32))
    assert b and b == GRAM_AATB.algorithms((32, 32, 32))


def test_algorithms_memo_bypassed_under_verify_enumeration(monkeypatch):
    calls = []
    real = expressions_mod.enumerate_algorithms

    def counting(expr):
        calls.append(expr)
        return real(expr)

    monkeypatch.setattr(expressions_mod, "enumerate_algorithms", counting)
    monkeypatch.setenv("REPRO_VERIFY_ENUMERATION", "1")
    clear_algorithm_cache()
    GRAM_AATB.algorithms((32, 32, 32))
    GRAM_AATB.algorithms((32, 32, 32))
    assert len(calls) == 2                       # every call re-enumerates


# ------------------------------------------------------------ operand arena --

def test_seeded_leaf_synthesis_is_reproducible_and_matches_legacy():
    algos = GRAM_AATB.algorithms((32, 48, 64))
    r1 = NumpyBackend(reps=1, flush_cache=False, seed=5)
    r2 = NumpyBackend(reps=1, flush_cache=False, seed=5)
    legacy = r1.make_operands(algos[0])
    arena = OperandArena(r2)
    pooled = arena.operands(algos)
    assert set(legacy) <= set(pooled)
    for base, buf in legacy.items():
        np.testing.assert_array_equal(buf, pooled[base])
    # a second pass is pure hits and returns the same buffers
    hits0, misses0, _ = arena.snapshot()
    again = arena.operands(algos)
    assert all(again[k] is pooled[k] for k in pooled)
    hits1, misses1, _ = arena.snapshot()
    assert misses1 == misses0
    assert hits1 > hits0


def test_seed_makes_leaf_draws_pure_unseeded_stays_stateful():
    algos = GRAM_AATB.algorithms((32, 32, 32))
    # unseeded: the shared rng advances, so repeat draws differ
    stateful = NumpyBackend(reps=1, flush_cache=False)
    a = stateful.make_operands(algos[0])
    b = stateful.make_operands(algos[0])
    assert any(not np.array_equal(a[k], b[k]) for k in a)
    # seeded: each leaf is a pure function of (seed, base, shape)
    pure = NumpyBackend(reps=1, flush_cache=False, seed=5)
    c = pure.make_operands(algos[0])
    d = pure.make_operands(algos[0])
    for k in c:
        np.testing.assert_array_equal(c[k], d[k])


def test_arena_handles_operandless_planted_algorithms():
    spec = PlantedSpec()
    arena = OperandArena(MaskRunner(planted_masks(
        GridSpec.uniform((10, 20), spec.ndims))["full"]))
    assert arena.operands(spec.algorithms((10, 20))) == {}


def test_arena_for_is_stable_per_runner():
    r = NumpyBackend(reps=1, flush_cache=False, seed=1)
    assert arena_for(r) is arena_for(r)
    other = NumpyBackend(reps=1, flush_cache=False, seed=1)
    assert arena_for(r) is not arena_for(other)


# -------------------------------------------------- structural keys / order --

def test_structural_keys_distinct_within_point_shared_across_dims():
    a32 = GRAM_AATB.algorithms((32, 32, 32))
    keys32 = [algorithm_structural_key(a) for a in a32]
    assert len(set(keys32)) == len(keys32)       # no memo collisions
    a64 = GRAM_AATB.algorithms((64, 96, 128))
    keys64 = [algorithm_structural_key(a) for a in a64]
    assert set(keys32) == set(keys64)            # dims-free: shared wrappers


def test_order_points_for_locality_is_sorted_and_total():
    pts = [(3, 1), (1, 2), (2, 9), (1, 1)]
    out = order_points_for_locality(pts)
    assert sorted(out) == out and sorted(pts) == out
    assert order_points_for_locality(list(reversed(pts))) == out


# --------------------------------------------------------------- stats type --

def test_fastpath_stats_merge_and_roundtrip():
    a = FastPathStats(arena_hits=2, arena_misses=1, prep_s=0.5,
                      overlap_s=0.25, points_pipelined=3)
    b = FastPathStats.from_dict(a.as_dict())
    assert b == a
    a.merge(FastPathStats(arena_hits=1, memo_hits=4))
    assert a.arena_hits == 3 and a.memo_hits == 4
    assert a.overlap_fraction == pytest.approx(0.5)
    assert FastPathStats().overlap_fraction == 0.0


# -------------------------------------------------- batched kernel benching --

def test_benchmark_unique_calls_with_arena_counts_reuse():
    runner = SeededFakeTimeNumpy(reps=1, flush_cache=False, seed=9)
    arena = arena_for(runner)
    stats = FastPathStats()
    calls = [gemm(32, 32, 32), syrk(32, 32), gemm(32, 32, 32),
             gemm(32, 48, 32)]
    profile, n_meas, n_reused = benchmark_unique_calls(
        runner, calls, arena=arena, stats=stats)
    assert n_meas == 3 and n_reused == 0         # dedup unchanged
    assert all(c in profile for c in calls)
    _, misses, _ = arena.snapshot()
    assert misses > 0                            # buffers came from the pool
    assert stats.arena_misses == misses
    # second pass: profile cache short-circuits, arena untouched
    _, n2, r2 = benchmark_unique_calls(runner, calls, profile=profile,
                                       arena=arena, stats=stats)
    assert n2 == 0 and r2 == 3
    assert arena.snapshot()[1] == misses


# ------------------------------------------------------------ executable memo --

def test_jax_executable_memo_reuses_wrappers_across_dims():
    pytest.importorskip("jax")
    be = make_backend("jax", reps=1)
    algos = GRAM_AATB.algorithms((16, 16, 16))
    alg = algos[0]
    ops = be.make_operands(alg)
    be.time_algorithm(alg, ops)
    h0, m0 = be.memo_hits, be.memo_misses
    be.time_algorithm(alg, ops)                  # same alg: wrapper reused
    assert (be.memo_hits, be.memo_misses) == (h0 + 1, m0)
    # same structure at other dims: still the same memo entry (jit itself
    # retraces per shape under the shared wrapper)
    key = algorithm_structural_key(alg)
    twin = next(a for a in GRAM_AATB.algorithms((8, 8, 8))
                if algorithm_structural_key(a) == key)
    be.time_algorithm(twin, be.make_operands(twin))
    assert (be.memo_hits, be.memo_misses) == (h0 + 2, m0)


def test_pallas_tuning_generation_invalidates_memo():
    pytest.importorskip("jax")
    from repro.core.backends import PallasBackend

    be = PallasBackend(reps=1, tuning=None)
    g0 = be._memo_generation()
    be.set_tuning(None)
    assert be._memo_generation() != g0           # any set_tuning bumps
    g1 = be._memo_generation()
    with be.tuning_override({("gemm", (32, 32, 32)): {"bm": 32}}):
        g_in = be._memo_generation()
        assert g_in != g1
    assert be._memo_generation() not in (g0, g1, g_in)  # exit bumps again
