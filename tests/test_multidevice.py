"""Multi-device behaviours, exercised in subprocesses.

The main pytest session keeps the default single CPU device (per project
policy — forcing host devices globally would distort smoke tests), so
anything needing a real mesh runs as a child python with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

# These scenarios are written against the unified mesh API
# (jax.set_mesh + jax.sharding.AxisType, post-0.4.x): the child
# processes construct explicit-axis-type meshes that older jax cannot
# express, so on such jax they are skipped rather than failed — the same
# version gate repro.launch.mesh applies to AxisType itself.
pytestmark = pytest.mark.skipif(
    not hasattr(jax, "set_mesh"),
    reason="multi-device scenarios need the unified mesh API "
           "(jax.set_mesh/AxisType), not present on this jax",
)

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_child(code: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr}"
    return p.stdout


def test_sharded_train_step_8dev():
    out = run_child("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.data.pipeline import SyntheticLM
        from repro.launch.mesh import make_host_mesh, set_mesh
        from repro.sharding.context import activation_sharding
        from repro.train.train_step import make_train_state, make_train_step
        assert jax.device_count() == 8
        mesh = make_host_mesh(model=2)
        cfg = get_smoke("glm4_9b")
        with set_mesh(mesh), activation_sharding(mesh):
            state, _ = make_train_state(jax.random.PRNGKey(0), cfg)
            src = SyntheticLM(cfg.vocab, 32, 8)
            batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
            step = jax.jit(make_train_step(cfg))
            losses = []
            for i in range(4):
                batch = {k: jnp.asarray(v)
                         for k, v in src.batch_at(i).items()}
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        assert all(np.isfinite(l) for l in losses), losses
        assert losses[-1] < losses[0], losses
        print("OK", losses)
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard_8dev(tmp_path):
    out = run_child(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import store
        mesh1 = jax.make_mesh((4,), ("data",),
                              axis_types=(jax.sharding.AxisType.Auto,))
        arr = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        tree = {{"w": jax.device_put(
            arr, NamedSharding(mesh1, P("data", None)))}}
        store.save({str(tmp_path)!r}, 1, tree,
                   specs={{"w": P("data", None)}},
                   mesh_shape={{"data": 4}})
        # restore onto a 2x4 mesh (elastic re-mesh)
        mesh2 = jax.make_mesh((2, 4), ("data", "model"),
                              axis_types=(jax.sharding.AxisType.Auto,)*2)
        out = store.restore({str(tmp_path)!r}, 1, tree, mesh=mesh2)
        assert np.array_equal(np.asarray(out["w"]), np.asarray(arr))
        shard_shapes = {{d.index for d in out["w"].addressable_shards}}
        print("OK", len(shard_shapes))
    """)
    assert "OK" in out


def test_tiny_mesh_dryrun_roofline_8dev():
    """End-to-end mini dry-run: proxy config, 4x2 mesh, roofline terms."""
    out = run_child("""
        import jax, jax.numpy as jnp
        from jax.sharding import AxisType
        from repro.configs.base import ShapeSpec
        from repro.launch import specs as specs_lib
        from repro.launch.hlo import Roofline, collective_stats
        from repro.models.transformer import ModelConfig
        from repro.sharding.context import activation_sharding
        from repro.train.train_step import make_train_step
        from repro.models import scan_util

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        cfg = ModelConfig(name="proxy", family="dense", n_layers=2,
                          d_model=256, n_heads=4, n_kv_heads=2,
                          head_dim=64, d_ff=512, vocab=4096,
                          tied_embeddings=True, remat="full")
        shape = ShapeSpec("t", 512, 8, "train")
        with set_mesh(mesh), activation_sharding(mesh), \
                scan_util.unrolled():
            state, sshard = specs_lib.abstract_train_state(cfg, mesh)
            batch, bshard = specs_lib.abstract_batch(cfg, shape, mesh)
            step = make_train_step(cfg)
            compiled = jax.jit(
                step, in_shardings=(sshard, bshard),
                out_shardings=(sshard, None)).lower(state, batch).compile()
        ca = compiled.cost_analysis()
        st = collective_stats(compiled.as_text())
        r = Roofline(flops_per_device=ca["flops"],
                     bytes_per_device=ca["bytes accessed"],
                     collective_bytes=st.total_bytes, chips=8)
        assert r.t_compute > 0 and r.t_memory > 0
        assert st.total_count > 0, "expected collectives in sharded step"
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        print("OK", r.bottleneck, st.total_count)
    """)
    assert "OK" in out


def test_serve_step_sharded_8dev():
    out = run_child("""
        import jax, jax.numpy as jnp
        from jax.sharding import AxisType
        from repro.configs.base import ShapeSpec
        from repro.launch import specs as specs_lib
        from repro.models.transformer import ModelConfig
        from repro.serve.decode import make_serve_step

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        cfg = ModelConfig(name="proxy", family="dense", n_layers=2,
                          d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=32, d_ff=256, vocab=2048,
                          tied_embeddings=True)
        shape = ShapeSpec("d", 256, 8, "decode")
        with set_mesh(mesh):
            st, sshard, pshapes, pshard = \
                specs_lib.abstract_serve_state(cfg, shape, mesh)
            step = make_serve_step(cfg)
            compiled = jax.jit(
                step, in_shardings=(sshard, pshard),
                out_shardings=(sshard, sshard.last_tokens)
            ).lower(st, pshapes).compile()
        print("OK", compiled.memory_analysis().temp_size_in_bytes >= 0)
    """)
    assert "OK" in out
