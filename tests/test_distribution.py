"""Distribution layer: sharding rules, data pipeline determinism,
serve/generate consistency, HLO collective parsing, small-mesh train."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo import Roofline, collective_stats
from repro.sharding.rules import batch_spec, params_specs, spec_for


def mk_mesh():
    n = jax.device_count()
    if n < 2:
        pytest.skip("needs >= 2 local devices")
    return jax.make_mesh((n // 2, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# ------------------------------------------------------------- rules -----

def test_spec_for_tp_and_fsdp():
    mesh = mk_mesh()
    s = spec_for(("embed", "ffn"), (64, 128), mesh)
    assert s == P("data", "model")


def test_spec_for_divisibility_guard():
    mesh = mk_mesh()
    # 7 not divisible by model size → replicated on that dim
    s = spec_for(("embed", "ffn"), (64, 7), mesh)
    assert s == P("data")


def test_spec_for_no_double_axis_use():
    mesh = mk_mesh()
    s = spec_for(("ffn", "heads"), (64, 64), mesh)
    # both want "model"; only the first gets it
    assert s == P("model")


def test_params_specs_cover_model():
    from repro.configs import get_smoke
    from repro.models import api
    mesh = mk_mesh()
    cfg = get_smoke("glm4_9b")
    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    specs = params_specs(axes, params, mesh)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)


def test_batch_spec_axes():
    mesh = mk_mesh()
    assert batch_spec(mesh) == P("data")


# ---------------------------------------------------------- pipeline -----

def test_pipeline_determinism_across_dp_resize():
    """Global sample ids make the stream invariant to dp_size (elastic)."""
    from repro.data.pipeline import SyntheticLM
    a = SyntheticLM(1000, 16, 8, dp_rank=0, dp_size=1, seed=3)
    b0 = SyntheticLM(1000, 16, 8, dp_rank=0, dp_size=2, seed=3)
    b1 = SyntheticLM(1000, 16, 8, dp_rank=1, dp_size=2, seed=3)
    full = a.batch_at(5)["tokens"]
    half0 = b0.batch_at(5)["tokens"]
    half1 = b1.batch_at(5)["tokens"]
    np.testing.assert_array_equal(full, np.concatenate([half0, half1]))


def test_pipeline_batch_at_reproducible():
    from repro.data.pipeline import SyntheticLM
    src = SyntheticLM(500, 8, 4, seed=1)
    np.testing.assert_array_equal(src.batch_at(9)["tokens"],
                                  src.batch_at(9)["tokens"])


def test_prefetcher_yields_in_order():
    from repro.data.pipeline import Prefetcher, SyntheticLM
    src = SyntheticLM(100, 4, 2, seed=0)
    pf = Prefetcher(src, start_step=3, depth=2)
    try:
        for want in (3, 4, 5):
            step, batch = next(pf)
            assert step == want
            np.testing.assert_array_equal(batch["tokens"],
                                          src.batch_at(want)["tokens"])
    finally:
        pf.close()


def test_memmap_source(tmp_path):
    from repro.data.pipeline import MemmapLM
    toks = np.arange(10000, dtype=np.uint32)
    path = str(tmp_path / "toks.bin")
    toks.tofile(path)
    src = MemmapLM(path, vocab=50000, seq_len=16, global_batch=4, seed=0)
    b = src.batch_at(0)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# -------------------------------------------------------------- serve ----

def test_generate_greedy_consistency():
    """generate() then teacher-forced forward agree on the argmax path."""
    from repro.configs import get_smoke
    from repro.models import api
    from repro.serve.decode import generate
    cfg = get_smoke("yi_9b")
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    prompt = jnp.asarray([[5, 9, 2]], jnp.int32)
    out = generate(params, cfg, prompt, max_new=4, max_s=16)
    assert out.shape == (1, 7)
    # re-score: feeding out[:, :-1] must predict out[:, -1] greedily
    batch = {"tokens": out[:, :-1], "labels": out[:, :-1]}
    logits, _ = api.forward_train(params, cfg, batch)
    nxt = int(jnp.argmax(logits[0, -1]))
    assert nxt == int(out[0, -1])


# ---------------------------------------------------------------- hlo ----

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[512,256]{1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar = f32[128,256]{1,0} all-reduce(%p0), to_apply=%sum
  %rs = bf16[64,256]{1,0} reduce-scatter(%p0), dimensions={0}
  ROOT %cp = f32[128,256]{1,0} collective-permute(%ar)
}
"""


def test_collective_stats_parses_ops_and_bytes():
    st = collective_stats(HLO_SAMPLE)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1}
    assert st.bytes_["all-gather"] == 512 * 256 * 4
    assert st.bytes_["reduce-scatter"] == 64 * 256 * 2
    assert st.total_count == 4


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops_per_device=197e12, bytes_per_device=819e9 * 2,
                 collective_bytes=0.0, chips=256)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.bottleneck == "memory"
    assert r.t_bound == pytest.approx(2.0)
    # useful-flops roofline fraction
    frac = r.roofline_fraction(model_flops_total=197e12 * 256)
    assert frac == pytest.approx(0.5)


# --------------------------------------------------- tiny-mesh training ---

def test_sharded_train_step_runs_on_host_mesh():
    from repro.configs import get_smoke
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh, set_mesh
    from repro.sharding.context import activation_sharding
    from repro.train.train_step import make_train_state, make_train_step
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 local devices")
    mesh = make_host_mesh(model=2)
    cfg = get_smoke("olmoe_1b_7b")
    with set_mesh(mesh), activation_sharding(mesh):
        state, _ = make_train_state(jax.random.PRNGKey(0), cfg)
        src = SyntheticLM(cfg.vocab, 32, 4)
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
        step = jax.jit(make_train_step(cfg))
        state, m1 = step(state, batch)
        state, m2 = step(state, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 1.0


def test_scan_util_unrolled_matches_loop():
    from repro.models import scan_util

    def body(c, x):
        return c + x, c * 2

    xs = jnp.arange(6, dtype=jnp.float32)
    c1, y1 = jax.lax.scan(body, jnp.float32(0), xs)
    with scan_util.unrolled():
        c2, y2 = scan_util.scan(body, jnp.float32(0), xs)
    assert float(c1) == float(c2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2))
