"""Execution-backend registry: protocol conformance, the generic walker,
derived kernel benchmarks, vmap batching, cross-backend comparison, and
the deprecation shims (ISSUE 4)."""

import functools
import pickle

import numpy as np
import pytest

from repro.core.backends import (
    BlasBackend,
    JaxBackend,
    KernelOps,
    NumpyBackend,
    PallasBackend,
    backend_default_dtype,
    backend_shard_mode,
    get_backend,
    get_backend_class,
    make_backend,
    reference_execute,
    register_backend,
    registered_backends,
    synthetic_algorithm,
)
from repro.core.backends import base as backends_base
from repro.core.expressions import REGISTRY
from repro.core.flops import KernelCall, gemm, symm, syrk, tri2full
from repro.core.perfmodel import TableProfile
from repro.core.profile_store import HardwareFingerprint, current_fingerprint
from repro.core.sweep import (
    GRAM_AATB,
    AnomalyAtlas,
    GridSpec,
    compare_backends,
    main as sweep_main,
    sweep,
)

SHIPPED = ("blas", "numpy", "jax", "pallas")


def _cheap(name, **kw):
    """A backend instance configured for test speed (no 64MB flush)."""
    return make_backend(name, reps=1, flush_cache=False, **kw)


# ---------------------------------------------------------------- registry --

def test_registry_ships_four_backends():
    assert set(SHIPPED) <= set(registered_backends())


def test_get_backend_unknown_name_is_helpful():
    with pytest.raises(KeyError, match="registered"):
        get_backend("mkl")


def test_register_backend_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        register_backend("blas", BlasBackend)


def test_registry_classes_and_metadata():
    assert get_backend_class("blas") is BlasBackend
    assert get_backend_class("numpy") is NumpyBackend
    assert get_backend_class("jax") is JaxBackend
    assert get_backend_class("pallas") is PallasBackend
    assert backend_default_dtype("blas") == "float64"
    assert backend_default_dtype("pallas") == "float32"
    assert backend_shard_mode("numpy") == "process"
    assert backend_shard_mode("jax") == "device"
    assert backend_shard_mode("pallas") == "device"


def test_fingerprint_tags_are_registry_keys():
    for name in SHIPPED:
        tag, dtype = _cheap(name).fingerprint_tags()
        assert tag == name
        assert dtype == backend_default_dtype(name)


def test_make_backend_drops_foreign_options():
    # flush_cache is a CPU-backend knob; jax must not choke on it.
    be = make_backend("jax", reps=2, flush_cache=False)
    assert be.reps == 2
    # ...while get_backend stays strict.
    with pytest.raises(TypeError):
        get_backend("jax", flush_cache=False)


def test_make_backend_partial_pickles_for_process_pool():
    factory = functools.partial(make_backend, "numpy", reps=1,
                                flush_cache=False)
    runner = pickle.loads(pickle.dumps(factory))()
    assert isinstance(runner, NumpyBackend)


def test_fixed_dtype_backends_reject_wrong_labels():
    for name in ("blas", "numpy"):
        with pytest.raises(ValueError, match="float64"):
            get_backend(name, dtype="float32")


# ------------------------------------------------------- protocol / walker --

@pytest.mark.parametrize("name", SHIPPED)
def test_execute_matches_oracle_on_every_aatb_algorithm(name):
    spec = REGISTRY["aatb"]
    point = (24, 16, 32)
    algos = spec.algorithms(point)
    oracle = NumpyBackend(reps=1, flush_cache=False,
                          rng=np.random.default_rng(0))
    operands = {}
    for a in algos:
        for k, v in oracle.make_operands(a).items():
            operands.setdefault(k, v)
    expected = spec.reference_value(point, operands)
    be = _cheap(name)
    ops = {k: be._asarray(np.asarray(v)) for k, v in operands.items()}
    scale = float(np.abs(expected).max())
    tol = 1e-8 if be.dtype == "float64" else 3e-4 * max(1.0, scale)
    for a in algos:
        np.testing.assert_allclose(np.asarray(be.execute(a, ops)), expected,
                                   rtol=3e-4, atol=tol,
                                   err_msg=f"{name} {a.name}")


@pytest.mark.parametrize("name", SHIPPED)
def test_build_is_positional_and_matches_execute(name):
    be = _cheap(name)
    alg = REGISTRY["aatb"].algorithms((16, 8, 12))[0]
    operands = be.make_operands(alg)
    fn = be.build(alg)
    args = [operands.get(i, operands[0]) for i in range(be.num_inputs(alg))]
    np.testing.assert_allclose(np.asarray(fn(*args)),
                               np.asarray(be.execute(alg, operands)),
                               rtol=1e-6, atol=1e-6)


def test_reference_execute_equals_numpy_backend():
    alg = REGISTRY["abab"].algorithms((12, 9, 7))[0]
    be = NumpyBackend(reps=1, flush_cache=False)
    operands = be.make_operands(alg)
    np.testing.assert_allclose(reference_execute(alg, operands),
                               be.execute(alg, operands))


def test_walker_rejects_unknown_kernel_kind():
    import dataclasses

    alg = synthetic_algorithm(gemm(4, 4, 4))
    bad = dataclasses.replace(alg.steps[0],
                              call=KernelCall("cholesky", (4, 4, 4)))
    with pytest.raises(ValueError, match="cholesky"):
        backends_base.walk_steps((bad,), {0: np.eye(4), 1: np.eye(4)}.get,
                                 NumpyBackend(flush_cache=False).ops())


def test_time_algorithm_and_benchmark_call_protocol():
    be = _cheap("numpy")
    alg = REGISTRY["aatb"].algorithms((16, 8, 12))[0]
    assert be.time_algorithm(alg) >= 0.0
    for call in (gemm(16, 16, 16), syrk(16, 8), symm(16, 8), tri2full(16)):
        assert be.benchmark_call(call, reps=1) >= 0.0


@pytest.mark.parametrize("call", [gemm(12, 10, 8), syrk(12, 8),
                                  symm(12, 8), tri2full(12)])
def test_synthetic_algorithms_execute_every_kind(call):
    """benchmark_call's synthetic one-step algorithms are numerically
    valid programs: the oracle executes them and shapes come out right."""
    alg = synthetic_algorithm(call)
    be = NumpyBackend(reps=1, flush_cache=False)
    out = be.execute(alg, be.make_operands(alg))
    if call.kind == "gemm":
        assert out.shape == (12, 10)
    elif call.kind == "syrk":
        assert out.shape == (12, 12)
        assert np.allclose(out, np.tril(out))  # tri storage
    elif call.kind == "symm":
        assert out.shape == (12, 8)
    else:
        np.testing.assert_allclose(out, out.T)  # mirrored


def test_synthetic_algorithm_rejects_unknown_kind():
    with pytest.raises(ValueError):
        synthetic_algorithm(KernelCall("trsm", (8, 8)))


# ------------------------------------------------------------ vmap batching --

@pytest.mark.parametrize("name", ["jax", "pallas"])
def test_batched_execution_matches_per_instance(name):
    be = get_backend(name, reps=1)
    alg = REGISTRY["aatb"].algorithms((16, 8, 12))[1]
    batch = 3
    operands = be.make_batched_operands(alg, batch)
    out = np.asarray(be.execute_batch(alg, operands))
    assert out.shape[0] == batch
    for i in range(batch):
        single = {k: v[i] for k, v in operands.items()}
        np.testing.assert_allclose(
            out[i], np.asarray(be.execute(alg, single)),
            rtol=1e-5, atol=1e-5)


def test_batched_timing_runs():
    be = get_backend("jax", reps=1)
    alg = REGISTRY["aatb"].algorithms((16, 8, 12))[0]
    assert be.time_algorithm_batched(alg, batch=2, reps=1) >= 0.0


# --------------------------------------------------- sweep engine plumbing --

FP = HardwareFingerprint(backend="blas", device="testdev", dtype="float64")


def test_sweep_exec_backend_serial(tmp_path):
    g = GridSpec.uniform((8, 16), GRAM_AATB.ndims)
    res = sweep(GRAM_AATB, g.points(), exec_backend="numpy", reps=1)
    assert res.n_measured == g.n_points


def test_sweep_exec_backend_process_pool(tmp_path):
    g = GridSpec.uniform((8, 16), GRAM_AATB.ndims)
    factory = functools.partial(make_backend, "numpy", reps=1,
                                flush_cache=False)
    res = sweep(GRAM_AATB, g.points(), backend="process", shards=2,
                runner_factory=factory)
    assert res.n_measured == g.n_points


def test_sweep_use_pallas_is_deprecated_spelling(tmp_path):
    g = GridSpec.uniform((8,), GRAM_AATB.ndims)
    res = sweep(GRAM_AATB, g.points(), backend="jax", reps=1,
                use_pallas=True)
    assert res.n_measured == 1
    with pytest.raises(ValueError, match="conflicts"):
        sweep(GRAM_AATB, g.points(), backend="jax", reps=1,
              use_pallas=True, exec_backend="jax")


# ------------------------------------------------------ backend comparison --

class _CliffRunner:
    """FLOP-proportional fake timer; optional SYRK cliff (pickles)."""

    def __init__(self, syrk_penalty=0.0):
        self.syrk_penalty = syrk_penalty

    def make_operands(self, alg):
        return {}

    def time_algorithm(self, alg, operands=None):
        t = 0.0
        for call in alg.calls:
            t += call.flops * 1e-9
            if call.kind == "syrk":
                t += call.flops * self.syrk_penalty
            if call.kind == "tri2full":
                t += 1e-6
        return t


def test_compare_backends_reports_disjoint_fastest(tmp_path):
    g = GridSpec.uniform((32, 64), GRAM_AATB.ndims, name="cmp")
    pts = g.points()
    # "backend A": SYRK catastrophic -> GEMM algorithms win everywhere.
    res_a = sweep(GRAM_AATB, pts, runner=_CliffRunner(syrk_penalty=5e-9))
    # "backend B": SYRK free-ish -> SYRK algorithms win everywhere.
    res_b = sweep(GRAM_AATB, pts, runner=_CliffRunner(syrk_penalty=-0.9e-9))
    cmp = compare_backends(GRAM_AATB, pts, {"a": res_a, "b": res_b})
    assert cmp.n_points == len(pts)
    assert cmp.backends == ("a", "b")
    # exactly the disjoint-fastest instances are reported (first-principles
    # recomputation from the per-backend records)
    fa = {r.point: set(r.cls.fastest) for r in res_a.records}
    fb = {r.point: set(r.cls.fastest) for r in res_b.records}
    expected = {p for p in fa if not (fa[p] & fb[p])}
    assert expected  # the cliff flip must actually produce disagreements
    assert {d.point for d in cmp.fastest_differs} == expected
    for d in cmp.fastest_differs:
        assert not (set(d.fastest["a"]) & set(d.fastest["b"]))
    # identical sweeps disagree nowhere
    same = compare_backends(GRAM_AATB, pts, {"x": res_a, "y": res_a})
    assert same.fastest_differs == [] and same.anomaly_differs == []


def test_compare_backends_needs_two():
    res = sweep(GRAM_AATB, [(8, 8, 8)], runner=_CliffRunner())
    with pytest.raises(ValueError, match="two"):
        compare_backends(GRAM_AATB, [(8, 8, 8)], {"only": res})


def test_cli_compare_backends_smoke(tmp_path, capsys):
    args = ["--expr", "aatb", "--grid", "8,16",
            "--compare-backends", "numpy,jax", "--reps", "1", "--no-flush",
            "--atlas-dir", str(tmp_path), "--quiet"]
    assert sweep_main(args) == 0
    out = capsys.readouterr().out
    assert "fastest-differs=" in out and "numpy vs jax" in out
    # one atlas per backend, each under its own fingerprint
    assert list(tmp_path.glob("atlas-aatb-*numpy*.jsonl"))
    assert list(tmp_path.glob("atlas-aatb-*jax*.jsonl"))


def test_cli_compare_backends_rejects_bad_pairs(tmp_path, capsys):
    base = ["--expr", "aatb", "--grid", "8", "--atlas-dir", str(tmp_path)]
    assert sweep_main(base + ["--compare-backends", "blas"]) == 2
    assert sweep_main(base + ["--compare-backends", "blas,blas"]) == 2
    assert sweep_main(base + ["--compare-backends", "blas,nope"]) == 2
    # comparison is measured-only: an explicit predict request must error
    # loudly instead of silently running two full measured sweeps
    with pytest.raises(SystemExit):
        sweep_main(base + ["--compare-backends", "numpy,jax",
                           "--mode", "predict"])


def test_flops_planner_memo_survives_observations():
    """Profile-independent discriminants must not re-enumerate per
    observation: the generation key is pinned for them (review fix)."""
    from repro.core.planner import Planner
    from repro.core.expr import gram_times

    table = TableProfile(1e11)
    planner = Planner(discriminant="flops", profile=table, record=True)
    c = gram_times(24, 16, 8)
    plan1 = planner.plan(c)
    planner.observe(plan1, seconds=0.1)  # bumps table.generation
    assert planner.plan(c) is plan1  # flops ranking cannot change


def test_cli_backend_pallas_smoke(tmp_path, capsys):
    args = ["--expr", "aatb", "--grid", "8,16", "--backend", "pallas",
            "--reps", "1", "--atlas-dir", str(tmp_path), "--quiet"]
    assert sweep_main(args) == 0
    out = capsys.readouterr().out
    assert "measured=8" in out
    files = list(tmp_path.glob("atlas-aatb-*pallas*.jsonl"))
    assert len(files) == 1


# ------------------------------------------------------- calibrate / select --

def test_calibrate_accepts_registry_backends(tmp_path):
    from repro.core.calibrate import calibrate

    res = calibrate(backend="numpy", grid="small", reps=1, out=tmp_path,
                    save=True)
    assert res.fingerprint.backend == "numpy"
    assert res.fingerprint.dtype == "float64"
    assert res.path is not None and res.path.is_file()
    with pytest.raises(ValueError, match="unknown backend"):
        calibrate(backend="nope", grid="small")


def test_select_expression_measured_on_named_backend():
    from repro.core.selector import select_expression

    ranked = select_expression("aatb", (16, 8, 12),
                               discriminant="measured", backend="numpy")
    assert len(ranked) == 5
    with pytest.raises(ValueError, match="not both"):
        select_expression("aatb", (16, 8, 12), discriminant="measured",
                          backend="numpy", runner=_CliffRunner())


def test_planner_resolves_backend_via_registry():
    from repro.core.planner import Planner

    p = Planner(backend="numpy")
    assert isinstance(p.runner, NumpyBackend)
    from repro.core.expr import gram_times
    c = gram_times(24, 16, 8)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((24, 16))
    b = rng.standard_normal((24, 8))
    out = p(c, a, a, b)
    assert np.asarray(out).shape == (24, 8)


def test_planner_use_pallas_shim_warns():
    from repro.core.planner import Planner

    with pytest.warns(DeprecationWarning, match="use_pallas"):
        p = Planner(use_pallas=True)
    assert p.backend == "pallas"
    assert isinstance(p.runner, JaxBackend) and p.runner.use_pallas
    with pytest.warns(DeprecationWarning):
        assert Planner(use_pallas=False).backend == "jax"


def test_recording_planner_files_under_its_backend_tag():
    from repro.core.planner import Planner

    p = Planner(backend="pallas", record=True)
    assert (p.profile_backend, p.profile_dtype) == ("pallas", "float32")
    q = Planner(backend="jax")  # read-only: consumes the BLAS calibration
    assert (q.profile_backend, q.profile_dtype) == ("blas", "float64")


def test_jaxrunner_alias_still_works():
    from repro.core.runners import BlasRunner, JaxRunner

    assert BlasRunner is BlasBackend
    r = JaxRunner(use_pallas=True, reps=2, dtype="float32")
    assert isinstance(r, JaxBackend) and r.use_pallas and r.reps == 2
    assert r.fingerprint_tags() == ("pallas", "float32")


def test_current_fingerprint_pallas_uses_device_kind():
    fp = current_fingerprint(backend="pallas", dtype="float32")
    assert fp.backend == "pallas"
    # on this CPU container the jax device kind is "cpu", not the host ISA
    import jax
    assert fp.device == jax.devices()[0].device_kind


# ----------------------------------------------- fifth-backend registration --

class _ScaledNumpyOps(KernelOps):
    def __init__(self, inner):
        self.inner = inner

    def transpose(self, a):
        return self.inner.transpose(a)

    def gemm(self, a, b):
        return self.inner.gemm(a, b)

    def syrk(self, a):
        return self.inner.syrk(a)

    def symm(self, s, b):
        return self.inner.symm(s, b)

    def symm_r(self, b, s):
        return self.inner.symm_r(b, s)

    def tri2full(self, t):
        return self.inner.tri2full(t)


def test_registering_a_fifth_backend_flows_through(monkeypatch, tmp_path):
    """The docs/architecture.md recipe: a new backend registered at
    runtime sweeps, calibrates and fingerprints with no further wiring."""
    monkeypatch.setattr(backends_base, "_REGISTRY",
                        dict(backends_base._REGISTRY))

    class EchoBackend(NumpyBackend):
        name = "echo"

        def ops(self):
            return _ScaledNumpyOps(super().ops())

    register_backend("echo", EchoBackend)
    assert "echo" in registered_backends()
    be = get_backend("echo", reps=1, flush_cache=False)
    assert be.fingerprint_tags() == ("echo", "float64")
    g = GridSpec.uniform((8, 16), GRAM_AATB.ndims)
    atlas = AnomalyAtlas(tmp_path / "echo.jsonl",
                         HardwareFingerprint("echo", "testdev", "float64"),
                         GRAM_AATB.name, 0.10)
    res = sweep(GRAM_AATB, g.points(), exec_backend="echo", reps=1,
                atlas=atlas)
    assert res.n_measured == g.n_points
