"""Autotuner tests: search space, roofline pruning, persistence, dispatch.

The load-bearing guarantee (ISSUE-9 acceptance): any candidate whose VMEM
estimate exceeds the hardware budget is rejected by the pre-filter and
*no timing is ever spent on it* — asserted with a spy backend that
records every config reaching the timer.
"""

import json

import numpy as np
import pytest

from repro.core.backends.jax_backend import PallasBackend, PallasOps
from repro.core.perfmodel import HardwareSpec, RooflineProfile
from repro.core.profile_store import (
    FingerprintMismatchError,
    HardwareFingerprint,
    SchemaVersionError,
)
from repro.core.tuning import (
    BLOCK_CHOICES,
    DEFAULT_CONFIGS,
    TUNABLE_KINDS,
    TunedEntry,
    TuningTable,
    candidate_configs,
    kernel_vmem_bytes,
    load_default_tuning_table,
    load_tuning_table,
    modeled_time,
    padded_dims,
    prune_candidates,
    save_tuning_table,
    tuning_path,
)
from repro.kernels.autotune import autotune_request, default_tune_requests

FP = HardwareFingerprint(backend="pallas", device="testdev", dtype="float32")


def small_vmem_profile(vmem_bytes: int) -> RooflineProfile:
    return RooflineProfile(HardwareSpec(
        name="test", peak_flops=1e12, hbm_bw=1e11, link_bw=1e9,
        vmem_bytes=vmem_bytes))


# -------------------------------------------------------- search space ---

def test_candidate_spaces_cover_the_block_cross_product():
    n = len(BLOCK_CHOICES)
    assert len(candidate_configs("gemm", (512, 512, 512))) == n ** 3
    assert len(candidate_configs("syrk", (512, 512))) == n ** 2
    assert len(candidate_configs("symm", (512, 512))) == n ** 2
    assert len(candidate_configs("chain_gemm", (512,) * 4)) == n ** 4
    assert len(candidate_configs("gemm_syrk", (512,) * 3)) == n ** 2


def test_tri2full_is_not_tunable():
    assert "tri2full" not in TUNABLE_KINDS
    with pytest.raises(ValueError, match="not tunable"):
        candidate_configs("tri2full", (256,))


def test_padded_dims_quantize_to_blocks():
    assert padded_dims("gemm", (129, 257, 100),
                       {"bm": 256, "bn": 128, "bk": 128}) == (256, 384, 128)
    assert padded_dims("syrk", (64, 64), {"bm": 128, "bk": 128}) == (128, 128)


# ------------------------------------------------- the VMEM guarantee ---

def test_vmem_over_budget_is_rejected_before_any_timing():
    """The acceptance-criterion test: over-budget candidates are pruned
    with reason "vmem" and provably never reach the timer."""
    budget = 600_000  # fits 128-edge gemm tiles (~460 KB); rejects larger
    profile = small_vmem_profile(budget)
    dims = (512, 512, 512)
    report = prune_candidates("gemm", dims, profile=profile, dtype_bytes=4)

    vmem_rejected = [r for r in report.rejected if r.reason == "vmem"]
    assert vmem_rejected, "expected over-budget candidates on this profile"
    for r in vmem_rejected:
        assert kernel_vmem_bytes("gemm", dims, r.config,
                                 dtype_bytes=4) > budget
    for cfg in report.survivors:
        assert kernel_vmem_bytes("gemm", dims, cfg, dtype_bytes=4) <= budget

    timed_configs = []

    class SpyBackend(PallasBackend):
        def make_operands(self, alg, leading=()):
            return {}

        def time_algorithm(self, alg, operands=None, reps=None):
            timed_configs.append(self._config_lookup("gemm", dims))
            return 1.0

    entry = autotune_request(SpyBackend(reps=1), "gemm", dims,
                             profile=profile)
    assert entry.timed == len(timed_configs)
    assert entry.pruned == len(report.rejected)
    for cfg in timed_configs:
        assert cfg is not None
        assert kernel_vmem_bytes("gemm", dims, cfg, dtype_bytes=4) <= budget


def test_fused_kind_vmem_estimates_delegate_to_kernel_estimators():
    from repro.kernels.chain_gemm import (
        chain_gemm_vmem_bytes,
        gemm_syrk_vmem_bytes,
    )
    cfg = dict(DEFAULT_CONFIGS["chain_gemm"])
    assert kernel_vmem_bytes("chain_gemm", (256, 256, 256, 256), cfg,
                             dtype_bytes=4) == chain_gemm_vmem_bytes(
        256, 256, 256, 256, bm=128, bn=128, dtype_bytes=4)
    cfg = dict(DEFAULT_CONFIGS["gemm_syrk"])
    assert kernel_vmem_bytes("gemm_syrk", (256, 256, 256), cfg,
                             dtype_bytes=4) == gemm_syrk_vmem_bytes(
        256, 256, 256, bm=128, dtype_bytes=4)


# ----------------------------------------------------- pruning policy ---

def test_padding_waste_blocks_are_rejected():
    report = prune_candidates("gemm", (64, 64, 64), dtype_bytes=4)
    padded = [r for r in report.rejected if r.reason == "padding"]
    assert padded  # 256/512 blocks on a 64-dim problem are pure padding
    for r in padded:
        assert any(v > 128 for v in r.config.values())
    for cfg in report.survivors:
        assert max(cfg.values()) <= 128


def test_survivors_are_ordered_cheapest_modeled_first():
    profile = RooflineProfile()
    report = prune_candidates("gemm", (1024, 1024, 1024), profile=profile,
                              dtype_bytes=4)
    assert report.modeled == sorted(report.modeled)
    for cfg, t in zip(report.survivors, report.modeled):
        assert modeled_time("gemm", (1024, 1024, 1024), cfg, profile,
                            dtype_bytes=4) == pytest.approx(t)


def test_default_config_always_survives():
    # Even with a survivor cap of 1, the default tiles must be timed so
    # the persisted winner is measured against the status quo.
    report = prune_candidates("gemm", (1024, 1024, 1024), dtype_bytes=4,
                              max_survivors=1)
    defaults = [c for c in report.survivors
                if all(c.get(k, 128) == 128 for k in ("bm", "bn", "bk"))]
    assert defaults, report.survivors


def test_bigger_tiles_model_less_traffic():
    # The arithmetic-intensity lever the pre-filter ranks by: doubling bn
    # halves A-panel re-streaming, so modeled time must not increase.
    from repro.core.tuning import traffic_elems
    dims = (2048, 2048, 2048)
    small = traffic_elems("gemm", dims, {"bm": 128, "bn": 128, "bk": 128})
    big = traffic_elems("gemm", dims, {"bm": 256, "bn": 256, "bk": 128})
    assert big < small


# -------------------------------------------------------- persistence ---

def test_tuning_table_round_trips(tmp_path):
    table = TuningTable()
    table.set("gemm", (256, 256, 256), TunedEntry(
        config={"bm": 256, "bn": 128, "bk": 128, "pipeline": 1},
        seconds=1e-4, default_seconds=2e-4, timed=5, pruned=22))
    table.set("chain_gemm", (128, 128, 128, 128), TunedEntry(
        config={"bm": 128, "bn": 128, "bk": 128, "bl": 128},
        seconds=3e-4, default_seconds=3e-4, timed=1, pruned=15))
    path = save_tuning_table(table, FP, directory=tmp_path,
                             meta={"grid": "test"})
    assert path == tuning_path(FP, tmp_path)
    loaded, fp = load_tuning_table(path, expected_fingerprint=FP)
    assert fp == FP
    assert len(loaded) == 2
    entry = loaded.entry("gemm", (256, 256, 256))
    assert entry.config == {"bm": 256, "bn": 128, "bk": 128, "pipeline": 1}
    assert entry.seconds == pytest.approx(1e-4)
    assert entry.default_seconds == pytest.approx(2e-4)
    assert (entry.timed, entry.pruned) == (5, 22)
    assert loaded.meta["grid"] == "test"


def test_tuning_table_rejects_wrong_fingerprint(tmp_path):
    path = save_tuning_table(TuningTable(), FP, directory=tmp_path)
    other = HardwareFingerprint(backend="pallas", device="elsewhere",
                                dtype="float32")
    with pytest.raises(FingerprintMismatchError):
        load_tuning_table(path, expected_fingerprint=other)


def test_tuning_table_rejects_wrong_schema(tmp_path):
    path = save_tuning_table(TuningTable(), FP, directory=tmp_path)
    doc = json.loads(path.read_text())
    doc["version"] = 99
    path.write_text(json.dumps(doc))
    with pytest.raises(SchemaVersionError):
        load_tuning_table(path)


def test_nearest_config_fallback_in_log_dim_space():
    table = TuningTable()
    table.set("gemm", (128, 128, 128), TunedEntry(
        config={"bm": 128, "bn": 128, "bk": 128}, seconds=1.0,
        default_seconds=1.0, timed=1, pruned=0))
    table.set("gemm", (2048, 2048, 2048), TunedEntry(
        config={"bm": 512, "bn": 512, "bk": 128}, seconds=1.0,
        default_seconds=1.0, timed=1, pruned=0))
    # Near the big entry → borrows its tiles; near the small one → 128s.
    assert table.config("gemm", (1500, 1800, 2000))["bm"] == 512
    assert table.config("gemm", (150, 100, 128))["bm"] == 128
    # Unknown kind/arity → None (kernel defaults apply).
    assert table.config("syrk", (256, 256)) is None


def test_kill_switch_disables_auto_load(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path))
    from repro.core.profile_store import current_fingerprint
    fp = current_fingerprint(backend="pallas", dtype="float32")
    table = TuningTable()
    table.set("gemm", (128, 128, 128), TunedEntry(
        config={"bm": 128, "bn": 128, "bk": 128}, seconds=1.0,
        default_seconds=1.0, timed=1, pruned=0))
    save_tuning_table(table, fp)
    assert load_default_tuning_table() is not None
    monkeypatch.setenv("REPRO_NO_TUNING", "1")
    assert load_default_tuning_table() is None
    # And dispatch-time lookup goes dark too, even with a table pinned.
    backend = PallasBackend(reps=1)
    backend.set_tuning(table)
    assert backend._config_lookup("gemm", (128, 128, 128)) is None


def test_corrupt_table_degrades_to_none(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path))
    from repro.core.profile_store import current_fingerprint
    fp = current_fingerprint(backend="pallas", dtype="float32")
    tuning_path(fp).parent.mkdir(parents=True, exist_ok=True)
    tuning_path(fp).write_text("{not json")
    assert load_default_tuning_table() is None


# ------------------------------------------------- dispatch integration ---

def test_pallas_backend_auto_loads_saved_table(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path))
    from repro.core.profile_store import current_fingerprint
    fp = current_fingerprint(backend="pallas", dtype="float32")
    table = TuningTable()
    table.set("gemm", (256, 256, 256), TunedEntry(
        config={"bm": 256, "bn": 256, "bk": 128}, seconds=1.0,
        default_seconds=1.0, timed=1, pruned=0))
    save_tuning_table(table, fp)
    backend = PallasBackend(reps=1)
    loaded = backend.tuning_table()
    assert loaded is not None and len(loaded) == 1
    assert backend._config_lookup("gemm", (256, 256, 256)) == {
        "bm": 256, "bn": 256, "bk": 128}
    # The ops vocabulary sanitizes and applies the same config.
    assert backend.ops()._cfg("gemm", (256, 256, 256)) == {
        "bm": 256, "bn": 256, "bk": 128}


def test_pallas_ops_drops_unknown_config_keys():
    ops = PallasOps(lambda kind, dims: {"bm": 256, "evil": 7, "bq": 1})
    assert ops._cfg("gemm", (256, 256, 256)) == {"bm": 256}
    assert ops._cfg("syrk", (256, 256)) == {"bm": 256}


def test_tuning_override_wins_over_table_and_is_scoped():
    backend = PallasBackend(reps=1, tuning=None)
    dims = (256, 256, 256)
    assert backend._config_lookup("gemm", dims) is None
    with backend.tuning_override({("gemm", dims): {"bm": 256}}):
        assert backend._config_lookup("gemm", dims) == {"bm": 256}
        assert backend._config_lookup("gemm", (128, 128, 128)) is None
    assert backend._config_lookup("gemm", dims) is None


def test_tuned_config_changes_execution_and_stays_correct():
    # End-to-end: a tuned table entry reaches the kernel (observed via the
    # config lookup) and the tuned result still matches the oracle.
    table = TuningTable()
    table.set("gemm", (130, 150, 70), TunedEntry(
        config={"bm": 256, "bn": 256, "bk": 128}, seconds=1.0,
        default_seconds=1.0, timed=1, pruned=0))
    backend = PallasBackend(reps=1, tuning=table)
    from repro.core.backends.base import synthetic_algorithm
    from repro.core.flops import KernelCall
    alg = synthetic_algorithm(KernelCall("gemm", (130, 150, 70)))
    operands = backend.make_operands(alg)
    out = backend.execute(alg, operands)
    a, b = np.asarray(operands[0]), np.asarray(operands[1])
    np.testing.assert_allclose(np.asarray(out), a @ b, rtol=1e-4, atol=1e-3)


# ------------------------------------------------------- the autotuner ---

def test_autotune_request_picks_measured_winner_and_counts():
    dims = (256, 256, 256)
    fake_times = {}

    class SpyBackend(PallasBackend):
        def make_operands(self, alg, leading=()):
            return {}

        def time_algorithm(self, alg, operands=None, reps=None):
            cfg = self._config_lookup("gemm", dims) or {}
            key = tuple(sorted(cfg.items()))
            # Make one non-default config the clear winner.
            t = 1.0
            if cfg.get("bm") == 256 and not cfg.get("pipeline"):
                t = 0.25
            fake_times[key] = t
            return t

    entry = autotune_request(SpyBackend(reps=1), "gemm", dims,
                             profile=RooflineProfile())
    assert entry.config["bm"] == 256
    assert entry.seconds == pytest.approx(0.25)
    assert entry.default_seconds == pytest.approx(1.0)
    assert entry.timed == len(fake_times)
    assert entry.seconds <= entry.default_seconds


def test_autotune_probes_gemm_pipeline_on_winner_tile():
    dims = (256, 256, 256)
    seen_pipeline = []

    class SpyBackend(PallasBackend):
        def make_operands(self, alg, leading=()):
            return {}

        def time_algorithm(self, alg, operands=None, reps=None):
            cfg = self._config_lookup("gemm", dims) or {}
            if cfg.get("pipeline"):
                seen_pipeline.append(dict(cfg))
                return 0.01   # the pipelined probe wins
            return 1.0

    entry = autotune_request(SpyBackend(reps=1), "gemm", dims,
                             profile=RooflineProfile())
    assert len(seen_pipeline) == 1
    assert entry.config["pipeline"] == 1


def test_default_tune_requests_dedup_and_fused_diagonal():
    from repro.core.calibrate import grid_calls
    calls = grid_calls((64, 128))
    requests = default_tune_requests(calls, fused_dims=(64, 128))
    kinds = {k for k, _ in requests}
    assert kinds == {"gemm", "syrk", "symm", "chain_gemm", "gemm_syrk"}
    assert ("tri2full", (64,)) not in requests
    assert ("chain_gemm", (64, 64, 64, 64)) in requests
    assert ("gemm_syrk", (128, 128, 128)) in requests
    assert len(requests) == len(set(requests))


def test_autotune_real_backend_tiny_request():
    # One real (interpret-mode) tuning request end to end: winner config
    # is timed, measured no slower than the measured default, and valid.
    backend = PallasBackend(reps=1, tuning=None)
    entry = autotune_request(backend, "gemm", (64, 64, 64), budget=2)
    assert entry.seconds > 0
    assert entry.seconds <= entry.default_seconds
    assert set(entry.config) <= {"bm", "bn", "bk", "pipeline"}


def test_calibrate_tune_cli_persists_and_backend_autoloads(tmp_path,
                                                          monkeypatch):
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path))
    from repro.core.calibrate import tune
    res = tune(backend="pallas", grid="tiny", reps=1, budget=1)
    assert res.path is not None and res.path.is_file()
    assert res.n_requests == len(res.table.entries)
    backend = PallasBackend(reps=1)
    loaded = backend.tuning_table()
    assert loaded is not None
    assert len(loaded) == res.n_requests
    assert loaded.config("gemm", (64, 64, 64)) is not None


def test_tune_rejects_untunable_backend():
    from repro.core.calibrate import tune
    with pytest.raises(ValueError, match="tunable"):
        tune(backend="jax", grid="tiny")
