"""Test-session plumbing.

* Registers the deterministic fallback shim for `hypothesis` when the real
  library is not installed (it is an extra: ``pip install -e .[test]``),
  so the suite collects and runs everywhere the core deps exist.
* Isolates the profile cache: tests must never read a developer's real
  calibration (or write into it), so the cache is pointed at a per-session
  temp dir and the process-wide planner is reset around the session.
"""

import importlib.util
import os
import pathlib
import sys
import tempfile
import types


def _register_hypothesis_fallback() -> None:
    if importlib.util.find_spec("hypothesis") is not None:
        return  # real hypothesis available; use it
    shim_path = pathlib.Path(__file__).parent / "_hypothesis_fallback.py"
    spec = importlib.util.spec_from_file_location(
        "_hypothesis_fallback", shim_path)
    shim = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(shim)

    mod = types.ModuleType("hypothesis")
    mod.given = shim.given
    mod.settings = shim.settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = shim.integers
    strategies.sampled_from = shim.sampled_from
    strategies.SearchStrategy = shim.SearchStrategy
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


_register_hypothesis_fallback()

# Point the profile cache and the anomaly atlas away from the developer's
# real ones for the whole session (individual tests override with their own
# tmp dirs as needed). Unconditional: a pre-existing REPRO_PROFILE_DIR /
# REPRO_ATLAS_DIR would otherwise leak the machine's real calibration (or
# swept ground truth) into what the tests observe.
os.environ["REPRO_PROFILE_DIR"] = tempfile.mkdtemp(
    prefix="repro-test-profiles-")
os.environ["REPRO_ATLAS_DIR"] = tempfile.mkdtemp(
    prefix="repro-test-atlas-")
