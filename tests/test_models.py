"""Per-architecture smoke tests (reduced configs): forward, grad, decode
consistency, SSD dual equivalence, MoE routing invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, get, get_smoke
from repro.models import api

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=64, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s), dtype=np.int32))
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (b, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(rng.standard_normal(
            (b, cfg.vision_tokens, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    params, axes = api.init(KEY, cfg)
    batch = make_batch(cfg)
    logits, aux = api.forward_train(params, cfg, batch)
    s_expect = 64 + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_expect, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, metrics = api.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    assert 1.0 < float(loss) < 20.0      # ~ln(vocab) at init


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_gradients_finite(arch):
    cfg = get_smoke(arch)
    params, _ = api.init(KEY, cfg)
    batch = make_batch(cfg)
    g = jax.grad(lambda p: api.loss_fn(p, cfg, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                         for x in jax.tree.leaves(g)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_steps(arch):
    cfg = get_smoke(arch)
    params, _ = api.init(KEY, cfg)
    b, max_s = 2, 64
    bi = {}
    if cfg.family == "encdec":
        bi["frames"] = jnp.asarray(np.random.default_rng(0).standard_normal(
            (b, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
    caches = api.init_caches(params, cfg, b, max_s, batch_inputs=bi)
    tok = jnp.ones((b, 1), jnp.int32)
    for _ in range(3):
        logits, caches = api.decode_step(params, cfg, tok, caches)
        assert logits.shape == (b, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["glm4_9b", "gemma2_9b", "mamba2_370m"])
def test_decode_matches_teacher_forced_forward(arch):
    """Token-by-token decode logits == full forward logits (same prefix).

    Decode attention computes at activation precision (fp32 here); the
    only difference from the teacher-forced forward is that k/v pass
    through bf16 KV-cache *storage* (~0.4 % relative rounding per
    element). The 2e-2 tolerance covers that storage quantization after
    it compounds through the layer stack and the LM head — with an fp32
    cache the two paths agree to ~1e-6.
    """
    cfg = get_smoke(arch)
    params, _ = api.init(KEY, cfg)
    b, s = 1, 8
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s), dtype=np.int32))
    batch = {"tokens": tokens, "labels": tokens}
    full_logits, _ = api.forward_train(params, cfg, batch)

    caches = api.init_caches(params, cfg, b, 32)
    outs = []
    for i in range(s):
        step_logits, caches = api.decode_step(
            params, cfg, tokens[:, i:i + 1], caches)
        outs.append(step_logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


# ------------------------------------------------------------------ SSD ---

def test_ssd_quadratic_equals_chunked():
    from repro.models.ssm import ssd_chunked, ssd_quadratic
    rng = np.random.default_rng(0)
    B, S, H, P, G, N = 2, 128, 4, 16, 2, 8
    x = jnp.asarray(rng.standard_normal((B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)).astype(np.float32))
    a_log = jnp.asarray(np.log(rng.uniform(1, 8, (H,))).astype(np.float32))
    bm = jnp.asarray(rng.standard_normal((B, S, G, N)).astype(np.float32))
    cm = jnp.asarray(rng.standard_normal((B, S, G, N)).astype(np.float32))
    yq = ssd_quadratic(x, dt, a_log, bm, cm)
    yc = ssd_chunked(x, dt, a_log, bm, cm, chunk=32)
    np.testing.assert_allclose(np.asarray(yq), np.asarray(yc),
                               rtol=1e-3, atol=1e-3)


def test_ssd_chunked_state_handoff_matches_monolithic():
    """Prefill in two halves with state handoff == one full pass (the
    prefill→decode contract)."""
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(1)
    B, S, H, P, G, N = 1, 128, 2, 8, 1, 4
    x = jnp.asarray(rng.standard_normal((B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (B, S, H)).astype(np.float32))
    a_log = jnp.asarray(np.log(rng.uniform(1, 4, (H,))).astype(np.float32))
    bm = jnp.asarray(rng.standard_normal((B, S, G, N)).astype(np.float32))
    cm = jnp.asarray(rng.standard_normal((B, S, G, N)).astype(np.float32))
    y_full, st_full = ssd_chunked(x, dt, a_log, bm, cm, chunk=32,
                                  return_state=True)
    h = S // 2
    y1, st1 = ssd_chunked(x[:, :h], dt[:, :h], a_log, bm[:, :h], cm[:, :h],
                          chunk=32, return_state=True)
    y2, st2 = ssd_chunked(x[:, h:], dt[:, h:], a_log, bm[:, h:], cm[:, h:],
                          chunk=32, h0=st1, return_state=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-3, atol=1e-3)


def test_ssd_mode_selection_crossover():
    """The LAMP discriminant picks quadratic for short sequences and
    chunked for long — the crossover the paper's technique automates."""
    from repro.models.ssm import select_ssd_mode
    short = select_ssd_mode(64, 128, 64, 64, discriminant="flops")
    long_ = select_ssd_mode(8192, 128, 64, 128, discriminant="flops")
    assert short == "quadratic"
    assert long_ == "chunked"
    # perfmodel discriminant may flip near the boundary, never at extremes
    assert select_ssd_mode(65536, 128, 64, 128,
                           discriminant="perfmodel") == "chunked"


def test_ssm_decode_matches_prefill_state():
    """apply_prefill state == sequential apply_decode states."""
    from repro.models import ssm as ssm_lib
    from repro.models.ssm import SSMConfig
    cfg = SSMConfig(d_model=32, d_inner=64, n_heads=2, head_dim=32,
                    n_groups=1, d_state=8, conv_kernel=4, chunk=16)
    params, _ = ssm_lib.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, S = 1, 32
    u = jnp.asarray(rng.standard_normal((B, S, 32)).astype(np.float32))
    cache0 = ssm_lib.init_cache(cfg, B, dtype=jnp.float32)
    out_pre, cache_pre = ssm_lib.apply_prefill(params, cfg, u, cache0)
    cache = ssm_lib.init_cache(cfg, B, dtype=jnp.float32)
    outs = []
    for i in range(S):
        o, cache = ssm_lib.apply_decode(params, cfg, u[:, i:i + 1], cache)
        outs.append(o)
    out_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(out_pre),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(cache.state),
                               np.asarray(cache_pre.state),
                               rtol=5e-3, atol=5e-3)


# ------------------------------------------------------------------ MoE ---

def test_moe_combine_weights_sum_to_one_under_capacity():
    from repro.models import moe as moe_lib
    from repro.models.moe import MoEConfig
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=2,
                    capacity_factor=4.0)  # ample capacity: nothing dropped
    params, _ = moe_lib.init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (1, 8, 16)).astype(np.float32))
    out, aux = moe_lib.apply(params, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) > 0


def test_moe_capacity_drops_tokens_gracefully():
    from repro.models import moe as moe_lib
    from repro.models.moe import MoEConfig
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=2, top_k=1,
                    capacity_factor=0.25)   # most tokens dropped
    params, _ = moe_lib.init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (1, 16, 16)).astype(np.float32))
    out, _ = moe_lib.apply(params, cfg, x)
    assert bool(jnp.isfinite(out).all())


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_moe_permutation_equivariance(seed):
    """Token order must not change each token's output (property test)."""
    from repro.models import moe as moe_lib
    from repro.models.moe import MoEConfig
    cfg = MoEConfig(d_model=8, d_ff=16, n_experts=2, top_k=2,
                    capacity_factor=8.0)
    params, _ = moe_lib.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, 6, 8)).astype(np.float32)
    perm = rng.permutation(6)
    out1, _ = moe_lib.apply(params, cfg, jnp.asarray(x))
    out2, _ = moe_lib.apply(params, cfg, jnp.asarray(x[:, perm]))
    np.testing.assert_allclose(np.asarray(out1)[:, perm],
                               np.asarray(out2), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------- window pattern ---

def test_gemma2_window_pattern_cycles():
    cfg = get("gemma2_9b")
    w = np.asarray(cfg.layer_windows())
    assert len(w) == 42
    assert list(w[:4]) == [4096, 0, 4096, 0]


def test_full_configs_match_assignment():
    """Exact published hyperparameters (the assignment table)."""
    c = get("gemma2_9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (42, 3584, 16, 8, 14336, 256000)
    c = get("glm4_9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (40, 4096, 32, 2, 13696, 151552)
    c = get("phi3_mini")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 3072, 32, 32, 8192, 32064)
    c = get("yi_9b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (48, 4096, 32, 4, 11008, 64000)
    c = get("internvl2_76b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (80, 8192, 64, 8, 28672, 128256)
    c = get("arctic_480b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.moe.n_experts, c.moe.top_k, c.vocab) == (
        35, 7168, 56, 8, 128, 2, 32000)
    c = get("olmoe_1b_7b")
    assert (c.n_layers, c.d_model, c.moe.n_experts, c.moe.top_k,
            c.vocab) == (16, 2048, 64, 8, 50304)
    c = get("mamba2_370m")
    assert (c.n_layers, c.d_model, c.ssm.d_state, c.vocab) == (
        48, 1024, 128, 50280)
    c = get("zamba2_1p2b")
    assert (c.n_layers, c.d_model, c.ssm.d_state, c.vocab) == (
        38, 2048, 64, 32000)
    c = get("whisper_tiny")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == (
        4, 384, 6, 1536, 51865)
