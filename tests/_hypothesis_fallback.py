"""Deterministic mini-implementation of the `hypothesis` API this suite uses.

The container may lack `hypothesis` (it is a test extra: install via
``pip install -e .[test]``). Rather than losing three test modules to
collection errors, ``conftest.py`` registers this shim in ``sys.modules``
when the real library is absent. It covers exactly the surface the tests
use — ``@settings(max_examples=…, deadline=…)``, ``@given(**strategies)``,
``strategies.integers`` and ``strategies.sampled_from`` — and draws
examples from a fixed-seed PRNG, so the fallback is deterministic (no
shrinking, no database, no edge-case bias: strictly weaker than real
hypothesis, strictly better than not running the tests).
"""

from __future__ import annotations

import functools
import random
from typing import Any, Callable, Dict

_DEFAULT_MAX_EXAMPLES = 100
_SEED = 0x5EED_CAFE


class SearchStrategy:
    """A draw rule: PRNG -> example value."""

    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example_at(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements) -> SearchStrategy:
    seq = list(elements)
    return SearchStrategy(lambda rng: seq[rng.randrange(len(seq))])


def given(**strategies: SearchStrategy):
    """Run the test once per drawn example (order-stable across runs)."""

    def decorate(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            names = sorted(strategies)
            for i in range(n):
                rng = random.Random((_SEED, i))
                drawn: Dict[str, Any] = {
                    name: strategies[name].example_at(rng) for name in names
                }
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (shim, draw {i}): {drawn}"
                    ) from e
        # pytest must see the zero-arg wrapper signature, not the wrapped
        # test's (else drawn params look like missing fixtures).
        del runner.__wrapped__
        runner._hypothesis_shim = True
        return runner

    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES,
             deadline=None, **_ignored):
    """Accepts (and mostly ignores) real-hypothesis knobs."""

    def decorate(fn: Callable) -> Callable:
        fn._max_examples = max_examples
        return fn

    return decorate
