"""Adaptive boundary-refinement sweeps against planted-mask ground truth
(ISSUE 7).

The oracles are *known by construction* (:mod:`repro.core.synthetic`): a
mask function decides which grid points are anomalous, the dense grid
evaluated through the mask is ground truth, and the property tests pin the
engine's contract against it — ≥ 0.9 frontier recall at ≤ 40 % of the
dense measurement count, refinement candidates always on-grid and never
already measured, kill/resume convergence to the same measured set, and
shard-merge(k parts) ≡ the unsharded run point-for-point.
"""

import importlib.util
import json
import pathlib
import sys

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.adaptive import (
    adaptive_sweep,
    boundary_cells,
    refinement_candidates,
    seed_points,
)
from repro.core.anomaly import cluster_regions
from repro.core.expressions import GridSpec
from repro.core.profile_store import HardwareFingerprint
from repro.core.sweep import (
    AnomalyAtlas,
    AtlasError,
    atlas_shard_path,
    main as sweep_main,
)
from repro.core.synthetic import (
    BlobMask,
    EmptyMask,
    MaskRunner,
    PlantedSpec,
    dense_oracle,
    frontier_recall,
    planted_masks,
    true_frontier,
)

REPO = pathlib.Path(__file__).resolve().parent.parent
FP = HardwareFingerprint(backend="blas", device="testdev", dtype="float64")
SPEC = PlantedSpec()

# 20x20 uniform grid: large enough that regions dominate the frontier and
# a 40 % budget is a real constraint, small enough for a fast suite.
GRID = GridSpec.uniform(tuple(range(10, 210, 10)), 2, name="planted20")
BUDGET = int(0.40 * GRID.n_points)  # the ISSUE's headline budget: 160


def _merge_mod():
    if "atlas_merge" in sys.modules:
        return sys.modules["atlas_merge"]
    spec = importlib.util.spec_from_file_location(
        "atlas_merge", REPO / "tools" / "atlas_merge.py")
    mod = importlib.util.module_from_spec(spec)
    # registered so dataclasses can resolve the module's PEP 563 string
    # annotations when building MergeReport
    sys.modules["atlas_merge"] = mod
    spec.loader.exec_module(mod)
    return mod


class KillingRunner:
    """MaskRunner that dies after ``fail_after`` timings (kill mid-round)."""

    def __init__(self, mask, fail_after):
        self.inner = MaskRunner(mask)
        self.fail_after = fail_after
        self.count = 0

    def make_operands(self, alg):
        return {}

    def time_algorithm(self, alg, operands=None):
        self.count += 1
        if self.count > self.fail_after:
            raise RuntimeError("simulated kill")
        return self.inner.time_algorithm(alg, operands)


# ------------------------------------------------------------ seed lattice --

@settings(max_examples=40, deadline=None)
@given(stride=st.integers(min_value=1, max_value=9),
       nx=st.integers(min_value=2, max_value=12),
       ny=st.integers(min_value=2, max_value=12))
def test_seed_points_lattice_properties(stride, nx, ny):
    axes = (tuple(range(0, 7 * nx, 7)), tuple(range(100, 100 + 2 * ny, 2)))
    grid = GridSpec(name="g", axes=axes)
    pts = seed_points(grid, stride)
    assert len(set(pts)) == len(pts)            # no duplicates
    assert set(pts) <= set(grid.points())       # always on-grid
    for d in (0, 1):                            # endpoints bracket the grid
        vals = {p[d] for p in pts}
        assert axes[d][0] in vals and axes[d][-1] in vals
    if stride == 1:
        assert set(pts) == set(grid.points())
    assert pts == sorted(pts)                   # deterministic row-major


def test_seed_points_rejects_bad_stride():
    with pytest.raises(ValueError, match="stride"):
        seed_points(GRID, 0)


# --------------------------------------------------- the headline contract --

@pytest.mark.parametrize("name", sorted(planted_masks(GRID)))
def test_recall_at_forty_percent_of_dense_budget(name):
    """≥ 0.9 frontier recall at ≤ 40 % of the dense measurement count."""
    mask = planted_masks(GRID)[name]
    res = adaptive_sweep(SPEC, GRID, BUDGET, runner=MaskRunner(mask))
    # the budget is honoured: trajectory == measured set, ≤ 40 % of dense
    assert res.spent <= BUDGET
    assert len(res.known) == res.spent == res.n_measured
    assert sum(r.n_admitted for r in res.rounds) == res.spent
    assert res.stopped in ("converged", "budget")
    # every verdict agrees with the planted ground truth
    oracle = dense_oracle(mask, GRID)
    for p, v in res.verdicts().items():
        assert v == oracle[p], p
    recall = frontier_recall(res.known, true_frontier(mask, GRID))
    assert recall >= 0.9, (name, recall, res.spent)


def test_empty_and_full_masks_converge_at_the_seed():
    for name in ("empty", "full"):
        res = adaptive_sweep(SPEC, GRID, BUDGET,
                             runner=MaskRunner(planted_masks(GRID)[name]))
        assert res.stopped == "converged"
        assert res.n_refine_rounds == 0
        assert set(res.known) == set(seed_points(GRID, 4))
        assert res.frontier() == set()          # nothing to localize


# --------------------------------------------------- refinement candidates --

@settings(max_examples=25, deadline=None)
@given(cx=st.integers(min_value=10, max_value=200),
       cy=st.integers(min_value=10, max_value=200),
       r=st.integers(min_value=5, max_value=80),
       stride=st.integers(min_value=2, max_value=7))
def test_candidates_always_on_grid_and_never_measured(cx, cy, r, stride):
    """Refinement never proposes an off-grid or already-measured point,
    and the localized frontier it converges on is a subset of the true
    one (boundary cells straddle a real verdict flip by construction)."""
    mask = BlobMask(center=(cx, cy), radius=float(r))
    oracle = dense_oracle(mask, GRID)
    grid_pts = set(GRID.points())
    verdicts = {p: oracle[p] for p in seed_points(GRID, stride)}
    for _ in range(40):
        cands = refinement_candidates(verdicts, GRID)
        assert len(set(cands)) == len(cands)
        for c in cands:
            assert c in grid_pts, c
            assert c not in verdicts, c
        assert boundary_cells(verdicts, GRID) <= true_frontier(mask, GRID)
        if not cands:
            return
        for c in cands:
            verdicts[c] = oracle[c]
    pytest.fail("refinement did not converge in 40 rounds")


def test_candidates_reject_off_grid_verdicts():
    with pytest.raises(ValueError, match="off-grid"):
        refinement_candidates({(15, 10): True, (10, 10): False}, GRID)
    with pytest.raises(ValueError, match="dims"):
        refinement_candidates({(10, 10, 10): True}, GRID)


# --------------------------------------------------------- budget + rounds --

def test_budget_is_a_hard_cap_on_the_trajectory():
    mask = planted_masks(GRID)["stripe"]
    full = adaptive_sweep(SPEC, GRID, GRID.n_points,
                          runner=MaskRunner(mask))
    assert full.stopped == "converged"
    budget = full.spent - 5
    res = adaptive_sweep(SPEC, GRID, budget, runner=MaskRunner(mask))
    assert res.stopped == "budget"
    assert res.spent == budget == len(res.known)
    # deterministic trajectory: the capped run is a prefix of the full one
    assert set(res.known) <= set(full.known)


def test_rounds_zero_measures_only_the_seed():
    res = adaptive_sweep(SPEC, GRID, GRID.n_points, rounds=0,
                         runner=MaskRunner(planted_masks(GRID)["blob"]))
    assert res.stopped == "rounds"
    assert res.n_refine_rounds == 0
    assert set(res.known) == set(seed_points(GRID, 4))


def test_adaptive_validation_errors(tmp_path):
    r = MaskRunner(EmptyMask())
    with pytest.raises(ValueError, match="budget"):
        adaptive_sweep(SPEC, GRID, 0, runner=r)
    with pytest.raises(ValueError, match="rounds"):
        adaptive_sweep(SPEC, GRID, 5, rounds=-1, runner=r)
    with pytest.raises(ValueError, match="stride"):
        adaptive_sweep(SPEC, GRID, 5, seed_stride=0, runner=r)
    with pytest.raises(ValueError, match="grid has 3 axes"):
        adaptive_sweep(SPEC, GridSpec.uniform((10, 20), 3), 5, runner=r)
    with pytest.raises(ValueError, match="0 <= k < n"):
        adaptive_sweep(SPEC, GRID, 5, shard=(2, 2), runner=r)
    with pytest.raises(ValueError, match="shard mode needs"):
        adaptive_sweep(SPEC, GRID, 5, shard=(0, 2), runner=r)
    canonical = AnomalyAtlas(tmp_path / "c.jsonl", FP, SPEC.name, 0.10)
    with pytest.raises(ValueError, match="shard"):
        adaptive_sweep(SPEC, GRID, 5, shard=(0, 2), atlas=canonical,
                       runner=r)


# ------------------------------------------------------------- kill/resume --

@pytest.mark.parametrize("fail_after", (7, 91, 200))
def test_kill_resume_converges_to_the_same_measured_set(tmp_path,
                                                        fail_after):
    """A sweep killed mid-round, restarted with the same arguments,
    converges to exactly the measured set of an uninterrupted run."""
    mask = planted_masks(GRID)["blob"]
    ref = adaptive_sweep(
        SPEC, GRID, BUDGET, runner=MaskRunner(mask),
        atlas=AnomalyAtlas(tmp_path / "ref.jsonl", FP, SPEC.name, 0.10))

    path = tmp_path / "killed.jsonl"
    atlas = AnomalyAtlas(path, FP, SPEC.name, 0.10, chunk_size=4)
    with pytest.raises(RuntimeError, match="simulated kill"):
        adaptive_sweep(SPEC, GRID, BUDGET, atlas=atlas,
                       runner=KillingRunner(mask, fail_after))
    survivors = {r.point
                 for r in AnomalyAtlas(path, FP, SPEC.name, 0.10).records()}
    assert survivors < set(ref.known)           # partial, but a subset

    resumed_atlas = AnomalyAtlas(path, FP, SPEC.name, 0.10)
    res = adaptive_sweep(SPEC, GRID, BUDGET, atlas=resumed_atlas,
                         runner=MaskRunner(mask))
    assert set(res.known) == set(ref.known)     # same measured set
    assert res.verdicts() == ref.verdicts()
    assert res.spent == ref.spent               # same trajectory accounting
    assert res.stopped == ref.stopped
    # replayed points cost trajectory budget but zero new measurements
    assert res.n_measured == len(ref.known) - len(survivors)


def test_resumed_adaptive_run_honors_remaining_budget(tmp_path):
    """ISSUE 7 satellite: a resumed adaptive run spends only what remains
    of the budget — replayed rounds are admitted to the trajectory (so
    the cap still binds globally) but cost zero new measurements."""
    mask = planted_masks(GRID)["blob"]
    ref = adaptive_sweep(SPEC, GRID, BUDGET, runner=MaskRunner(mask))

    path = tmp_path / "resume.jsonl"
    first = adaptive_sweep(
        SPEC, GRID, BUDGET, rounds=1, runner=MaskRunner(mask),
        atlas=AnomalyAtlas(path, FP, SPEC.name, 0.10))
    assert first.stopped == "rounds"
    assert 0 < first.spent < ref.spent

    resumed = adaptive_sweep(
        SPEC, GRID, BUDGET, runner=MaskRunner(mask),
        atlas=AnomalyAtlas(path, FP, SPEC.name, 0.10))
    assert resumed.spent == ref.spent <= BUDGET
    assert resumed.n_measured == ref.spent - first.spent
    assert first.n_measured + resumed.n_measured == ref.n_measured
    assert set(resumed.known) == set(ref.known)
    assert resumed.verdicts() == ref.verdicts()


# ----------------------------------------------------------- shard fan-out --

def _lockstep(tmp_path, mask, n_hosts, budget=None):
    """Re-invoke every host until none is awaiting siblings (the
    documented ops recipe for the CLI's exit-3 state)."""
    budget = BUDGET if budget is None else budget
    paths = [atlas_shard_path(SPEC.name, FP, 0.10, k, tmp_path)
             for k in range(n_hosts)]
    last = None
    for _ in range(40):
        done = True
        for k in range(n_hosts):
            atlas = AnomalyAtlas(paths[k], FP, SPEC.name, 0.10,
                                 shard=(k, n_hosts))
            last = adaptive_sweep(SPEC, GRID, budget, atlas=atlas,
                                  shard=(k, n_hosts),
                                  runner=MaskRunner(mask))
            if last.stopped == "awaiting-siblings":
                done = False
        if done:
            return paths, last
    pytest.fail(f"{n_hosts}-way shard lockstep did not converge")


@pytest.mark.parametrize("n_hosts", (2, 3))
def test_shard_merge_equals_unsharded_point_for_point(tmp_path, n_hosts):
    mask = planted_masks(GRID)["multi"]
    ref = adaptive_sweep(
        SPEC, GRID, BUDGET, runner=MaskRunner(mask),
        atlas=AnomalyAtlas(tmp_path / "ref.jsonl", FP, SPEC.name, 0.10))
    paths, last = _lockstep(tmp_path, mask, n_hosts)
    assert last.stopped == ref.stopped

    # shard files partition the trajectory: disjoint, union == unsharded
    per_shard = [
        {r.point for r in AnomalyAtlas(p, FP, SPEC.name, 0.10,
                                       shard=(k, n_hosts)).records()}
        for k, p in enumerate(paths)]
    union = set().union(*per_shard)
    assert sum(len(s) for s in per_shard) == len(union)
    assert union == set(ref.known)

    merge = _merge_mod()
    out = tmp_path / "merged.jsonl"
    report = merge.merge_shards(paths, out)
    assert report.n_records == len(ref.known)
    assert report.n_duplicates == report.n_conflicts == 0
    # the merged file is a canonical atlas, identical to the unsharded one
    merged = {r.point: (r.cls, r.times, r.flops)
              for r in AnomalyAtlas(out, FP, SPEC.name, 0.10).records()}
    unsharded = {
        r.point: (r.cls, r.times, r.flops)
        for r in AnomalyAtlas(tmp_path / "ref.jsonl", FP, SPEC.name,
                              0.10).records()}
    assert merged == unsharded


def test_shard_atlas_never_mixes_with_canonical(tmp_path):
    mask = planted_masks(GRID)["blob"]
    spath = atlas_shard_path(SPEC.name, FP, 0.10, 0, tmp_path)
    adaptive_sweep(SPEC, GRID, 40, shard=(0, 2), runner=MaskRunner(mask),
                   atlas=AnomalyAtlas(spath, FP, SPEC.name, 0.10,
                                      shard=(0, 2)))
    # a shard file must not silently resume as the canonical atlas...
    with pytest.raises(AtlasError, match="atlas_merge"):
        AnomalyAtlas(spath, FP, SPEC.name, 0.10)
    # ...nor as a different shard of the fan-out
    with pytest.raises(AtlasError, match="shard"):
        AnomalyAtlas(spath, FP, SPEC.name, 0.10, shard=(1, 2))
    # and a canonical atlas cannot be reopened as a shard
    cpath = tmp_path / "canonical.jsonl"
    adaptive_sweep(SPEC, GRID, 40, runner=MaskRunner(mask),
                   atlas=AnomalyAtlas(cpath, FP, SPEC.name, 0.10))
    with pytest.raises(AtlasError, match="shard"):
        AnomalyAtlas(cpath, FP, SPEC.name, 0.10, shard=(0, 2))


# ------------------------------------------------------------- atlas merge --

def test_merge_rejects_mismatched_headers(tmp_path):
    mask = planted_masks(GRID)["blob"]
    a = atlas_shard_path(SPEC.name, FP, 0.10, 0, tmp_path)
    adaptive_sweep(SPEC, GRID, 40, shard=(0, 2), runner=MaskRunner(mask),
                   atlas=AnomalyAtlas(a, FP, SPEC.name, 0.10,
                                      shard=(0, 2)),
                   threshold=0.10)
    b = atlas_shard_path(SPEC.name, FP, 0.05, 1, tmp_path)
    adaptive_sweep(SPEC, GRID, 40, shard=(1, 2), runner=MaskRunner(mask),
                   atlas=AnomalyAtlas(b, FP, SPEC.name, 0.05,
                                      shard=(1, 2)),
                   threshold=0.05)
    merge = _merge_mod()
    with pytest.raises(merge.MergeError, match="threshold"):
        merge.merge_shards([a, b], tmp_path / "out.jsonl")
    assert not (tmp_path / "out.jsonl").exists()
    with pytest.raises(merge.MergeError, match="no shard files"):
        merge.merge_shards([], tmp_path / "out.jsonl")


def test_merge_dedups_first_writer_wins_and_reports_conflicts(tmp_path):
    mask = planted_masks(GRID)["blob"]
    paths, _ = _lockstep(tmp_path, mask, 2, budget=60)
    # replay one of shard 0's records into shard 1 with a tampered payload
    lines0 = paths[0].read_text().splitlines()
    dup = json.loads(lines0[1])
    dup["times"] = {k: v + 1.0 for k, v in dup["times"].items()}
    with paths[1].open("a") as f:
        f.write(json.dumps(dup) + "\n")

    merge = _merge_mod()
    report = merge.merge_shards(paths, tmp_path / "merged.jsonl")
    assert report.n_duplicates == 1 and report.n_conflicts == 1
    assert "conflicting payloads" in report.summary()
    # first writer won: the merged record matches shard 0's original
    merged = {r.point: r
              for r in AnomalyAtlas(tmp_path / "merged.jsonl", FP,
                                    SPEC.name, 0.10).records()}
    point = tuple(dup["point"])
    kept = merged[point].times
    assert kept != dup["times"]


def test_merge_tolerates_torn_tails_but_not_torn_headers(tmp_path):
    mask = planted_masks(GRID)["stripe"]
    paths, _ = _lockstep(tmp_path, mask, 2, budget=60)
    n_before = sum(
        len(AnomalyAtlas(p, FP, SPEC.name, 0.10, shard=(k, 2)).records())
        for k, p in enumerate(paths))
    with paths[0].open("a") as f:
        f.write('{"point": [70, 7')        # host killed mid-write
    merge = _merge_mod()
    report = merge.merge_shards(paths, tmp_path / "merged.jsonl")
    assert report.n_torn == 1
    assert "torn tail lines tolerated: 1" in report.summary()
    assert report.n_records == n_before
    # a torn *header* is not tolerated: the configuration is unreadable
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"kind": "head')
    with pytest.raises(merge.MergeError, match="header"):
        merge.merge_shards([bad])
    not_atlas = tmp_path / "not_atlas.jsonl"
    not_atlas.write_text('{"kind": "other"}\n')
    with pytest.raises(merge.MergeError, match="not an atlas header"):
        merge.merge_shards([not_atlas])


def test_merge_cli_dry_run_and_write(tmp_path, capsys):
    mask = planted_masks(GRID)["blob"]
    paths, _ = _lockstep(tmp_path, mask, 2, budget=60)
    merge = _merge_mod()
    assert merge.main([str(p) for p in paths]) == 0   # dry run: no --out
    out = capsys.readouterr().out
    assert "merged 2 shard(s)" in out and "->" not in out.splitlines()[0]
    out_path = tmp_path / "merged.jsonl"
    assert merge.main([str(p) for p in paths] + ["-o", str(out_path)]) == 0
    assert out_path.is_file()
    # a shard swept under a different threshold aborts with exit 1
    clash = tmp_path / "clash.jsonl"
    head = json.loads(paths[0].read_text().splitlines()[0])
    head["threshold"] = 0.05
    clash.write_text(json.dumps(head) + "\n")
    assert merge.main([str(paths[0]), str(clash)]) == 1
    err = capsys.readouterr().err
    assert "atlas merge failed" in err and "threshold" in err


# -------------------------------------------------- region regressions ---

def test_cluster_regions_names_off_grid_point_and_axis():
    """ISSUE 7 satellite: a bare KeyError became a ValueError naming the
    offending point and axis (reachable from adaptive refinement)."""
    axes = [(10, 20), (10, 20)]
    with pytest.raises(ValueError,
                       match=r"point \(10, 15\) is off-grid: value 15 is "
                             r"not on axis 1"):
        cluster_regions({(10, 15): (0.1, 0.1)}, axes)
    with pytest.raises(ValueError, match=r"has 3 dims but the grid has 2"):
        cluster_regions({(10, 10, 10): (0.1, 0.1)}, axes)


def test_adaptive_regions_match_oracle_regions():
    """Regions clustered from the sparse adaptive point set agree with the
    dense oracle on count and bounding boxes for the multi-blob plant."""
    mask = planted_masks(GRID)["multi"]
    res = adaptive_sweep(SPEC, GRID, BUDGET, runner=MaskRunner(mask))
    dense = cluster_regions(
        {p: (0.5, 0.5) for p, v in dense_oracle(mask, GRID).items() if v},
        GRID.axes)
    got = res.regions()
    assert len(dense) == 2
    # sparse clustering can split a region (interior seed points are not
    # grid-adjacent to the frontier ring), but the two largest sparse
    # regions recover the oracle regions' bounding boxes exactly
    assert sorted((r.lo, r.hi) for r in got[:2]) == \
        sorted((r.lo, r.hi) for r in dense)


# -------------------------------------------------------------------- CLI --

def test_cli_adaptive_writes_replayable_atlas(tmp_path, capsys):
    args = ["--expr", "aatb", "--grid", "smoke", "--mode", "adaptive",
            "--budget", "6", "--reps", "1", "--no-flush",
            "--atlas-dir", str(tmp_path), "--quiet"]
    assert sweep_main(args) == 0
    out1 = capsys.readouterr().out
    assert "budget=6 spent=6 measured=6" in out1
    assert "stopped=budget" in out1
    assert len(list(tmp_path.glob("atlas-aatb-*.jsonl"))) == 1
    # re-run: the trajectory replays from the atlas, zero new measurements
    assert sweep_main(args) == 0
    out2 = capsys.readouterr().out
    assert "spent=6 measured=0" in out2


def test_cli_adaptive_flag_validation(tmp_path, capsys):
    base = ["--expr", "aatb", "--grid", "smoke",
            "--atlas-dir", str(tmp_path), "--quiet"]
    with pytest.raises(SystemExit):
        sweep_main(base + ["--mode", "adaptive"])          # no --budget
    with pytest.raises(SystemExit):
        sweep_main(base + ["--budget", "6"])               # not adaptive
    with pytest.raises(SystemExit):
        sweep_main(base + ["--shard", "0/2"])              # not adaptive
    with pytest.raises(SystemExit):
        sweep_main(base + ["--mode", "adaptive", "--budget", "6",
                           "--limit", "3"])                # wrong knob
    capsys.readouterr()
    # malformed K/N is a usage error (exit 2), not a crash
    assert sweep_main(base + ["--mode", "adaptive", "--budget", "6",
                              "--shard", "2/2"]) == 2
    assert "0 <= K < N" in capsys.readouterr().err


def test_cli_sharded_adaptive_lockstep_and_merge(tmp_path, capsys):
    base = ["--expr", "aatb", "--grid", "smoke", "--mode", "adaptive",
            "--budget", "8", "--reps", "1", "--no-flush",
            "--atlas-dir", str(tmp_path), "--quiet"]
    codes = set()
    for _ in range(10):
        rcs = [sweep_main(base + ["--shard", f"{k}/2"]) for k in (0, 1)]
        codes.update(rcs)
        if rcs == [0, 0]:
            break
    else:
        pytest.fail("CLI shard lockstep did not converge")
    assert 3 in codes             # somebody had to wait for a sibling
    capsys.readouterr()

    shards = sorted(tmp_path.glob("atlas-aatb-*-shard*.jsonl"))
    assert len(shards) == 2
    merge = _merge_mod()
    out = tmp_path / "merged.jsonl"
    report = merge.merge_shards(shards, out)
    assert report.n_records == 8 and report.n_duplicates == 0
    # the merged file is a canonical atlas: header without a shard
    # identity, then one sorted record line per measured point
    lines = out.read_text().splitlines()
    head = json.loads(lines[0])
    assert head["kind"] == "header" and "shard" not in head
    pts = [tuple(json.loads(li)["point"]) for li in lines[1:]]
    assert len(pts) == 8 and pts == sorted(pts)
