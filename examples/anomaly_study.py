"""Mini reproduction of the paper end-to-end: Experiments 1→2→3 on AAᵀB.

Random-searches for anomalies with real BLAS, traverses one region, then
predicts anomalies from isolated kernel benchmarks and prints the
confusion matrix — the complete §3.4 pipeline, scaled to a few minutes.

Everything measured here persists: classified instances stream into the
anomaly atlas (see ``python -m repro.core.sweep``) and kernel timings are
reused from — and persisted back to — the machine's calibrated profile
cache (``python -m repro.core.calibrate``), so repeat runs resume from
disk instead of re-measuring.

Run:  PYTHONPATH=src python examples/anomaly_study.py
"""

from repro.core import (
    GRAM_AATB,
    AnomalyAtlas,
    BlasRunner,
    current_fingerprint,
    experiment1_random_search,
    experiment2_regions,
    experiment3_predict_from_benchmarks,
    load_default_profile,
    save_profile,
)


def main():
    runner = BlasRunner(reps=3)
    fp = current_fingerprint()

    print("Experiment 1: random search for anomalies (box [20, 500]³)...")
    with AnomalyAtlas.open(GRAM_AATB.name, fp, threshold=0.10) as atlas:
        if len(atlas):
            print(f"  (atlas resumes from {len(atlas)} instances at "
                  f"{atlas.path})")
        e1 = experiment1_random_search(
            GRAM_AATB, runner, box=(20, 500), n_anomalies=6,
            max_samples=150, threshold=0.10, seed=2, verbose=True,
            atlas=atlas)
    print(f"  abundance ≈ {e1.abundance:.1%} "
          f"({len(e1.anomalies)}/{e1.samples} samples)")
    if not e1.anomalies:
        print("  no anomalies in this tiny budget — rerun with a larger "
              "max_samples")
        return

    print("\nExperiment 2: region traversal around the first anomaly...")
    with AnomalyAtlas.open(GRAM_AATB.name, fp, threshold=0.05) as atlas2:
        e2 = experiment2_regions(GRAM_AATB, runner, e1.anomalies[:2],
                                 box=(20, 500), threshold=0.05,
                                 atlas=atlas2)
    for scan in e2.scans[:6]:
        print(f"  seed={scan.origin} dim=d{scan.dim}: region "
              f"[{scan.lo}, {scan.hi}] thickness={scan.thickness}")

    print("\nExperiment 3: predict anomalies from kernel benchmarks...")
    cached = load_default_profile()
    n_cached = len(cached.table) if cached is not None else 0
    if n_cached:
        print(f"  (seeding from {n_cached} persisted kernel timings)")
    e3 = experiment3_predict_from_benchmarks(
        GRAM_AATB, runner, e2.classified, threshold=0.05, profile=cached)
    path = save_profile(e3.profile, fp,
                        meta={"source": "examples/anomaly_study"})
    print(f"  (kernel calls: {e3.n_calls_reused} reused, "
          f"{e3.n_calls_measured} newly measured; profile now "
          f"{len(e3.profile.table)} entries -> {path})")
    print(e3.confusion.as_table())
    print("\npaper's qualitative claim — anomalies are largely "
          "predictable from per-kernel profiles — "
          f"{'CONFIRMED' if e3.confusion.recall > 0.5 else 'NOT confirmed'}"
          f" here (recall {e3.confusion.recall:.0%}).")
    print("\nNext: map whole regions with the sharded grid sweep —\n"
          "  PYTHONPATH=src python -m repro.core.sweep --expr aatb "
          "--grid small --shards 4")


if __name__ == "__main__":
    main()
