"""Mini reproduction of the paper end-to-end: Experiments 1→2→3 on AAᵀB.

Random-searches for anomalies with real BLAS, traverses one region, then
predicts anomalies from isolated kernel benchmarks and prints the
confusion matrix — the complete §3.4 pipeline, scaled to a few minutes.

Kernel timings measured here are reused from — and persisted back to — the
machine's calibrated profile cache (see ``python -m repro.core.calibrate``),
so repeat runs skip already-benchmarked shapes.

Run:  PYTHONPATH=src python examples/anomaly_study.py
"""

from repro.core import (
    GRAM_AATB,
    BlasRunner,
    current_fingerprint,
    experiment1_random_search,
    experiment2_regions,
    experiment3_predict_from_benchmarks,
    load_default_profile,
    save_profile,
)


def main():
    runner = BlasRunner(reps=3)

    print("Experiment 1: random search for anomalies (box [20, 500]³)...")
    e1 = experiment1_random_search(
        GRAM_AATB, runner, box=(20, 500), n_anomalies=6, max_samples=150,
        threshold=0.10, seed=2, verbose=True)
    print(f"  abundance ≈ {e1.abundance:.1%} "
          f"({len(e1.anomalies)}/{e1.samples} samples)")
    if not e1.anomalies:
        print("  no anomalies in this tiny budget — rerun with a larger "
              "max_samples")
        return

    print("\nExperiment 2: region traversal around the first anomaly...")
    e2 = experiment2_regions(GRAM_AATB, runner, e1.anomalies[:2],
                             box=(20, 500), threshold=0.05)
    for scan in e2.scans[:6]:
        print(f"  seed={scan.origin} dim=d{scan.dim}: region "
              f"[{scan.lo}, {scan.hi}] thickness={scan.thickness}")

    print("\nExperiment 3: predict anomalies from kernel benchmarks...")
    cached = load_default_profile()
    n_cached = len(cached.table) if cached is not None else 0
    if n_cached:
        print(f"  (seeding from {n_cached} persisted kernel timings)")
    e3 = experiment3_predict_from_benchmarks(
        GRAM_AATB, runner, e2.classified, threshold=0.05, profile=cached)
    path = save_profile(e3.profile, current_fingerprint(),
                        meta={"source": "examples/anomaly_study"})
    print(f"  (profile now {len(e3.profile.table)} entries -> {path})")
    print(e3.confusion.as_table())
    print("\npaper's qualitative claim — anomalies are largely "
          "predictable from per-kernel profiles — "
          f"{'CONFIRMED' if e3.confusion.recall > 0.5 else 'NOT confirmed'}"
          f" here (recall {e3.confusion.recall:.0%}).")


if __name__ == "__main__":
    main()
