"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

A mamba2-family model sized to ~100M params (the paper's planner picks the
SSD form per shape; Muon orthogonalizes the 2-D weights via the planned
AAᵀB chains). Checkpoints + crash-resume supervisor included — kill the
process mid-run and rerun to watch it resume.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse

import jax

from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh, set_mesh
from repro.models.ssm import SSMConfig
from repro.models.transformer import ModelConfig
from repro.runtime.supervisor import RestartPolicy, Supervisor
from repro.train import loop as train_loop


def model_100m() -> ModelConfig:
    """~100M params: 12 layers, d=768 mamba2 (SSD planner active)."""
    return ModelConfig(
        name="mamba2-100m", family="ssm", n_layers=12, d_model=768,
        vocab=50280, tied_embeddings=True,
        ssm=SSMConfig(d_model=768, d_inner=1536, n_heads=24, head_dim=64,
                      n_groups=1, d_state=64, conv_kernel=4, chunk=64,
                      ssd_mode="auto", discriminant="perfmodel"),
        max_seq=2048,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--optimizer", default="muon",
                    choices=("adamw", "muon"))
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = model_100m()
    n_params = sum(
        x.size for x in jax.tree.leaves(
            __import__("repro.models.api", fromlist=["api"]).init(
                jax.random.PRNGKey(0), cfg)[0]))
    print(f"model: {cfg.name} — {n_params/1e6:.1f}M params, "
          f"optimizer={args.optimizer}")

    src = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=0)
    mesh = make_host_mesh()

    def run(attempt):
        with set_mesh(mesh):
            return train_loop.train(
                cfg, src, args.steps, ckpt_dir=args.ckpt, save_every=50,
                optimizer=args.optimizer, peak_lr=3e-4, warmup=20,
                log_every=10, mesh=mesh)

    state = Supervisor(RestartPolicy(max_restarts=3)).run(run)
    print(f"finished at step {int(jax.device_get(state.step))}")


if __name__ == "__main__":
    main()
