"""Batched serving example: prefill + decode with KV caches.

Loads a smoke-scale yi-9b-family model (random weights — the serving path
is the product), runs batched greedy generation, and prints tokens/s. The
1-token decode GEMMs are the skinny-matmul regime where kernel efficiency
(not FLOPs) dominates — the paper's thesis at serving time.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import api
from repro.serve.decode import ServeState, make_serve_step


def main():
    cfg = get_smoke("yi_9b")
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    batch, max_s, new_tokens = 8, 128, 48

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, 4)),
                          jnp.int32)

    caches = api.init_caches(params, cfg, batch, max_s)
    step = jax.jit(make_serve_step(cfg, temperature=0.0))
    state = ServeState(caches=caches, last_tokens=prompts[:, :1],
                       rng=jax.random.PRNGKey(1))

    # prefill (teacher-forced through the decode path — exact for all
    # families including SSM)
    for i in range(prompts.shape[1] - 1):
        state, _ = step(state, params)
        state = state._replace(last_tokens=prompts[:, i + 1:i + 2])

    # timed decode
    state, tok = step(state, params)   # compile + first token
    outs = [tok]
    t0 = time.perf_counter()
    for _ in range(new_tokens - 1):
        state, tok = step(state, params)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(outs, axis=1)
    tps = batch * (new_tokens - 1) / dt
    print(f"generated {gen.shape} tokens for batch={batch}")
    print(f"decode throughput: {tps:.1f} tokens/s "
          f"({dt/(new_tokens-1)*1e3:.1f} ms/step)")
    print("sample:", np.asarray(gen[0][:16]))


if __name__ == "__main__":
    main()
