"""Quickstart: the LAMP planner in 60 seconds.

Builds the paper's two expressions, enumerates their algorithms, shows the
FLOP counts, selects with both discriminants, executes the plan in JAX,
and measures a real instance with BLAS to look for an anomaly.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (
    BlasRunner,
    GRAM_AATB,
    enumerate_algorithms,
    gram_times,
    matrix_chain,
    measure_instance,
    plan,
)


def main():
    # --- 1. the paper's matrix chain ABCD -----------------------------
    chain = matrix_chain(331, 279, 338, 854, 427)   # a paper anomaly seed
    algos = enumerate_algorithms(chain)
    print(f"ABCD instance (331,279,338,854,427): {len(algos)} algorithms")
    for a in sorted(algos, key=lambda a: a.flops):
        print(f"  {a.name:24s} {a.flops/1e6:10.1f} MFLOPs")

    # --- 2. the paper's AAᵀB expression --------------------------------
    g = gram_times(300, 700, 200)
    for a in enumerate_algorithms(g):
        print(f"  {a.name:28s} {a.flops/1e6:10.1f} MFLOPs  "
              f"[{' → '.join(c.kind for c in a.calls)}]")

    # --- 3. plan + execute via the runtime planner ---------------------
    p_flops = plan(g, discriminant="flops")       # paper baseline
    p_model = plan(g, discriminant="perfmodel")   # paper's conclusion
    print(f"flops discriminant chose:     {p_flops.algorithm.name}")
    print(f"perfmodel discriminant chose: {p_model.algorithm.name}")

    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((300, 700)).astype(np.float32))
    B = jnp.asarray(rng.standard_normal((300, 200)).astype(np.float32))
    out = p_model.fn(A, A, B)
    ref = A @ A.T @ B
    print(f"plan output max err vs direct: "
          f"{float(jnp.abs(out - ref).max()):.2e}")

    # --- 4. measure one real instance with BLAS (the paper's method) ---
    runner = BlasRunner(reps=3)
    inst = measure_instance(GRAM_AATB, (128, 512, 96), runner,
                            threshold=0.10)
    print(f"measured instance {inst.point}: "
          f"anomaly={inst.cls.is_anomaly} "
          f"time_score={inst.cls.time_score:.1%} "
          f"flop_score={inst.cls.flop_score:.1%}")
    print(f"  cheapest: {inst.cls.cheapest}")
    print(f"  fastest:  {inst.cls.fastest}")

    # --- 5. the expression zoo ----------------------------------------
    # Every registered family selects/sweeps through the same machinery;
    # (AB)(AB)ᵀ enumerates the intermediate-Gram GEMM+SYRK algorithm
    # that leaf-level inspection cannot see.
    from repro.core import registered_names, select_expression
    print(f"registered families: {', '.join(registered_names())}")
    ranked = select_expression("abab", (256, 64, 512),
                               discriminant="perfmodel")
    print(f"abab(256,64,512) perfmodel pick: {ranked[0].name} "
          f"({ranked[0].flops/1e6:.1f} MFLOPs of "
          f"{len(ranked)} candidates)")


if __name__ == "__main__":
    main()
