"""§Roofline table renderer: reads the dry-run JSON artifacts and prints
the per-(arch × shape × mesh) three-term roofline with bottleneck,
MODEL_FLOPS/HLO ratio and roofline fraction.

The dry-run itself (launch/dryrun.py) is the expensive producer; this
reader keeps benchmarks/run.py cheap and reproducible.
"""

from __future__ import annotations

import json
import os

from .common import emit, note

CANDIDATES = ("dryrun_single_pod.json", "dryrun_multi_pod.json")


def render(path: str) -> None:
    with open(path) as f:
        rows = json.load(f)
    note(f"\n== roofline table from {os.path.basename(path)} ==")
    note(f"{'arch':<18} {'shape':<12} {'mesh':<8} {'tc_ms':>9} "
         f"{'tm_ms':>10} {'tl_ms':>10} {'bound':>10} {'GiB/dev':>8} "
         f"{'useful%':>8} {'roof%':>7}")
    for r in rows:
        if r.get("skipped"):
            note(f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<8} "
                 f"{'(skipped: ' + r['reason'][:40] + '...)'}")
            continue
        if r.get("error"):
            note(f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<8} "
                 f"ERROR {r['error'][:60]}")
            continue
        gib = r["bytes_per_device"]["peak_est"] / 2 ** 30
        if r.get("proof_only") or "t_compute" not in r:
            note(f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<8} "
                 f"{'compile-proof':>31} {'ok':>10} {gib:>8.2f}")
            emit(f"dryrun_{r['arch']}_{r['shape']}_{r['mesh']}",
                 float(r.get("compile_s", 0.0)) * 1e6,
                 f"proof_only;gib={gib:.2f}")
            continue
        note(f"{r['arch']:<18} {r['shape']:<12} {r['mesh']:<8} "
             f"{r['t_compute']*1e3:>9.2f} {r['t_memory']*1e3:>10.2f} "
             f"{r['t_collective']*1e3:>10.2f} {r['bottleneck']:>10} "
             f"{gib:>8.2f} {r['model_flops_ratio']*100:>7.1f}% "
             f"{r['roofline_fraction']*100:>6.2f}%")
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
             f"bottleneck={r['bottleneck']};"
             f"roof={r['roofline_fraction']:.4f};"
             f"useful={r['model_flops_ratio']:.4f};gib={gib:.2f}")


def main() -> None:
    found = False
    for cand in CANDIDATES:
        for base in (".", os.path.dirname(os.path.dirname(__file__))):
            path = os.path.join(base, cand)
            if os.path.exists(path):
                render(path)
                found = True
                break
    if not found:
        note("no dryrun*.json found — run "
             "`python -m repro.launch.dryrun --all --out "
             "dryrun_single_pod.json` first")
        emit("roofline_missing", 0.0, "run_dryrun_first")


if __name__ == "__main__":
    main()
