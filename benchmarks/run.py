"""Benchmark entry point: one section per paper table/figure + the
framework's own planner/SSD/Muon selection benches + the roofline reader.

Prints ``name,us_per_call,derived`` CSV rows to stdout (human-readable
tables go to stderr). REPRO_BENCH_SCALE=full runs paper-scale sizes.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (
        analysis_bench,
        calibrate_bench,
        discriminant_bench,
        experiment1,
        experiment2,
        experiment3,
        kernel_profiles,
        muon_bench,
        pallas_bench,
        planner_bench,
        roofline,
        serve_bench,
        ssd_bench,
        sweep_bench,
        zoo_bench,
    )

    sections = [
        ("kernel_profiles (paper Fig 1)", kernel_profiles.main),
        ("calibration subsystem", calibrate_bench.main),
        ("pallas autotuning (tile search + fusion)", pallas_bench.main),
        ("sweep engine (serial vs sharded)", sweep_bench.main),
        ("expression zoo (enumeration + abundance)", zoo_bench.main),
        ("static plan verifier (zoo lint + mutants)", analysis_bench.main),
        ("discriminant scoreboard (atlas replay)", discriminant_bench.main),
        ("experiment1 (paper §4.1.1/§4.2.1)", experiment1.main),
        ("experiment2 (paper §4.1.2/§4.2.2)", experiment2.main),
        ("experiment3 (paper Tables 1-2)", experiment3.main),
        ("planner discriminants (productized)", planner_bench.main),
        ("serving plan cache (loadtest)", serve_bench.main),
        ("ssd dual-form selection", ssd_bench.main),
        ("muon NS association selection", muon_bench.main),
        ("roofline (dry-run artifacts)", roofline.main),
    ]
    failures = 0
    for name, fn in sections:
        print(f"# --- {name} ---", file=sys.stderr)
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"bench_section_failed,{0.0},{name}")
    if failures:
        print(f"# {failures} section(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
