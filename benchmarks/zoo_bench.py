"""Expression-zoo benchmark: per-family enumeration cost + anomaly rates.

Two questions, per registered family:

1. **How expensive is the enumeration layer itself?** `enumerate_algorithms`
   runs on every planner miss and at every sweep point, so its wall time
   is engine overhead — measured here per family (us/call), along with
   the algorithm count and the deduplicated kernel-call count of a small
   grid (the quantity that bounds predicted-sweep cost).
2. **Is anomaly abundance expression-dependent?** A real-BLAS smoke sweep
   per family (shared persistent atlas: repeat runs resume) reports the
   measured anomaly rate — the paper's `ABCD`-rare vs `AAᵀB`-abundant
   contrast, extended across the zoo.

REPRO_BENCH_SCALE=full sweeps the `small` grid instead of `smoke`.
"""

from __future__ import annotations

from repro.core.expressions import REGISTRY
from repro.core.sweep import collect_unique_calls, sweep

from .common import FULL, emit, make_runner, note, open_atlas, time_call


def main():
    grid_name = "small" if FULL else "smoke"
    reps = 3 if FULL else 1
    note(f"\n== expression zoo: {len(REGISTRY)} families, "
         f"grid={grid_name} ==")
    note(f"{'expr':<7} {'algs':>5} {'ukernels':>8} {'enum us':>9} "
         f"{'anomaly rate':>13}")
    for cli_name in sorted(REGISTRY):
        spec = REGISTRY[cli_name]
        grid = spec.grid(grid_name)
        mid = grid.points()[len(grid.points()) // 2]
        n_algos = len(spec.algorithms(mid))
        enum_s = time_call(lambda: spec.algorithms(mid), reps=5)
        ucalls = len(collect_unique_calls(spec, grid.points()))
        # the configured backend: its timings must land in the atlas
        # open_atlas keys under that backend's fingerprint
        runner = make_runner(reps, flush_cache=False)
        with open_atlas(spec.name, 0.10) as atlas:
            res = sweep(spec, grid.points(), runner=runner, atlas=atlas)
        note(f"{cli_name:<7} {n_algos:>5} {ucalls:>8} "
             f"{enum_s * 1e6:>9.0f} {res.anomaly_rate:>12.1%}")
        emit(f"zoo_{cli_name}_enumerate", enum_s * 1e6,
             f"algorithms={n_algos};unique_kernels={ucalls};"
             f"anomaly_rate={res.anomaly_rate:.4f};"
             f"points={res.n_points};measured={res.n_measured}")


if __name__ == "__main__":
    main()
