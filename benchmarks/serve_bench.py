"""Serving plan-cache bench: selection latency, hit rate, coalescing.

Drives the :mod:`tools.loadtest` harness through a planner-backed
:class:`repro.serve.plan_cache.PlanService` and emits the serving rows of
the perf trajectory (``BENCH_6.json``):

    serve_select_hit_p50 / _p99   steady-state cache-hit selection (µs)
    serve_select_miss_p50 / _p99  cold enumeration+selection (µs)
    serve_cache_hit_rate          storm-phase hit rate (percent)
    serve_coalesce_effectiveness  duplicate enumerations avoided (percent)
    serve_throughput              lookups/s through the thread pool (rps)
    serve_refine_drain            per-timing drain cost of the async
                                  refinement worker (µs)

CI scale is a few thousand requests; REPRO_BENCH_SCALE=full raises the
storm an order of magnitude.
"""

from __future__ import annotations

import importlib.util
import pathlib
import sys

import numpy as np

from .common import FULL, emit, note

_TOOLS = pathlib.Path(__file__).resolve().parent.parent / "tools"


def _load_loadtest():
    spec = importlib.util.spec_from_file_location(
        "loadtest", _TOOLS / "loadtest.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules["loadtest"] = mod   # dataclasses resolve via sys.modules
    spec.loader.exec_module(mod)
    return mod


def _refine_drain_us(service_cls) -> float:
    """Enqueue timings against a table-backed planner, time the drain."""
    import time

    from repro.core.discriminants import as_hybrid
    from repro.core.perfmodel import TableProfile
    from repro.core.planner import Planner

    # Empty table wrapped in the hybrid: analytical estimates rank, the
    # table accumulates the refinements we are here to time.
    planner = Planner(discriminant="perfmodel", backend="numpy",
                      profile=as_hybrid(TableProfile(peak_flops=1e12)))
    svc = service_cls(planner=planner, refine=True, queue_maxlen=4096)
    dims = (4, 128, 512)
    x = np.ones((4, 128), np.float32)
    wu = np.ones((128, 512), np.float32)
    wd = np.ones((512, 128), np.float32)
    n = 512 if FULL else 128
    for _ in range(n):
        svc.execute("decmlp", dims, x, wu, wd)
    t0 = time.perf_counter()
    svc.shutdown(drain=True, timeout=60.0)
    drain = time.perf_counter() - t0
    processed = max(1, svc.worker.steps)
    return drain / processed * 1e6


def main() -> None:
    lt = _load_loadtest()
    from repro.serve.plan_cache import PlanService

    requests = 20000 if FULL else 3000
    threads = 8

    def make_service() -> PlanService:
        return PlanService(discriminant="perfmodel", backend="numpy")

    rep = lt.run_loadtest(make_service(), requests=requests,
                          threads=threads, make_service=make_service)
    note(f"storm: {rep.requests} lookups / {threads} threads in "
         f"{rep.wall_s:.3f}s ({rep.throughput_rps:,.0f} rps)")
    note(f"hit p50/p99 {rep.hit_p50_us:.1f}/{rep.hit_p99_us:.1f}us, "
         f"miss p50/p99 {rep.miss_p50_us:.1f}/{rep.miss_p99_us:.1f}us")

    emit("serve_select_hit_p50", rep.hit_p50_us,
         "steady-state cache-hit selection p50")
    emit("serve_select_hit_p99", rep.hit_p99_us,
         "steady-state cache-hit selection p99 (CI-gated)")
    emit("serve_select_miss_p50", rep.miss_p50_us,
         "cold enumeration+selection p50")
    emit("serve_select_miss_p99", rep.miss_p99_us,
         "cold enumeration+selection p99")
    emit("serve_cache_hit_rate", rep.hit_rate * 100.0,
         "unit=percent storm-phase hit rate")
    emit("serve_coalesce_effectiveness", rep.coalesce_effectiveness * 100.0,
         f"unit=percent burst enumerations={rep.burst_misses}")
    emit("serve_throughput", rep.throughput_rps,
         f"unit=rps {threads}-thread lookup storm")
    emit("serve_refine_drain", _refine_drain_us(PlanService),
         "async refinement drain per timing")


if __name__ == "__main__":
    main()
