"""Muon Newton–Schulz association selection — the paper's AAᵀB in the
optimizer. Times the three NS associations per weight shape on XLA-CPU
and reports each discriminant's pick vs the measured winner.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.muon import (
    _ns_iteration_gram,
    _ns_iteration_right,
    plan_ns_mode,
)

from .common import FULL, emit, note, time_call


SHAPES = [(256, 256), (128, 1024), (1024, 128), (512, 4096)]
if FULL:
    SHAPES += [(1024, 8192), (4096, 4096), (2048, 16384)]


def main():
    rng = np.random.default_rng(0)
    note("\n== Muon NS association selection (AAᵀB in the optimizer) ==")
    note(f"{'shape':>14} {'gram_ms':>9} {'right_ms':>9} {'faster':>8} "
         f"{'flops-pick':>11} {'model-pick':>11}")
    for (m, k) in SHAPES:
        if m > k:
            m, k = k, m  # muon transposes to m <= k
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        fg = jax.jit(lambda x: _ns_iteration_gram(x, use_symmetry=False))
        fr = jax.jit(_ns_iteration_right)
        tg = time_call(lambda: jax.block_until_ready(fg(x)))
        tr = time_call(lambda: jax.block_until_ready(fr(x)))
        faster = "gram" if tg < tr else "right"
        pf = plan_ns_mode(m, k, "flops")
        pm = plan_ns_mode(m, k, "perfmodel")
        note(f"{f'{m}x{k}':>14} {tg*1e3:>9.2f} {tr*1e3:>9.2f} "
             f"{faster:>8} {pf:>11} {pm:>11}")
        emit(f"muon_ns_{m}x{k}", min(tg, tr) * 1e6,
             f"faster={faster};flops_pick={pf};model_pick={pm}")


if __name__ == "__main__":
    main()
