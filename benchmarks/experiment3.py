"""Paper Experiment 3 (§3.4.3): predict anomalies from isolated kernel
benchmarks (the additive model) — confusion matrix vs measured truth.

Thin config over the sweep engine: ground-truth measurement shards via
REPRO_SWEEP_SHARDS and persists in the anomaly atlas; the isolated kernel
benchmarks are deduplicated (batched) and seeded from / persisted back to
the machine's calibration cache, so only never-seen (kind, dims) calls are
timed.

Paper results: ABCD recall 92 %/precision 96 %; AAᵀB recall 75 %/
precision 98.5 %. The qualitative claim under test: *most anomalies are
predictable from per-kernel profiles alone* — the basis for the
``perfmodel`` discriminant the framework ships.
"""

from __future__ import annotations

from repro.core import (
    GRAM_AATB,
    MATRIX_CHAIN_ABCD,
    current_fingerprint,
    experiment1_random_search,
    experiment2_regions,
    experiment3_predict_from_benchmarks,
    load_default_profile,
    save_profile,
)

from .common import FULL, emit, engine_kwargs, make_runner, note, open_atlas


def run_spec(spec, box, n_seeds, reps):
    runner = make_runner(reps)  # used by the serial probes below
    kwargs = engine_kwargs(reps)
    with open_atlas(spec.name, 0.10) as seed_atlas:
        seeds = experiment1_random_search(
            spec, None if kwargs else runner, box=box, n_anomalies=n_seeds,
            max_samples=2500 if FULL else 250, threshold=0.10, seed=11,
            atlas=seed_atlas, **kwargs)
    if not seeds.anomalies:
        note(f"Experiment 3 {spec.name}: no anomaly seeds in budget")
        emit(f"exp3_{spec.name}_recall", 0.0, "no_anomalies")
        return
    with open_atlas(spec.name, 0.05) as atlas:
        regions = experiment2_regions(spec, runner, seeds.anomalies,
                                      box=box, threshold=0.05, atlas=atlas)
    # Seed from the machine's persisted calibration (only unmeasured calls
    # are benchmarked, deduplicated across all instances), then persist the
    # enriched table back — under the configured backend's fingerprint, so
    # REPRO_EXEC_BACKEND=jax timings never pollute the BLAS calibration.
    backend, dtype = runner.fingerprint_tags()
    cached = load_default_profile(backend=backend, dtype=dtype)
    res = experiment3_predict_from_benchmarks(
        spec, runner, regions.classified, threshold=0.05, profile=cached)
    save_profile(res.profile, current_fingerprint(backend=backend,
                                                  dtype=dtype),
                 meta={"source": f"experiment3:{spec.name}"})
    note(f"\n== Experiment 3: {spec.name} ==")
    note(f"(kernel calls: {res.n_calls_reused} reused from the "
         f"calibration cache, {res.n_calls_measured} newly measured)")
    note(res.confusion.as_table())
    emit(f"exp3_{spec.name}_recall", res.confusion.recall * 100,
         f"precision={res.confusion.precision:.3f};"
         f"n={res.confusion.total}")


def main():
    box = (20, 1200) if FULL else (20, 600)
    run_spec(GRAM_AATB, box, 4 if not FULL else 25, reps=3 if not FULL
             else 10)
    if FULL:
        run_spec(MATRIX_CHAIN_ABCD, box, 10, reps=10)


if __name__ == "__main__":
    main()
