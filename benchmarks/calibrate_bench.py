"""Calibration subsystem benchmark: sweep cost, cache speedup, ranking drift.

Three questions a deployer asks before adopting calibrated profiles:

1. how long does a calibration sweep take (per grid)?
2. how much does the persistent cache save on subsequent startups?
3. where do the ``flops`` / ``perfmodel`` / ``hybrid`` discriminants
   disagree on real instances — i.e. what the calibration actually buys.
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

from repro.core import (
    GRAM_AATB,
    enumerate_algorithms,
    get_discriminant,
    load_profile,
    select,
)
from repro.core.calibrate import GRIDS, calibrate

from .common import FULL, emit, note


def main() -> None:
    grid = "default" if FULL else "small"
    tmp = Path(tempfile.mkdtemp(prefix="repro-calib-bench-"))

    # 1. sweep cost
    t0 = time.perf_counter()
    res = calibrate(backend="blas", grid=grid, reps=3 if not FULL else 10,
                    out=tmp)
    sweep_s = time.perf_counter() - t0
    note(f"\n== calibration sweep ({grid}: {len(GRIDS[grid])}-point grid, "
         f"{res.n_calls} kernel shapes) ==")
    note(f"sweep: {sweep_s:.2f}s  peak ≈ {res.profile.peak() / 1e9:.1f} "
         f"GFLOP/s  -> {res.path}")
    emit(f"calibrate_sweep_{grid}", sweep_s * 1e6,
         f"n_calls={res.n_calls}")

    # 2. cache load vs re-measurement
    t0 = time.perf_counter()
    cached, _ = load_profile(res.path)
    load_s = time.perf_counter() - t0
    note(f"cache load: {load_s * 1e3:.2f}ms "
         f"(speedup ×{sweep_s / max(load_s, 1e-9):.0f} vs re-measuring)")
    emit("calibrate_cache_load", load_s * 1e6,
         f"speedup_x={sweep_s / max(load_s, 1e-9):.0f}")

    # 3. nearest-neighbour lookup cost during ranking: off-grid queries
    # force the per-(kind, ndims) bucket index path on every call (the
    # pre-index linear scan walked the whole table per un-memoised call).
    queries = [("gemm", (m + 1, n + 3, k + 5))
               for m in GRIDS[grid] for n in GRIDS[grid]
               for k in GRIDS[grid]]
    from repro.core import gemm as gemm_call
    t0 = time.perf_counter()
    reps_nn = 20
    for _ in range(reps_nn):
        for _, dims in queries:
            cached.time(gemm_call(*dims))
    nn_us = (time.perf_counter() - t0) / (reps_nn * len(queries)) * 1e6
    note(f"nearest-neighbour query: {nn_us:.2f}us/call "
         f"({len(cached.table)} table entries, bucket index)")
    emit("calibrate_nearest_query", nn_us,
         f"entries={len(cached.table)};queries={len(queries)}")

    # 4. discriminant agreement on a spread of AAᵀB instances
    points = [(300, 200, 100), (600, 80, 400), (120, 500, 90),
              (256, 256, 256)]
    if FULL:
        points += [(900, 150, 700), (1000, 1000, 60)]
    note("\n== discriminant picks (AAᵀB) ==")
    note(f"{'instance':>18} {'flops':>24} {'perfmodel':>24} {'hybrid':>24}")
    disagreements = 0
    for pt in points:
        algos = enumerate_algorithms(GRAM_AATB.build(pt))
        picks = {}
        for disc in ("flops", "perfmodel", "hybrid"):
            # select() now rejects a profile handed to a policy that never
            # reads one; the capability flag says who gets the calibration.
            prof = cached if get_discriminant(disc).requires_profile \
                else None
            ranked = select(algos, discriminant=disc, profile=prof,
                            dtype_bytes=8)
            picks[disc] = ranked[0].name
        if len(set(picks.values())) > 1:
            disagreements += 1
        note(f"{str(pt):>18} {picks['flops']:>24} "
             f"{picks['perfmodel']:>24} {picks['hybrid']:>24}")
    emit("calibrate_disagreements", float(disagreements),
         f"instances={len(points)}")
    note(f"({disagreements}/{len(points)} instances where a calibrated "
         f"discriminant overrides the FLOP choice)")


if __name__ == "__main__":
    main()
