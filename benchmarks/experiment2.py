"""Paper Experiment 2 (§3.4.2): axis-aligned lines through anomalous
regions — region thickness distribution per dimension.

Thin config over the sweep engine: the seed search shards via
REPRO_SWEEP_SHARDS and both the seeds and every line probe persist in the
anomaly atlas, so a re-run traverses from cached classifications.

Seeds come from a short Experiment-1 search; each seed is traversed in
every dimension with step 10, hole tolerance 2, boundary = 3 consecutive
non-anomalies (the paper's protocol, threshold 5 %).
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    GRAM_AATB,
    MATRIX_CHAIN_ABCD,
    experiment1_random_search,
    experiment2_regions,
)

from .common import FULL, emit, engine_kwargs, make_runner, note, open_atlas


def run_spec(spec, box, n_seeds, reps):
    runner = make_runner(reps)  # used by the serial probes below
    kwargs = engine_kwargs(reps)
    with open_atlas(spec.name, 0.10) as seed_atlas:
        seeds = experiment1_random_search(
            spec, None if kwargs else runner, box=box, n_anomalies=n_seeds,
            max_samples=2500 if FULL else 250, threshold=0.10, seed=7,
            atlas=seed_atlas, **kwargs)
    if not seeds.anomalies:
        note(f"Experiment 2 {spec.name}: no anomalies found in budget; "
             "skipping region scan")
        emit(f"exp2_{spec.name}_thickness", 0.0, "no_anomalies")
        return None
    with open_atlas(spec.name, 0.05) as atlas:
        res = experiment2_regions(spec, runner, seeds.anomalies, box=box,
                                  threshold=0.05, atlas=atlas)
    note(f"\n== Experiment 2: {spec.name} "
         f"({len(seeds.anomalies)} seeds) ==")
    by_dim = {}
    for scan in res.scans:
        by_dim.setdefault(scan.dim, []).append(scan.thickness)
    for dim, ths in sorted(by_dim.items()):
        note(f"d{dim}: thickness median={np.median(ths):.0f} "
             f"max={max(ths)} min={min(ths)} (n={len(ths)})")
        emit(f"exp2_{spec.name}_d{dim}_thickness",
             float(np.median(ths)),
             f"max={max(ths)};min={min(ths)};n={len(ths)}")
    return res


def main():
    box = (20, 1200) if FULL else (20, 600)
    n = 5 if not FULL else 30
    run_spec(GRAM_AATB, box, n, reps=3 if not FULL else 10)
    if FULL:
        run_spec(MATRIX_CHAIN_ABCD, box, 10, reps=10)


if __name__ == "__main__":
    main()
