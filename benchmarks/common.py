"""Shared benchmark plumbing: CSV emission + scaled-down defaults.

Every benchmark prints ``name,us_per_call,derived`` rows (project
convention) plus human-readable tables to stderr. Paper-scale runs are
hours of BLAS time; defaults here are scaled to CI budgets and can be
raised with REPRO_BENCH_SCALE=full.
"""

from __future__ import annotations

import os
import sys
import time
from typing import Callable

FULL = os.environ.get("REPRO_BENCH_SCALE", "ci") == "full"


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


def note(msg: str) -> None:
    print(msg, file=sys.stderr)
    sys.stderr.flush()


def time_call(fn: Callable, reps: int = 5, warmup: int = 1) -> float:
    """Median wall seconds."""
    import numpy as np
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
