"""Shared benchmark plumbing: CSV emission + scaled-down defaults.

Every benchmark prints ``name,us_per_call,derived`` rows (project
convention) plus human-readable tables to stderr. Paper-scale runs are
hours of BLAS time; defaults here are scaled to CI budgets and can be
raised with REPRO_BENCH_SCALE=full.
"""

from __future__ import annotations

import functools
import os
import sys
import time
from typing import Callable

FULL = os.environ.get("REPRO_BENCH_SCALE", "ci") == "full"

#: Worker shards for the sweep engine (REPRO_SWEEP_SHARDS=4 fans the
#: experiment benchmarks out over a process pool; 1 = serial).
SHARDS = max(1, int(os.environ.get("REPRO_SWEEP_SHARDS", "1")))

#: Execution backend the experiment benchmarks measure on — any
#: repro.core.backends registry key (REPRO_EXEC_BACKEND=jax reruns the
#: paper pipeline on XLA; default is the paper's BLAS protocol). The
#: benchmarks are thin configs over this name.
EXEC_BACKEND = os.environ.get("REPRO_EXEC_BACKEND", "blas")


def make_runner(reps: int, **opts):
    """The configured execution backend, CLI-leniently constructed."""
    from repro.core.backends import make_backend
    return make_backend(EXEC_BACKEND, reps=reps, **opts)


def engine_kwargs(reps: int) -> dict:
    """Sweep-engine fan-out shared by every experiment benchmark."""
    if SHARDS > 1:
        from repro.core.backends import make_backend
        return {
            "backend": "process",
            "shards": SHARDS,
            "runner_factory": functools.partial(make_backend, EXEC_BACKEND,
                                                reps=reps),
        }
    return {}


def open_atlas(spec_name: str, threshold: float):
    """The persistent atlas the experiment benchmarks stream into.

    Uses the default atlas directory ($REPRO_ATLAS_DIR or the shared
    cache), keyed by the configured execution backend's fingerprint —
    repeat benchmark runs resume from it instead of re-measuring, and
    each backend's ground truth stays in its own atlas.
    """
    from repro.core import AnomalyAtlas
    from repro.core.backends import backend_default_dtype
    from repro.core.profile_store import current_fingerprint
    fp = current_fingerprint(backend=EXEC_BACKEND,
                             dtype=backend_default_dtype(EXEC_BACKEND))
    return AnomalyAtlas.open(spec_name, fp, threshold=threshold)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


def note(msg: str) -> None:
    print(msg, file=sys.stderr)
    sys.stderr.flush()


def time_call(fn: Callable, reps: int = 5, warmup: int = 1) -> float:
    """Median wall seconds."""
    import numpy as np
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
