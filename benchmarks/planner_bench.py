"""The productized claim: the ``perfmodel`` discriminant picks faster
algorithms than the paper-baseline ``flops`` discriminant.

For a random sample of AAᵀB instances (the anomaly-rich expression), we
measure every algorithm with real BLAS, then compare the wall time of the
algorithm each discriminant *would* have chosen (using a TableProfile
calibrated only from isolated kernel benchmarks — no end-to-end
measurement leaks into the selector). Reports total selected-time ratio
and per-instance regret vs the oracle.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    GRAM_AATB,
    BlasRunner,
    TableProfile,
    measure_instance,
    predict_algorithm_time,
)

from .common import FULL, emit, note


def main():
    rng = np.random.default_rng(5)
    n_inst = 40 if FULL else 10
    box = (20, 1200) if FULL else (40, 500)
    runner = BlasRunner(reps=5 if FULL else 3)
    profile = TableProfile(peak_flops=1e11)

    tot = {"flops": 0.0, "perfmodel": 0.0, "oracle": 0.0}
    regress = {"flops": 0, "perfmodel": 0}
    for _ in range(n_inst):
        point = tuple(int(x) for x in rng.integers(box[0], box[1], 3))
        inst = measure_instance(GRAM_AATB, point, runner, threshold=0.0)
        algos = GRAM_AATB.algorithms(point)
        # calibrate profile on isolated kernel calls only
        for a in algos:
            for call in a.calls:
                if call not in profile:
                    profile.record(call, runner.benchmark_call(call))
        by_flops = min(algos, key=lambda a: (a.flops, a.name))
        by_model = min(algos, key=lambda a: (
            predict_algorithm_time(a.calls, profile), a.name))
        t_oracle = min(inst.times.values())
        tot["flops"] += inst.times[by_flops.name]
        tot["perfmodel"] += inst.times[by_model.name]
        tot["oracle"] += t_oracle
        for k, alg in (("flops", by_flops), ("perfmodel", by_model)):
            if inst.times[alg.name] > 1.10 * t_oracle:
                regress[k] += 1

    note("\n== planner discriminant comparison (AAᵀB) ==")
    note(f"total selected time: flops={tot['flops']*1e3:.1f}ms "
         f"perfmodel={tot['perfmodel']*1e3:.1f}ms "
         f"oracle={tot['oracle']*1e3:.1f}ms")
    note(f">10% regret instances: flops={regress['flops']}/{n_inst} "
         f"perfmodel={regress['perfmodel']}/{n_inst}")
    speedup = tot["flops"] / tot["perfmodel"] if tot["perfmodel"] else 0
    emit("planner_flops_vs_perfmodel", tot["perfmodel"] / n_inst * 1e6,
         f"speedup={speedup:.3f};flops_regret={regress['flops']};"
         f"perfmodel_regret={regress['perfmodel']};n={n_inst}")


if __name__ == "__main__":
    main()
