"""Pallas autotuning benchmark: default-tile vs tuned vs fused.

The perf trajectory of ISSUE-9: for each benchmark shape, measure the
Pallas backend at (a) the hard-coded 128-edge default tiles, (b) the
config a fresh autotune pass picks for that shape, and (c) — for the
two fusable patterns — the fused launch vs the unfused two-kernel walk.
Rows report µs/call and achieved GFLOP/s.

The tuning-smoke CI gate reads the ``pallas_tuned_worst_ratio`` row:
tuned-or-fused must be ≥ default-tile on every row, within an
interpret-mode tolerance on CPU (interpret mode executes the kernel body
in Python, so tile-shape effects are noise there; on a real TPU the
ratio is the headline).

On this CPU container everything runs in interpret mode and stays tiny;
REPRO_BENCH_SCALE=full widens the shapes.
"""

from __future__ import annotations

import os

from .common import FULL, emit, note


def _gflops(flops: int, seconds: float) -> float:
    return flops / max(seconds, 1e-12) / 1e9


def main() -> None:
    from repro.core.backends import get_backend
    from repro.core.backends.base import synthetic_fused_algorithm
    from repro.core.flops import KernelCall
    from repro.core.tuning import padded_flops
    from repro.kernels.autotune import autotune_request

    backend = get_backend("pallas", reps=3 if FULL else 2, tuning=None)
    reps = 3 if FULL else 2

    base_shapes = [
        ("gemm", (256, 256, 256)),
        ("gemm", (384, 128, 256)),
        ("syrk", (256, 256)),
        ("symm", (256, 128)),
    ] if not FULL else [
        ("gemm", (1024, 1024, 1024)),
        ("gemm", (2048, 256, 1024)),
        ("syrk", (1024, 1024)),
        ("symm", (1024, 512)),
    ]
    fused_shapes = [
        ("chain_gemm", (256, 128, 128, 256)),
        ("gemm_syrk", (256, 128, 128)),
    ] if not FULL else [
        ("chain_gemm", (1024, 512, 512, 1024)),
        ("gemm_syrk", (1024, 512, 512)),
    ]

    note("\n== pallas autotuning (default tile vs tuned vs fused) ==")
    note(f"{'shape':>30} {'default':>12} {'best':>12} {'ratio':>7}  config")
    worst_ratio = float("inf")

    # (a)/(b): default vs tuned, measured by the autotuner itself — the
    # default config is always force-timed next to the survivors, so one
    # request yields both sides on shared operands.
    for kind, dims in base_shapes:
        entry = autotune_request(backend, kind, dims, reps=reps, budget=4)
        flops = KernelCall(kind, dims).flops
        ratio = entry.default_seconds / max(entry.seconds, 1e-12)
        worst_ratio = min(worst_ratio, ratio)
        label = f"pallas_{kind}_{'x'.join(map(str, dims))}"
        emit(f"{label}_default", entry.default_seconds * 1e6,
             f"gflops={_gflops(flops, entry.default_seconds):.2f}")
        emit(f"{label}_tuned", entry.seconds * 1e6,
             f"gflops={_gflops(flops, entry.seconds):.2f};"
             f"config={'/'.join(f'{k}={v}' for k, v in sorted(entry.config.items()))}")
        note(f"{kind + str(dims):>30} {entry.default_seconds * 1e6:>10.1f}us "
             f"{entry.seconds * 1e6:>10.1f}us {ratio:>6.2f}x  {entry.config}")

    # (c): fused launch vs the unfused two-kernel walk of the same DAG.
    for kind, dims in fused_shapes:
        alg = synthetic_fused_algorithm(kind, dims)
        operands = backend.make_operands(alg)
        os.environ["REPRO_NO_FUSION"] = "1"
        try:
            unfused_s = backend.time_algorithm(alg, operands, reps=reps)
        finally:
            del os.environ["REPRO_NO_FUSION"]
        fused_s = backend.time_algorithm(alg, operands, reps=reps)
        flops = padded_flops(kind, dims, {})
        ratio = unfused_s / max(fused_s, 1e-12)
        worst_ratio = min(worst_ratio, ratio)
        label = f"pallas_{kind}_{'x'.join(map(str, dims))}"
        emit(f"{label}_unfused", unfused_s * 1e6,
             f"gflops={_gflops(flops, unfused_s):.2f}")
        emit(f"{label}_fused", fused_s * 1e6,
             f"gflops={_gflops(flops, fused_s):.2f}")
        note(f"{kind + str(dims):>30} {unfused_s * 1e6:>10.1f}us "
             f"{fused_s * 1e6:>10.1f}us {ratio:>6.2f}x  (fused vs unfused)")

    # The CI gate row: min over rows of (default-or-unfused / tuned-or-
    # fused). ≥ 1.0 means the tuned/fused path never lost; interpret mode
    # on CPU tolerates a slack factor (tile effects are noise there).
    interpret = True
    try:
        import jax
        interpret = jax.default_backend() != "tpu"
    except Exception:
        pass
    emit("pallas_tuned_worst_ratio", float(worst_ratio),
         f"interpret={int(interpret)}")
    note(f"worst tuned-or-fused vs default ratio: {worst_ratio:.3f} "
         f"(interpret={interpret})")


if __name__ == "__main__":
    main()
