"""Benchmark harness — one module per paper table/figure + framework
selection benches + roofline reader. Entry: python -m benchmarks.run"""
