"""The discriminant scoreboard: replay atlas ground truth, score every
registered selection policy.

This is the paper's open question made into a perf-trajectory artifact:
*which discriminant is best, and by how much?* A small AAᵀB grid is swept
once into the persistent atlas (repeat runs resume, measuring nothing),
the deduplicated kernel calls feed a measured table profile, and every
policy in :mod:`repro.core.discriminants` is scored by replay — top-1
accuracy, mean time regret, and (where the policy predicts times) anomaly
recall — so `flops` vs `perfmodel` vs `rankk` quality is tracked across
PRs next to the latency rows.

Accuracy/regret rows carry ``unit=percent`` in their derived field;
``tools/bench_to_json.py`` lands them in ``BENCH_<n>.json`` tagged with
that unit.
"""

from __future__ import annotations

import time

from repro.core import GRAM_AATB, benchmark_unique_calls, registered_discriminants
from repro.core.evaluate import evaluate_discriminants
from repro.core.sweep import collect_unique_calls, sweep

from .common import FULL, emit, make_runner, note, open_atlas


def main() -> None:
    spec = GRAM_AATB
    grid = spec.grid("small" if FULL else "smoke")
    points = grid.points()
    reps = 3 if FULL else 1
    runner = make_runner(reps, flush_cache=FULL)

    with open_atlas(spec.name, 0.10) as atlas:
        res = sweep(spec, points, runner=runner, threshold=0.10,
                    atlas=atlas)
    note(f"\n== discriminant scoreboard ({spec.name}/{grid.name}: "
         f"{res.n_points} instances, {res.n_measured} newly measured, "
         f"{len(res.anomalies)} anomalies) ==")

    # Arm the profile-consuming policies with measured per-kernel times
    # (deduplicated; the calibration-cache feedback loop at bench scale).
    profile, n_meas, n_reused = benchmark_unique_calls(
        runner, collect_unique_calls(spec, points))
    note(f"profile: {n_meas} kernel calls measured, {n_reused} reused")

    t0 = time.perf_counter()
    ev = evaluate_discriminants(spec, res.records,
                                registered_discriminants(),
                                profile=profile, threshold=0.10)
    eval_s = time.perf_counter() - t0
    note(ev.summary())

    emit("disc_eval_replay", eval_s / max(1, ev.n_instances) * 1e6,
         f"instances={ev.n_instances};"
         f"discriminants={len(ev.scores)}")
    for name, score in ev.scores.items():
        derived = f"unit=percent;n={score.n_instances}"
        if score.recall is not None:
            derived += f";recall={score.recall:.3f}"
        emit(f"disc_eval_{name}_top1", score.top1_accuracy * 100, derived)
        emit(f"disc_eval_{name}_mean_regret", score.mean_regret * 100,
             f"unit=percent;p95={score.p95_regret * 100:.1f}")


if __name__ == "__main__":
    main()
