"""SSD dual-form selection: the paper's technique inside Mamba2.

Times the quadratic and chunked SSD algorithms (XLA-CPU, jitted) across
sequence lengths and reports which algorithm each discriminant selects vs
which was actually faster — the in-model analogue of the paper's anomaly
study.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.ssm import select_ssd_mode, ssd_chunked, ssd_quadratic

from .common import FULL, emit, note, time_call


def main():
    rng = np.random.default_rng(0)
    B, H, P, G, N = 1, 4, 64, 1, 64
    chunk = 64
    seqs = (64, 128, 256, 512, 1024) if not FULL else (
        64, 128, 256, 512, 1024, 2048, 4096)
    note("\n== SSD dual-form crossover (mamba2) ==")
    note(f"{'S':>6} {'quad_ms':>9} {'chunk_ms':>9} {'faster':>9} "
         f"{'flops-pick':>10} {'model-pick':>10}")
    for S in seqs:
        x = jnp.asarray(rng.standard_normal((B, S, H, P)).astype(
            np.float32))
        dt = jnp.asarray(rng.uniform(0.01, 0.1, (B, S, H)).astype(
            np.float32))
        a_log = jnp.asarray(np.log(rng.uniform(1, 8, (H,))).astype(
            np.float32))
        bm = jnp.asarray(rng.standard_normal((B, S, G, N)).astype(
            np.float32))
        cm = jnp.asarray(rng.standard_normal((B, S, G, N)).astype(
            np.float32))
        fq = jax.jit(lambda *a: ssd_quadratic(*a))
        fc = jax.jit(lambda *a: ssd_chunked(*a, chunk=chunk))
        tq = time_call(lambda: jax.block_until_ready(
            fq(x, dt, a_log, bm, cm)))
        tc = time_call(lambda: jax.block_until_ready(
            fc(x, dt, a_log, bm, cm)))
        faster = "quadratic" if tq < tc else "chunked"
        pick_f = select_ssd_mode(S, N, P, chunk, heads=H,
                                 discriminant="flops")
        pick_m = select_ssd_mode(S, N, P, chunk, heads=H,
                                 discriminant="perfmodel")
        note(f"{S:>6} {tq*1e3:>9.2f} {tc*1e3:>9.2f} {faster:>9} "
             f"{pick_f:>10} {pick_m:>10}")
        emit(f"ssd_S{S}", min(tq, tc) * 1e6,
             f"faster={faster};flops_pick={pick_f};model_pick={pick_m};"
             f"quad_ms={tq*1e3:.2f};chunk_ms={tc*1e3:.2f}")


if __name__ == "__main__":
    main()
