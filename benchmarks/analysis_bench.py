"""Static plan verifier benchmark: zoo-lint throughput + mutation gate.

Rows (``name,us_per_call,derived`` convention):

* ``analysis_verify_algorithm`` — µs to statically verify ONE algorithm
  (all passes: shapes, storage, liveness, FLOP recount, result check).
* ``analysis_verify_zoo`` — the full-zoo lint the ``analysis-smoke`` CI
  job gates on: every algorithm of every registered family across the
  smoke grid. Derived carries algorithms/s, instance and rule counts —
  the number that says "verification is cheap enough to leave on".
* ``analysis_mutation_suite`` — the 8-way mutation harness; derived
  carries caught/total (CI requires 8/8).
"""

from __future__ import annotations

from .common import emit, note, time_call


def main() -> None:
    from repro.core.analysis import (
        mutation_catch_rate,
        run_mutation_suite,
        verify_algorithm,
        verify_zoo,
    )
    from repro.core.expressions import get_spec

    spec = get_spec("aatb")
    point = (192, 128, 96)
    algos = spec.algorithms(point)

    def one_algorithm() -> None:
        for a in algos:
            if verify_algorithm(a):
                raise AssertionError("zoo algorithm failed verification")

    secs = time_call(one_algorithm, reps=5)
    per_alg_us = secs / len(algos) * 1e6
    emit("analysis_verify_algorithm", per_alg_us,
         f"unit=us_per_algorithm;family=aatb;algorithms={len(algos)}")

    lint = verify_zoo(grids=("smoke",))
    if lint.findings:
        raise AssertionError(
            f"zoo lint found {len(lint.findings)} finding(s)")
    rate = lint.algorithms / lint.seconds if lint.seconds else 0.0
    emit("analysis_verify_zoo",
         lint.seconds / max(lint.algorithms, 1) * 1e6,
         f"unit=us_per_algorithm;algorithms_per_s={rate:.0f};"
         f"algorithms={lint.algorithms};instances={lint.instances};"
         f"rules={lint.rules_run}")
    note(f"zoo lint: {lint.algorithms} algorithms / "
         f"{lint.instances} instances in {lint.seconds:.2f}s "
         f"({rate:.0f} alg/s, {lint.rules_run} rules)")

    secs = time_call(lambda: run_mutation_suite(), reps=3)
    outcomes = run_mutation_suite()
    caught, total = mutation_catch_rate(outcomes)
    emit("analysis_mutation_suite", secs * 1e6,
         f"unit=us_per_suite;caught={caught};total={total}")
    note(f"mutation suite: {caught}/{total} caught in {secs * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
