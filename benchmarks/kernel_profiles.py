"""Paper Figure 1: efficiency of GEMM / SYRK / SYMM vs operand size.

Measured on this host's real BLAS (the paper's methodology) and modeled
for TPU v5e by the analytical profile — the two ends the perfmodel
discriminant bridges.
"""

from __future__ import annotations


from repro.core import AnalyticalTPUProfile
from repro.core.flops import gemm, symm, syrk
from repro.core.runners import BlasRunner

from .common import FULL, emit, note


def main() -> None:
    sizes = (128, 256, 512, 1024) if not FULL else (
        128, 256, 384, 512, 768, 1024, 1536, 2048)
    runner = BlasRunner(reps=3 if not FULL else 10)
    prof = AnalyticalTPUProfile()
    note("\n== kernel efficiency profiles (paper Fig. 1) ==")
    note(f"{'n':>6} {'gemm_gflops':>12} {'syrk_gflops':>12} "
         f"{'symm_gflops':>12} | tpu-model eff g/s/s")
    for n in sizes:
        calls = {"gemm": gemm(n, n, n), "syrk": syrk(n, n),
                 "symm": symm(n, n)}
        row = []
        effs = []
        for kind, call in calls.items():
            t = runner.benchmark_call(call)
            gf = call.flops / t / 1e9
            row.append(gf)
            effs.append(prof.efficiency(call, 2))
            emit(f"fig1_{kind}_n{n}", t * 1e6,
                 f"gflops={gf:.1f};tpu_model_eff={effs[-1]:.3f}")
        note(f"{n:>6} {row[0]:>12.1f} {row[1]:>12.1f} {row[2]:>12.1f} | "
             f"{effs[0]:.2f}/{effs[1]:.2f}/{effs[2]:.2f}")
    # The paper's qualitative claim: kernels differ in efficiency at equal
    # FLOP budgets; verify SYRK achieves lower GFLOP/s than GEMM (it has
    # half the parallel work for the same interface size).
    note("(qualitative check: efficiencies differ across kernels — "
         "the root cause of anomalies)")


if __name__ == "__main__":
    main()
