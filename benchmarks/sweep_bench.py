"""Sweep-engine throughput: serial vs sharded grid sweeps (instances/sec),
plus adaptive-vs-dense budget efficiency on planted ground truth.

Measures the engine itself, not the kernels: a fixed AAᵀB grid is swept
once serially and once over a process pool, with cache flushing off and
reps=1 so the denominator is engine + dispatch overhead rather than BLAS
time. Derived fields report instances/sec and the sharded speedup; the
atlas write path is exercised in a throwaway directory so persistence cost
is included.

The adaptive rows sweep the planted masks of :mod:`repro.core.synthetic`
(ground truth known by construction) and report frontier recall and
measurement savings against the dense grid — the quantities the
``adaptive-smoke`` CI job gates on (recall ≥ 90 %, savings > 0).

REPRO_BENCH_SCALE=full uses a denser grid and more shards.
"""

from __future__ import annotations

import functools
import os
import tempfile
import time
from pathlib import Path

from repro.core import BlasRunner
from repro.core.adaptive import adaptive_sweep
from repro.core.backends import make_backend
from repro.core.expressions import clear_algorithm_cache
from repro.core.profile_store import current_fingerprint
from repro.core.sweep import GRAM_AATB, AnomalyAtlas, GridSpec, sweep
from repro.core.synthetic import (
    MaskRunner,
    PlantedSpec,
    frontier_recall,
    planted_masks,
    true_frontier,
)

from .common import FULL, emit, note


def _run(points, backend, shards, factory, atlas_dir):
    atlas = AnomalyAtlas.open(
        GRAM_AATB.name, current_fingerprint(), threshold=0.10,
        directory=Path(atlas_dir) / f"{backend}{shards or 0}")
    if backend == "serial":
        res = sweep(GRAM_AATB, points, runner=factory(), atlas=atlas)
    else:
        res = sweep(GRAM_AATB, points, backend=backend, shards=shards,
                    runner_factory=factory, atlas=atlas)
    atlas.flush()
    return res


def main():
    values = (32, 64, 96, 128) if FULL else (32, 64, 96)
    shards = min(8 if FULL else 2, os.cpu_count() or 1)
    grid = GridSpec.uniform(values, GRAM_AATB.ndims, name="bench")
    points = grid.points()
    factory = functools.partial(BlasRunner, reps=1, flush_cache=False)

    note(f"\n== sweep engine: {len(points)} AAᵀB instances, "
         f"{shards} shards ==")
    with tempfile.TemporaryDirectory() as atlas_dir:
        serial = _run(points, "serial", None, factory, atlas_dir)
        sharded = _run(points, "process", shards, factory, atlas_dir)

    note(f"serial : {serial.instances_per_s:8.1f} inst/s "
         f"({serial.wall_s:.2f}s)")
    note(f"sharded: {sharded.instances_per_s:8.1f} inst/s "
         f"({sharded.wall_s:.2f}s, {shards} procs)")
    speedup = (sharded.instances_per_s / serial.instances_per_s
               if serial.instances_per_s else 0.0)
    note(f"speedup: {speedup:.2f}x")

    emit("sweep_serial", serial.wall_s * 1e6 / max(1, serial.n_measured),
         f"inst_per_s={serial.instances_per_s:.2f};n={serial.n_measured}")
    emit("sweep_sharded", sharded.wall_s * 1e6 / max(1, sharded.n_measured),
         f"inst_per_s={sharded.instances_per_s:.2f};"
         f"shards={shards};speedup={speedup:.2f}")

    adaptive_vs_dense()
    fastpath_vs_legacy()


class _FixedCostRunner:
    """Deterministic sleep-kernel runner for the fastpath benchmark.

    The timed "kernel" is a GIL-releasing ``time.sleep`` — so the pipelined
    prepare thread can genuinely overlap it — while the *reported* seconds
    are a pure function of the algorithm's FLOPs (with a planted SYRK skew
    so classifications are non-trivial). Reported times are identical in
    both modes, so the two atlases must match byte for byte.
    """

    def __init__(self, kernel_s: float):
        self.kernel_s = kernel_s
        self._ops = make_backend("numpy", reps=1, flush_cache=False, seed=7)

    def make_operands(self, alg):
        return self._ops.make_operands(alg)

    def make_leaf_operand(self, ref, leading=()):
        return self._ops.make_leaf_operand(ref, leading)

    def time_algorithm(self, alg, operands=None, reps=None):
        time.sleep(self.kernel_s)
        skew = 1.35 if any(c.kind == "syrk" for c in alg.calls) else 1.0
        return 1e-12 * alg.flops * skew


def fastpath_vs_legacy():
    """Serial fastpath vs legacy sweep on a fixed-cost kernel.

    The synthetic kernel is self-calibrated so per-point kernel time is on
    par with per-point prepare cost (enumeration + operand synthesis) —
    the regime the fast path targets, where pipelining can hide nearly all
    of the prep. The ``fastpath-smoke`` CI job gates on the emitted
    ``speedup`` (≥ 1.3×) and ``atlas_identical`` (byte parity) fields.
    """
    # Dims large enough that operand synthesis dominates prepare cost —
    # the component the arena and the prepare pipeline actually remove.
    values = (256, 320, 384, 448) if FULL else (192, 256, 320)
    grid = GridSpec.uniform(values, GRAM_AATB.ndims, name="fpbench")
    points = grid.points()

    # Calibrate the sleep so total kernel time per point ≈ prepare cost
    # per point (measured cold: enumeration + one operand synthesis pass).
    clear_algorithm_cache()
    probe = make_backend("numpy", reps=1, flush_cache=False, seed=7)
    t0 = time.perf_counter()
    n_algos = 0
    for p in points:
        algos = GRAM_AATB.algorithms(p)
        n_algos += len(algos)
        probe.make_operands(algos[0])
    prep_total = time.perf_counter() - t0
    kernel_s = max(5e-4, prep_total / max(1, n_algos))

    note(f"\n== fastpath vs legacy: {len(points)} AAᵀB instances, "
         f"kernel {kernel_s * 1e3:.2f} ms ==")
    results = {}
    blobs = {}
    with tempfile.TemporaryDirectory() as atlas_dir:
        for mode, fp_on in (("fast", True), ("legacy", False)):
            clear_algorithm_cache()  # don't gift enumeration to mode 2
            d = Path(atlas_dir) / mode
            atlas = AnomalyAtlas.open(
                GRAM_AATB.name, current_fingerprint(), threshold=0.10,
                directory=d)
            results[mode] = sweep(GRAM_AATB, points,
                                  runner=_FixedCostRunner(kernel_s),
                                  atlas=atlas, fastpath=fp_on)
            atlas.flush()
            blobs[mode] = b"".join(
                f.read_bytes() for f in sorted(d.rglob("*")) if f.is_file())
    fast, legacy = results["fast"], results["legacy"]
    identical = int(blobs["fast"] == blobs["legacy"] and bool(blobs["fast"]))
    speedup = (fast.instances_per_s / legacy.instances_per_s
               if legacy.instances_per_s else 0.0)

    note(f"fast   : {fast.instances_per_s:8.1f} inst/s ({fast.wall_s:.2f}s)")
    note(f"legacy : {legacy.instances_per_s:8.1f} inst/s "
         f"({legacy.wall_s:.2f}s)")
    note(f"speedup: {speedup:.2f}x  atlas_identical={identical}")
    if fast.fastpath is not None:
        note(f"fastpath: {fast.fastpath.summary()}")
    emit("fastpath_vs_legacy",
         fast.wall_s * 1e6 / max(1, fast.n_measured),
         f"inst_per_s={fast.instances_per_s:.2f};"
         f"legacy_inst_per_s={legacy.instances_per_s:.2f};"
         f"speedup={speedup:.2f};atlas_identical={identical}")


def adaptive_vs_dense():
    """Adaptive boundary refinement vs the dense grid, per planted mask."""
    n = 30 if FULL else 20
    spec = PlantedSpec()
    grid = GridSpec.uniform(tuple(range(10, 10 * n + 10, 10)), spec.ndims,
                            name=f"planted{n}")
    budget = int(0.40 * grid.n_points)
    note(f"\n== adaptive vs dense: {grid.n_points}-point planted grid, "
         f"budget {budget} (40%) ==")
    recalls = []
    for name, mask in sorted(planted_masks(grid).items()):
        res = adaptive_sweep(spec, grid, budget, runner=MaskRunner(mask))
        recall = frontier_recall(res.known, true_frontier(mask, grid))
        savings = 1.0 - res.n_measured / grid.n_points
        recalls.append(recall)
        note(f"{name:8s}: recall={recall:6.1%} "
             f"measured={res.n_measured}/{grid.n_points} "
             f"(savings {savings:.1%}) rounds={res.n_refine_rounds} "
             f"stopped={res.stopped}")
        emit(f"adaptive_recall_{name}", 100.0 * recall,
             f"unit=percent;measured={res.n_measured};"
             f"dense={grid.n_points};savings_pct={100 * savings:.1f};"
             f"rounds={res.n_refine_rounds};stopped={res.stopped}")
    emit("adaptive_frontier_recall", 100.0 * min(recalls),
         f"unit=percent;masks={len(recalls)};budget_pct=40.0")


if __name__ == "__main__":
    main()
