"""Sweep-engine throughput: serial vs sharded grid sweeps (instances/sec).

Measures the engine itself, not the kernels: a fixed AAᵀB grid is swept
once serially and once over a process pool, with cache flushing off and
reps=1 so the denominator is engine + dispatch overhead rather than BLAS
time. Derived fields report instances/sec and the sharded speedup; the
atlas write path is exercised in a throwaway directory so persistence cost
is included.

REPRO_BENCH_SCALE=full uses a denser grid and more shards.
"""

from __future__ import annotations

import functools
import os
import tempfile
from pathlib import Path

from repro.core import BlasRunner
from repro.core.profile_store import current_fingerprint
from repro.core.sweep import GRAM_AATB, AnomalyAtlas, GridSpec, sweep

from .common import FULL, emit, note


def _run(points, backend, shards, factory, atlas_dir):
    atlas = AnomalyAtlas.open(
        GRAM_AATB.name, current_fingerprint(), threshold=0.10,
        directory=Path(atlas_dir) / f"{backend}{shards or 0}")
    if backend == "serial":
        res = sweep(GRAM_AATB, points, runner=factory(), atlas=atlas)
    else:
        res = sweep(GRAM_AATB, points, backend=backend, shards=shards,
                    runner_factory=factory, atlas=atlas)
    atlas.flush()
    return res


def main():
    values = (32, 64, 96, 128) if FULL else (32, 64, 96)
    shards = min(8 if FULL else 2, os.cpu_count() or 1)
    grid = GridSpec.uniform(values, GRAM_AATB.ndims, name="bench")
    points = grid.points()
    factory = functools.partial(BlasRunner, reps=1, flush_cache=False)

    note(f"\n== sweep engine: {len(points)} AAᵀB instances, "
         f"{shards} shards ==")
    with tempfile.TemporaryDirectory() as atlas_dir:
        serial = _run(points, "serial", None, factory, atlas_dir)
        sharded = _run(points, "process", shards, factory, atlas_dir)

    note(f"serial : {serial.instances_per_s:8.1f} inst/s "
         f"({serial.wall_s:.2f}s)")
    note(f"sharded: {sharded.instances_per_s:8.1f} inst/s "
         f"({sharded.wall_s:.2f}s, {shards} procs)")
    speedup = (sharded.instances_per_s / serial.instances_per_s
               if serial.instances_per_s else 0.0)
    note(f"speedup: {speedup:.2f}x")

    emit("sweep_serial", serial.wall_s * 1e6 / max(1, serial.n_measured),
         f"inst_per_s={serial.instances_per_s:.2f};n={serial.n_measured}")
    emit("sweep_sharded", sharded.wall_s * 1e6 / max(1, sharded.n_measured),
         f"inst_per_s={sharded.instances_per_s:.2f};"
         f"shards={shards};speedup={speedup:.2f}")


if __name__ == "__main__":
    main()
