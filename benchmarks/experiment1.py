"""Paper Experiment 1 (§3.4.1): random search for anomalies — abundance
and severity, for both expressions.

Thin config over the sweep engine: sampling/measurement go through
:func:`repro.core.sweep.sweep` (shardable with REPRO_SWEEP_SHARDS=N) and
every classified instance streams into the persistent anomaly atlas, so
repeat runs resume instead of re-measuring.

Paper-scale: box [20,1200], 100/1000 anomalies, 23k/10k samples.
CI-scale default: box [20,600], stop after N_ANOM anomalies or MAX samples.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    GRAM_AATB,
    MATRIX_CHAIN_ABCD,
    experiment1_random_search,
)

from .common import FULL, emit, engine_kwargs, make_runner, note, open_atlas


def run_spec(spec, box, n_anom, max_samples, reps, threshold=0.10,
             seed=0):
    # Sharded runs build per-worker runners from engine_kwargs' factory;
    # the (64 MB flush buffer) serial runner exists only when used.
    kwargs = engine_kwargs(reps)
    runner = None if kwargs else make_runner(reps)
    with open_atlas(spec.name, threshold) as atlas:
        n_cached = len(atlas)
        res = experiment1_random_search(
            spec, runner, box=box, n_anomalies=n_anom,
            max_samples=max_samples, threshold=threshold, seed=seed,
            atlas=atlas, **kwargs)
    ts = [i.cls.time_score for i in res.anomalies]
    fs = [i.cls.flop_score for i in res.anomalies]
    note(f"\n== Experiment 1: {spec.name} ==")
    note(f"samples={res.samples} anomalies={len(res.anomalies)} "
         f"abundance={res.abundance:.2%} wall={res.wall_s:.0f}s "
         f"(atlas held {n_cached} instances going in)")
    if ts:
        note(f"time_score:  max={max(ts):.1%} median={np.median(ts):.1%}")
        note(f"flop_score:  max={max(fs):.1%} median={np.median(fs):.1%}")
        sev = sum(1 for t, f in zip(ts, fs) if t > 0.20 or f > 0.30)
        note(f"severe (ts>20% or fs>30%): {sev}/{len(ts)}")
    emit(f"exp1_{spec.name}_abundance", res.wall_s * 1e6 / max(res.samples, 1),
         f"abundance={res.abundance:.4f};n={len(res.anomalies)};"
         f"samples={res.samples}")
    return res


def main():
    box = (20, 1200) if FULL else (20, 600)
    if FULL:
        run_spec(MATRIX_CHAIN_ABCD, box, 100, 25000, reps=10)
        run_spec(GRAM_AATB, box, 1000, 12000, reps=10)
    else:
        run_spec(MATRIX_CHAIN_ABCD, box, 8, 300, reps=3)
        run_spec(GRAM_AATB, box, 25, 300, reps=3)


if __name__ == "__main__":
    main()
