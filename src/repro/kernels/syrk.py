"""SYRK Pallas kernel: lower triangle of A·Aᵀ, triangular block grid.

The paper's FLOP asymmetry — SYRK costs (m+1)·m·k vs GEMM's 2·m²·k — is
realized on TPU by iterating only the lower-triangular *block* grid: for an
``mt×mt`` block matrix we run ``T = mt(mt+1)/2`` programs instead of
``mt²``, each contracting over K. MKL does the same thing with cache
blocks; on TPU the unit is the 128×128 MXU tile.

The triangular index space is linearized with **scalar prefetch**
(`pltpu.PrefetchScalarGridSpec`): host-computed index vectors ``ii[t], jj[t]``
map the flat grid coordinate ``t`` to block row/column, so BlockSpec index
maps stay affine — the TPU-idiomatic replacement for the non-rectangular
loop nests a CPU BLAS would use.

Strictly-upper output blocks are never touched by any program; they are
zero-initialized by the wrapper so the result equals ``jnp.tril(A @ A.T)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._checks import check_divisible


def _syrk_kernel(ii_ref, jj_ref, a_ref, at_ref, o_ref, acc_ref,
                 *, k_steps: int, bm: int):
    t = pl.program_id(0)
    i = ii_ref[t]
    j = jj_ref[t]

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], at_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(1) == k_steps - 1)
    def _flush():
        acc = acc_ref[...]
        # Diagonal blocks: mask strictly-upper entries so the output is a
        # clean lower triangle (off-diagonal blocks are fully kept).
        rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 1)
        masked = jnp.where(rows >= cols, acc, 0.0)
        o_ref[...] = jnp.where(i == j, masked, acc).astype(o_ref.dtype)


def syrk_pallas(
    a: jax.Array,
    *,
    bm: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Lower triangle of A[m,k] @ A[m,k]ᵀ; m % bm == 0, k % bk == 0."""
    m, k = a.shape
    check_divisible("syrk_pallas", ("m", m, "bm", bm), ("k", k, "bk", bk))
    mt = m // bm
    k_steps = k // bk
    # Host-side triangular index vectors (scalar-prefetched).
    ii, jj = np.tril_indices(mt)
    ii = jnp.asarray(ii, dtype=jnp.int32)
    jj = jnp.asarray(jj, dtype=jnp.int32)
    t_blocks = int(ii.shape[0])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t_blocks, k_steps),
        in_specs=[
            # A block-row i tile: (bm, bk) at block (ii[t], l)
            pl.BlockSpec((bm, bk), lambda t, l, ii, jj: (ii[t], l)),
            # A block-row j tile (the transposed operand): (bm, bk)
            pl.BlockSpec((bm, bk), lambda t, l, ii, jj: (jj[t], l)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda t, l, ii, jj: (ii[t], jj[t])),
        scratch_shapes=[pltpu.VMEM((bm, bm), jnp.float32)],
    )

    kernel = functools.partial(_syrk_kernel, k_steps=k_steps, bm=bm)

    def _run(x):
        # Contract a_i · a_jᵀ: pass A twice; kernel dots (bm,bk)·(bk,bm).
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((m, m), x.dtype),
            interpret=interpret,
        )(ii, jj, x, x)

    out = _run(a)
    # Programs only write lower-tri blocks; zero the untouched upper blocks.
    return jnp.tril(out)


def _syrk_kernel_docflops(m: int, k: int) -> int:
    """Block-quantized MXU work actually scheduled (for the perf model)."""
    mt = (m + 127) // 128
    return (mt * (mt + 1) // 2) * ((k + 127) // 128) * 2 * 128 ** 3
