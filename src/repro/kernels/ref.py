"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical definition, written with plain jnp ops and
no tiling — the ground truth that tests/test_kernels.py asserts the Pallas
kernels against (interpret mode on CPU, real Mosaic on TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with fp32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def syrk(a: jax.Array) -> jax.Array:
    """Lower triangle of A @ Aᵀ (strictly-upper entries zero)."""
    full = jnp.dot(a, a.T, preferred_element_type=jnp.float32)
    return jnp.tril(full).astype(a.dtype)


def symm(s_lower: jax.Array, b: jax.Array) -> jax.Array:
    """C = S @ B where S is symmetric, stored in the lower triangle of
    ``s_lower`` (strictly-upper entries ignored)."""
    s = jnp.tril(s_lower) + jnp.tril(s_lower, -1).T
    return jnp.dot(s, b, preferred_element_type=jnp.float32).astype(b.dtype)


def tri2full(t: jax.Array) -> jax.Array:
    """Mirror the lower triangle into a full symmetric matrix."""
    return (jnp.tril(t) + jnp.tril(t, -1).T).astype(t.dtype)


def chain_gemm(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """(A @ B) @ C with fp32 accumulation throughout."""
    m1 = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return jnp.dot(m1, c.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(a.dtype)


def flash_attention(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, Hkv, S, D)
    v: jax.Array,  # (B, Hkv, S, D)
    causal: bool = True,
    scale: float | None = None,
    logit_softcap: float = 0.0,
    window: int = 0,
) -> jax.Array:
    """Reference attention with GQA broadcast, optional causal mask,
    sliding window, and Gemma-2 style logit soft-capping."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    if scale is None:
        scale = d ** -0.5
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kq,
                        preferred_element_type=jnp.float32) * scale
    if logit_softcap > 0:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    idx = jnp.arange(s)
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= idx[:, None] >= idx[None, :]
    if window > 0:
        mask &= idx[:, None] - idx[None, :] < window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(vq.dtype), vq)
    return out.astype(q.dtype)
