"""Pallas TPU kernels for the paper's compute hot spots.

GEMM / SYRK / SYMM — the paper's three BLAS kernels, re-tiled for the MXU —
plus two beyond-paper fusions: chain_gemm (VMEM-resident intermediate) and
flash_attention (online softmax, required by the 32k shape cells).

Use :mod:`repro.kernels.ops` (jit wrappers, padding, CPU interpret
fallback). :mod:`repro.kernels.ref` holds the pure-jnp oracles.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
