"""SYMM Pallas kernel: C = S·B with S symmetric, stored as lower triangle.

The paper's SYMM halves memory traffic for S by reading one triangle. On
TPU we do the same at block granularity: the BlockSpec index map fetches
S-block ``(max(i,l), min(i,l))`` — always from the lower triangle — and the
kernel transposes the tile in-register when the logical block lies above
the diagonal (``l > i``). Diagonal blocks are symmetrized in-register from
their stored lower triangle.

HBM traffic for S is thus ``m(m+1)/2`` elements instead of ``m²`` — the
exact asymmetry (SYMM vs GEMM efficiency) whose interplay with FLOP counts
produces the paper's AAᵀB anomalies.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._checks import check_divisible, check_same


def _symm_kernel(s_ref, b_ref, o_ref, acc_ref, *, k_steps: int, bm: int):
    i = pl.program_id(0)
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    tile = s_ref[...]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 1)
    lower = jnp.where(rows >= cols, tile, 0.0)
    # Diagonal block: symmetrize the stored lower triangle.
    sym = lower + jnp.where(rows > cols, tile, 0.0).T
    # Off-diagonal: stored block is (max(i,l), min(i,l)); transpose if the
    # logical block is in the upper triangle (l > i).
    eff = jnp.where(i == l, sym, jnp.where(i > l, tile, tile.T))
    acc_ref[...] += jnp.dot(
        eff.astype(b_ref.dtype), b_ref[...],
        preferred_element_type=jnp.float32,
    )

    @pl.when(l == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def symm_pallas(
    s_lower: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """C[m,n] = sym(S)·B with S stored lower-triangular; m % bm == 0."""
    m, m2 = s_lower.shape
    mb, n = b.shape
    check_same("symm_pallas", "symmetric dim m",
               ("S.shape[0]", m), ("S.shape[1]", m2), ("B.shape[0]", mb))
    check_divisible("symm_pallas", ("m", m, "bm", bm), ("n", n, "bn", bn))
    k_steps = m // bm

    return pl.pallas_call(
        functools.partial(_symm_kernel, k_steps=k_steps, bm=bm),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            # Always fetch from the lower triangle: block (max(i,l), min(i,l))
            pl.BlockSpec(
                (bm, bm),
                lambda i, j, l: (jnp.maximum(i, l), jnp.minimum(i, l)),
            ),
            pl.BlockSpec((bm, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), b.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(s_lower, b)
