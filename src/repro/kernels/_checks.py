"""Shape/divisibility validation shared by the Pallas kernels.

These used to be bare ``assert`` statements, which vanish under
``python -O`` — a mis-blocked call would then run the kernel on
non-divisible dims and silently corrupt the output. Kernels now raise
:class:`ValueError` naming the offending dim and block so the failure is
unconditional and diagnosable from the message alone.
"""

from __future__ import annotations

from typing import Tuple


def check_divisible(kernel: str,
                    *constraints: Tuple[str, int, str, int]) -> None:
    """Each constraint is ``(dim_name, dim, block_name, block)``;
    raises ``ValueError`` listing every dim not divisible by its block."""
    bad = [(dn, d, bn, b) for dn, d, bn, b in constraints if d % b != 0]
    if bad:
        detail = "; ".join(
            f"{dn}={d} is not a multiple of block {bn}={b}"
            for dn, d, bn, b in bad)
        raise ValueError(
            f"{kernel}: {detail} (the ops.* wrappers pad to block "
            f"multiples before calling this kernel)")


def check_same(kernel: str, what: str,
               *values: Tuple[str, int]) -> None:
    """Each value is ``(source_name, dim)``; raises ``ValueError`` when
    they disagree (operand shape mismatch on a shared dimension)."""
    dims = {d for _, d in values}
    if len(dims) > 1:
        detail = ", ".join(f"{name}={d}" for name, d in values)
        raise ValueError(f"{kernel}: {what} mismatch: {detail}")
