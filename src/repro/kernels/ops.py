"""Public jit'd wrappers for the Pallas kernels.

Responsibilities:
  * pad operands to block multiples (MXU 128-alignment) and slice results —
    the quantization the perf model charges for is made explicit here;
  * select ``interpret=True`` automatically off-TPU so the same call sites
    work on this CPU container (kernel body runs in Python) and on real
    TPUs (Mosaic);
  * fall back to the jnp reference where a kernel's structural premise
    doesn't hold (e.g. chain_gemm beyond its VMEM bound).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .chain_gemm import (
    chain_gemm_pallas,
    chain_gemm_vmem_bytes,
    gemm_syrk_pallas,
    gemm_syrk_vmem_bytes,
)
from .flash_attention import flash_attention_pallas
from .gemm import gemm_pallas
from .symm import symm_pallas
from .syrk import syrk_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mults) -> jax.Array:
    pads = []
    for dim, q in zip(x.shape, mults):
        pads.append((0, (-dim) % q))
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "pipeline"))
def gemm(a: jax.Array, b: jax.Array, bm: int = 128, bn: int = 128,
         bk: int = 128, pipeline: int = 0) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b, (bk, bn))
    out = gemm_pallas(ap, bp, bm=bm, bn=bn, bk=bk, pipeline=pipeline,
                      interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def syrk(a: jax.Array, bm: int = 128, bk: int = 128) -> jax.Array:
    m, _ = a.shape
    ap = _pad_to(a, (bm, bk))
    out = syrk_pallas(ap, bm=bm, bk=bk, interpret=_interpret())
    return out[:m, :m]


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def symm(s_lower: jax.Array, b: jax.Array, bm: int = 128,
         bn: int = 128) -> jax.Array:
    m, _ = s_lower.shape
    _, n = b.shape
    sp = _pad_to(s_lower, (bm, bm))
    bp = _pad_to(b, (bm, bn))
    out = symm_pallas(sp, bp, bm=bm, bn=bn, interpret=_interpret())
    return out[:m, :n]


# Fused chain beyond this VMEM residency falls back to two GEMMs.
_CHAIN_VMEM_LIMIT = 32 * 1024 * 1024


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "bl"))
def chain_gemm(a: jax.Array, b: jax.Array, c: jax.Array, bm: int = 128,
               bn: int = 128, bk: int = 128, bl: int = 128) -> jax.Array:
    m, k = a.shape
    _, l = b.shape
    _, n = c.shape
    need = chain_gemm_vmem_bytes(m, k, l, n, bm, bn,
                                 dtype_bytes=a.dtype.itemsize)
    if need > _CHAIN_VMEM_LIMIT:
        return gemm(gemm(a, b), c)
    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b, (bk, bl))
    cp = _pad_to(c, (bl, bn))
    out = chain_gemm_pallas(ap, bp, cp, bm=bm, bn=bn, bk=bk, bl=bl,
                            interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def gemm_syrk(a: jax.Array, b: jax.Array, bm: int = 128,
              bk: int = 128) -> jax.Array:
    """Lower triangle of (A·B)(A·B)ᵀ, fused (GEMM+SYRK epilogue)."""
    m, k = a.shape
    _, l = b.shape
    need = gemm_syrk_vmem_bytes(m, k, l, bm,
                                dtype_bytes=a.dtype.itemsize)
    if need > _CHAIN_VMEM_LIMIT:
        return syrk(gemm(a, b))
    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b, (bk, 128))
    out = gemm_syrk_pallas(ap, bp, bm=bm, bk=bk, interpret=_interpret())
    return out[:m, :m]


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "logit_softcap", "window", "bq", "bkv"))
def flash_attention(q, k, v, causal: bool = True, scale=None,
                    logit_softcap: float = 0.0, window: int = 0,
                    bq: int = 128, bkv: int = 128) -> jax.Array:
    s = q.shape[2]
    if s % bq or s % bkv:
        # Sequence not block-divisible: shrink blocks or use the reference.
        if s % 128 == 0:
            bq = bkv = 128
        else:
            return ref.flash_attention(q, k, v, causal=causal, scale=scale,
                                       logit_softcap=logit_softcap,
                                       window=window)
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale, logit_softcap=logit_softcap,
        window=window, bq=bq, bkv=bkv, interpret=_interpret())


def tri2full(t: jax.Array) -> jax.Array:
    """Pure data movement (paper charges 0 FLOPs); no kernel needed —
    XLA's fused tril/transpose is already bandwidth-bound."""
    return ref.tri2full(t)
