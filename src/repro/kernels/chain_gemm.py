"""Fused matrix-chain kernel: (A·B)·C without an HBM round-trip.

Beyond-paper optimization. The paper's cost model (and its BLAS execution)
materializes every intermediate in main memory; on TPU the intermediate
``M₁ = A·B`` tile can stay in VMEM. For chain instances where M₁ is large
relative to the final output (e.g. the paper's anomaly at
d = (331, 279, 338, 854, 427): M₁ is 331×338 but feeds an 854-wide
contraction), the eliminated ``2·m·l`` HBM traffic moves the memory-roofline
term directly.

Layout: grid ``(M/bm, N/bn)``. For each output row-block i, the fused
intermediate row ``M₁[i, :] = A[i, :]·B`` is computed once (at j == 0) into
a persistent VMEM scratch of shape (bm, L), then every j-step contracts it
with ``C[:, j]``. B and C stream through VMEM in (bk/bl)-sized slabs via
``lax.fori_loop`` + ``pl.ds`` dynamic slices.

VMEM bound: bm·L fp32 scratch + slabs. With bm=128, L ≤ 8192 → ≤ 4 MiB.
``ops.chain_gemm`` falls back to two ``gemm`` calls above the bound.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._checks import check_divisible, check_same


def _chain_kernel(a_ref, b_ref, c_ref, o_ref, m1_ref, *, bk: int, bl: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_intermediate():
        k_total = a_ref.shape[1]
        l_total = b_ref.shape[1]

        def k_body(kk, acc):
            a_slab = a_ref[:, pl.ds(kk * bk, bk)]
            b_slab = b_ref[pl.ds(kk * bk, bk), :]
            return acc + jnp.dot(a_slab, b_slab,
                                 preferred_element_type=jnp.float32)

        acc0 = jnp.zeros((a_ref.shape[0], l_total), dtype=jnp.float32)
        m1_ref[...] = jax.lax.fori_loop(0, k_total // bk, k_body, acc0)

    l_total = b_ref.shape[1]

    def l_body(ll, acc):
        m1_slab = m1_ref[:, pl.ds(ll * bl, bl)]
        c_slab = c_ref[pl.ds(ll * bl, bl), :]
        return acc + jnp.dot(m1_slab.astype(c_slab.dtype), c_slab,
                             preferred_element_type=jnp.float32)

    acc0 = jnp.zeros_like(o_ref, dtype=jnp.float32)
    out = jax.lax.fori_loop(0, l_total // bl, l_body, acc0)
    o_ref[...] = out.astype(o_ref.dtype)


def chain_gemm_pallas(
    a: jax.Array,   # (m, k)
    b: jax.Array,   # (k, l)
    c: jax.Array,   # (l, n)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    bl: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(A@B)@C fused; all dims must divide their block size."""
    m, k = a.shape
    k2, l = b.shape
    l2, n = c.shape
    check_same("chain_gemm_pallas", "contraction dim k",
               ("A.shape[1]", k), ("B.shape[0]", k2))
    check_same("chain_gemm_pallas", "contraction dim l",
               ("B.shape[1]", l), ("C.shape[0]", l2))
    check_divisible("chain_gemm_pallas",
                    ("m", m, "bm", bm), ("n", n, "bn", bn),
                    ("k", k, "bk", bk), ("l", l, "bl", bl))

    return pl.pallas_call(
        functools.partial(_chain_kernel, bk=bk, bl=bl),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),   # A row-block
            pl.BlockSpec((k, l), lambda i, j: (0, 0)),    # B resident
            pl.BlockSpec((l, bn), lambda i, j: (0, j)),   # C col-block
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, l), jnp.float32)],
        interpret=interpret,
    )(a, b, c)


def chain_gemm_vmem_bytes(m, k, l, n, bm=128, bn=128, *, dtype_bytes) -> int:
    """Estimated VMEM residency for the fused kernel (wrapper fallback).

    ``dtype_bytes`` is keyword-required with no default: this estimate
    used to default to 2 (bf16) while the pallas backend executes f32,
    halving the footprint the VMEM pre-filter reasoned about. Callers
    must pass the actual operand itemsize (``a.dtype.itemsize``).
    """
    return (bm * k + k * l + l * bn) * dtype_bytes + bm * l * 4


def _gemm_syrk_kernel(ii_ref, jj_ref, a_i_ref, a_j_ref, b_ref, o_ref,
                      m1i_ref, m1j_ref, *, bk: int, bm: int):
    t = pl.program_id(0)
    i = ii_ref[t]
    j = jj_ref[t]
    k_total = a_i_ref.shape[1]

    def _m1(a_ref, out_ref):
        # Row-block of the intermediate M₁ = A·B, built K-slab by K-slab.
        def k_body(kk, acc):
            a_slab = a_ref[:, pl.ds(kk * bk, bk)]
            b_slab = b_ref[pl.ds(kk * bk, bk), :]
            return acc + jnp.dot(a_slab, b_slab,
                                 preferred_element_type=jnp.float32)

        acc0 = jnp.zeros((a_ref.shape[0], b_ref.shape[1]),
                         dtype=jnp.float32)
        out_ref[...] = jax.lax.fori_loop(0, k_total // bk, k_body, acc0)

    _m1(a_i_ref, m1i_ref)
    _m1(a_j_ref, m1j_ref)
    acc = jnp.dot(m1i_ref[...], m1j_ref[...].T,
                  preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bm), 1)
    masked = jnp.where(rows >= cols, acc, 0.0)
    o_ref[...] = jnp.where(i == j, masked, acc).astype(o_ref.dtype)


def gemm_syrk_pallas(
    a: jax.Array,   # (m, k)
    b: jax.Array,   # (k, l)
    *,
    bm: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Lower triangle of (A·B)(A·B)ᵀ without materializing M₁ = A·B in HBM.

    The GEMM+SYRK epilogue fusion: SYRK's triangular block grid (scalar-
    prefetched ``ii[t], jj[t]`` as in :mod:`repro.kernels.syrk`), but each
    program rebuilds the two M₁ row-blocks it contracts from A and B in
    VMEM. Trades ``2·bm·k·l`` recompute FLOPs per program for the full
    ``m·l`` HBM round-trip of the intermediate — the same trade
    :func:`chain_gemm_pallas` makes, with SYRK's half-grid savings kept.
    """
    m, k = a.shape
    k2, l = b.shape
    check_same("gemm_syrk_pallas", "contraction dim k",
               ("A.shape[1]", k), ("B.shape[0]", k2))
    check_divisible("gemm_syrk_pallas",
                    ("m", m, "bm", bm), ("k", k, "bk", bk),
                    ("l", l, "lane", 128))
    mt = m // bm
    ii, jj = np.tril_indices(mt)
    ii = jnp.asarray(ii, dtype=jnp.int32)
    jj = jnp.asarray(jj, dtype=jnp.int32)
    t_blocks = int(ii.shape[0])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t_blocks,),
        in_specs=[
            # A block-row i and block-row j (full K extent, slabbed in-kernel)
            pl.BlockSpec((bm, k), lambda t, ii, jj: (ii[t], 0)),
            pl.BlockSpec((bm, k), lambda t, ii, jj: (jj[t], 0)),
            # B stays fully VMEM-resident across the grid
            pl.BlockSpec((k, l), lambda t, ii, jj: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda t, ii, jj: (ii[t], jj[t])),
        scratch_shapes=[pltpu.VMEM((bm, l), jnp.float32),
                        pltpu.VMEM((bm, l), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_gemm_syrk_kernel, bk=bk, bm=bm),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, m), a.dtype),
        interpret=interpret,
    )(ii, jj, a, a, b)
    # Strictly-upper blocks are never written; zero them like syrk_pallas.
    return jnp.tril(out)


def gemm_syrk_vmem_bytes(m, k, l, bm=128, *, dtype_bytes) -> int:
    """Estimated VMEM residency of the fused GEMM+SYRK kernel."""
    return (2 * bm * k + k * l) * dtype_bytes + 2 * bm * l * 4 \
        + bm * bm * dtype_bytes
