"""Fused matrix-chain kernel: (A·B)·C without an HBM round-trip.

Beyond-paper optimization. The paper's cost model (and its BLAS execution)
materializes every intermediate in main memory; on TPU the intermediate
``M₁ = A·B`` tile can stay in VMEM. For chain instances where M₁ is large
relative to the final output (e.g. the paper's anomaly at
d = (331, 279, 338, 854, 427): M₁ is 331×338 but feeds an 854-wide
contraction), the eliminated ``2·m·l`` HBM traffic moves the memory-roofline
term directly.

Layout: grid ``(M/bm, N/bn)``. For each output row-block i, the fused
intermediate row ``M₁[i, :] = A[i, :]·B`` is computed once (at j == 0) into
a persistent VMEM scratch of shape (bm, L), then every j-step contracts it
with ``C[:, j]``. B and C stream through VMEM in (bk/bl)-sized slabs via
``lax.fori_loop`` + ``pl.ds`` dynamic slices.

VMEM bound: bm·L fp32 scratch + slabs. With bm=128, L ≤ 8192 → ≤ 4 MiB.
``ops.chain_gemm`` falls back to two ``gemm`` calls above the bound.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chain_kernel(a_ref, b_ref, c_ref, o_ref, m1_ref, *, bk: int, bl: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_intermediate():
        k_total = a_ref.shape[1]
        l_total = b_ref.shape[1]

        def k_body(kk, acc):
            a_slab = a_ref[:, pl.ds(kk * bk, bk)]
            b_slab = b_ref[pl.ds(kk * bk, bk), :]
            return acc + jnp.dot(a_slab, b_slab,
                                 preferred_element_type=jnp.float32)

        acc0 = jnp.zeros((a_ref.shape[0], l_total), dtype=jnp.float32)
        m1_ref[...] = jax.lax.fori_loop(0, k_total // bk, k_body, acc0)

    l_total = b_ref.shape[1]

    def l_body(ll, acc):
        m1_slab = m1_ref[:, pl.ds(ll * bl, bl)]
        c_slab = c_ref[pl.ds(ll * bl, bl), :]
        return acc + jnp.dot(m1_slab.astype(c_slab.dtype), c_slab,
                             preferred_element_type=jnp.float32)

    acc0 = jnp.zeros_like(o_ref, dtype=jnp.float32)
    out = jax.lax.fori_loop(0, l_total // bl, l_body, acc0)
    o_ref[...] = out.astype(o_ref.dtype)


def chain_gemm_pallas(
    a: jax.Array,   # (m, k)
    b: jax.Array,   # (k, l)
    c: jax.Array,   # (l, n)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    bl: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(A@B)@C fused; all dims must divide their block size."""
    m, k = a.shape
    k2, l = b.shape
    l2, n = c.shape
    assert k == k2 and l == l2, (a.shape, b.shape, c.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and l % bl == 0

    return pl.pallas_call(
        functools.partial(_chain_kernel, bk=bk, bl=bl),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),   # A row-block
            pl.BlockSpec((k, l), lambda i, j: (0, 0)),    # B resident
            pl.BlockSpec((l, bn), lambda i, j: (0, j)),   # C col-block
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, l), jnp.float32)],
        interpret=interpret,
    )(a, b, c)


def chain_gemm_vmem_bytes(m, k, l, n, bm=128, bn=128, dtype_bytes=2) -> int:
    """Estimated VMEM residency for the fused kernel (wrapper fallback)."""
    return (bm * k + k * l + l * bn) * dtype_bytes + bm * l * 4
