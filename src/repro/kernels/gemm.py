"""Blocked GEMM Pallas kernel — the paper's workhorse, TPU edition.

Grid ``(M/bm, N/bn, K/bk)``; each program multiplies a ``(bm, bk)`` A-tile
with a ``(bk, bn)`` B-tile on the MXU and accumulates into an fp32 VMEM
scratch tile that persists across the K grid dimension (last-minor iteration
order on TPU). Block shapes default to 128 — the MXU edge — which the
perf model (core/perfmodel.py) assumes when charging quantized block work.

VMEM footprint per program: bm·bk + bk·bn + 2·bm·bn fp32 words
(= 256 KiB at 128³), far under the v5e budget, leaving room for the
double-buffered pipeline Mosaic inserts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """C[m,n] = A[m,k] @ B[k,n]. Dims must divide the block shape —
    ``ops.gemm`` pads and unpads around this core."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
