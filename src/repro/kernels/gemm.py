"""Blocked GEMM Pallas kernel — the paper's workhorse, TPU edition.

Grid ``(M/bm, N/bn, K/bk)``; each program multiplies a ``(bm, bk)`` A-tile
with a ``(bk, bn)`` B-tile on the MXU and accumulates into an fp32 VMEM
scratch tile that persists across the K grid dimension (last-minor iteration
order on TPU). Block shapes default to 128 — the MXU edge — which the
perf model (core/perfmodel.py) assumes when charging quantized block work.

VMEM footprint per program: bm·bk + bk·bn + 2·bm·bn fp32 words
(= 256 KiB at 128³), far under the v5e budget, leaving room for the
double-buffered pipeline Mosaic inserts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._checks import check_divisible, check_same


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gemm_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    pipeline: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """C[m,n] = A[m,k] @ B[k,n]. Dims must divide the block shape —
    ``ops.gemm`` pads and unpads around this core.

    ``pipeline=1`` annotates the grid with Mosaic ``dimension_semantics``
    (M/N parallel, K arbitrary) so the compiler may reorder/parallelize
    the output-tile dimensions; the autotuner probes this knob on the
    winning tile shape. Ignored (harmless) in interpret mode.
    """
    m, k = a.shape
    k2, n = b.shape
    check_same("gemm_pallas", "contraction dim k",
               ("A.shape[1]", k), ("B.shape[0]", k2))
    check_divisible("gemm_pallas",
                    ("m", m, "bm", bm), ("n", n, "bn", bn),
                    ("k", k, "bk", bk))
    k_steps = k // bk
    extra = {}
    if pipeline:
        extra["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        **extra,
    )(a, b)
