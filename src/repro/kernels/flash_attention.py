"""Blocked online-softmax attention (FlashAttention, TPU edition).

Needed by the 32k-prefill shape cells: materializing a 32768² logits matrix
per head is 4 GiB fp32 — far beyond VMEM and a needless HBM round-trip.
The kernel streams KV blocks, maintaining the running max ``m`` and
normalizer ``l`` in VMEM scratch (the standard online-softmax recurrence),
so the working set is O(block²) regardless of sequence length.

Supports the features the assigned architectures need:
  * GQA — KV heads broadcast over query-head groups via the index map
    (no repeat in HBM);
  * causal masking — KV blocks strictly above the diagonal are skipped via
    ``pl.when`` (the compute saving that makes causal prefill ~2× cheaper);
  * sliding window (gemma2 local layers, zamba2 long-context);
  * logit soft-capping (gemma2).

Grid: (batch·heads, q_blocks, kv_blocks); kv minor so scratch persists
across the kv sweep for one (bh, q) tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  logit_softcap: float, bq: int, bkv: int, kv_steps: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bkv

    # Causal/window skip: whole KV blocks with no visible key are skipped —
    # this is where blocked attention beats the dense oracle on FLOPs.
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + bkv > q_start - window + 1)

    @pl.when(run)
    def _block():
        q = q_ref[0]                     # (bq, d)
        k = k_ref[0]                     # (bkv, d)
        v = v_ref[0]                     # (bkv, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), dtype=bool)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]              # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)           # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,   # (B, H, S, D)
    k: jax.Array,   # (B, Hkv, S, D)
    v: jax.Array,   # (B, Hkv, S, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    logit_softcap: float = 0.0,
    window: int = 0,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    if scale is None:
        scale = d ** -0.5
    assert s % bq == 0 and s % bkv == 0, (s, bq, bkv)
    kv_steps = s // bkv

    qr = q.reshape(b * h, s, d)
    kr = k.reshape(b * hkv, s, d)
    vr = v.reshape(b * hkv, s, d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        logit_softcap=logit_softcap, bq=bq, bkv=bkv, kv_steps=kv_steps)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            # GQA: query head bh maps to kv head bh//group within its batch.
            pl.BlockSpec(
                (1, bkv, d),
                lambda bh, qi, ki, grp=group, hh=h, hkv_=hkv:
                    ((bh // hh) * hkv_ + (bh % hh) // grp, ki, 0),
            ),
            pl.BlockSpec(
                (1, bkv, d),
                lambda bh, qi, ki, grp=group, hh=h, hkv_=hkv:
                    ((bh // hh) * hkv_ + (bh % hh) // grp, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d)
