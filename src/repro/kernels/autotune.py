"""The autotuner's measurement loop: time pruned survivors, keep winners.

:mod:`repro.core.tuning` decides *what* deserves timing (candidate
generation + the roofline/VMEM pre-filter); this module spends the
measurement budget. Per ``(kind, dims)`` request:

1. prune the candidate space (:func:`~repro.core.tuning.prune_candidates`)
   — survivors arrive cheapest-modeled-first with the default config
   force-included, Sankaran & Bientinesi's "measure only the cheapest
   candidates" budget shape (arXiv 2209.03258);
2. time each survivor through the backend's existing
   ``time_algorithm`` path — base kinds as a
   :func:`~repro.core.backends.base.synthetic_algorithm`, fused kinds as
   a :func:`~repro.core.backends.base.synthetic_fused_algorithm` —
   with the candidate config injected via
   :meth:`~repro.core.backends.jax_backend.PallasBackend.tuning_override`
   (the exact dispatch path production traffic uses);
3. record the fastest measured config as a
   :class:`~repro.core.tuning.TunedEntry`; for gemm, additionally probe
   the Mosaic ``dimension_semantics`` pipeline knob on the winning tile
   (one extra timing — it does not move the roofline model, so it is
   never enumerated into the candidate space).

Operands are synthesized once per request and shared by every candidate
timing, so candidates race on identical data.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.backends.base import (
    synthetic_algorithm,
    synthetic_fused_algorithm,
)
from repro.core.flops import KernelCall
from repro.core.perfmodel import RooflineProfile
from repro.core.tuning import (
    DEFAULT_CONFIGS,
    TUNABLE_KINDS,
    TunedEntry,
    TuningTable,
    prune_candidates,
)


def _request_algorithm(kind: str, dims: Sequence[int]):
    if kind in ("chain_gemm", "gemm_syrk"):
        return synthetic_fused_algorithm(kind, dims)
    return synthetic_algorithm(KernelCall(kind, tuple(dims)))


def autotune_request(
    backend,
    kind: str,
    dims: Sequence[int],
    *,
    profile: Optional[RooflineProfile] = None,
    reps: Optional[int] = None,
    budget: int = 8,
    slack: float = 2.0,
) -> TunedEntry:
    """Tune one ``(kind, dims)``: prune, time survivors, return the winner.

    ``budget`` caps how many configs reach the timer (the pre-filter's
    ``max_survivors``); ``slack`` is its roofline rejection threshold.
    ``backend`` must expose ``tuning_override`` (i.e. be a
    ``PallasBackend``) — candidates are injected through the same config
    lookup production dispatch uses, so what is measured is exactly what
    a table hit will later run.
    """
    dims = tuple(int(d) for d in dims)
    dtype_bytes = _dtype_bytes(backend)
    report = prune_candidates(kind, dims, profile=profile,
                              dtype_bytes=dtype_bytes, slack=slack,
                              max_survivors=budget)
    alg = _request_algorithm(kind, dims)
    operands = backend.make_operands(alg)

    def _time(config: Dict[str, int]) -> float:
        with backend.tuning_override({(kind, dims): config}):
            return backend.time_algorithm(alg, operands, reps=reps)

    timed: List[Tuple[float, Dict[str, int]]] = []
    default_seconds = None
    default = DEFAULT_CONFIGS[kind]
    for config in report.survivors:
        seconds = _time(config)
        timed.append((seconds, config))
        if _tiles_equal(config, default):
            default_seconds = seconds
    best_seconds, best_config = min(timed, key=lambda e: e[0])
    if kind == "gemm":
        piped = dict(best_config, pipeline=1)
        piped_seconds = _time(piped)
        timed.append((piped_seconds, piped))
        if piped_seconds < best_seconds:
            best_seconds, best_config = piped_seconds, piped
    if default_seconds is None:  # pragma: no cover - default is force-kept
        default_seconds = _time(default)
    return TunedEntry(
        config=dict(best_config),
        seconds=float(best_seconds),
        default_seconds=float(default_seconds),
        timed=len(timed),
        pruned=len(report.rejected),
    )


def autotune(
    backend,
    requests: Sequence[Tuple[str, Sequence[int]]],
    *,
    profile: Optional[RooflineProfile] = None,
    reps: Optional[int] = None,
    budget: int = 8,
    slack: float = 2.0,
    progress=None,
) -> TuningTable:
    """Tune every ``(kind, dims)`` request into one :class:`TuningTable`."""
    table = TuningTable()
    for i, (kind, dims) in enumerate(requests):
        entry = autotune_request(backend, kind, dims, profile=profile,
                                 reps=reps, budget=budget, slack=slack)
        table.set(kind, dims, entry)
        if progress is not None:
            progress(i + 1, len(requests), kind, tuple(dims), entry)
    return table


def default_tune_requests(
    calls: Sequence[KernelCall],
    fused_dims: Sequence[int] = (),
) -> List[Tuple[str, Tuple[int, ...]]]:
    """Tuning requests for a calibration grid's calls + fused diagonals.

    Base kinds come straight from the grid (minus ``tri2full``, which has
    no tile parameters); the fused patterns have no
    :class:`~repro.core.flops.KernelCall` representation, so each
    ``d ∈ fused_dims`` contributes the square-ish shapes
    ``chain_gemm (d,d,d,d)`` and ``gemm_syrk (d,d,d)``.
    """
    requests: List[Tuple[str, Tuple[int, ...]]] = []
    seen = set()
    for call in calls:
        key = (call.kind, call.dims)
        if call.kind in TUNABLE_KINDS and key not in seen:
            seen.add(key)
            requests.append(key)
    for d in fused_dims:
        d = int(d)
        for key in (("chain_gemm", (d, d, d, d)), ("gemm_syrk", (d, d, d))):
            if key not in seen:
                seen.add(key)
                requests.append(key)
    return requests


def _tiles_equal(a: Dict[str, int], b: Dict[str, int]) -> bool:
    keys = (set(a) | set(b)) - {"pipeline"}
    return all(a.get(k, 128) == b.get(k, 128) for k in keys)


def _dtype_bytes(backend) -> int:
    import numpy as np
    try:
        return int(np.dtype(backend.dtype).itemsize)
    except TypeError:
        return 4
