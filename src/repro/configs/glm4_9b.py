"""glm4-9b [dense] — hf:THUDM/glm-4-9b.

40L, d_model 4096, 32H (GQA kv=2), d_ff 13696, vocab 151552, RoPE, SwiGLU.
"""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab=151552,
        activation="silu",
        rope_theta=10000.0,
        tied_embeddings=False,
        max_seq=131072,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        activation="silu",
        tied_embeddings=False,
        max_seq=256,
    )
