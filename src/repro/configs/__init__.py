"""Assigned-architecture configs (exact published hyperparameters) +
reduced smoke variants + shape-cell definitions."""

from .base import (
    ALIASES,
    ARCH_IDS,
    SHAPES,
    ShapeSpec,
    all_cells,
    get,
    get_smoke,
    normalize,
    shape_applicable,
)

__all__ = [
    "ALIASES", "ARCH_IDS", "SHAPES", "ShapeSpec", "all_cells", "get",
    "get_smoke", "normalize", "shape_applicable",
]
