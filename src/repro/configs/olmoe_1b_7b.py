"""olmoe-1b-7b [moe] — arXiv:2409.02060.

16L, d_model 2048, 16H (kv=16), vocab 50304; every MLP is a 64-expert
top-8 MoE with expert d_ff 1024.
"""

from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab=50304,
        activation="silu",
        tied_embeddings=False,
        moe=MoEConfig(d_model=2048, d_ff=1024, n_experts=64, top_k=8),
        dense_residual=False,
        max_seq=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=64,
        vocab=256,
        activation="silu",
        tied_embeddings=False,
        moe=MoEConfig(d_model=64, d_ff=64, n_experts=4, top_k=2),
        dense_residual=False,
        max_seq=256,
    )
