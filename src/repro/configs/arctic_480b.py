"""arctic-480b [moe] — hf:Snowflake/snowflake-arctic-base.

35L, d_model 7168, 56H (GQA kv=8), vocab 32000. Dense-MoE hybrid
residual: every layer runs a dense SwiGLU MLP (d_ff 4864) in parallel with
a 128-expert top-2 MoE (expert d_ff 4864).
"""

from repro.models.moe import MoEConfig
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab=32000,
        activation="silu",
        tied_embeddings=False,
        moe=MoEConfig(d_model=7168, d_ff=4864, n_experts=128, top_k=2),
        dense_residual=True,
        max_seq=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab=256,
        activation="silu",
        tied_embeddings=False,
        moe=MoEConfig(d_model=64, d_ff=96, n_experts=4, top_k=2),
        dense_residual=True,
        max_seq=256,
    )
