"""mamba2-370m [ssm] — SSD (state-space duality), arXiv:2405.21060.

48L, d_model 1024, attention-free, vocab 50280, ssm_state N=128.
Standard Mamba2 hyperparameters: expand=2 → d_inner 2048, head_dim 64
→ 32 SSD heads, 1 B/C group, conv kernel 4.

This arch is the strongest in-model application of the paper's technique:
the SSD layer's quadratic/chunked dual is selected per shape by the LAMP
discriminant (models/ssm.py::select_ssd_mode).
"""

from repro.models.ssm import SSMConfig
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        vocab=50280,
        tied_embeddings=True,
        ssm=SSMConfig(
            d_model=1024, d_inner=2048, n_heads=32, head_dim=64,
            n_groups=1, d_state=128, conv_kernel=4, chunk=128,
            ssd_mode="auto", discriminant="perfmodel",
        ),
        max_seq=1048576,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        vocab=256,
        tied_embeddings=True,
        ssm=SSMConfig(
            d_model=64, d_inner=128, n_heads=4, head_dim=32,
            n_groups=1, d_state=16, conv_kernel=4, chunk=32,
            ssd_mode="auto", discriminant="perfmodel",
        ),
        max_seq=512,
    )
