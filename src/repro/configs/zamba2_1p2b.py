"""zamba2-1.2b [hybrid] — arXiv:2411.15242.

38 Mamba2 layers (d_model 2048, ssm_state 64) with a single *shared*
attention+MLP block (32H kv=32, d_ff 8192) applied every 6 Mamba2 layers.
The shared block uses sliding-window attention (4096) so the long_500k
decode cell stays sub-quadratic with a ring-buffer KV cache.
"""

from repro.models.ssm import SSMConfig
from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab=32000,
        activation="silu",
        tied_embeddings=True,
        ssm=SSMConfig(
            d_model=2048, d_inner=4096, n_heads=64, head_dim=64,
            n_groups=1, d_state=64, conv_kernel=4, chunk=128,
            ssd_mode="auto", discriminant="perfmodel",
        ),
        attn_every=6,
        shared_attn=True,
        shared_window=4096,
        max_seq=1048576,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        activation="silu",
        tied_embeddings=True,
        ssm=SSMConfig(
            d_model=64, d_inner=128, n_heads=4, head_dim=32,
            n_groups=1, d_state=16, conv_kernel=4, chunk=32,
            ssd_mode="auto", discriminant="perfmodel",
        ),
        attn_every=2,
        shared_attn=True,
        shared_window=32,
        max_seq=256,
    )
