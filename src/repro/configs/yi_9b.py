"""yi-9b [dense] — arXiv:2403.04652 (llama-architecture GQA).

48L, d_model 4096, 32H (GQA kv=4), d_ff 11008, vocab 64000.
"""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b",
        family="dense",
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab=64000,
        activation="silu",
        rope_theta=5000000.0,
        tied_embeddings=False,
        max_seq=131072,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-9b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        activation="silu",
        tied_embeddings=False,
        max_seq=256,
    )
