"""Architecture config registry + input-shape cells.

Each assigned architecture lives in ``configs/<id>.py`` exposing
``config()`` (the exact published configuration) and ``smoke()`` (a reduced
same-family variant for CPU smoke tests). ``registry()`` maps arch ids to
modules; ``get(name)`` / ``get_smoke(name)`` return ModelConfigs.

Shape cells (assignment):
  train_4k     seq 4096,   global_batch 256  (train_step)
  prefill_32k  seq 32768,  global_batch 32   (prefill)
  decode_32k   seq 32768,  global_batch 128  (serve_step, 1 new token)
  long_500k    seq 524288, global_batch 1    (decode; SSM/hybrid only)
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

from repro.models.transformer import ModelConfig

ARCH_IDS = (
    "mamba2_370m",
    "whisper_tiny",
    "internvl2_76b",
    "gemma2_9b",
    "glm4_9b",
    "phi3_mini",
    "yi_9b",
    "arctic_480b",
    "olmoe_1b_7b",
    "zamba2_1p2b",
)

# Assignment ids → module names (dashes/dots not importable).
ALIASES = {
    "mamba2-370m": "mamba2_370m",
    "whisper-tiny": "whisper_tiny",
    "internvl2-76b": "internvl2_76b",
    "gemma2-9b": "gemma2_9b",
    "glm4-9b": "glm4_9b",
    "phi3-mini-3.8b": "phi3_mini",
    "yi-9b": "yi_9b",
    "arctic-480b": "arctic_480b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "zamba2-1.2b": "zamba2_1p2b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def normalize(name: str) -> str:
    return ALIASES.get(name, name)


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(name)}")
    return mod.config()


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(name)}")
    return mod.smoke()


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Assignment skip rules. Returns (run?, reason-if-skipped)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("pure full-attention arch: 524288-token dense decode "
                       "requires sub-quadratic attention (DESIGN.md §6)")
    return True, ""


def all_cells():
    """Every (arch, shape) pair with its skip status — the 40-cell table."""
    out = []
    for arch in ARCH_IDS:
        cfg = get(arch)
        for sname, sspec in SHAPES.items():
            run, why = shape_applicable(cfg, sspec)
            out.append((arch, sname, run, why))
    return out
