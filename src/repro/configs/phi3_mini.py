"""phi3-mini-3.8b [dense] — arXiv:2404.14219.

32L, d_model 3072, 32H (kv=32, i.e. MHA), d_ff 8192, vocab 32064,
RoPE + SwiGLU. head_dim = 96 (non-128 — exercises MXU padding in the
perf model and kernels).
"""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab=32064,
        activation="silu",
        tied_embeddings=True,
        max_seq=131072,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        activation="silu",
        tied_embeddings=True,
        max_seq=256,
    )
