"""whisper-tiny [audio] — enc-dec, arXiv:2212.04356.

4L decoder (+4L encoder), d_model 384, 6 heads (kv=6), d_ff 1536,
vocab 51865. Conv/log-mel frontend is a STUB per the assignment:
input_specs provides the 1500 precomputed frame embeddings.
"""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        n_layers=4,
        encoder_layers=4,
        encoder_seq=1500,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab=51865,
        activation="gelu",       # plain MLP, not GLU
        tied_embeddings=True,
        max_seq=448,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-smoke",
        family="encdec",
        n_layers=2,
        encoder_layers=2,
        encoder_seq=64,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=256,
        activation="gelu",
        tied_embeddings=True,
        max_seq=64,
    )
