"""gemma2-9b [dense] — arXiv:2408.00118 (hf: google/gemma-2-9b).

42L, d_model 3584, 16H (GQA kv=8, head_dim 256), d_ff 14336,
vocab 256000. Gemma-2 specifics reproduced: alternating local(4096)/global
attention, attention logit softcap 50, final logit softcap 30, RMSNorm
(1+g) convention, pre+post norms, embedding scaled by sqrt(d), GeGLU.
"""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab=256000,
        activation="gelu_glu",
        window_pattern=(4096, 0),      # local, global alternating
        attn_softcap=50.0,
        final_softcap=30.0,
        post_norms=True,
        norm_plus_one=True,
        embed_scale=True,
        tied_embeddings=True,
        max_seq=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        activation="gelu_glu",
        window_pattern=(32, 0),
        attn_softcap=50.0,
        final_softcap=30.0,
        post_norms=True,
        norm_plus_one=True,
        embed_scale=True,
        tied_embeddings=True,
        max_seq=256,
    )
