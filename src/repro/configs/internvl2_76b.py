"""internvl2-76b [vlm] — InternViT + LLaMA3-70B-family LM, arXiv:2404.16821.

LM backbone: 80L, d_model 8192, 64H (GQA kv=8), d_ff 28672, vocab 128256.
The InternViT vision frontend is a STUB per the assignment: input_specs
provides precomputed patch embeddings (vision_tokens × d_model) prepended
to the token embeddings.
"""

from repro.models.transformer import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab=128256,
        activation="silu",
        rope_theta=500000.0,
        tied_embeddings=False,
        vision_tokens=256,
        max_seq=131072,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
        activation="silu",
        tied_embeddings=False,
        vision_tokens=8,
        max_seq=256,
    )
