"""Planner-as-a-service: the concurrent shape→plan cache for serving.

The planner (:mod:`repro.core.planner`) makes algorithm selection a
runtime feature; this module makes it a *servable* one. A live decode
path cannot afford enumeration+ranking per request (milliseconds), nor a
lock on the hit path (convoys under thousands of concurrent requests).
The serving layer therefore splits the problem three ways
(docs/serving.md is the narrative version):

* :class:`PlanCache` — shape→plan map with **lock-free reads**. Hits are
  a single ``dict.get`` on an immutable-once-published entry (safe under
  both the GIL and free-threaded builds: entries are published fully
  constructed, never mutated). The single lock is taken only on miss, to
  install a :class:`_Inflight` marker — which also gives **request
  coalescing**: N concurrent same-shape misses run ONE
  enumeration+selection; the other N−1 park on an event and read the
  published plan.
* **Generation invalidation** — the cache key is ``(expr, dims, dtype,
  backend, policy fingerprint, profile generation)``. Online refinement
  bumps the profile's generation; the next lookup misses, re-ranks under
  the new table, and publishing the fresh plan purges the stale
  same-shape entry so the cache never grows per refinement.
* :class:`RefinementQueue` + a :class:`~repro.runtime.supervisor.
  BackgroundWorker` — production timings are folded into the profile
  *asynchronously*. The request path only appends to a bounded deque
  (drop-oldest on overflow, never blocks); the worker drains it through
  :meth:`Planner.observe`, and ``shutdown(drain=True)`` quiesces
  producers, drains the worker, then re-drains inline so timings from
  racing producers are folded too (the supervisor's drain contract).

:class:`PlanService` is the facade model code talks to; the process-wide
instance comes from :func:`default_plan_service` and honours the
``REPRO_SERVE_PLANNER=0`` kill-switch.
"""

from __future__ import annotations

import collections
import os
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.analysis import assert_algorithms_valid
from repro.core.backends import measure_seconds
from repro.core.expressions import get_spec
from repro.core.planner import Plan, Planner
from repro.runtime.supervisor import BackgroundWorker

__all__ = [
    "PlanCache", "PlanService", "RefinementQueue",
    "default_plan_service", "planner_enabled", "reset_default_plan_service",
]


def planner_enabled() -> bool:
    """Serving kill-switch: ``REPRO_SERVE_PLANNER=0`` disables the consult.

    Model hot paths check this before touching the service, so a
    mis-calibrated profile can be neutralised in production without a
    code change (docs/serving.md §tuning).
    """
    return os.environ.get("REPRO_SERVE_PLANNER", "1") != "0"


class _Inflight:
    """Per-key miss marker: the first thread computes, the rest wait."""

    __slots__ = ("event", "plan", "error")

    def __init__(self):
        self.event = threading.Event()
        self.plan: Optional[Plan] = None
        self.error: Optional[BaseException] = None


class _StatSlot:
    """One thread's counters; written without any lock (single writer)."""

    __slots__ = ("hits", "misses", "coalesced", "errors")

    def __init__(self):
        self.hits = 0
        self.misses = 0
        self.coalesced = 0
        self.errors = 0


class PlanCache:
    """Concurrent shape→plan cache: lock-free hits, coalesced misses.

    Keys are opaque hashable tuples whose LAST component is the profile
    generation; the prefix (everything else) identifies the shape. When a
    plan for generation *g* is published, any entry for the same prefix
    at an older generation is purged — invalidation never leaks memory.

    Read path (hit): one ``dict.get``. No lock, no allocation. Entries
    are fully-constructed :class:`Plan` objects, published exactly once.

    Miss path: the lock guards only the inflight map. The first thread
    per key installs an :class:`_Inflight` and runs ``compute()`` OUTSIDE
    the lock; concurrent same-key callers wait on its event (coalescing:
    exactly one enumeration per shape, a property the stress tests pin).
    A failed compute propagates to every waiter and uninstalls the
    marker, so the shape can be retried.

    Stats are exact *and* lock-free on the hot path: each thread owns a
    private :class:`_StatSlot` (registered once, under the lock);
    :meth:`stats` aggregates across slots.
    """

    def __init__(self):
        self._plans: Dict[Tuple, Plan] = {}
        self._by_prefix: Dict[Tuple, Tuple] = {}   # prefix -> live full key
        self._inflight: Dict[Tuple, _Inflight] = {}
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._slots: List[_StatSlot] = []

    # -- stats ------------------------------------------------------------
    def _slot(self) -> _StatSlot:
        slot = getattr(self._tls, "slot", None)
        if slot is None:
            slot = _StatSlot()
            self._tls.slot = slot
            with self._lock:
                self._slots.append(slot)
        return slot

    def stats(self) -> Dict[str, int]:
        """Aggregate counters across threads (cold path; exact totals)."""
        with self._lock:
            slots = list(self._slots)
            size = len(self._plans)
        out = {"hits": 0, "misses": 0, "coalesced": 0, "errors": 0}
        for s in slots:
            out["hits"] += s.hits
            out["misses"] += s.misses
            out["coalesced"] += s.coalesced
            out["errors"] += s.errors
        out["size"] = size
        lookups = out["hits"] + out["misses"] + out["coalesced"]
        out["lookups"] = lookups
        return out

    # -- lookup -----------------------------------------------------------
    def get(self, key: Tuple, compute: Callable[[], Plan]) -> Plan:
        """Return the plan for ``key``, computing it at most once.

        ``key[:-1]`` is the shape prefix, ``key[-1]`` the profile
        generation (see class docstring). ``compute`` runs outside the
        lock in exactly one thread per in-flight key.
        """
        # Acquire the stat slot BEFORE any critical section: a thread's
        # first _slot() call registers itself under self._lock, which is
        # non-reentrant — calling it while holding the lock would
        # self-deadlock (exactly the thundering-herd cold start where a
        # fresh thread races a just-published plan).
        slot = self._slot()
        plan = self._plans.get(key)          # lock-free hit path
        if plan is not None:
            slot.hits += 1
            return plan
        with self._lock:
            plan = self._plans.get(key)      # published while we raced
            if plan is not None:
                slot.hits += 1
                return plan
            inflight = self._inflight.get(key)
            if inflight is None:
                inflight = _Inflight()
                self._inflight[key] = inflight
                owner = True
            else:
                owner = False
        if not owner:
            slot.coalesced += 1
            inflight.event.wait()
            if inflight.error is not None:
                raise inflight.error
            return inflight.plan
        slot.misses += 1
        try:
            plan = compute()
        except BaseException as e:
            slot.errors += 1
            with self._lock:
                self._inflight.pop(key, None)
            inflight.error = e
            inflight.event.set()
            raise
        prefix = key[:-1]
        with self._lock:
            self._plans[key] = plan
            stale = self._by_prefix.get(prefix)
            if stale is not None and stale != key:
                self._plans.pop(stale, None)  # generation-bump purge
            self._by_prefix[prefix] = key
            self._inflight.pop(key, None)
        inflight.plan = plan
        inflight.event.set()
        return plan

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self._by_prefix.clear()


class RefinementQueue:
    """Bounded timing queue between the request path and the worker.

    ``put`` NEVER blocks: at capacity the oldest pending timing is
    dropped (``dropped`` counts them). Backpressure therefore degrades
    refinement freshness, not request latency — the right trade for a
    serving path where a timing is advisory but a stall is an SLO miss
    (docs/serving.md §refinement).
    """

    def __init__(self, maxlen: int = 1024):
        self._items: collections.deque = collections.deque(maxlen=maxlen)
        self._lock = threading.Lock()
        self.maxlen = maxlen
        self.enqueued = 0
        self.dropped = 0

    def put(self, item: Any) -> bool:
        """Append; returns False iff an older item was dropped to make room."""
        with self._lock:
            full = len(self._items) == self.maxlen
            self._items.append(item)       # deque(maxlen) evicts the head
            self.enqueued += 1
            if full:
                self.dropped += 1
            return not full

    def pop(self) -> Optional[Any]:
        with self._lock:
            return self._items.popleft() if self._items else None

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)


class PlanService:
    """Facade: zoo family + dims → plan, with async online refinement.

    Owns a :class:`~repro.core.planner.Planner`, a :class:`PlanCache`,
    a :class:`RefinementQueue` and (when ``refine=True``) a
    :class:`~repro.runtime.supervisor.BackgroundWorker` that drains
    timings into :meth:`Planner.observe`.

    ``lookup(family, dims)`` is the hot path: build the cache key (one
    ``profile_generation()`` read — a plain attribute load), then a
    lock-free cache probe; a miss delegates to the planner under the
    coalescing protocol. ``execute(...)`` additionally runs the plan,
    times it, and enqueues the timing for asynchronous refinement —
    request latency never includes profile maintenance.
    """

    def __init__(self, discriminant: str = "perfmodel",
                 backend: str = "numpy", dtype: str = "float32",
                 planner: Optional[Planner] = None, refine: bool = False,
                 queue_maxlen: int = 1024, verify_plans: bool = True):
        self.planner = planner if planner is not None else Planner(
            discriminant=discriminant, backend=backend)
        self.dtype = dtype
        self.verify_plans = verify_plans
        self.cache = PlanCache()
        self.queue = RefinementQueue(maxlen=queue_maxlen)
        self.refine = refine
        self._accepting = True
        self.worker: Optional[BackgroundWorker] = None
        if refine:
            self.worker = BackgroundWorker(
                self._refine_step, name="plan-refine").start()

    # -- hot path ---------------------------------------------------------
    def key(self, family: str, dims: Sequence[int]) -> Tuple:
        """The serving cache key (docs/serving.md §cache-key):
        ``(expr, dims, dtype, backend, policy fingerprint, generation)``.
        """
        return (family, tuple(int(d) for d in dims), self.dtype,
                self.planner.backend, self.planner.policy_fingerprint(),
                self.planner.profile_generation())

    def lookup(self, family: str, dims: Sequence[int]) -> Plan:
        """Shape → plan. Lock-free on hit; coalesced planner call on miss.

        With ``verify_plans`` (the default) the selected algorithm runs
        through the static plan verifier *inside* the coalesced compute:
        an invalid DAG raises :class:`repro.core.analysis.AnalysisError`
        before publication, so the cache can never serve — or retain — a
        plan that fails analysis (the :class:`PlanCache` failure path
        propagates to coalesced waiters and uninstalls the in-flight
        marker).
        """
        key = self.key(family, dims)

        def compute() -> Plan:
            spec = get_spec(family)
            chain = spec.chain(key[1])
            plan = self.planner.plan(chain)
            if self.verify_plans:
                assert_algorithms_valid(
                    [plan.algorithm], chain=chain,
                    context=f"serving plan {family}@{key[1]}")
            return plan

        return self.cache.get(key, compute)

    def execute(self, family: str, dims: Sequence[int], *arrays: Any) -> Any:
        """Plan, run, and (async) refine: the full serving request path.

        The execution is wall-timed (blocking on async dispatch — see
        :func:`repro.core.backends.measure_seconds`); the timing is
        appended to the bounded queue and folded into the profile by the
        background worker, never on this thread.
        """
        plan = self.lookup(family, dims)
        if not self.refine:
            return plan.fn(*arrays)
        out, seconds = measure_seconds(plan.fn, *arrays)
        if self._accepting:
            self.queue.put((plan, seconds))
            if self.worker is not None:
                self.worker.notify()
        return out

    # -- refinement worker ------------------------------------------------
    def _refine_step(self) -> bool:
        item = self.queue.pop()
        if item is None:
            return False
        plan, seconds = item
        self.planner.observe(plan, seconds)
        return True

    # -- lifecycle --------------------------------------------------------
    def warmup(self, shapes: Sequence[Tuple[str, Sequence[int]]]) -> None:
        """Pre-plan known shapes so first requests hit the cache."""
        for family, dims in shapes:
            self.lookup(family, dims)

    def stats(self) -> Dict[str, Any]:
        out = dict(self.cache.stats())
        out["refine_enqueued"] = self.queue.enqueued
        out["refine_dropped"] = self.queue.dropped
        out["refine_pending"] = len(self.queue)
        if self.worker is not None:
            out["refine_steps"] = self.worker.steps
            out["refine_errors"] = self.worker.errors
        return out

    def shutdown(self, drain: bool = True, timeout: float = 10.0) -> bool:
        """Quiesce producers, then stop the worker (drain by default).

        With ``drain=True`` every timing enqueued by quiesced producers
        is folded into the profile before we return. A producer racing
        this call (already past the ``_accepting`` check in
        :meth:`execute`) may enqueue after the worker observes an empty
        queue and exits; once the worker has exited we re-drain the
        queue inline, so such stragglers are folded too rather than
        silently dropped. Returns True iff the worker exited within
        ``timeout``.
        """
        self._accepting = False
        if self.worker is None:
            return True
        ok = self.worker.stop(drain=drain, timeout=timeout)
        if drain and ok:
            # Worker is gone, so stepping inline cannot race it.
            while self._refine_step():
                pass
        return ok


_default_service: Optional[PlanService] = None
_default_lock = threading.Lock()


def default_plan_service() -> PlanService:
    """Process-wide service used by the model hot paths (lazy singleton).

    Discriminant and backend come from ``REPRO_SERVE_DISCRIMINANT`` /
    ``REPRO_SERVE_BACKEND`` (defaults ``perfmodel`` / ``numpy`` — the
    consult is trace-time only, so the execution backend of the service
    is irrelevant to model numerics; see docs/serving.md §hot-path).
    """
    global _default_service
    with _default_lock:
        if _default_service is None:
            _default_service = PlanService(
                discriminant=os.environ.get(
                    "REPRO_SERVE_DISCRIMINANT", "perfmodel"),
                backend=os.environ.get("REPRO_SERVE_BACKEND", "numpy"))
        return _default_service


def reset_default_plan_service(shutdown: bool = True) -> None:
    """Drop the process-wide service (tests; config change)."""
    global _default_service
    with _default_lock:
        svc, _default_service = _default_service, None
    if svc is not None and shutdown:
        svc.shutdown(drain=False, timeout=2.0)
