"""Serving substrate: KV/SSM cache decode steps + generation loop."""

from . import decode

__all__ = ["decode"]
