"""Serving substrate: decode steps, generation loop, and the plan cache.

``decode`` hosts the KV/SSM-cache serving steps; ``plan_cache`` is the
planner-as-a-service layer (shape→plan cache with lock-free reads,
request coalescing, async refinement — see docs/serving.md).
"""

from . import decode, plan_cache
from .plan_cache import PlanService, default_plan_service

__all__ = ["decode", "plan_cache", "PlanService", "default_plan_service"]
