"""Serving steps: batched prefill + single-token decode (greedy/temperature).

``serve_step`` is what the decode shape cells lower: one new token against
a KV/SSM cache of ``seq_len`` per sequence. The surrounding projection
chains of a 1-token step are exactly the skinny-GEMM regime where the
paper's FLOPs-vs-efficiency divergence is largest (an (1×d)·(d×V) product
runs at a tiny fraction of MXU peak, so algorithm choice is dominated by
the efficiency profile, not FLOPs).

Planner integration (docs/serving.md): the decode attention tail consults
the serving plan cache at *trace* time (``attention.pv_wo_output``), so
:func:`plan_warmup` pre-populates the cache for a model's decode shapes
before the first request traces — first-token latency then never includes
an enumeration. :func:`generate` feeds per-step wall times to an optional
:class:`~repro.runtime.supervisor.StragglerMonitor`; whole-step times are
deliberately NOT folded into kernel tables (apportioning a step across
one GEMM's calls would poison the profile — per-plan refinement happens
in :meth:`repro.serve.plan_cache.PlanService.execute`).
"""

from __future__ import annotations

import functools
import time
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.transformer import ModelConfig
from repro.runtime.supervisor import StragglerMonitor


def plan_warmup(cfg: ModelConfig, max_s: int) -> List[Tuple[str, Tuple]]:
    """Pre-plan the zoo families a decode step of ``cfg`` will consult.

    Returns the (family, dims) pairs warmed so callers can log them.
    No-op (empty list) when the planner consult is disabled via
    ``REPRO_SERVE_PLANNER=0`` or the model has no attention layers.
    """
    from repro.serve.plan_cache import default_plan_service, planner_enabled
    if not planner_enabled():
        return []
    shapes: List[Tuple[str, Tuple]] = []
    if cfg.n_heads and cfg.head_dim:
        # attention.pv_wo_output's trace-time consult, per-head view.
        shapes.append(("decattn", (1, max_s, cfg.head_dim, cfg.d_model)))
        shapes.append(("decproj", (1, cfg.d_model,
                                   cfg.n_heads * cfg.head_dim)))
    if cfg.d_ff:
        shapes.append(("decmlp", (1, cfg.d_model, cfg.d_ff)))
    shapes.append(("decproj", (1, cfg.d_model, cfg.vocab)))  # logits
    default_plan_service().warmup(shapes)
    return shapes


class ServeState(NamedTuple):
    caches: Any
    last_tokens: jax.Array    # (B, 1)
    rng: jax.Array


def serve_step(state: ServeState, params: Any, *, cfg: ModelConfig,
               temperature: float = 0.0
               ) -> Tuple[ServeState, jax.Array]:
    """One decode step for the whole batch → (new state, next tokens)."""
    logits, caches = api.decode_step(params, cfg, state.last_tokens,
                                     state.caches)
    logits = logits[:, -1, :]
    if temperature > 0:
        rng, sub = jax.random.split(state.rng)
        nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
    else:
        rng = state.rng
        nxt = jnp.argmax(logits, axis=-1)
    nxt = nxt[:, None].astype(jnp.int32)
    return ServeState(caches=caches, last_tokens=nxt, rng=rng), nxt


def make_serve_step(cfg: ModelConfig, **kw):
    return functools.partial(serve_step, cfg=cfg, **kw)


def generate(params: Any, cfg: ModelConfig, prompt: jax.Array,
             max_new: int, max_s: Optional[int] = None,
             batch_inputs: Optional[Dict[str, Any]] = None,
             temperature: float = 0.0, seed: int = 0,
             monitor: Optional[StragglerMonitor] = None) -> jax.Array:
    """Greedy/temperature generation: prompt (B, S0) → (B, S0 + max_new).

    Prefill fills the caches token-by-token for cache-correct semantics on
    every family (attention archs could batch-prefill; the SSM/hybrid
    single-step path is exact for all).

    The plan cache is warmed for this config's decode shapes before the
    first trace (:func:`plan_warmup`). Pass a ``monitor`` to feed decode
    step wall times into a straggler watchdog — generation itself never
    writes step times into kernel profiles (see module docstring).
    """
    b, s0 = prompt.shape
    max_s = max_s or (s0 + max_new + 1)
    plan_warmup(cfg, max_s)
    caches = api.init_caches(params, cfg, b, max_s,
                             batch_inputs=batch_inputs)
    state = ServeState(caches=caches,
                       last_tokens=prompt[:, :1],
                       rng=jax.random.PRNGKey(seed))
    step = jax.jit(make_serve_step(cfg, temperature=temperature))
    out = [prompt]
    # Teacher-forced prefill: feed prompt tokens, ignore predictions.
    for i in range(s0 - 1):
        state, _ = step(state, params)
        state = state._replace(last_tokens=prompt[:, i + 1: i + 2])
    gen = []
    for n in range(max_new):
        t0 = time.perf_counter()
        state, nxt = step(state, params)
        if monitor is not None:
            jax.block_until_ready(nxt)
            monitor.observe(n, time.perf_counter() - t0)
        gen.append(nxt)
    return jnp.concatenate(out + gen, axis=1)
