"""Serving steps: batched prefill + single-token decode (greedy/temperature).

``serve_step`` is what the decode shape cells lower: one new token against
a KV/SSM cache of ``seq_len`` per sequence. The surrounding projection
chains of a 1-token step are exactly the skinny-GEMM regime where the
paper's FLOPs-vs-efficiency divergence is largest (an (1×d)·(d×V) product
runs at a tiny fraction of MXU peak, so algorithm choice is dominated by
the efficiency profile, not FLOPs).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.transformer import ModelConfig


class ServeState(NamedTuple):
    caches: Any
    last_tokens: jax.Array    # (B, 1)
    rng: jax.Array


def serve_step(state: ServeState, params: Any, *, cfg: ModelConfig,
               temperature: float = 0.0
               ) -> Tuple[ServeState, jax.Array]:
    """One decode step for the whole batch → (new state, next tokens)."""
    logits, caches = api.decode_step(params, cfg, state.last_tokens,
                                     state.caches)
    logits = logits[:, -1, :]
    if temperature > 0:
        rng, sub = jax.random.split(state.rng)
        nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
    else:
        rng = state.rng
        nxt = jnp.argmax(logits, axis=-1)
    nxt = nxt[:, None].astype(jnp.int32)
    return ServeState(caches=caches, last_tokens=nxt, rng=rng), nxt


def make_serve_step(cfg: ModelConfig, **kw):
    return functools.partial(serve_step, cfg=cfg, **kw)


def generate(params: Any, cfg: ModelConfig, prompt: jax.Array,
             max_new: int, max_s: Optional[int] = None,
             batch_inputs: Optional[Dict[str, Any]] = None,
             temperature: float = 0.0, seed: int = 0) -> jax.Array:
    """Greedy/temperature generation: prompt (B, S0) → (B, S0 + max_new).

    Prefill fills the caches token-by-token for cache-correct semantics on
    every family (attention archs could batch-prefill; the SSM/hybrid
    single-step path is exact for all)."""
    b, s0 = prompt.shape
    max_s = max_s or (s0 + max_new + 1)
    caches = api.init_caches(params, cfg, b, max_s,
                             batch_inputs=batch_inputs)
    state = ServeState(caches=caches,
                       last_tokens=prompt[:, :1],
                       rng=jax.random.PRNGKey(seed))
    step = jax.jit(make_serve_step(cfg, temperature=temperature))
    out = [prompt]
    # Teacher-forced prefill: feed prompt tokens, ignore predictions.
    for i in range(s0 - 1):
        state, _ = step(state, params)
        state = state._replace(last_tokens=prompt[:, i + 1: i + 2])
    gen = []
    state, nxt = step(state, params)
    gen.append(nxt)
    for _ in range(max_new - 1):
        state, nxt = step(state, params)
        gen.append(nxt)
    return jnp.concatenate(out + gen, axis=1)
