"""LR schedules (warmup + cosine / linear / constant) as pure functions of
the step — jit-safe, checkpoint-free."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(1, warmup)
    t = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(s < warmup, warm, cos)


def warmup_linear(step, peak_lr: float, warmup: int, total: int):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(1, warmup)
    t = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
    return jnp.where(s < warmup, warm, peak_lr * (1 - t))


def constant(step, peak_lr: float, warmup: int = 0, total: int = 0):
    s = step.astype(jnp.float32)
    if warmup:
        return jnp.minimum(peak_lr, peak_lr * s / warmup)
    return jnp.full_like(s, peak_lr)


SCHEDULES = {
    "cosine": warmup_cosine,
    "linear": warmup_linear,
    "constant": constant,
}
