"""Muon optimizer — the paper's AAᵀB expression in production.

Muon (momentum + Newton–Schulz orthogonalization; Jordan et al. 2024)
post-processes each 2-D momentum matrix M with the quintic iteration

    X ← a·X + b·(X Xᵀ)·X + c·(X Xᵀ)²·X

Every iteration evaluates Gram-times-matrix products — *exactly* the
paper's ``A·Aᵀ·B`` expression (§3.2.2). The LAMP layer exposes the same
five algorithms the paper enumerates (SYRK+SYMM / SYRK+fill+GEMM /
GEMM+SYMM / GEMM+GEMM / (AᵀB)-first) and two associations of the quintic:

  * ``gram``   — G = X Xᵀ once (m×m), then b·G·X + c·G·(G·X):
    FLOPs 2m²k + 4m²k ~ better when m ≪ k (wide matrices);
  * ``seq``    — right-to-left without materializing the m×m Gram when
    m ≫ k is false... (tall): Y₁ = Xᵀ·X (k×k) association.

``plan_ns_step`` scores the associations per weight shape with the paper's
discriminants (``flops`` = what a naive implementation does; ``perfmodel``
= the paper's conclusion). On the transposed-orientation trick: Muon
conventionally transposes X so m ≤ k; the planner makes that decision
quantitative instead of heuristic.

The non-2D params (norms, embeddings by convention) fall through to AdamW.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.flops import gemm as gemm_call, symm as symm_call, \
    syrk as syrk_call
from repro.core.perfmodel import AnalyticalTPUProfile, KernelProfile

from . import adamw

# Quintic Newton–Schulz coefficients (Jordan et al.).
NS_COEFFS = (3.4445, -4.7750, 2.0315)
NS_STEPS = 5


def ns_algorithm_calls(mode: str, m: int, k: int):
    """Kernel-call bags for one NS iteration on an (m, k) matrix."""
    if mode == "gram":
        # G = X Xᵀ (syrk-able), A = G X (symm-able), B = G A
        return [syrk_call(m, k), symm_call(m, k), symm_call(m, k)]
    if mode == "gram_gemm":
        return [gemm_call(m, m, k), gemm_call(m, k, m), gemm_call(m, k, m)]
    if mode == "right":
        # K = Xᵀ X (k×k, syrk-able in transpose), then X·K, X·K²
        return [syrk_call(k, m), symm_call(k, m), gemm_call(k, k, k),
                gemm_call(m, k, k)]
    raise ValueError(mode)


def plan_ns_mode(m: int, k: int, discriminant: str = "perfmodel",
                 profile: Optional[KernelProfile] = None) -> str:
    """Pick the NS association per weight shape (the paper's selection)."""
    prof = profile or AnalyticalTPUProfile()
    modes = ("gram", "gram_gemm", "right")
    scores = {}
    for mode in modes:
        calls = ns_algorithm_calls(mode, m, k)
        if discriminant == "flops":
            scores[mode] = sum(c.flops for c in calls)
        else:
            scores[mode] = sum(prof.time(c, 2) for c in calls)
    return min(scores, key=scores.get)


def _ns_iteration_gram(x: jax.Array, use_symmetry: bool) -> jax.Array:
    a, b, c = NS_COEFFS
    if use_symmetry:
        # SYRK/SYMM realization: materialize one triangle of G, mirror in
        # registers (what repro.kernels.{syrk,symm} do on TPU). In pure-jnp
        # form XLA sees the symmetric structure through the tril+mirror.
        gl = jnp.tril(x @ x.T)
        g = gl + jnp.tril(gl, -1).T
    else:
        g = x @ x.T
    gx = g @ x
    return a * x + b * gx + c * (g @ gx)


def _ns_iteration_right(x: jax.Array) -> jax.Array:
    a, b, c = NS_COEFFS
    k = x.T @ x
    k2 = k @ k
    return a * x + x @ (b * k + c * k2)


def newton_schulz(x: jax.Array, steps: int = NS_STEPS,
                  mode: str = "auto", discriminant: str = "perfmodel"
                  ) -> jax.Array:
    """Orthogonalize via quintic NS in bf16 (Muon's recipe), with the
    association chosen by the LAMP discriminant per shape."""
    m, k = x.shape
    transpose = m > k
    if transpose:
        x = x.T
        m, k = k, m
    if mode == "auto":
        mode = plan_ns_mode(m, k, discriminant)
    xf = x.astype(jnp.bfloat16)
    norm = jnp.linalg.norm(xf.astype(jnp.float32)) + 1e-7
    xf = (xf.astype(jnp.float32) / norm).astype(jnp.bfloat16)
    for _ in range(steps):
        if mode in ("gram", "gram_gemm"):
            xf = _ns_iteration_gram(xf, use_symmetry=(mode == "gram"))
        else:
            xf = _ns_iteration_right(xf)
    out = xf.astype(x.dtype)
    return out.T if transpose else out


class MuonState(NamedTuple):
    step: jax.Array
    momentum: Any            # fp32, 2-D params only
    adamw: adamw.AdamWState  # fallback for non-matrix params


def _is_matrix(p: jax.Array) -> bool:
    return p.ndim == 2 and min(p.shape) >= 8


def partition(params: Any) -> Any:
    """Label pytree leaves: True → Muon, False → AdamW."""
    return jax.tree.map(_is_matrix, params)


def init(params: Any) -> MuonState:
    mom = jax.tree.map(
        lambda p: jnp.zeros_like(p, jnp.float32) if _is_matrix(p) else None,
        params)
    return MuonState(step=jnp.zeros((), jnp.int32), momentum=mom,
                     adamw=adamw.init(params))


def update(
    grads: Any,
    state: MuonState,
    params: Any,
    lr: jax.Array,
    momentum: float = 0.95,
    weight_decay: float = 0.0,
    adamw_lr_scale: float = 0.3,
    ns_mode: str = "auto",
    discriminant: str = "perfmodel",
) -> Tuple[Any, MuonState]:
    step = state.step + 1
    # AdamW branch updates everything; Muon overwrites matrix leaves.
    aw_params, aw_state = adamw.update(
        grads, state.adamw, params, lr * adamw_lr_scale,
        weight_decay=weight_decay)

    def muon_leaf(p, g, m):
        if m is None:
            return None, None
        gf = g.astype(jnp.float32)
        mnew = momentum * m + gf
        upd = newton_schulz(momentum * mnew + gf, mode=ns_mode,
                            discriminant=discriminant)
        # Shape-aware lr scale (Muon convention).
        scale = jnp.sqrt(jnp.maximum(1.0, p.shape[0] / p.shape[1]))
        pn = p.astype(jnp.float32) - lr * scale * upd.astype(jnp.float32)
        if weight_decay > 0:
            pn = pn - lr * weight_decay * p.astype(jnp.float32)
        return pn.astype(p.dtype), mnew

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.momentum)
    flat_aw = treedef.flatten_up_to(aw_params)
    new_p, new_m = [], []
    for p, g, m, aw in zip(flat_p, flat_g, flat_m, flat_aw):
        if m is None:
            new_p.append(aw)
            new_m.append(None)
        else:
            pn, mn = muon_leaf(p, g, m)
            new_p.append(pn)
            new_m.append(mn)
    return (treedef.unflatten(new_p),
            MuonState(step=step, momentum=treedef.unflatten(new_m),
                      adamw=aw_state))
