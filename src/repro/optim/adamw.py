"""AdamW from scratch (no optax in this environment).

Mixed-precision discipline: fp32 master params + fp32 moments regardless of
compute dtype; the train step casts a bf16 working copy for the forward/
backward. State is a plain pytree so the checkpoint store and the
ZeRO-style sharding rules (optimizer state sharded like params over the
``data`` axis) apply uniformly.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Any, AdamWState]:
    """Returns (new_params, new_state). Global-norm clipping included."""
    step = state.step + 1
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if grad_clip > 0:
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        gf = jax.tree.map(lambda g: g * scale, gf)
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, gf)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, gf)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        wd = weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (delta + wd)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
