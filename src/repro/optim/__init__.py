"""Optimizer stack (from scratch — no optax in this environment):
AdamW, Muon (Newton-Schulz over the paper's AA^TB expression, association
chosen by the LAMP discriminant), LR schedules, int8 error-feedback
gradient compression."""

from . import adamw, grad_compress, muon, schedule

__all__ = ["adamw", "grad_compress", "muon", "schedule"]
