"""Int8 error-feedback gradient compression for cross-pod all-reduce.

At 2×16×16 scale the cross-pod (DCN) all-reduce is the narrowest pipe;
compressing pod-boundary gradient traffic 4× (bf16→int8 blockwise) moves
the collective roofline term directly. Error feedback (Seide et al.;
Karimireddy et al.) keeps the quantization noise from biasing convergence:
the residual of each quantization is added back before the next one.

Usage inside the train step::

    comp, state = compress(grads, state)          # int8 + scales
    comp = lax.pmean(comp, axis_name="pod")        # cheap collective
    grads = decompress(comp)

The compression is blockwise-symmetric per 256-element block (last axis),
matching TPU lane width; scales are fp32.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: jax.Array        # int8, padded to block multiple
    scale: jax.Array    # fp32 per block
    shape: Tuple[int, ...]


class EFState(NamedTuple):
    residual: Any       # same pytree as grads, fp32


def init_state(grads: Any) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def _compress_leaf(g: jax.Array, r: jax.Array
                   ) -> Tuple[Compressed, jax.Array]:
    x = g.astype(jnp.float32) + r
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    deq = deq[: x.size].reshape(x.shape)
    new_r = x - deq
    return Compressed(q=q, scale=scale[:, 0], shape=tuple(g.shape)), new_r


def compress(grads: Any, state: EFState) -> Tuple[Any, EFState]:
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    comp, res = [], []
    for g, r in zip(flat_g, flat_r):
        c, nr = _compress_leaf(g, r)
        comp.append(c)
        res.append(nr)
    return (treedef.unflatten(comp),
            EFState(residual=treedef.unflatten(res)))


def _decompress_leaf(c: Compressed) -> jax.Array:
    deq = (c.q.astype(jnp.float32) * c.scale[:, None]).reshape(-1)
    n = 1
    for d in c.shape:
        n *= d
    return deq[:n].reshape(c.shape)


def decompress(comp: Any) -> Any:
    return jax.tree.map(_decompress_leaf, comp,
                        is_leaf=lambda x: isinstance(x, Compressed))
