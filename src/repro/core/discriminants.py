"""The discriminant registry — selection policies as pluggable entries.

A *discriminant* ranks the mathematically equivalent algorithms of one
expression instance. The paper evaluates FLOP count as a discriminant and
finds it unreliable in contiguous regions of the problem-size space; its
conclusion — "combining FLOP counts with kernel performance models will
significantly improve our ability to choose optimal algorithms" — and the
follow-up by Sankaran & Bientinesi (ranking from cheap *relative*
measurements) are both selection policies. This module makes the policy
axis pluggable, the same way :mod:`repro.core.backends` made the executor
axis pluggable and :mod:`repro.core.expressions` the expression axis:

* :class:`Discriminant` — the protocol: ``rank(algos, ctx)`` plus the
  capability flags ``requires_profile`` (ranking consults
  ``ctx.profile``) and ``requires_measurement`` (ranking executes on an
  execution backend). The flags let callers reject meaningless argument
  combinations loudly (a profile handed to ``flops`` used to be silently
  ignored) and let the planner skip profile-generation invalidation for
  policies whose ranking can never change with the profile.
* :class:`DiscriminantContext` — everything a policy may consult:
  profile, runner/backend, dtype width, and (for atlas replay —
  :mod:`repro.core.evaluate`) pre-recorded per-algorithm times that stand
  in for live measurement.
* :func:`register_discriminant` / :func:`get_discriminant` /
  :func:`registered_discriminants` — the registry ``selector.select``,
  the planner, the sweep CLI (``--mode evaluate --discriminants``) and
  the evaluation scoreboard resolve policies through.

Six entries ship:

====================  =========================================================
``flops``             min FLOP count (paper baseline; Linnea/Julia/Armadillo)
``perfmodel``         Σ predicted per-kernel time under the given profile
``hybrid``            perfmodel over the table-∨-analytical hybrid coercion
``roofline``          memory-traffic roofline max(flops/peak, bytes/bw) — no
                      MXU quantization, no profile; sees the zero-FLOP
                      TRI2FULL traffic that FLOPs cannot
``measured``          deduplicated per-kernel measurement on a backend
``rankk``             Sankaran-style budget-limited ranking: measure only the
                      top-k FLOPs candidates, rescale the model for the rest
====================  =========================================================

Every policy also exposes ``predict_times`` — the per-algorithm "times"
its ranking is the argsort of. That is what generalizes Experiment 3 into
a first-class API: a predicted classification (anomaly or not) can be
computed for *any* discriminant and scored against atlas ground truth
(:mod:`repro.core.evaluate`). For ``flops`` the predicted time IS the
FLOP count — literally the paper's premise — so its predicted fastest set
always equals its cheapest set and it can never predict an anomaly.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Mapping, Optional, Sequence

from .algorithms import Algorithm
from .perfmodel import (
    AnalyticalTPUProfile,
    HybridProfile,
    KernelProfile,
    RooflineProfile,
    TableProfile,
    predict_algorithm_time,
)

# ----------------------------------------------------------------- context --

#: Process-wide default runners for measurement-backed discriminants, one
#: per registry name. ``rank_by_measurement`` used to build a fresh
#: ``blas`` backend per call — re-zeroing the 64 MB cache-flush buffer
#: every time; the shared instance pays that once per process.
_SHARED_RUNNERS: Dict[str, object] = {}
_SHARED_LOCK = threading.Lock()


def shared_runner(name: str):
    """The process-wide default backend instance for ``name`` (cached)."""
    key = name.lower()
    with _SHARED_LOCK:
        runner = _SHARED_RUNNERS.get(key)
        if runner is None:
            from .backends import get_backend

            runner = get_backend(key, reps=3)
            _SHARED_RUNNERS[key] = runner
        return runner


@dataclasses.dataclass
class DiscriminantContext:
    """Everything a discriminant may consult while ranking.

    ``times`` is the replay channel: when set (atlas evaluation), it maps
    algorithm name -> measured seconds and stands in for live execution,
    so measurement-backed policies (``measured``, ``rankk``) can be
    scored against persisted ground truth without re-running anything.
    """

    profile: Optional[KernelProfile] = None
    runner: object = None
    backend: Optional[str] = None
    dtype_bytes: int = 2
    times: Optional[Mapping[str, float]] = None
    reps: Optional[int] = None

    def resolve_runner(self):
        """Explicit runner ∨ named backend ∨ the shared ``blas`` default."""
        if self.runner is not None:
            return self.runner
        return shared_runner(self.backend or "blas")

    def measure(self, algos: Sequence[Algorithm]) -> Dict[str, float]:
        """Per-algorithm seconds: replayed, or dedup-benchmarked live.

        The live path routes through
        :func:`repro.core.sweep.benchmark_unique_calls`: kernel calls
        shared across algorithms (most of them — sibling algorithms share
        long call prefixes) are timed once, and each algorithm's time is
        the additive model over its own *measured* entries.
        """
        if self.times is not None:
            return {a.name: float(self.times[a.name]) for a in algos}
        from .sweep import benchmark_unique_calls

        runner = self.resolve_runner()
        table, _, _ = benchmark_unique_calls(
            runner, [c for a in algos for c in a.calls],
            profile=TableProfile(peak_flops=1.0), reps=self.reps)
        return {a.name: sum(table.time(c) for c in a.calls) for a in algos}


# ---------------------------------------------------------------- protocol --


class Discriminant:
    """One selection policy: rank algorithms best-first.

    Capability flags (consulted by :func:`validate_arguments`, the
    selector shim and the planner):

    * ``requires_profile`` — the ranking consults ``ctx.profile`` (a
      missing profile may still default to the analytical model; the flag
      says a profile is *meaningful*, not mandatory).
    * ``requires_measurement`` — the ranking executes kernels on an
      execution backend (``ctx.runner``/``ctx.backend``), or replays
      recorded times through ``ctx.times``.

    Subclasses implement :meth:`predict_times` (the per-algorithm scores
    the ranking sorts by) and inherit :meth:`rank`; a policy whose order
    is not an argsort of scalar scores overrides :meth:`rank` directly
    and may return ``None`` from :meth:`predict_times`.
    """

    name: str = "abstract"
    requires_profile: bool = False
    requires_measurement: bool = False

    def fingerprint(self) -> str:
        """Identity for memo keys (parametrized policies extend this)."""
        return self.name

    def predict_times(self, algos: Sequence[Algorithm],
                      ctx: DiscriminantContext) -> Optional[Dict[str, float]]:
        """Per-algorithm predicted seconds (or score standing in for them).

        ``None`` means the policy has no per-algorithm scores (pure
        ordering); such a policy cannot predict anomaly classifications
        and is skipped by the recall/precision columns of the evaluation
        scoreboard.
        """
        return None

    def rank(self, algos: Sequence[Algorithm],
             ctx: DiscriminantContext) -> List[Algorithm]:
        """Best-first ranking; FLOPs then name break score ties."""
        times = self.predict_times(algos, ctx)
        if times is None:
            raise NotImplementedError(
                f"discriminant {self.name!r} defines neither predict_times "
                f"nor rank")
        return sorted(algos,
                      key=lambda a: (times[a.name], a.flops, a.name))


def as_hybrid(profile: Optional[KernelProfile]) -> HybridProfile:
    """Coerce any profile into the hybrid (table ∨ analytical) policy.

    * ``HybridProfile``   → used as-is;
    * ``TableProfile``    → wrapped with an analytical fallback;
    * anything else/None  → empty table over the given (or default)
      analytical model, so every call falls through to analytical until
      online refinement records measurements.
    """
    if isinstance(profile, HybridProfile):
        return profile
    if isinstance(profile, TableProfile):
        return HybridProfile(profile)
    analytical = profile or AnalyticalTPUProfile()
    return HybridProfile(TableProfile(peak_flops=analytical.peak()),
                         analytical=analytical)


# ----------------------------------------------------------- the policies --


class FlopsDiscriminant(Discriminant):
    """Paper-faithful baseline: ascending FLOP count, ties by name.

    ``predict_times`` returns the FLOP counts themselves — "FLOPs as the
    time estimate" is literally the premise the paper interrogates. Its
    predicted fastest set therefore always equals its cheapest set, so
    this policy can never predict an anomaly (scoreboard recall 0 by
    construction whenever anomalies exist).
    """

    name = "flops"

    def predict_times(self, algos, ctx):
        return {a.name: float(a.flops) for a in algos}


class PerfModelDiscriminant(Discriminant):
    """Ascending Σ predicted per-kernel time under the profile *as given*.

    ``None`` falls back to the closed-form analytical model. A bare,
    partially calibrated :class:`TableProfile` may raise ``KeyError`` on
    kernel kinds it has never seen — use ``hybrid`` when the calibration
    may be partial.
    """

    name = "perfmodel"
    requires_profile = True

    def _profile(self, ctx: DiscriminantContext) -> KernelProfile:
        return ctx.profile or AnalyticalTPUProfile()

    def predict_times(self, algos, ctx):
        prof = self._profile(ctx)
        return {a.name: predict_algorithm_time(a.calls, prof,
                                               ctx.dtype_bytes)
                for a in algos}


class HybridDiscriminant(PerfModelDiscriminant):
    """Perfmodel over :func:`as_hybrid` coercion — measured table entries
    where a calibration has them (exactly or by near nearest-neighbour),
    analytical fallback elsewhere, so partial calibrations still rank
    every candidate."""

    name = "hybrid"

    def _profile(self, ctx: DiscriminantContext) -> KernelProfile:
        return as_hybrid(ctx.profile)


class RooflineDiscriminant(Discriminant):
    """Memory-traffic-aware analytical ranking (no profile, no MXU model).

    Scores each call ``max(flops / peak, bytes·dtype / bandwidth)`` via
    :class:`~repro.core.perfmodel.RooflineProfile` — the simplest model
    that still charges the zero-FLOP TRI2FULL copies and SYRK's halved
    output traffic. Deliberately distinct from ``perfmodel``'s default
    analytical model (MXU tile quantization + per-call overhead): the two
    disagree exactly where tile-quantization cliffs dominate traffic.
    """

    name = "roofline"

    def __init__(self, profile: Optional[RooflineProfile] = None):
        self._roofline = profile or RooflineProfile()

    def predict_times(self, algos, ctx):
        return {a.name: predict_algorithm_time(a.calls, self._roofline,
                                               ctx.dtype_bytes)
                for a in algos}


class MeasuredDiscriminant(Discriminant):
    """Ground truth: ascending measured time on an execution backend.

    Measurement is deduplicated per kernel call
    (:meth:`DiscriminantContext.measure`): sibling algorithms share most
    of their calls, so each distinct ``(kind, dims)`` is timed once and
    algorithm times are additive over measured entries. Only affordable
    offline, or via the replay channel during atlas evaluation.
    """

    name = "measured"
    requires_measurement = True

    def predict_times(self, algos, ctx):
        return ctx.measure(algos)


class RankKDiscriminant(Discriminant):
    """Budget-limited relative-measurement ranking (Sankaran-style).

    Sankaran & Bientinesi rank algorithms from cheap *relative*
    measurements instead of exhaustive timing. This policy spends its
    measurement budget on the ``k`` FLOP-cheapest candidates only (the
    set FLOPs says should contain the winner — and where the paper shows
    it is most dangerously wrong), then rescales the model's predictions
    for the remaining candidates by the median measured/modelled ratio
    over the measured set, so every algorithm lands on one comparable
    time axis. ``k >= len(algos)`` degrades to ``measured``; ``k == 0``
    would degrade to ``hybrid`` (and is rejected).
    """

    name = "rankk"
    requires_profile = True
    requires_measurement = True

    def __init__(self, k: int = 3):
        if k < 1:
            raise ValueError("rankk needs a measurement budget k >= 1")
        self.k = k

    def fingerprint(self) -> str:
        return f"{self.name}(k={self.k})"

    def predict_times(self, algos, ctx):
        by_flops = sorted(algos, key=lambda a: (a.flops, a.name))
        top = by_flops[:self.k]
        measured = ctx.measure(top)
        prof = as_hybrid(ctx.profile)
        model = {a.name: predict_algorithm_time(a.calls, prof,
                                                ctx.dtype_bytes)
                 for a in algos}
        ratios = sorted(measured[a.name] / model[a.name] for a in top
                        if model[a.name] > 0 and measured[a.name] > 0)
        scale = ratios[len(ratios) // 2] if ratios else 1.0
        return {a.name: measured.get(a.name, model[a.name] * scale)
                for a in algos}


# ---------------------------------------------------------------- registry --

_REGISTRY: Dict[str, Discriminant] = {}


def register_discriminant(disc: Discriminant,
                          name: Optional[str] = None) -> Discriminant:
    """Register a policy instance under ``name`` (default ``disc.name``).

    Returns ``disc`` (declaration style). Duplicate names are rejected:
    silently shadowing ``flops`` would re-define the paper baseline every
    atlas evaluation is scored against.
    """
    key = (name or disc.name).lower()
    if key in _REGISTRY:
        raise ValueError(f"discriminant {key!r} is already registered")
    _REGISTRY[key] = disc
    return disc


def get_discriminant(name: str) -> Discriminant:
    """Resolve a registry name to its policy instance."""
    try:
        return _REGISTRY[name.lower()]
    except KeyError:
        raise KeyError(
            f"unknown discriminant {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def registered_discriminants() -> List[str]:
    return sorted(_REGISTRY)


def validate_arguments(disc: Discriminant,
                       profile: Optional[KernelProfile] = None,
                       runner: object = None,
                       backend: Optional[str] = None) -> None:
    """Reject argument combinations the policy would silently ignore.

    The capability flags make "this argument is meaningless here" a
    property of the policy instead of folklore: a profile handed to
    ``flops``/``measured`` or a runner handed to ``flops``/``perfmodel``
    used to be dropped on the floor — now it raises, naming the flag.
    """
    if runner is not None and backend is not None:
        raise ValueError("pass either runner= or backend=, not both")
    if profile is not None and not disc.requires_profile:
        raise ValueError(
            f"discriminant {disc.name!r} does not consult a profile "
            f"(requires_profile=False); the profile= argument would be "
            f"silently ignored")
    if (runner is not None or backend is not None) \
            and not disc.requires_measurement:
        raise ValueError(
            f"discriminant {disc.name!r} never executes kernels "
            f"(requires_measurement=False); the "
            f"{'runner=' if runner is not None else 'backend='} argument "
            f"would be silently ignored")


register_discriminant(FlopsDiscriminant())
register_discriminant(PerfModelDiscriminant())
register_discriminant(HybridDiscriminant())
register_discriminant(RooflineDiscriminant())
register_discriminant(MeasuredDiscriminant())
register_discriminant(RankKDiscriminant())
