"""Adaptive boundary-refinement sweeps + multi-host shard fan-out (ISSUE 7).

The paper's structural finding — anomalies "cluster into large contiguous
regions" (§3.4.2) — means a dense grid sweep spends most of its budget far
from any region boundary. This module is the active-learning alternative:

* :func:`adaptive_sweep` seeds a coarse sub-lattice of the grid through the
  one measurement path (:func:`repro.core.sweep.sweep`), classifies it,
  clusters the anomalies (:func:`repro.core.anomaly.cluster_regions`), and
  then spends the remaining budget only near region frontiers: *bisection*
  between axis-aligned nearest measured neighbours whose verdicts disagree
  (halving the gap until the boundary sits between adjacent grid cells),
  and *tracing* sideways from each adjacent opposite-verdict pair (walking
  the frontier at full resolution). It iterates until the budget is
  exhausted, a round proposes no new frontier, or the round cap is hit.

* Every measurement streams into the same resumable
  :class:`~repro.core.sweep.AnomalyAtlas`. Budget accounting is
  *trajectory-based*: a point admitted to the trajectory costs one unit of
  budget whether it is measured now or served from the atlas, so a killed
  adaptive sweep re-run with the same arguments deterministically replays
  the rounds already on disk (paying zero new measurements for them),
  resumes mid-round, and converges to exactly the measured set an
  uninterrupted run would have produced.

* ``shard=(k, n)`` fans one trajectory out across ``n`` hosts: every host
  computes the same deterministic candidate sequence, measures only its
  ``k``-th slice into its own per-host shard file
  (``atlas-…-shardK.jsonl``, same header/fingerprint format — see
  :func:`repro.core.sweep.atlas_shard_path`), and reads the sibling shard
  files back at each round boundary for the slices it did not measure.
  Per Peise & Bientinesi (arXiv:1409.8602), measurements are only
  comparable under matching hardware/cache conditions, so sibling shards
  are validated against the same fingerprint/spec/threshold header before
  their classifications are trusted. A host that gets ahead of its
  siblings stops with ``stopped="awaiting-siblings"`` (exit code 3 on the
  CLI) and is simply re-invoked once they catch up — the replay makes the
  re-invocation nearly free. ``tools/atlas_merge.py`` reconciles the shard
  files into one canonical atlas afterwards.

The planted-mask oracles in :mod:`repro.core.synthetic` pin the contract
(``tests/test_adaptive.py``): ≥ 0.9 frontier recall at ≤ 40 % of the dense
measurement count, candidates always on-grid and never already measured,
kill/resume convergence, and shard-merge ≡ unsharded equivalence.

Known limitation, by design: refinement only grows from seed hits — an
anomaly region smaller than the seed spacing along every axis can be
missed entirely. Size ``seed_stride`` below the narrowest region that
must not be lost.
"""

from __future__ import annotations

import dataclasses
import itertools
import os
from collections import defaultdict
from concurrent.futures import ProcessPoolExecutor
from pathlib import Path
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Set, Tuple

from .anomaly import Region, cluster_regions
from .expressions import GridSpec
from .sweep import AnomalyAtlas, Instance, sweep

Point = Tuple[int, ...]


# ------------------------------------------------------- frontier geometry ---


def seed_points(grid: GridSpec, stride: int) -> List[Point]:
    """Coarse sub-lattice: every ``stride``-th index per axis + endpoints.

    Endpoints are always included so the seed brackets the whole grid —
    bisection can only localize boundaries *between* measured points.
    Deterministic row-major order (the budget truncates a prefix of it).
    """
    if stride < 1:
        raise ValueError(f"seed stride must be >= 1, got {stride}")
    axes = []
    for ax in grid.axes:
        idx = list(range(0, len(ax), stride))
        if idx[-1] != len(ax) - 1:
            idx.append(len(ax) - 1)
        axes.append([int(ax[i]) for i in idx])
    return [tuple(p) for p in itertools.product(*axes)]


def _coords(verdicts: Mapping[Point, bool],
            grid: GridSpec) -> Dict[Point, Tuple[int, ...]]:
    """Map measured points to grid-index coordinates (all must be on-grid)."""
    index = [{int(v): i for i, v in enumerate(ax)} for ax in grid.axes]
    out: Dict[Point, Tuple[int, ...]] = {}
    for p in verdicts:
        if len(p) != grid.ndims:
            raise ValueError(
                f"measured point {p} has {len(p)} dims but the grid has "
                f"{grid.ndims} axes")
        c = []
        for d, v in enumerate(p):
            pos = index[d].get(int(v))
            if pos is None:
                raise ValueError(
                    f"measured point {p} is off-grid: value {v} is not on "
                    f"axis {d}")
            c.append(pos)
        out[p] = tuple(c)
    return out


def boundary_cells(verdicts: Mapping[Point, bool],
                   grid: GridSpec) -> Set[Point]:
    """Measured points with a measured grid-adjacent opposite-verdict
    neighbour — the localized frontier (ISSUE 7's boundary cells)."""
    coords = _coords(verdicts, grid)
    by_coord = {c: p for p, c in coords.items()}
    out: Set[Point] = set()
    for p, c in coords.items():
        for d in range(grid.ndims):
            for step in (-1, 1):
                q = by_coord.get(c[:d] + (c[d] + step,) + c[d + 1:])
                if q is not None and verdicts[q] != verdicts[p]:
                    out.add(p)
                    break
            else:
                continue
            break
    return out


def refinement_candidates(verdicts: Mapping[Point, bool],
                          grid: GridSpec) -> List[Point]:
    """Unmeasured grid points the next round should measure.

    Two deterministic generators, both driven by axis-aligned *nearest
    measured neighbour* pairs with opposite verdicts:

    * gap ≥ 2 grid positions → the index midpoint (bisection: each round
      halves the bracket until the boundary is between adjacent cells);
    * gap = 1 (a boundary cell pair) → the unmeasured grid neighbours of
      both endpoints along every *other* axis (tracing: the frontier is
      locally perpendicular to the pair's axis, so lateral steps follow
      it at full resolution without re-measuring straight-line interior/
      exterior cells).

    Never proposes an off-grid or already-measured point; sorted output,
    so budget truncation is deterministic.
    """
    coords = _coords(verdicts, grid)
    measured = set(coords.values())
    nd = grid.ndims
    out: Set[Tuple[int, ...]] = set()
    for d in range(nd):
        lines: Dict[Tuple[int, ...], List[Tuple[int, Point]]] = \
            defaultdict(list)
        for p, c in coords.items():
            lines[c[:d] + c[d + 1:]].append((c[d], p))
        for key, col in lines.items():
            col.sort()
            for (ia, pa), (ib, pb) in zip(col, col[1:]):
                if verdicts[pa] == verdicts[pb]:
                    continue
                if ib - ia >= 2:
                    out.add(key[:d] + ((ia + ib) // 2,) + key[d:])
                    continue
                for cend in (coords[pa], coords[pb]):
                    for e in range(nd):
                        if e == d:
                            continue
                        for step in (-1, 1):
                            j = cend[e] + step
                            if 0 <= j < len(grid.axes[e]):
                                out.add(cend[:e] + (j,) + cend[e + 1:])
    return sorted(
        tuple(int(grid.axes[d][i]) for d, i in enumerate(c))
        for c in out if c not in measured
    )


# --------------------------------------------------------- sibling shards ---


def _sibling_records(atlas: AnomalyAtlas,
                     shard: Tuple[int, int]) -> Dict[Point, Instance]:
    """Classifications measured by the other hosts of an n-way fan-out.

    Re-reads every sibling shard file next to ``atlas`` (tolerating torn
    tails exactly like any atlas load); headers are validated against this
    host's fingerprint/spec/threshold, so a foreign shard dropped into the
    directory fails loudly instead of polluting the frontier computation.
    """
    k, n = shard
    own = atlas.path.name
    suffix = f"-shard{k}.jsonl"
    if not own.endswith(suffix):
        raise ValueError(
            f"shard atlas path {atlas.path} does not end in {suffix!r}; "
            f"open it via atlas_shard_path()")
    out: Dict[Point, Instance] = {}
    for j in range(n):
        if j == k:
            continue
        path = atlas.path.with_name(own[:-len(suffix)] + f"-shard{j}.jsonl")
        if not path.is_file():
            continue
        sib = AnomalyAtlas(path, atlas.fingerprint, atlas.spec_name,
                           atlas.threshold, shard=(j, n))
        for rec in sib.records():
            out[rec.point] = rec
    return out


# ------------------------------------------------------------------ engine ---


@dataclasses.dataclass
class RoundStats:
    """One trajectory round (round 0 is the seed)."""

    index: int
    admitted: Tuple[Point, ...]   # global trajectory points, in order
    n_measured: int               # newly measured by this host
    n_cached: int                 # served from this host's atlas
    n_sibling: int                # served from sibling shard files
    n_missing: int                # admitted but not yet known (sibling lag)
    n_regions: int                # anomaly regions known after the round

    @property
    def n_admitted(self) -> int:
        return len(self.admitted)


@dataclasses.dataclass
class AdaptiveResult:
    """Everything an adaptive run learned, plus how it stopped.

    ``stopped`` is one of ``converged`` (a round proposed no new
    frontier), ``budget``, ``rounds`` (round cap), or
    ``awaiting-siblings`` (shard mode only: the trajectory needs
    classifications a sibling host has not written yet — re-invoke after
    the siblings advance; the replay resumes mid-round for free).
    """

    spec_name: str
    grid: GridSpec
    budget: int
    spent: int                    # global trajectory points admitted
    stopped: str
    rounds: List[RoundStats]
    known: Dict[Point, Instance]
    shard: Optional[Tuple[int, int]]
    atlas_path: Optional[Path]
    wall_s: float

    @property
    def n_measured(self) -> int:
        """New measurements performed by this host, this invocation."""
        return sum(r.n_measured for r in self.rounds)

    @property
    def n_refine_rounds(self) -> int:
        return max(0, len(self.rounds) - 1)

    def records(self) -> List[Instance]:
        return list(self.known.values())

    def anomalies(self) -> List[Instance]:
        return [r for r in self.known.values() if r.cls.is_anomaly]

    def verdicts(self) -> Dict[Point, bool]:
        return {p: i.cls.is_anomaly for p, i in self.known.items()}

    def frontier(self) -> Set[Point]:
        """Localized boundary cells among everything known."""
        return boundary_cells(self.verdicts(), self.grid)

    def regions(self) -> List[Region]:
        """Contiguous anomaly regions over the known (sparse) point set."""
        scores = {p: (i.cls.time_score, i.cls.flop_score)
                  for p, i in self.known.items() if i.cls.is_anomaly}
        return cluster_regions(scores, self.grid.axes)


def adaptive_sweep(
    spec,
    grid: GridSpec,
    budget: int,
    rounds: Optional[int] = None,
    *,
    threshold: float = 0.10,
    atlas: Optional[AnomalyAtlas] = None,
    shard: Optional[Tuple[int, int]] = None,
    seed_stride: int = 4,
    runner=None,
    runner_factory: Optional[Callable[[], object]] = None,
    backend: str = "serial",
    shards: Optional[int] = None,
    exec_backend: Optional[str] = None,
    reps: int = 3,
    dtype: str = "float32",
    chunk_size: int = 8,
    progress: Optional[Callable[[int, int, Instance], None]] = None,
    fastpath: Optional[bool] = None,
    seed: Optional[int] = None,
) -> AdaptiveResult:
    """Boundary-refining sweep: coarse seed, then budgeted frontier rounds.

    ``budget`` caps the number of *trajectory* points (seed + refinement,
    global across shard hosts); points replayed from the atlas consume
    trajectory budget but zero new measurements, which is what makes a
    resumed run honor the remaining budget instead of the original.
    ``rounds`` caps refinement rounds (``None`` = until budget or
    convergence). Runner/backend knobs — including the fast-path switch
    and operand ``seed`` — are forwarded verbatim to
    :func:`repro.core.sweep.sweep`; with ``backend="process"`` one pool is
    reused across every round, so worker arenas and executable memos
    persist across rounds too (refinement revisits neighbouring shapes).
    ``shard=(k, n)`` requires ``atlas`` to be the host's shard file opened
    with the same shard identity.
    """
    import time as _time

    if budget < 1:
        raise ValueError(f"budget must be >= 1, got {budget}")
    if rounds is not None and rounds < 0:
        raise ValueError(f"rounds must be >= 0, got {rounds}")
    if grid.ndims != spec.ndims:
        raise ValueError(
            f"grid has {grid.ndims} axes but expression {spec.name} takes "
            f"{spec.ndims} dims")
    if shard is not None:
        k, n = int(shard[0]), int(shard[1])
        if not 0 <= k < n:
            raise ValueError(f"shard must be (k, n) with 0 <= k < n; "
                             f"got {shard}")
        shard = (k, n)
        if atlas is None:
            raise ValueError(
                "shard mode needs the host's shard atlas (open it via "
                "atlas_shard_path) — shards without persistence cannot "
                "be merged")
        if atlas.shard != shard:
            raise ValueError(
                f"atlas {atlas.path} is shard {atlas.shard}, but "
                f"adaptive_sweep was called with shard {shard}")

    t0 = _time.perf_counter()
    known: Dict[Point, Instance] = {}
    stats: List[RoundStats] = []
    executor = None
    if backend == "process":
        # One pool across every round: refinement rounds are many small
        # sweeps, so per-round process start-up would dominate.
        executor = ProcessPoolExecutor(
            max_workers=shards or os.cpu_count() or 1)

    def run_round(idx: int, admitted: Sequence[Point]) -> bool:
        """Measure this host's slice; pull the rest from siblings.
        Returns True when every admitted point is now known."""
        mine = list(admitted) if shard is None else list(admitted)[k::n]
        res = sweep(spec, mine, runner=runner,
                    runner_factory=runner_factory, backend=backend,
                    shards=shards, exec_backend=exec_backend, reps=reps,
                    dtype=dtype, chunk_size=chunk_size,
                    threshold=threshold, atlas=atlas, executor=executor,
                    progress=progress, fastpath=fastpath, seed=seed)
        for rec in res.records:
            known[rec.point] = rec
        n_sib = n_missing = 0
        if shard is not None:
            theirs = [p for i, p in enumerate(admitted) if i % n != k]
            if theirs:
                sib = _sibling_records(atlas, shard)
                for p in theirs:
                    inst = sib.get(p)
                    if inst is None:
                        n_missing += 1
                    else:
                        known[p] = inst
                        n_sib += 1
        regions = cluster_regions(
            {p: (i.cls.time_score, i.cls.flop_score)
             for p, i in known.items() if i.cls.is_anomaly},
            grid.axes)
        stats.append(RoundStats(
            index=idx, admitted=tuple(admitted), n_measured=res.n_measured,
            n_cached=res.n_skipped, n_sibling=n_sib, n_missing=n_missing,
            n_regions=len(regions)))
        return n_missing == 0

    try:
        seed = seed_points(grid, seed_stride)
        admitted = seed[:budget]
        spent = len(admitted)
        complete = run_round(0, admitted)
        r = 0
        while True:
            if not complete:
                stopped = "awaiting-siblings"
                break
            if spent >= budget:
                stopped = "budget"
                break
            if rounds is not None and r >= rounds:
                stopped = "rounds"
                break
            cands = refinement_candidates(
                {p: i.cls.is_anomaly for p, i in known.items()}, grid)
            if not cands:
                stopped = "converged"
                break
            r += 1
            admitted = cands[:budget - spent]
            spent += len(admitted)
            complete = run_round(r, admitted)
    finally:
        if executor is not None:
            executor.shutdown()
        if atlas is not None:
            atlas.flush()

    return AdaptiveResult(
        spec_name=spec.name,
        grid=grid,
        budget=budget,
        spent=spent,
        stopped=stopped,
        rounds=stats,
        known=known,
        shard=shard,
        atlas_path=atlas.path if atlas is not None else None,
        wall_s=_time.perf_counter() - t0,
    )
