"""Calibration: measure this machine's kernel profile, once.

The paper's conclusion — FLOPs alone mispredict; combine them with kernel
performance models — needs those models to exist for *this* hardware.
:func:`calibrate` sweeps the kernel space (gemm/syrk/symm over a
log-spaced dim grid, plus tri2full) with any registered execution backend
(:mod:`repro.core.backends` — the registry key is the profile fingerprint
key), builds a measured :class:`~repro.core.perfmodel.TableProfile`, and
persists it via :mod:`repro.core.profile_store` so the cost is paid once
per machine: subsequent processes auto-load it through
``default_planner()``.

CLI::

    PYTHONPATH=src python -m repro.core.calibrate --grid small --out DIR
    PYTHONPATH=src python -m repro.core.calibrate --backend jax --grid default
    PYTHONPATH=src python -m repro.core.calibrate --backend pallas --grid small

Grids are named (small/default/full) rather than free-form so cache files
produced on different machines cover comparable shape ranges.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from pathlib import Path
from typing import Iterable, List, Optional

from .backends import backend_default_dtype, make_backend, registered_backends
from .flops import KernelCall, gemm, symm, syrk, tri2full
from .perfmodel import TableProfile
from .profile_store import (
    HardwareFingerprint,
    current_fingerprint,
    save_profile,
)

# Log-spaced (power-of-two) dim grids. "small" finishes in seconds and is
# meant for tests/smoke; "default" is the per-machine calibration;
# "full" approaches the paper's boxes (minutes of BLAS time).
GRIDS = {
    "tiny": (64, 128),
    "small": (64, 128, 256),
    "default": (32, 64, 128, 256, 512, 1024),
    "full": (32, 64, 128, 256, 512, 1024, 1536, 2048),
}


def expression_calls(spec, grid_name: str = "small") -> List[KernelCall]:
    """The deduplicated kernel-call set of one registered expression family
    over its named sweep grid — the targeted alternative to the full
    :func:`grid_calls` cross product.

    ``python -m repro.core.calibrate --expr NAME`` uses this so a machine
    can be calibrated for exactly the shapes one family's sweep will
    predict with (``--mode predict`` in :mod:`repro.core.sweep`), instead
    of paying for the whole kernel-space cross product.
    """
    from .sweep import collect_unique_calls
    return collect_unique_calls(spec, spec.grid(grid_name).points())


def grid_calls(grid: Iterable[int]) -> List[KernelCall]:
    """Every kernel call the sweep measures, in deterministic order.

    gemm covers the full (m, n, k) cross product — the aspect-ratio
    extremes are exactly where efficiency cliffs live (paper Fig. 1) —
    while syrk/symm take (m, k)/(m, n) pairs and tri2full the diagonal.
    """
    dims = sorted(set(int(d) for d in grid))
    calls: List[KernelCall] = []
    for m in dims:
        for n in dims:
            for k in dims:
                calls.append(gemm(m, n, k))
    for m in dims:
        for k in dims:
            calls.append(syrk(m, k))
    for m in dims:
        for n in dims:
            calls.append(symm(m, n))
    for m in dims:
        calls.append(tri2full(m))
    return calls


@dataclasses.dataclass
class CalibrationResult:
    profile: TableProfile
    fingerprint: HardwareFingerprint
    path: Optional[Path]      # None when persistence was disabled
    wall_s: float
    n_calls: int


def sweep_kernels(
    runner,
    grid: Iterable[int],
    reps: int = 3,
    dtype: Optional[str] = None,
    progress=None,
    calls: Optional[List[KernelCall]] = None,
) -> TableProfile:
    """Benchmark every grid call in isolation; returns the measured table.

    ``runner`` is any object with ``benchmark_call(call, reps=None) ->
    float`` — every registered :class:`~repro.core.backends
    .ExecutionBackend` qualifies, and dtype/device/flush protocol live on
    the runner instance (one signature across backends). ``dtype`` is a
    consistency guard only: if the runner declares a dtype, a mismatch
    raises rather than stamping a fingerprint the measurements don't
    match. Peak FLOP/s is estimated as the best throughput observed
    anywhere in the sweep, so ``TableProfile.efficiency`` is relative to
    this machine's own best. ``calls`` overrides the measured set (e.g.
    one expression family's deduplicated calls from
    :func:`expression_calls`); ``grid`` is ignored then.
    """
    runner_dtype = getattr(runner, "dtype", None)
    if dtype is not None and runner_dtype is not None \
            and runner_dtype != dtype:
        raise ValueError(
            f"runner measures dtype {runner_dtype!r} but the sweep was "
            f"asked to label {dtype!r}")
    calls = grid_calls(grid) if calls is None else list(calls)
    n_calls = len(calls)
    table = {}
    peak = 1.0
    for i, call in enumerate(calls):
        seconds = runner.benchmark_call(call, reps=reps)
        table[(call.kind, call.dims)] = seconds
        if seconds > 0 and call.flops:
            peak = max(peak, call.flops / seconds)
        if progress:
            progress(i + 1, n_calls, call, seconds)
    return TableProfile(peak_flops=peak, table=table)


def calibrate(
    backend: str = "blas",
    grid: str = "small",
    reps: int = 3,
    out: Optional[Path] = None,
    dtype: Optional[str] = None,
    save: bool = True,
    progress=None,
    expr: Optional[str] = None,
    seed: Optional[int] = None,
) -> CalibrationResult:
    """Measure + persist this machine's kernel profile.

    ``out`` is a *directory*; the filename is derived from the hardware
    fingerprint so calibrations for different backends/dtypes coexist.
    With ``out=None`` the default cache dir is used — which is exactly
    where ``default_planner()`` looks, closing the loop.

    ``expr`` (a registered expression CLI name, see
    :mod:`repro.core.expressions`) restricts the measured set to exactly
    the kernel calls that family's named sweep grid enumerates — ``grid``
    then names a *sweep* grid (smoke/small/default/full, with per-family
    overrides) rather than a calibration grid.

    ``seed`` pins operand synthesis: each benchmark operand becomes a
    pure function of ``(seed, base, shape)``, so two calibration runs of
    the same grid time bit-identical inputs.
    """
    calls = None
    if expr is not None:
        from .expressions import get_spec
        calls = expression_calls(get_spec(expr), grid)
    elif grid not in GRIDS:
        raise ValueError(f"unknown grid {grid!r}; expected {sorted(GRIDS)}")
    if backend not in registered_backends():
        raise ValueError(
            f"unknown backend {backend!r}; registered: "
            f"{registered_backends()}")
    dtype = dtype or backend_default_dtype(backend)
    # Fixed-dtype backends (blas/numpy measure float64 only) raise here on
    # a mismatched label rather than stamping a wrong fingerprint.
    runner = make_backend(backend, reps=reps, dtype=dtype, seed=seed)
    fp = current_fingerprint(backend=backend, dtype=dtype)
    t0 = time.perf_counter()
    profile = sweep_kernels(runner, GRIDS.get(grid, ()), reps=reps,
                            dtype=dtype, progress=progress, calls=calls)
    wall = time.perf_counter() - t0
    if expr is not None:
        # A family-targeted run is *additive*: merge the new measurements
        # into whatever calibration this fingerprint already has — saving
        # the tiny restricted table wholesale would gut a full-grid
        # calibration sharing the same cache path.
        from .profile_store import load_profile, profile_path
        prev_path = profile_path(fp, directory=out)
        if prev_path.is_file():
            prev, _ = load_profile(prev_path, expected_fingerprint=fp)
            # Rebind rather than update() in place: TableProfile's
            # nearest-neighbour bucket index invalidates on rebinding.
            prev.table = {**prev.table, **profile.table}
            prev.observe_peak(profile.peak())
            profile = prev
    path = None
    if save:
        meta = {"grid": grid, "reps": reps, "wall_s": round(wall, 3)}
        if expr is not None:
            meta["expr"] = expr
        path = save_profile(profile, fp, directory=out, meta=meta)
    return CalibrationResult(profile=profile, fingerprint=fp, path=path,
                             wall_s=wall, n_calls=len(profile.table))


@dataclasses.dataclass
class TuneResult:
    table: object                 # repro.core.tuning.TuningTable
    fingerprint: HardwareFingerprint
    path: Optional[Path]          # None when persistence was disabled
    wall_s: float
    n_requests: int


def tune(
    backend: str = "pallas",
    grid: str = "tiny",
    reps: int = 3,
    out: Optional[Path] = None,
    dtype: Optional[str] = None,
    save: bool = True,
    budget: int = 8,
    progress=None,
    seed: Optional[int] = None,
) -> TuneResult:
    """``calibrate --tune``: autotune kernel tiles, persist the winners.

    The tuning sibling of :func:`calibrate`: the same named grids, the
    same fingerprint, the same cache directory — but the measured object
    is a :class:`~repro.core.tuning.TuningTable` of winning tile configs
    (one per ``(kind, dims)``; tri2full has none, and the grid diagonal
    additionally contributes the two fused patterns), pruned by the
    roofline pre-filter before any timing and measured under a
    per-request ``budget``. Only backends whose kernels take tile
    parameters can be tuned — i.e. ``pallas``.
    """
    if grid not in GRIDS:
        raise ValueError(f"unknown grid {grid!r}; expected {sorted(GRIDS)}")
    if backend not in registered_backends():
        raise ValueError(
            f"unknown backend {backend!r}; registered: "
            f"{registered_backends()}")
    dtype = dtype or backend_default_dtype(backend)
    runner = make_backend(backend, reps=reps, dtype=dtype, seed=seed)
    if not getattr(runner, "supports_tuning", False):
        raise ValueError(
            f"backend {backend!r} has no tunable kernel parameters; "
            f"--tune requires a tuning-capable backend (pallas)")
    from repro.kernels.autotune import autotune, default_tune_requests
    from .tuning import save_tuning_table
    dims = GRIDS[grid]
    requests = default_tune_requests(grid_calls(dims), fused_dims=dims)
    fp = current_fingerprint(backend=backend, dtype=dtype)
    t0 = time.perf_counter()
    table = autotune(runner, requests, reps=reps, budget=budget,
                     progress=progress)
    wall = time.perf_counter() - t0
    path = None
    if save:
        meta = {"grid": grid, "reps": reps, "budget": budget,
                "wall_s": round(wall, 3)}
        path = save_tuning_table(table, fp, directory=out, meta=meta)
    return TuneResult(table=table, fingerprint=fp, path=path, wall_s=wall,
                      n_requests=len(requests))


def main(argv: Optional[List[str]] = None) -> int:
    from .cli_help import (analysis_rules_epilog, backends_epilog,
                           discriminants_epilog)
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.calibrate",
        description="Calibrate this machine's kernel performance profile.",
        epilog=backends_epilog() + "\n\n" + discriminants_epilog()
               + "\n\n" + analysis_rules_epilog(),
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--backend", choices=registered_backends(),
                    default="blas",
                    help="execution backend to calibrate (the registry "
                         "key is also the profile fingerprint key)")
    ap.add_argument("--expr", default=None,
                    help="calibrate only the kernel calls of one registered "
                         "expression family (see `python -m repro.core.sweep "
                         "--list-exprs`); --grid then names a sweep grid")
    ap.add_argument("--grid", default="default",
                    help=f"calibration grid {sorted(GRIDS)}, or with "
                         "--expr a sweep grid (smoke/small/default/full)")
    ap.add_argument("--reps", type=int, default=3,
                    help="timing repetitions per kernel call")
    ap.add_argument("--out", type=Path, default=None,
                    help="output directory (default: the profile cache dir "
                         "that default_planner() auto-loads from)")
    ap.add_argument("--dtype", default=None,
                    help="dtype label for the fingerprint (default: the "
                         "backend's own, e.g. float64 for blas/numpy, "
                         "float32 for jax/pallas)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune kernel tile configs instead of "
                         "measuring a kernel profile: prune candidate "
                         "tilings with the roofline pre-filter, time the "
                         "survivors, persist winners as a TuningTable "
                         "the pallas backend auto-loads")
    ap.add_argument("--tune-budget", type=int, default=8,
                    help="with --tune: max candidate configs timed per "
                         "(kind, dims) request after pruning")
    ap.add_argument("--seed", type=int, default=None,
                    help="operand-synthesis seed: benchmark operands "
                         "become pure functions of (seed, base, shape), "
                         "so repeat calibrations time identical inputs")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.tune:
        if args.expr is not None:
            ap.error("--tune and --expr are mutually exclusive")

        def tune_progress(i, n, kind, dims, entry):
            if not args.quiet:
                speedup = entry.default_seconds / max(entry.seconds, 1e-12)
                print(f"  [{i}/{n}] {kind}{dims} -> {entry.config} "
                      f"({entry.timed} timed, {entry.pruned} pruned, "
                      f"{speedup:.2f}x vs default)", file=sys.stderr)

        res = tune(backend=args.backend, grid=args.grid, reps=args.reps,
                   out=args.out, dtype=args.dtype,
                   budget=args.tune_budget, progress=tune_progress,
                   seed=args.seed)
        print(f"tuned {res.n_requests} kernel shapes on "
              f"{res.fingerprint.backend}/{res.fingerprint.device}"
              f"/{res.fingerprint.dtype} in {res.wall_s:.1f}s")
        print(f"tuning table written to {res.path}")
        return 0

    def progress(i: int, n: int, call: KernelCall, seconds: float):
        if not args.quiet and (i % 25 == 0 or i == n):
            print(f"  [{i}/{n}] {call} {seconds * 1e6:.1f}us",
                  file=sys.stderr)

    res = calibrate(backend=args.backend, grid=args.grid, reps=args.reps,
                    out=args.out, dtype=args.dtype, progress=progress,
                    expr=args.expr, seed=args.seed)
    print(f"calibrated {res.n_calls} kernel shapes on "
          f"{res.fingerprint.backend}/{res.fingerprint.device}"
          f"/{res.fingerprint.dtype} in {res.wall_s:.1f}s "
          f"(peak ≈ {res.profile.peak() / 1e9:.1f} GFLOP/s)")
    print(f"profile written to {res.path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
