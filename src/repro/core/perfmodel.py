"""Kernel performance profiles — the paper's missing discriminant.

The paper's central finding is that FLOP count alone misleads because kernel
*efficiency* is a shape-dependent, kernel-dependent function (paper Fig. 1),
and that most anomalies are predictable from per-kernel performance profiles
benchmarked in isolation (Experiments 3, Tables 1–2: 92 % / 75 % recall).

This module productizes that: a :class:`KernelProfile` maps a
:class:`~repro.core.flops.KernelCall` to a predicted execution time, and the
``perfmodel`` discriminant (selector.py) ranks algorithms by
``Σ predicted call time`` — the paper's additive kernel-sequence model.

Two profile families:

* :class:`AnalyticalTPUProfile` — closed-form TPU v5e model: MXU tile
  quantization (128×128 systolic array) + HBM roofline + per-call overhead.
  Used by the runtime planner when no measurements exist (e.g. at trace
  time on a fresh topology).
* :class:`TableProfile` — exact benchmarked times keyed by (kind, dims);
  with log-space nearest-neighbour fallback for unseen shapes. This is the
  paper's "benchmarked performance profile", and is what Experiment 3
  consumes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Optional, Tuple

from .flops import KernelCall


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for one accelerator chip."""

    name: str
    peak_flops: float        # FLOP/s at the working dtype
    hbm_bw: float            # bytes/s
    link_bw: float           # bytes/s per ICI link (for the 3-term model)
    vmem_bytes: int
    mxu_dim: int = 128       # systolic array edge
    kernel_overhead_s: float = 2e-6   # dispatch latency per kernel call


# TPU v5e, bf16 — constants given by the assignment.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    vmem_bytes=128 * 1024 * 1024,
)

# This container's host CPU — rough constants for sanity checks only; the
# CPU path should prefer measured TableProfiles.
HOST_CPU = HardwareSpec(
    name="host_cpu",
    peak_flops=1.0e11,
    hbm_bw=3.0e10,
    link_bw=1e9,
    vmem_bytes=32 * 1024 * 1024,
    mxu_dim=16,
    kernel_overhead_s=5e-6,
)


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


class KernelProfile:
    """Interface: predicted seconds for one kernel call."""

    def time(self, call: KernelCall, dtype_bytes: int = 8) -> float:
        raise NotImplementedError

    def efficiency(self, call: KernelCall, dtype_bytes: int = 8) -> float:
        """Fraction of peak achieved — the paper's Fig. 1 quantity."""
        t = self.time(call, dtype_bytes)
        if t <= 0 or call.flops == 0:
            return 0.0
        return min(1.0, call.flops / (t * self.peak()))

    def peak(self) -> float:
        raise NotImplementedError


class AnalyticalTPUProfile(KernelProfile):
    """Closed-form TPU model: MXU block quantization × HBM roofline.

    The MXU is a ``q×q`` systolic array (q=128 on v5e); work is charged in
    whole q³ blocks, so a GEMM with m=129 pays for m=256 — the abrupt
    efficiency cliffs of the paper's Fig. 1, TPU edition. SYRK computes only
    the lower-triangular block grid (T(mt) = mt(mt+1)/2 blocks instead of
    mt²), and SYMM halves the HBM traffic of the symmetric operand — the
    same FLOPs/efficiency asymmetries the paper measures on MKL.
    """

    def __init__(self, hw: HardwareSpec = TPU_V5E):
        self.hw = hw

    def peak(self) -> float:
        return self.hw.peak_flops

    def _gemm_compute(self, m: int, n: int, k: int) -> float:
        q = self.hw.mxu_dim
        mt, nt, kt = (_ceil_to(m, q) // q, _ceil_to(n, q) // q,
                      _ceil_to(k, q) // q)
        return 2.0 * mt * nt * kt * q ** 3 / self.hw.peak_flops

    def time(self, call: KernelCall, dtype_bytes: int = 2) -> float:
        hw = self.hw
        mem = call.bytes_moved * dtype_bytes / hw.hbm_bw
        if call.kind == "gemm":
            m, n, k = call.dims
            comp = self._gemm_compute(m, n, k)
        elif call.kind == "syrk":
            m, k = call.dims
            q = hw.mxu_dim
            mt = _ceil_to(m, q) // q
            kt = _ceil_to(k, q) // q
            blocks = mt * (mt + 1) // 2
            comp = 2.0 * blocks * kt * q ** 3 / hw.peak_flops
        elif call.kind == "symm":
            m, n = call.dims
            comp = self._gemm_compute(m, n, m)
        elif call.kind == "tri2full":
            comp = 0.0
        else:
            raise ValueError(call.kind)
        return max(comp, mem) + hw.kernel_overhead_s


class TableProfile(KernelProfile):
    """Benchmarked per-call times (paper's Experiment 3 data structure).

    ``table[(kind, dims)] = seconds``. Exact lookups serve Experiment 3;
    for planner use on unseen shapes, falls back to nearest neighbour in
    log-dim space among same-kind entries, scaling by the FLOP ratio.
    """

    def __init__(self, peak_flops: float,
                 table: Optional[Dict[Tuple[str, Tuple[int, ...]], float]] = None):
        self._peak = peak_flops
        self.table: Dict[Tuple[str, Tuple[int, ...]], float] = dict(table or {})

    def peak(self) -> float:
        return self._peak

    def record(self, call: KernelCall, seconds: float) -> None:
        self.table[(call.kind, call.dims)] = seconds

    def __contains__(self, call: KernelCall) -> bool:
        return (call.kind, call.dims) in self.table

    def time(self, call: KernelCall, dtype_bytes: int = 8) -> float:
        key = (call.kind, call.dims)
        hit = self.table.get(key)
        if hit is not None:
            return hit
        if call.kind == "tri2full":
            # Memory-only op; charge linearly from any recorded copy, else 0
            # cost (paper charges 0 FLOPs; time is small vs matmuls).
            near = [(d, t) for (k2, d), t in self.table.items()
                    if k2 == "tri2full"]
            if near:
                d0, t0 = near[0]
                return t0 * (call.dims[0] ** 2) / (d0[0] ** 2)
            return 0.0
        # Nearest neighbour in log space, FLOP-ratio scaled.
        best, bestdist = None, math.inf
        lg = [math.log(max(2, d)) for d in call.dims]
        for (k2, dims), t in self.table.items():
            if k2 != call.kind or len(dims) != len(call.dims):
                continue
            dist = sum((math.log(max(2, d)) - g) ** 2 for d, g in zip(dims, lg))
            if dist < bestdist:
                bestdist, best = dist, (dims, t)
        if best is None:
            raise KeyError(f"no profile data for kernel kind {call.kind!r}")
        dims0, t0 = best
        f0 = KernelCall(call.kind, dims0).flops
        return t0 * (call.flops / max(1, f0))


def predict_algorithm_time(
    calls: Iterable[KernelCall],
    profile: KernelProfile,
    dtype_bytes: int = 8,
) -> float:
    """Paper's additive kernel-sequence model: T(alg) = Σ T(call).

    Experiment 3 shows this predicts 75–92 % of anomalies; it deliberately
    ignores inter-kernel cache coupling (paper §3.4.3), which is the
    residual error the paper attributes the remainder to.
    """
    return sum(profile.time(c, dtype_bytes) for c in calls)
