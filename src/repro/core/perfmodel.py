"""Kernel performance profiles — the paper's missing discriminant.

The paper's central finding is that FLOP count alone misleads because kernel
*efficiency* is a shape-dependent, kernel-dependent function (paper Fig. 1),
and that most anomalies are predictable from per-kernel performance profiles
benchmarked in isolation (Experiments 3, Tables 1–2: 92 % / 75 % recall).

This module productizes that: a :class:`KernelProfile` maps a
:class:`~repro.core.flops.KernelCall` to a predicted execution time, and the
``perfmodel`` discriminant (selector.py) ranks algorithms by
``Σ predicted call time`` — the paper's additive kernel-sequence model.

Two profile families:

* :class:`AnalyticalTPUProfile` — closed-form TPU v5e model: MXU tile
  quantization (128×128 systolic array) + HBM roofline + per-call overhead.
  Used by the runtime planner when no measurements exist (e.g. at trace
  time on a fresh topology).
* :class:`TableProfile` — exact benchmarked times keyed by (kind, dims);
  with log-space nearest-neighbour fallback for unseen shapes. This is the
  paper's "benchmarked performance profile", and is what Experiment 3
  consumes.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, Optional, Tuple

from .flops import KernelCall


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants for one accelerator chip."""

    name: str
    peak_flops: float        # FLOP/s at the working dtype
    hbm_bw: float            # bytes/s
    link_bw: float           # bytes/s per ICI link (for the 3-term model)
    vmem_bytes: int
    mxu_dim: int = 128       # systolic array edge
    kernel_overhead_s: float = 2e-6   # dispatch latency per kernel call


# TPU v5e, bf16 — constants given by the assignment.
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    vmem_bytes=128 * 1024 * 1024,
)

# This container's host CPU — rough constants for sanity checks only; the
# CPU path should prefer measured TableProfiles.
HOST_CPU = HardwareSpec(
    name="host_cpu",
    peak_flops=1.0e11,
    hbm_bw=3.0e10,
    link_bw=1e9,
    vmem_bytes=32 * 1024 * 1024,
    mxu_dim=16,
    kernel_overhead_s=5e-6,
)


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


class KernelProfile:
    """Interface: predicted seconds for one kernel call."""

    def time(self, call: KernelCall, dtype_bytes: int = 8) -> float:
        raise NotImplementedError

    def efficiency(self, call: KernelCall, dtype_bytes: int = 8) -> float:
        """Fraction of peak achieved — the paper's Fig. 1 quantity."""
        t = self.time(call, dtype_bytes)
        if t <= 0 or call.flops == 0:
            return 0.0
        return min(1.0, call.flops / (t * self.peak()))

    def peak(self) -> float:
        raise NotImplementedError


class AnalyticalTPUProfile(KernelProfile):
    """Closed-form TPU model: MXU block quantization × HBM roofline.

    The MXU is a ``q×q`` systolic array (q=128 on v5e); work is charged in
    whole q³ blocks, so a GEMM with m=129 pays for m=256 — the abrupt
    efficiency cliffs of the paper's Fig. 1, TPU edition. SYRK computes only
    the lower-triangular block grid (T(mt) = mt(mt+1)/2 blocks instead of
    mt²), and SYMM halves the HBM traffic of the symmetric operand — the
    same FLOPs/efficiency asymmetries the paper measures on MKL.
    """

    def __init__(self, hw: HardwareSpec = TPU_V5E):
        self.hw = hw

    def peak(self) -> float:
        return self.hw.peak_flops

    def _gemm_compute(self, m: int, n: int, k: int) -> float:
        q = self.hw.mxu_dim
        mt, nt, kt = (_ceil_to(m, q) // q, _ceil_to(n, q) // q,
                      _ceil_to(k, q) // q)
        return 2.0 * mt * nt * kt * q ** 3 / self.hw.peak_flops

    def time(self, call: KernelCall, dtype_bytes: int = 2) -> float:
        hw = self.hw
        mem = call.bytes_moved * dtype_bytes / hw.hbm_bw
        if call.kind == "gemm":
            m, n, k = call.dims
            comp = self._gemm_compute(m, n, k)
        elif call.kind == "syrk":
            m, k = call.dims
            q = hw.mxu_dim
            mt = _ceil_to(m, q) // q
            kt = _ceil_to(k, q) // q
            blocks = mt * (mt + 1) // 2
            comp = 2.0 * blocks * kt * q ** 3 / hw.peak_flops
        elif call.kind == "symm":
            m, n = call.dims
            comp = self._gemm_compute(m, n, m)
        elif call.kind == "tri2full":
            comp = 0.0
        else:
            raise ValueError(call.kind)
        return max(comp, mem) + hw.kernel_overhead_s


class RooflineProfile(KernelProfile):
    """Pure roofline: ``max(flops / peak, bytes·dtype / bandwidth)``.

    The minimal memory-traffic-aware model, and deliberately *simpler*
    than :class:`AnalyticalTPUProfile`: no MXU tile quantization and no
    per-call dispatch overhead, so the two analytical models disagree
    exactly where quantization cliffs (a 129-row GEMM paying for 256)
    dominate raw traffic. What it does see that FLOPs cannot: the
    zero-FLOP TRI2FULL copy costs ``m²`` bytes of traffic, and SYRK's
    triangular output halves its write traffic — the asymmetries behind
    the paper's anomalies. Backs the ``roofline`` discriminant
    (:mod:`repro.core.discriminants`).
    """

    def __init__(self, hw: HardwareSpec = TPU_V5E):
        self.hw = hw

    def peak(self) -> float:
        return self.hw.peak_flops

    def time(self, call: KernelCall, dtype_bytes: int = 2) -> float:
        return self.raw_time(call.flops, call.bytes_moved,
                             dtype_bytes=dtype_bytes)

    def raw_time(self, flops: float, elems_moved: float, *,
                 dtype_bytes: int = 2) -> float:
        """Roofline seconds for explicit (FLOPs, elements-moved) counts.

        The same ``max(compute, memory)`` as :meth:`time`, but taking raw
        counts instead of a :class:`KernelCall` — the autotuner's pruning
        pre-filter (:mod:`repro.core.tuning`) charges *tiling-dependent*
        work (block-quantized FLOPs, per-tiling operand re-streaming)
        that no fixed per-kind ``bytes_moved`` formula can express.
        """
        comp = flops / self.hw.peak_flops
        mem = elems_moved * dtype_bytes / self.hw.hbm_bw
        return max(comp, mem)


class TableProfile(KernelProfile):
    """Benchmarked per-call times (paper's Experiment 3 data structure).

    ``table[(kind, dims)] = seconds``. Exact lookups serve Experiment 3;
    for planner use on unseen shapes, falls back to nearest neighbour in
    log-dim space among same-kind entries, scaling by the FLOP ratio.
    """

    def __init__(self, peak_flops: float,
                 table: Optional[Dict[Tuple[str, Tuple[int, ...]], float]] = None):
        self._peak = peak_flops
        self.table: Dict[Tuple[str, Tuple[int, ...]], float] = dict(table or {})
        self._write_lock = threading.Lock()
        self._generation = 0
        # (table-ref, {(kind, ndims): [(logdims, dims, seconds), ...]});
        # rebuilt lazily whenever self.table has been rebound (record()
        # and every supported mutation path rebind rather than mutate).
        self._index: Optional[Tuple[Dict, Dict]] = None

    def peak(self) -> float:
        return self._peak

    @property
    def generation(self) -> int:
        """Monotonic mutation counter, bumped by every :meth:`record`.

        Consumers that memoise rankings derived from this table (the
        planner's plan cache) fold it into their keys, so online
        refinement invalidates stale decisions instead of freezing the
        first ranking forever.
        """
        return self._generation

    def observe_peak(self, flops_per_s: float) -> None:
        """Raise the recorded peak when a faster throughput is observed.

        Keeps :meth:`efficiency` (the paper's Fig. 1 quantity) meaningful
        as later sweeps measure kernels faster than the original
        calibration's best — without this, efficiency clamps at 1.0.
        """
        if flops_per_s > self._peak:
            self._peak = float(flops_per_s)

    def record(self, call: KernelCall, seconds: float) -> None:
        # Copy-on-write under a writer lock: readers (time/nearest iterate
        # the dict) hold the old mapping while recorders rebind — so the
        # planner's online refinement never trips "dict changed size
        # during iteration" in a planning thread — and the lock keeps two
        # recorders from losing each other's read-copy-rebind. Tables are
        # small (≤ ~10³ entries), so the copy is cheap relative to one
        # benchmark rep.
        with self._write_lock:
            self.table = {**self.table, (call.kind, call.dims): seconds}
            self._generation += 1

    def __contains__(self, call: KernelCall) -> bool:
        return (call.kind, call.dims) in self.table

    def _buckets(self) -> Dict:
        """Per-``(kind, ndims)`` entry index with vectorized log-dims.

        ``nearest`` used to scan the whole table per un-memoised call
        during ranking; the bucket restricts each query to same-kind,
        same-arity entries and turns the distance scan into one vectorized
        numpy reduction over a precomputed log-dim matrix (see the
        ``calibrate_nearest_query`` row in benchmarks/calibrate_bench.py).
        The index is rebuilt lazily when ``self.table`` has been rebound —
        every supported mutation path (:meth:`record`, the calibrate
        merge) rebinds rather than mutates in place, and readers snapshot
        one coherent (table, index) pair.
        """
        idx = self._index
        table = self.table
        if idx is not None and idx[0] is table:
            return idx[1]
        import numpy as np

        groups: Dict[Tuple[str, int], list] = {}
        for (kind, dims), t in table.items():
            groups.setdefault((kind, len(dims)), []).append((dims, t))
        buckets = {}
        for key, entries in groups.items():
            logdims = np.log(np.maximum(
                np.array([d for d, _ in entries], dtype=float), 2.0))
            buckets[key] = (logdims, entries)
        self._index = (table, buckets)
        return buckets

    def nearest(
        self, call: KernelCall,
    ) -> Optional[Tuple[Tuple[int, ...], float, float]]:
        """Closest same-kind entry in log-dim space.

        Returns ``(dims, seconds, squared_log_distance)`` or ``None`` when
        no same-kind entry exists. Shared by :meth:`time` and
        :class:`HybridProfile` so "which entry is closest" and "which entry
        we extrapolate from" can never disagree.
        """
        bucket = self._buckets().get((call.kind, len(call.dims)))
        if bucket is None:
            return None
        import numpy as np

        logdims, entries = bucket
        lg = np.log(np.maximum(np.array(call.dims, dtype=float), 2.0))
        dists = ((logdims - lg) ** 2).sum(axis=1)
        i = int(np.argmin(dists))
        dims, t = entries[i]
        return (dims, t, float(dists[i]))

    def extrapolate(
        self, call: KernelCall,
        near: Optional[Tuple[Tuple[int, ...], float, float]],
    ) -> float:
        """Scale a :meth:`nearest` hit to ``call``'s size.

        tri2full (0 FLOPs, memory-only) scales quadratically in the dim
        and costs 0 with no reference; compute kernels scale by FLOP
        ratio and raise without one.
        """
        if call.kind == "tri2full":
            if near is None:
                return 0.0
            dims0, t0, _ = near
            return t0 * (call.dims[0] ** 2) / (dims0[0] ** 2)
        if near is None:
            raise KeyError(f"no profile data for kernel kind {call.kind!r}")
        dims0, t0, _ = near
        f0 = KernelCall(call.kind, dims0).flops
        return t0 * (call.flops / max(1, f0))

    def time(self, call: KernelCall, dtype_bytes: int = 8) -> float:
        hit = self.table.get((call.kind, call.dims))
        if hit is not None:
            return hit
        return self.extrapolate(call, self.nearest(call))


class HybridProfile(KernelProfile):
    """Measured-where-known, analytical-elsewhere (paper's conjecture).

    The paper's conclusion proposes "combining FLOP counts with kernel
    performance models"; this profile is that combination as a per-call
    policy: a calibrated :class:`TableProfile` answers for shapes it has
    measured (exactly, or by same-kind nearest neighbour within
    ``max_log_dist`` of a recorded entry), and the closed-form
    :class:`AnalyticalTPUProfile` answers for everything else — so a
    partially calibrated machine still ranks *every* candidate algorithm.

    ``max_log_dist`` is the squared log-space distance beyond which a
    table entry is considered too remote to extrapolate from; the default
    0.5 ≈ each dim within ~2× of a measured one on average.
    """

    def __init__(self, table: TableProfile,
                 analytical: Optional[KernelProfile] = None,
                 max_log_dist: float = 0.5):
        self.table_profile = table
        self.analytical = analytical or AnalyticalTPUProfile()
        self.max_log_dist = max_log_dist

    def peak(self) -> float:
        return self.table_profile.peak()

    def _resolve(self, call: KernelCall) -> Tuple[str, Optional[float]]:
        """The one table-vs-analytical decision: ``(source, seconds)``.

        ``source()`` and ``time()`` both route here, so "which model
        answers" and "what it answers" can never diverge (they used to
        compute ``nearest`` independently). ``seconds`` is ``None`` iff
        the analytical member answers — the caller supplies
        ``dtype_bytes`` there.
        """
        hit = self.table_profile.table.get((call.kind, call.dims))
        if hit is not None:
            return "table", hit
        near = self.table_profile.nearest(call)
        if near is not None and near[2] <= self.max_log_dist:
            return "table", self.table_profile.extrapolate(call, near)
        return "analytical", None

    def source(self, call: KernelCall) -> str:
        """Which model answers for ``call``: ``"table"`` | ``"analytical"``."""
        return self._resolve(call)[0]

    def time(self, call: KernelCall, dtype_bytes: int = 8) -> float:
        src, seconds = self._resolve(call)
        if src == "table":
            return seconds
        return self.analytical.time(call, dtype_bytes)

    def record(self, call: KernelCall, seconds: float) -> None:
        self.table_profile.record(call, seconds)

    def observe_peak(self, flops_per_s: float) -> None:
        self.table_profile.observe_peak(flops_per_s)


def predict_algorithm_time(
    calls: Iterable[KernelCall],
    profile: KernelProfile,
    dtype_bytes: int = 8,
) -> float:
    """Paper's additive kernel-sequence model: T(alg) = Σ T(call).

    Experiment 3 shows this predicts 75–92 % of anomalies; it deliberately
    ignores inter-kernel cache coupling (paper §3.4.3), which is the
    residual error the paper attributes the remainder to.
    """
    return sum(profile.time(c, dtype_bytes) for c in calls)
