"""Algorithm discriminants — the selection policies the paper evaluates.

* ``flops``     — paper-faithful baseline: min FLOP count (Linnea/Julia).
* ``perfmodel`` — FLOPs weighted by kernel performance profiles (the paper's
  conclusion, productized; Experiment 3 shows it predicts 75–92 % of the
  anomalies the baseline falls into).
* ``hybrid``    — measured table entries where a calibration has them,
  analytical model per-call elsewhere (the paper's conjectured
  FLOPs × perf-model combination; see :class:`~repro.core.perfmodel
  .HybridProfile`).
* ``measured``  — brute-force empirical selection (ground truth; only
  feasible when sizes are concrete and measurement is affordable).

``select`` returns a ranked list so callers can implement fallbacks; the
planner takes rank 0.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from .algorithms import Algorithm
from .backends import get_backend
from .perfmodel import (
    AnalyticalTPUProfile,
    HybridProfile,
    KernelProfile,
    TableProfile,
    predict_algorithm_time,
)

DISCRIMINANTS = ("flops", "perfmodel", "hybrid", "measured")


def rank_by_flops(algos: Sequence[Algorithm]) -> List[Algorithm]:
    """Ascending FLOP count, ties broken by name (deterministic)."""
    return sorted(algos, key=lambda a: (a.flops, a.name))


def rank_by_perfmodel(
    algos: Sequence[Algorithm],
    profile: Optional[KernelProfile] = None,
    dtype_bytes: int = 2,
) -> List[Algorithm]:
    """Ascending predicted time under the additive per-kernel model.

    ``profile`` is used *as given* (no hybrid coercion — contrast
    :func:`rank_by_hybrid`); ``None`` falls back to the closed-form
    :class:`~repro.core.perfmodel.AnalyticalTPUProfile`. A bare
    :class:`~repro.core.perfmodel.TableProfile` may therefore raise
    ``KeyError`` on kernel kinds it has never seen — pass it through the
    ``hybrid`` discriminant if the calibration may be partial. FLOPs and
    name break prediction ties, keeping rankings deterministic.
    """
    prof = profile or AnalyticalTPUProfile()
    return sorted(
        algos,
        key=lambda a: (predict_algorithm_time(a.calls, prof, dtype_bytes),
                       a.flops, a.name),
    )


def as_hybrid(profile: Optional[KernelProfile]) -> HybridProfile:
    """Coerce any profile into the hybrid (table ∨ analytical) policy.

    * ``HybridProfile``   → used as-is;
    * ``TableProfile``    → wrapped with an analytical fallback;
    * anything else/None  → empty table over the given (or default)
      analytical model, so every call falls through to analytical until
      online refinement records measurements.
    """
    if isinstance(profile, HybridProfile):
        return profile
    if isinstance(profile, TableProfile):
        return HybridProfile(profile)
    analytical = profile or AnalyticalTPUProfile()
    return HybridProfile(TableProfile(peak_flops=analytical.peak()),
                         analytical=analytical)


def rank_by_hybrid(
    algos: Sequence[Algorithm],
    profile: Optional[KernelProfile] = None,
    dtype_bytes: int = 2,
) -> List[Algorithm]:
    return rank_by_perfmodel(algos, as_hybrid(profile), dtype_bytes)


def rank_by_measurement(
    algos: Sequence[Algorithm],
    runner=None,
    backend: Optional[str] = None,
) -> List[Algorithm]:
    """Ascending measured time on any registered execution backend.

    ``runner`` is an explicit backend instance; ``backend`` is a registry
    name (``blas``/``numpy``/``jax``/``pallas``/…) resolved through
    :func:`~repro.core.backends.get_backend`. Passing both raises —
    silently preferring one would measure on an unintended executor.
    Default: a fresh ``blas`` runner (the paper's ground-truth protocol).
    """
    if runner is not None and backend is not None:
        raise ValueError("pass either runner= or backend=, not both")
    r = runner if runner is not None else get_backend(backend or "blas",
                                                     reps=3)
    times: Dict[str, float] = {}
    for a in algos:
        times[a.name] = r.time_algorithm(a)
    return sorted(algos, key=lambda a: (times[a.name], a.name))


def select(
    algos: Sequence[Algorithm],
    discriminant: str = "perfmodel",
    profile: Optional[KernelProfile] = None,
    runner=None,
    dtype_bytes: int = 2,
    backend: Optional[str] = None,
) -> List[Algorithm]:
    """Rank ``algos`` best-first under the chosen discriminant.

    How the optional ``profile`` is interpreted depends on the
    discriminant:

    * ``flops``     — ignored (pure FLOP count).
    * ``perfmodel`` — used verbatim; ``None`` means the analytical model.
    * ``hybrid``    — coerced through :func:`as_hybrid` (measured table
      entries where a calibration has them — exactly or by near
      nearest-neighbour — analytical fallback elsewhere), so partial
      calibrations still rank every candidate.
    * ``measured``  — ignored; ``runner`` (an execution-backend instance)
      or ``backend`` (a :mod:`repro.core.backends` registry name —
      ``blas``/``numpy``/``jax``/``pallas``/…) times each algorithm;
      default a fresh ``blas`` runner.

    This is the single entry point the planner uses; it takes rank 0 of
    the returned list.
    """
    if discriminant == "flops":
        return rank_by_flops(algos)
    if discriminant == "perfmodel":
        return rank_by_perfmodel(algos, profile, dtype_bytes)
    if discriminant == "hybrid":
        return rank_by_hybrid(algos, profile, dtype_bytes)
    if discriminant == "measured":
        return rank_by_measurement(algos, runner, backend=backend)
    raise ValueError(
        f"unknown discriminant {discriminant!r}; expected {DISCRIMINANTS}")


def select_expression(
    expr: str,
    point: Sequence[int],
    discriminant: str = "perfmodel",
    profile: Optional[KernelProfile] = None,
    runner=None,
    dtype_bytes: int = 2,
    backend: Optional[str] = None,
) -> List[Algorithm]:
    """Rank a *registered* expression family's algorithms at one instance.

    ``expr`` is a registry CLI name (``abcd``, ``aatb``, ``abtb``, …, see
    :mod:`repro.core.expressions`); enumeration and ranking both flow from
    the registry entry, so newly registered families are selectable with
    no further wiring. With ``discriminant="measured"``, ``backend``
    names the execution backend to time on — any registry entry works,
    so a family can be ranked on MKL-style BLAS and on Pallas with the
    same call.
    """
    from .expressions import get_spec
    return select(get_spec(expr).algorithms(point), discriminant,
                  profile=profile, runner=runner, dtype_bytes=dtype_bytes,
                  backend=backend)
