"""Selection façade over the discriminant registry.

The policies themselves live in :mod:`repro.core.discriminants` — a
registry (``register_discriminant`` / ``get_discriminant`` /
``registered_discriminants``) shipping ``flops``, ``perfmodel``,
``hybrid``, ``roofline``, ``measured`` and ``rankk``, each declaring the
capability flags (``requires_profile`` / ``requires_measurement``) this
shim validates arguments against. ``select`` returns a ranked list so
callers can implement fallbacks; the planner takes rank 0.

The pre-registry module-level ``DISCRIMINANTS`` tuple is deprecated:
import :func:`~repro.core.discriminants.registered_discriminants`
instead (the alias still resolves, with a ``DeprecationWarning``).
"""

from __future__ import annotations

import warnings
from typing import List, Optional, Sequence

from .algorithms import Algorithm
from .discriminants import (
    DiscriminantContext,
    as_hybrid,
    get_discriminant,
    registered_discriminants,
    validate_arguments,
)
from .perfmodel import KernelProfile

__all__ = [
    "as_hybrid", "rank_by_flops", "rank_by_perfmodel", "rank_by_hybrid",
    "rank_by_measurement", "registered_discriminants", "select",
    "select_expression",
]


def rank_by_flops(algos: Sequence[Algorithm]) -> List[Algorithm]:
    """Ascending FLOP count, ties broken by name (deterministic)."""
    return get_discriminant("flops").rank(algos, DiscriminantContext())


def rank_by_perfmodel(
    algos: Sequence[Algorithm],
    profile: Optional[KernelProfile] = None,
    dtype_bytes: int = 2,
) -> List[Algorithm]:
    """Ascending predicted time under the additive per-kernel model.

    ``profile`` is used *as given* (no hybrid coercion — contrast
    :func:`rank_by_hybrid`); ``None`` falls back to the closed-form
    analytical model. A bare partially calibrated table may raise
    ``KeyError`` on kernel kinds it has never seen — pass it through the
    ``hybrid`` discriminant if the calibration may be partial.
    """
    return get_discriminant("perfmodel").rank(
        algos, DiscriminantContext(profile=profile, dtype_bytes=dtype_bytes))


def rank_by_hybrid(
    algos: Sequence[Algorithm],
    profile: Optional[KernelProfile] = None,
    dtype_bytes: int = 2,
) -> List[Algorithm]:
    return get_discriminant("hybrid").rank(
        algos, DiscriminantContext(profile=profile, dtype_bytes=dtype_bytes))


def rank_by_measurement(
    algos: Sequence[Algorithm],
    runner=None,
    backend: Optional[str] = None,
) -> List[Algorithm]:
    """Ascending measured time on any registered execution backend.

    ``runner`` is an explicit backend instance; ``backend`` is a registry
    name (``blas``/``numpy``/``jax``/``pallas``/…). Passing both raises.
    Default: the process-shared ``blas`` runner (the paper's ground-truth
    protocol; the shared instance keeps its 64 MB cache-flush buffer warm
    across calls). Kernel calls shared between algorithms are timed once
    (deduplicated unique-call benching) rather than per algorithm.
    """
    if runner is not None and backend is not None:
        raise ValueError("pass either runner= or backend=, not both")
    return get_discriminant("measured").rank(
        algos, DiscriminantContext(runner=runner, backend=backend))


def select(
    algos: Sequence[Algorithm],
    discriminant: str = "perfmodel",
    profile: Optional[KernelProfile] = None,
    runner=None,
    dtype_bytes: int = 2,
    backend: Optional[str] = None,
) -> List[Algorithm]:
    """Rank ``algos`` best-first under any registered discriminant.

    ``discriminant`` is a :mod:`repro.core.discriminants` registry key;
    arguments are validated against the policy's capability flags, so a
    ``profile`` handed to ``flops``/``measured``/``roofline`` or a
    ``runner``/``backend`` handed to a policy that never executes kernels
    raises ``ValueError`` instead of being silently ignored. This is the
    single entry point the planner uses; it takes rank 0 of the returned
    list.
    """
    try:
        d = get_discriminant(discriminant)
    except KeyError:
        raise ValueError(
            f"unknown discriminant {discriminant!r}; expected one of "
            f"{registered_discriminants()}") from None
    validate_arguments(d, profile=profile, runner=runner, backend=backend)
    ctx = DiscriminantContext(profile=profile, runner=runner,
                              backend=backend, dtype_bytes=dtype_bytes)
    return d.rank(algos, ctx)


def select_expression(
    expr: str,
    point: Sequence[int],
    discriminant: str = "perfmodel",
    profile: Optional[KernelProfile] = None,
    runner=None,
    dtype_bytes: int = 2,
    backend: Optional[str] = None,
) -> List[Algorithm]:
    """Rank a *registered* expression family's algorithms at one instance.

    ``expr`` is a registry CLI name (``abcd``, ``aatb``, ``abtb``, …, see
    :mod:`repro.core.expressions`); enumeration and ranking both flow from
    the registries, so newly registered families and newly registered
    discriminants are selectable with no further wiring. With a
    measurement-backed discriminant (``measured``/``rankk``), ``backend``
    names the execution backend to time on.
    """
    from .expressions import get_spec
    return select(get_spec(expr).algorithms(point), discriminant,
                  profile=profile, runner=runner, dtype_bytes=dtype_bytes,
                  backend=backend)


_DEPRECATED = {
    "DISCRIMINANTS": lambda: tuple(registered_discriminants()),
}


def __getattr__(name):
    hook = _DEPRECATED.get(name)
    if hook is not None:
        warnings.warn(
            f"selector.{name} is deprecated; call "
            f"repro.core.discriminants.registered_discriminants() instead",
            DeprecationWarning, stacklevel=2)
        return hook()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
