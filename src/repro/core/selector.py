"""Algorithm discriminants — the selection policies the paper evaluates.

* ``flops``     — paper-faithful baseline: min FLOP count (Linnea/Julia).
* ``perfmodel`` — FLOPs weighted by kernel performance profiles (the paper's
  conclusion, productized; Experiment 3 shows it predicts 75–92 % of the
  anomalies the baseline falls into).
* ``measured``  — brute-force empirical selection (ground truth; only
  feasible when sizes are concrete and measurement is affordable).

``select`` returns a ranked list so callers can implement fallbacks; the
planner takes rank 0.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from .algorithms import Algorithm
from .perfmodel import AnalyticalTPUProfile, KernelProfile, predict_algorithm_time
from .runners import BlasRunner

DISCRIMINANTS = ("flops", "perfmodel", "measured")


def rank_by_flops(algos: Sequence[Algorithm]) -> List[Algorithm]:
    return sorted(algos, key=lambda a: (a.flops, a.name))


def rank_by_perfmodel(
    algos: Sequence[Algorithm],
    profile: Optional[KernelProfile] = None,
    dtype_bytes: int = 2,
) -> List[Algorithm]:
    prof = profile or AnalyticalTPUProfile()
    return sorted(
        algos,
        key=lambda a: (predict_algorithm_time(a.calls, prof, dtype_bytes),
                       a.flops, a.name),
    )


def rank_by_measurement(
    algos: Sequence[Algorithm],
    runner: Optional[BlasRunner] = None,
) -> List[Algorithm]:
    r = runner or BlasRunner(reps=3)
    times: Dict[str, float] = {}
    for a in algos:
        times[a.name] = r.time_algorithm(a)
    return sorted(algos, key=lambda a: (times[a.name], a.name))


def select(
    algos: Sequence[Algorithm],
    discriminant: str = "perfmodel",
    profile: Optional[KernelProfile] = None,
    runner: Optional[BlasRunner] = None,
    dtype_bytes: int = 2,
) -> List[Algorithm]:
    if discriminant == "flops":
        return rank_by_flops(algos)
    if discriminant == "perfmodel":
        return rank_by_perfmodel(algos, profile, dtype_bytes)
    if discriminant == "measured":
        return rank_by_measurement(algos, runner)
    raise ValueError(
        f"unknown discriminant {discriminant!r}; expected {DISCRIMINANTS}")
