"""Sharded grid sweeps over problem-size space + the persistent anomaly atlas.

The paper's central empirical finding is that anomalies — instances where the
FLOP-cheapest algorithm is not the fastest — "cluster into large contiguous
regions" of the problem-size space (§3.4.2). Mapping those regions needs
*dense* sweeps over size grids (the methodology of Peise & Bientinesi's
performance-modeling line, arXiv:1209.2364 / arXiv:1409.8602), which a serial
Python loop cannot deliver at useful resolution. This module is the scaling
layer:

* :class:`GridSpec` / :data:`SWEEP_GRIDS` (defined in
  :mod:`repro.core.expressions`, re-exported here) — named dim grids over
  any registered expression family: the paper's ``ABCD``/``AAᵀB`` plus the
  zoo (``abcde``, ``abtb``, ``btsb``, ``atab``, ``abab``); ``--expr``
  accepts every registry entry and ``--list-exprs`` prints them.
* :func:`sweep` — the one measurement path. Shards the grid across workers:
  a process pool for the BLAS runner (kernel timing is GIL-bound and
  cache-sensitive, so isolation per process matches the paper's protocol),
  or one :class:`~repro.core.runners.JaxRunner` per JAX device (operands are
  device-pinned; devices measure their shards concurrently). Results stream
  into the atlas in chunks, so a killed sweep resumes from the last chunk.
* :class:`AnomalyAtlas` — persistent, resumable, versioned JSONL store of
  per-instance :class:`~repro.core.anomaly.Classification` results, one file
  per (expression, threshold, hardware fingerprint) — the same fingerprint
  scheme as :mod:`repro.core.profile_store`, so atlases calibrated on one
  machine are never silently mixed with another's.
* :func:`benchmark_unique_calls` / :func:`predict_classifications` — the
  batched kernel path: across a grid, algorithms share most of their kernel
  calls, so deduplicating (kind, dims) before benchmarking amortizes
  dispatch by orders of magnitude and feeds the calibration cache.
* :func:`cluster_sweep` — connected-component pass over a swept grid,
  reproducing the paper's contiguous-region claim with per-region severity
  summaries.

CLI::

    PYTHONPATH=src python -m repro.core.sweep --expr aatb --grid small
    PYTHONPATH=src python -m repro.core.sweep --expr aatb --grid small  # resumes: measured=0
    PYTHONPATH=src python -m repro.core.sweep --expr abcd --grid default --shards 8
    PYTHONPATH=src python -m repro.core.sweep --expr aatb --grid small --mode predict

The paper harnesses (:mod:`repro.core.experiments`) and the experiment
benchmarks are thin configurations over this engine.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import re
import sys
import time as _time
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, ThreadPoolExecutor, wait
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from .algorithms import Algorithm, Leaf
from .anomaly import Classification, Region, classify, cluster_regions, region_summary
# Expression specs + grids live in repro.core.expressions; the
# redundant-alias imports re-export them here for backwards compat
# (pre-registry callers import them from repro.core.sweep).
from .expressions import (
    GRAM_AATB as GRAM_AATB,
    MATRIX_CHAIN_ABCD as MATRIX_CHAIN_ABCD,
    REGISTRY as REGISTRY,
    SPECS as SPECS,
    SWEEP_GRIDS as SWEEP_GRIDS,
    ExpressionSpec as ExpressionSpec,
    GridSpec as GridSpec,
    get_spec as get_spec,
    registered_names as registered_names,
)
from .flops import KernelCall
from .perfmodel import KernelProfile, TableProfile, predict_algorithm_time
from .profile_store import (
    HardwareFingerprint,
    cache_base_dir,
    current_fingerprint,
    load_default_profile,
    save_profile,
)
from .runners import BlasRunner, JaxRunner

# --------------------------------------------------- instance measurement ---


def _leaf_bases(alg: Algorithm) -> set:
    """Distinct operand base indices an algorithm's steps reference."""
    return {ref.base for step in alg.steps for ref in (step.lhs, step.rhs)
            if isinstance(ref, Leaf)}


@dataclasses.dataclass
class Instance:
    """One fully measured grid point: per-algorithm times/FLOPs + verdict."""

    point: Tuple[int, ...]
    times: Dict[str, float]
    flops: Dict[str, int]
    cls: Classification


def measure_instance(
    spec: ExpressionSpec,
    point: Sequence[int],
    runner,
    threshold: float = 0.10,
) -> Instance:
    """Time every algorithm for one instance and classify it.

    ``runner`` is any object with ``make_operands(alg) -> dict`` and
    ``time_algorithm(alg, operands) -> seconds`` —
    :class:`~repro.core.runners.BlasRunner` and
    :class:`~repro.core.runners.JaxRunner` both qualify.
    """
    algos = spec.algorithms(point)
    times: Dict[str, float] = {}
    flops: Dict[str, int] = {}
    # Leaves are shared across algorithms: synthesize operands once, and
    # only fall back to make_operands for an algorithm referencing a base
    # the dict lacks — not per algorithm, which would generate (and mostly
    # discard) a full operand set each time.
    operands = runner.make_operands(algos[-1])
    for a in algos:
        if not _leaf_bases(a) <= operands.keys():
            for k, v in runner.make_operands(a).items():
                operands.setdefault(k, v)
        times[a.name] = runner.time_algorithm(a, operands)
        flops[a.name] = a.flops
    cls = classify(times, flops, threshold=threshold)
    return Instance(tuple(int(x) for x in point), times, flops, cls)


# ------------------------------------------------------------------ atlas ---

ATLAS_SCHEMA_VERSION = 1

_ENV_ATLAS_DIR = "REPRO_ATLAS_DIR"


class AtlasError(RuntimeError):
    """Atlas file exists but belongs to a different sweep configuration."""


def atlas_dir() -> Path:
    env = os.environ.get(_ENV_ATLAS_DIR)
    if env:
        return Path(env)
    return cache_base_dir() / "atlas"


def _slug(s: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]+", "_", s).lower()


def atlas_path(spec_name: str, fingerprint: HardwareFingerprint,
               threshold: float, directory: Optional[Path] = None) -> Path:
    d = Path(directory) if directory is not None else atlas_dir()
    t = f"{threshold:g}".replace(".", "p")
    return d / f"atlas-{_slug(spec_name)}-t{t}-{fingerprint.slug()}.jsonl"


def _instance_to_json(inst: Instance) -> dict:
    return {
        "point": list(inst.point),
        "is_anomaly": inst.cls.is_anomaly,
        "time_score": inst.cls.time_score,
        "flop_score": inst.cls.flop_score,
        "cheapest": list(inst.cls.cheapest),
        "fastest": list(inst.cls.fastest),
        "times": inst.times,
        "flops": inst.flops,
    }


def _instance_from_json(d: dict) -> Instance:
    cls = Classification(
        is_anomaly=bool(d["is_anomaly"]),
        time_score=float(d["time_score"]),
        flop_score=float(d["flop_score"]),
        cheapest=tuple(d["cheapest"]),
        fastest=tuple(d["fastest"]),
    )
    return Instance(
        point=tuple(int(x) for x in d["point"]),
        times={str(k): float(v) for k, v in d["times"].items()},
        flops={str(k): int(v) for k, v in d["flops"].items()},
        cls=cls,
    )


class AnomalyAtlas:
    """Persistent, resumable JSONL store of swept classifications.

    One file per (expression, anomaly threshold, hardware fingerprint).
    Line 1 is a header record ``{"kind": "header", ...}``; every other line
    is one instance. Appends are buffered and flushed in chunks of
    ``chunk_size`` (with fsync), so a killed sweep loses at most one
    unflushed chunk and a restart resumes from the last chunk: points
    already on disk are skipped by :func:`sweep`.

    A torn final line (the kill landed mid-write) is tolerated on load;
    any undecodable line is skipped and counted in ``skipped_lines``.
    """

    def __init__(self, path: Path, fingerprint: HardwareFingerprint,
                 spec_name: str, threshold: float, chunk_size: int = 32):
        if chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        self.path = Path(path)
        self.fingerprint = fingerprint
        self.spec_name = spec_name
        self.threshold = float(threshold)
        self.chunk_size = chunk_size
        self.skipped_lines = 0
        self._records: Dict[Tuple[int, ...], Instance] = {}
        self._buffer: List[str] = []
        self._header_on_disk = False
        self.recovered_from: Optional[Path] = None
        if self.path.is_file():
            self._load()

    @classmethod
    def open(cls, spec_name: str, fingerprint: HardwareFingerprint,
             threshold: float = 0.10, directory: Optional[Path] = None,
             chunk_size: int = 32) -> "AnomalyAtlas":
        """Open (resuming) or create the atlas for this configuration."""
        path = atlas_path(spec_name, fingerprint, threshold, directory)
        return cls(path, fingerprint, spec_name, threshold,
                   chunk_size=chunk_size)

    # -- persistence ------------------------------------------------------
    def _header(self) -> dict:
        return {
            "kind": "header",
            "version": ATLAS_SCHEMA_VERSION,
            "spec": self.spec_name,
            "threshold": self.threshold,
            "fingerprint": self.fingerprint.to_dict(),
        }

    def _load(self) -> None:
        with self.path.open() as f:
            first = f.readline()
            try:
                head = json.loads(first)
            except json.JSONDecodeError:
                # The kill landed mid-write of the header itself (it is the
                # first line of the first flushed chunk, so at most one
                # chunk existed). Resume must survive this: preserve the
                # torn file as a sidecar and start the atlas fresh.
                side = self.path.with_suffix(self.path.suffix + ".corrupt")
                self.path.replace(side)
                self.recovered_from = side
                return
            if head.get("kind") != "header":
                raise AtlasError(f"atlas {self.path} is missing its header")
            if head.get("version") != ATLAS_SCHEMA_VERSION:
                raise AtlasError(
                    f"atlas {self.path} has schema version "
                    f"{head.get('version')!r}; this build reads "
                    f"{ATLAS_SCHEMA_VERSION}")
            fp = HardwareFingerprint.from_dict(head["fingerprint"])
            if fp != self.fingerprint:
                raise AtlasError(
                    f"atlas {self.path} was swept on {fp}, but this "
                    f"process targets {self.fingerprint}")
            if head.get("spec") != self.spec_name or \
                    abs(head.get("threshold", -1) - self.threshold) > 1e-12:
                raise AtlasError(
                    f"atlas {self.path} records spec="
                    f"{head.get('spec')!r}/threshold="
                    f"{head.get('threshold')!r}, not "
                    f"{self.spec_name!r}/{self.threshold}")
            self._header_on_disk = True
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    inst = _instance_from_json(json.loads(line))
                except (json.JSONDecodeError, KeyError, TypeError, ValueError):
                    # Torn tail from a killed writer (or a corrupt line):
                    # drop it; the sweep will re-measure that point.
                    self.skipped_lines += 1
                    continue
                self._records[inst.point] = inst

    def append(self, inst: Instance) -> bool:
        """Add one instance; returns False (no write) for known points."""
        if inst.point in self._records:
            return False
        self._records[inst.point] = inst
        self._buffer.append(json.dumps(_instance_to_json(inst),
                                       sort_keys=True))
        if len(self._buffer) >= self.chunk_size:
            self.flush()
        return True

    def flush(self) -> None:
        """Durably write buffered records (chunk boundary for resume)."""
        if not self._buffer and self._header_on_disk:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with self.path.open("a") as f:
            if not self._header_on_disk:
                f.write(json.dumps(self._header(), sort_keys=True) + "\n")
                self._header_on_disk = True
            for line in self._buffer:
                f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._buffer.clear()

    def __enter__(self) -> "AnomalyAtlas":
        return self

    def __exit__(self, *exc) -> None:
        self.flush()

    # -- queries ----------------------------------------------------------
    def __contains__(self, point: Sequence[int]) -> bool:
        return tuple(int(x) for x in point) in self._records

    def __len__(self) -> int:
        return len(self._records)

    def get(self, point: Sequence[int]) -> Optional[Instance]:
        return self._records.get(tuple(int(x) for x in point))

    def records(self) -> List[Instance]:
        return list(self._records.values())

    def anomalies(self) -> List[Instance]:
        return [r for r in self._records.values() if r.cls.is_anomaly]


# ---------------------------------------------------------------- backends --


def _factory_key(factory) -> object:
    """Identity of a runner factory that survives pickling.

    ``functools.partial`` compares by object identity, and every chunk
    shipped to a worker unpickles to a *new* partial — so the worker-local
    runner cache keys on (func, args, kwargs) instead.
    """
    if isinstance(factory, functools.partial):
        return (factory.func, factory.args,
                tuple(sorted(factory.keywords.items())))
    return factory


_worker_runner: Optional[Tuple[object, object]] = None  # (key, runner)


def _measure_chunk(spec: ExpressionSpec, points: Sequence[Tuple[int, ...]],
                   runner_factory: Callable[[], object],
                   threshold: float) -> List[Instance]:
    """Process-pool worker: measure one shard of points.

    Module-level (picklable); each worker builds its own runner — BLAS
    state, RNGs and cache-flush buffers are never shared across processes
    — and caches it for the worker's lifetime, so the 64 MB flush buffer
    is zeroed once per worker rather than once per chunk.
    """
    global _worker_runner
    key = _factory_key(runner_factory)
    if _worker_runner is None or _worker_runner[0] != key:
        _worker_runner = (key, runner_factory())
    runner = _worker_runner[1]
    return [measure_instance(spec, p, runner, threshold) for p in points]


def _chunked(seq: Sequence, size: int) -> List[Sequence]:
    return [seq[i:i + size] for i in range(0, len(seq), size)]


def _run_serial(spec, points, runner, threshold, on_done) -> None:
    for p in points:
        on_done(measure_instance(spec, p, runner, threshold))


def _run_process_pool(spec, points, runner_factory, threshold, shards,
                      chunk_size, on_done, executor=None) -> None:
    """Shard points over a process pool (the BLAS fallback path).

    Chunks are submitted eagerly but results are drained as they complete,
    so the atlas keeps filling (and flushing) while workers run — a kill
    mid-pool still leaves every completed chunk on disk. An ``executor``
    passed in is reused and left open (callers measuring many point sets,
    e.g. Experiment 1's sampling loop, pay process start-up once).
    """
    chunks = _chunked(points, chunk_size)
    own = executor is None
    pool = executor if executor is not None else ProcessPoolExecutor(
        max_workers=shards)
    try:
        pending = {
            pool.submit(_measure_chunk, spec, c, runner_factory, threshold)
            for c in chunks
        }
        while pending:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            for fut in done:
                for inst in fut.result():
                    on_done(inst)
    finally:
        if own:
            pool.shutdown()


def _run_jax_devices(spec, points, threshold, reps, use_pallas, dtype,
                     shards, on_done) -> None:
    """Shard points across JAX devices, one pinned runner per device.

    Each device gets a round-robin shard and its own
    :class:`~repro.core.runners.JaxRunner` whose operands are
    ``device_put`` to it; device shards run concurrently on threads (jit
    dispatch releases the GIL while devices execute). On a 1-device host
    this degrades to the serial path. Results stream to ``on_done`` per
    instance (serialized by a lock), so the atlas keeps flushing and a
    killed sweep still resumes from the last chunk.
    """
    import threading

    import jax

    devices = jax.devices()
    if shards:
        devices = devices[:shards]
    runners = [JaxRunner(use_pallas=use_pallas, device=d, reps=reps,
                         dtype=dtype) for d in devices]
    shards_pts = [points[i::len(devices)] for i in range(len(devices))]
    lock = threading.Lock()

    def work(runner, pts):
        for p in pts:
            inst = measure_instance(spec, p, runner, threshold)
            with lock:
                on_done(inst)

    with ThreadPoolExecutor(max_workers=len(devices)) as pool:
        futs = [pool.submit(work, r, pts)
                for r, pts in zip(runners, shards_pts) if pts]
        for fut in futs:
            fut.result()  # surface worker exceptions


# ------------------------------------------------------------------ sweep ---


@dataclasses.dataclass
class SweepResult:
    spec_name: str
    records: List[Instance]   # one per requested point (measured or cached)
    n_measured: int
    n_skipped: int            # points served from the atlas
    wall_s: float
    atlas_path: Optional[Path] = None

    @property
    def n_points(self) -> int:
        return len(self.records)

    @property
    def anomalies(self) -> List[Instance]:
        return [r for r in self.records if r.cls.is_anomaly]

    @property
    def anomaly_rate(self) -> float:
        return len(self.anomalies) / len(self.records) if self.records \
            else 0.0

    @property
    def instances_per_s(self) -> float:
        return self.n_measured / self.wall_s if self.wall_s > 0 else 0.0


def sweep(
    spec: ExpressionSpec,
    points: Sequence[Sequence[int]],
    *,
    runner=None,
    runner_factory: Optional[Callable[[], object]] = None,
    threshold: float = 0.10,
    backend: str = "serial",
    shards: Optional[int] = None,
    atlas: Optional[AnomalyAtlas] = None,
    chunk_size: int = 8,
    max_instances: Optional[int] = None,
    reps: int = 3,
    use_pallas: bool = False,
    dtype: str = "float32",
    executor=None,
    progress: Optional[Callable[[int, int, Instance], None]] = None,
) -> SweepResult:
    """Measure + classify a set of instances — the one measurement path.

    * ``backend="serial"``  — this process, ``runner`` (or a fresh
      ``BlasRunner``) measuring point by point.
    * ``backend="process"`` — shard across ``shards`` worker processes;
      requires a picklable zero-arg ``runner_factory`` (e.g.
      ``functools.partial(BlasRunner, reps=3)``) since runners hold
      unshippable state (cache-flush buffers, BLAS handles).
    * ``backend="jax"``     — shard across JAX devices with device-pinned
      :class:`~repro.core.runners.JaxRunner` instances (``reps``,
      ``use_pallas``, ``dtype`` configure them).

    Points already present in ``atlas`` are *skipped* (served from disk) —
    that is what makes a restarted sweep resume instead of re-measuring.
    Newly measured instances stream into the atlas and are flushed in
    chunks. ``max_instances`` caps new measurements (budgeted/partial
    sweeps). Requested-point order is preserved in the result regardless
    of backend completion order. ``executor`` (process backend only) is an
    existing ``ProcessPoolExecutor`` to reuse across many sweep calls; it
    is left open for the caller.
    """
    if atlas is not None and abs(atlas.threshold - threshold) > 1e-12:
        raise ValueError(
            f"atlas {atlas.path} records threshold {atlas.threshold}, but "
            f"sweep() was called with threshold {threshold} — cached and "
            f"new classifications would silently disagree")
    if runner is not None and backend != "serial":
        raise ValueError(
            f"runner= only configures the serial backend; backend="
            f"{backend!r} builds its own workers (pass runner_factory for "
            f"'process', or reps/use_pallas/dtype for 'jax') — refusing to "
            f"silently measure with a different configuration")
    want = list(dict.fromkeys(tuple(int(x) for x in p) for p in points))
    for p in want:
        if len(p) != spec.ndims:
            raise ValueError(
                f"point {p} has {len(p)} dims but expression {spec.name} "
                f"takes {spec.ndims} — check the grid's ndims")
    cached: Dict[Tuple[int, ...], Instance] = {}
    todo: List[Tuple[int, ...]] = []
    for p in want:
        hit = atlas.get(p) if atlas is not None else None
        if hit is not None:
            cached[p] = hit
        else:
            todo.append(p)
    if max_instances is not None:
        todo = todo[:max_instances]

    measured: Dict[Tuple[int, ...], Instance] = {}
    n_total = len(todo)
    t0 = _time.perf_counter()

    def on_done(inst: Instance) -> None:
        measured[inst.point] = inst
        if atlas is not None:
            atlas.append(inst)
        if progress is not None:
            progress(len(measured), n_total, inst)

    try:
        if not todo:
            pass
        elif backend == "serial":
            r = runner
            if r is None:
                r = runner_factory() if runner_factory else BlasRunner(
                    reps=reps)
            _run_serial(spec, todo, r, threshold, on_done)
        elif backend == "process":
            if runner_factory is None:
                runner_factory = functools.partial(BlasRunner, reps=reps)
            _run_process_pool(spec, todo, runner_factory, threshold,
                              shards or os.cpu_count() or 1, chunk_size,
                              on_done, executor=executor)
        elif backend == "jax":
            _run_jax_devices(spec, todo, threshold, reps, use_pallas, dtype,
                             shards, on_done)
        else:
            raise ValueError(
                f"unknown backend {backend!r}; expected serial|process|jax")
    finally:
        if atlas is not None:
            atlas.flush()

    records = [cached.get(p) or measured[p] for p in want
               if p in cached or p in measured]
    return SweepResult(
        spec_name=spec.name,
        records=records,
        n_measured=len(measured),
        n_skipped=len(cached),
        wall_s=_time.perf_counter() - t0,
        atlas_path=atlas.path if atlas is not None else None,
    )


# --------------------------------------------- batched kernel measurement ---


def collect_unique_calls(
    spec: ExpressionSpec, points: Iterable[Sequence[int]],
) -> List[KernelCall]:
    """Distinct kernel calls across every algorithm of every point.

    Across a grid, neighbouring instances' algorithms share most calls, so
    the unique set is far smaller than the naive call stream — this dedup
    is what makes predicted sweeps (and Experiment 3) cheap.
    """
    seen: Dict[KernelCall, None] = {}
    for p in points:
        for a in spec.algorithms(p):
            for call in a.calls:
                seen.setdefault(call)
    return list(seen)


def benchmark_unique_calls(
    runner,
    calls: Iterable[KernelCall],
    profile: Optional[TableProfile] = None,
    reps: Optional[int] = None,
    progress: Optional[Callable[[int, int, KernelCall], None]] = None,
) -> Tuple[TableProfile, int, int]:
    """Benchmark the deduplicated call set, reusing ``profile`` entries.

    Returns ``(profile, n_measured, n_reused)``. Calls the profile already
    covers are never re-measured — so a persisted calibration makes repeat
    sweeps nearly free, and every new measurement lands in the profile for
    the *next* consumer (the calibration-cache feedback loop).
    """
    calls = list(dict.fromkeys(calls))
    if profile is None:
        profile = TableProfile(peak_flops=1.0)
    n_measured = n_reused = 0
    for i, call in enumerate(calls):
        if call in profile:
            n_reused += 1
            continue
        if isinstance(runner, JaxRunner):
            seconds = runner.benchmark_call(
                call, reps=reps or runner.reps, dtype=runner.dtype)
        else:
            seconds = runner.benchmark_call(call, reps=reps)
        profile.record(call, seconds)
        n_measured += 1
        if seconds > 0 and call.flops:
            # cached profiles included: a newly observed best throughput
            # raises peak_flops so efficiency stays a true fraction
            profile.observe_peak(call.flops / seconds)
        if progress is not None:
            progress(i + 1, len(calls), call)
    return profile, n_measured, n_reused


def predict_classifications(
    spec: ExpressionSpec,
    points: Iterable[Sequence[int]],
    profile: KernelProfile,
    threshold: float = 0.10,
    dtype_bytes: int = 8,
) -> Dict[Tuple[int, ...], Classification]:
    """Classify every point from the additive per-kernel model (no timing).

    This is the paper's Experiment-3 prediction generalized to arbitrary
    point sets: with a calibrated profile it maps anomaly regions at grid
    scale in milliseconds.
    """
    out: Dict[Tuple[int, ...], Classification] = {}
    for p in points:
        p = tuple(int(x) for x in p)
        algos = spec.algorithms(p)
        times = {a.name: predict_algorithm_time(a.calls, profile, dtype_bytes)
                 for a in algos}
        flops = {a.name: a.flops for a in algos}
        out[p] = classify(times, flops, threshold=threshold)
    return out


# ------------------------------------------------------------- clustering ---


def cluster_sweep(
    records: Iterable[Instance],
    grid: GridSpec,
) -> List[Region]:
    """Cluster a swept grid's anomalies into contiguous regions.

    Records off the grid (e.g. random-search points sharing the atlas) are
    ignored — adjacency is only defined on the grid's axes.
    """
    axes_sets = [set(ax) for ax in grid.axes]
    scores: Dict[Tuple[int, ...], Tuple[float, float]] = {}
    for r in records:
        if not r.cls.is_anomaly:
            continue
        if all(v in s for v, s in zip(r.point, axes_sets)):
            scores[r.point] = (r.cls.time_score, r.cls.flop_score)
    return cluster_regions(scores, grid.axes)


def cluster_predictions(
    predicted: Mapping[Tuple[int, ...], Classification],
    grid: GridSpec,
) -> List[Region]:
    """Cluster predicted (model-only) classifications over a grid."""
    scores = {p: (c.time_score, c.flop_score)
              for p, c in predicted.items() if c.is_anomaly}
    return cluster_regions(scores, grid.axes)


# -------------------------------------------------------------------- CLI ---


def _note(msg: str, quiet: bool) -> None:
    if not quiet:
        print(msg, file=sys.stderr)
        sys.stderr.flush()


def _registry_epilog() -> str:
    lines = ["registered expression families (repro.core.expressions):"]
    for cli_name in registered_names():
        s = REGISTRY[cli_name]
        lines.append(f"  {cli_name:<7} {s.name:<6} ndims={s.ndims}  "
                     f"{s.description}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.sweep",
        description="Sharded anomaly sweep over a problem-size grid; "
                    "results persist in the resumable anomaly atlas.",
        epilog=_registry_epilog(),
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--expr", choices=registered_names(), default="aatb",
                    help="expression family to sweep (see the registry "
                         "listing below)")
    ap.add_argument("--list-exprs", action="store_true",
                    help="print the registered expression families (one "
                         "CLI name per line) and exit")
    ap.add_argument("--grid", default="small",
                    help=f"named grid {sorted(SWEEP_GRIDS)} (per-family "
                         "axis overrides apply) or comma-separated axis "
                         "values, e.g. 64,128,256")
    ap.add_argument("--mode", choices=("measure", "predict"),
                    default="measure",
                    help="measure: time every algorithm per instance; "
                         "predict: classify from batched per-kernel "
                         "benchmarks (additive model, feeds the "
                         "calibration cache)")
    ap.add_argument("--backend", choices=("blas", "jax"), default="blas")
    ap.add_argument("--shards", type=int, default=1,
                    help="worker shards: for blas, >1 fans out over a "
                         "process pool; for jax, the number of devices to "
                         "use (0 = all devices)")
    ap.add_argument("--threshold", type=float, default=0.10)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--no-flush", action="store_true",
                    help="skip the per-rep cache flush (faster, noisier; "
                         "smoke/CI use)")
    ap.add_argument("--limit", type=int, default=None,
                    help="measure at most N new instances this run "
                         "(budgeted partial sweep; resume later)")
    ap.add_argument("--atlas-dir", type=Path, default=None,
                    help="atlas directory (default: $REPRO_ATLAS_DIR or "
                         "the shared cache under ~/.cache/repro/atlas)")
    ap.add_argument("--fresh", action="store_true",
                    help="delete any existing atlas file first")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.list_exprs:
        for cli_name in registered_names():
            print(cli_name)
        return 0

    spec = get_spec(args.expr)
    if args.grid in SWEEP_GRIDS or args.grid in spec.grids:
        grid = spec.grid(args.grid)
    else:
        try:
            values = [int(v) for v in args.grid.split(",") if v.strip()]
        except ValueError:
            ap.error(f"--grid must name one of {sorted(SWEEP_GRIDS)} or "
                     f"be comma-separated ints; got {args.grid!r}")
        grid = GridSpec.uniform(values, spec.ndims)
    points = grid.points()

    dtype = "float64" if args.backend == "blas" else "float32"
    fp = current_fingerprint(backend=args.backend, dtype=dtype)
    path = atlas_path(spec.name, fp, args.threshold, args.atlas_dir)
    if args.fresh and path.is_file():
        path.unlink()
    atlas = AnomalyAtlas(path, fp, spec.name, args.threshold)

    _note(f"sweep {spec.name} grid={grid.name} "
          f"({grid.n_points} instances over {spec.ndims} dims), "
          f"backend={args.backend} shards={args.shards}", args.quiet)
    _note(f"atlas: {path} ({len(atlas)} instances already recorded)",
          args.quiet)

    if args.mode == "predict":
        return _main_predict(args, spec, grid, points, atlas, dtype, fp)

    def progress(i, n, inst):
        if not args.quiet and (i % 25 == 0 or i == n):
            _note(f"  [{i}/{n}] {inst.point} "
                  f"{'ANOMALY' if inst.cls.is_anomaly else 'ok'} "
                  f"ts={inst.cls.time_score:.1%}", args.quiet)

    kwargs = dict(threshold=args.threshold, atlas=atlas,
                  max_instances=args.limit, reps=args.reps,
                  progress=progress)
    if args.backend == "jax":
        res = sweep(spec, points, backend="jax",
                    shards=args.shards or None,  # 0 = every device
                    **kwargs)
    elif args.shards > 1:
        factory = functools.partial(BlasRunner, reps=args.reps,
                                    flush_cache=not args.no_flush)
        res = sweep(spec, points, backend="process", shards=args.shards,
                    runner_factory=factory, **kwargs)
    else:
        res = sweep(spec, points,
                    runner=BlasRunner(reps=args.reps,
                                      flush_cache=not args.no_flush),
                    **kwargs)

    print(f"sweep {spec.name}/{grid.name}: points={res.n_points} "
          f"measured={res.n_measured} skipped={res.n_skipped} "
          f"anomalies={len(res.anomalies)} "
          f"({res.anomaly_rate:.1%}) in {res.wall_s:.1f}s "
          f"[{res.instances_per_s:.1f} inst/s]")
    regions = cluster_sweep(res.records, grid)
    print(region_summary(regions, res.n_points))
    print(f"atlas written to {res.atlas_path}")
    return 0


def _main_predict(args, spec, grid, points, atlas, dtype, fp) -> int:
    """--mode predict: batched kernel benchmarks → model-only sweep."""
    if args.backend == "jax":
        runner = JaxRunner(reps=args.reps, dtype=dtype)
    else:
        runner = BlasRunner(reps=args.reps,
                            flush_cache=not args.no_flush)
    cached = load_default_profile(backend=args.backend, dtype=dtype)
    calls = collect_unique_calls(spec, points)
    t0 = _time.perf_counter()
    profile, n_meas, n_reused = benchmark_unique_calls(
        runner, calls, profile=cached, reps=args.reps)
    bench_s = _time.perf_counter() - t0
    save_profile(profile, fp, meta={"source": f"sweep:{spec.name}"})
    predicted = predict_classifications(
        spec, points, profile, threshold=args.threshold,
        dtype_bytes=8 if dtype == "float64" else 4)
    n_anom = sum(1 for c in predicted.values() if c.is_anomaly)
    print(f"predict {spec.name}/{grid.name}: points={len(points)} "
          f"unique_kernels={len(calls)} measured={n_meas} "
          f"reused={n_reused} in {bench_s:.1f}s; "
          f"predicted anomalies={n_anom} ({n_anom / len(points):.1%})")
    regions = cluster_predictions(predicted, grid)
    print(region_summary(regions, len(points)))
    if len(atlas):
        # Confusion vs whatever ground truth the atlas already holds.
        from .anomaly import ConfusionMatrix
        cm = ConfusionMatrix()
        for p, c in predicted.items():
            actual = atlas.get(p)
            if actual is not None:
                cm.add(actual.cls.is_anomaly, c.is_anomaly)
        if cm.total:
            print(f"vs atlas ground truth ({cm.total} instances): "
                  f"recall={cm.recall:.1%} precision={cm.precision:.1%}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
