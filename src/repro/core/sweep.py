"""Sharded grid sweeps over problem-size space + the persistent anomaly atlas.

The paper's central empirical finding is that anomalies — instances where the
FLOP-cheapest algorithm is not the fastest — "cluster into large contiguous
regions" of the problem-size space (§3.4.2). Mapping those regions needs
*dense* sweeps over size grids (the methodology of Peise & Bientinesi's
performance-modeling line, arXiv:1209.2364 / arXiv:1409.8602), which a serial
Python loop cannot deliver at useful resolution. This module is the scaling
layer:

* :class:`GridSpec` / :data:`SWEEP_GRIDS` (defined in
  :mod:`repro.core.expressions`, re-exported here) — named dim grids over
  any registered expression family: the paper's ``ABCD``/``AAᵀB`` plus the
  zoo (``abcde``, ``abtb``, ``btsb``, ``atab``, ``abab``); ``--expr``
  accepts every registry entry and ``--list-exprs`` prints them.
* :func:`sweep` — the one measurement path, over any registered execution
  backend (:mod:`repro.core.backends`: ``blas``/``numpy``/``jax``/
  ``pallas``). Shards the grid across workers: a process pool for the
  CPU backends (kernel timing is GIL-bound and cache-sensitive, so
  isolation per process matches the paper's protocol), or one
  device-pinned backend instance per JAX device (devices measure their
  shards concurrently). Results stream into the atlas in chunks, so a
  killed sweep resumes from the last chunk. ``--compare-backends a,b``
  diffs two backends' atlases and reports instances where the *fastest
  algorithm differs by backend*.
* :class:`AnomalyAtlas` — persistent, resumable, versioned JSONL store of
  per-instance :class:`~repro.core.anomaly.Classification` results, one file
  per (expression, threshold, hardware fingerprint) — the same fingerprint
  scheme as :mod:`repro.core.profile_store`, so atlases calibrated on one
  machine are never silently mixed with another's.
* :func:`benchmark_unique_calls` / :func:`predict_classifications` — the
  batched kernel path: across a grid, algorithms share most of their kernel
  calls, so deduplicating (kind, dims) before benchmarking amortizes
  dispatch by orders of magnitude and feeds the calibration cache.
* :func:`cluster_sweep` — connected-component pass over a swept grid,
  reproducing the paper's contiguous-region claim with per-region severity
  summaries.

CLI::

    PYTHONPATH=src python -m repro.core.sweep --expr aatb --grid small
    PYTHONPATH=src python -m repro.core.sweep --expr aatb --grid small  # resumes: measured=0
    PYTHONPATH=src python -m repro.core.sweep --expr abcd --grid default --shards 8
    PYTHONPATH=src python -m repro.core.sweep --expr aatb --grid small --mode predict

The paper harnesses (:mod:`repro.core.experiments`) and the experiment
benchmarks are thin configurations over this engine.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import re
import sys
import time as _time
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, ThreadPoolExecutor, wait
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from .algorithms import Algorithm, Leaf
from .anomaly import Classification, Region, classify, cluster_regions, region_summary
from .arena import (
    FastPathStats,
    OperandArena,
    arena_for,
    memo_counts,
    order_points_for_locality,
)
# Expression specs + grids live in repro.core.expressions; the
# redundant-alias imports re-export them here for backwards compat
# (pre-registry callers import them from repro.core.sweep).
from .expressions import (
    GRAM_AATB as GRAM_AATB,
    MATRIX_CHAIN_ABCD as MATRIX_CHAIN_ABCD,
    REGISTRY as REGISTRY,
    SPECS as SPECS,
    SWEEP_GRIDS as SWEEP_GRIDS,
    ExpressionSpec as ExpressionSpec,
    GridSpec as GridSpec,
    get_spec as get_spec,
    registered_names as registered_names,
)
from .backends import (
    backend_default_dtype,
    backend_shard_mode,
    make_backend,
    registered_backends,
    synthetic_algorithm,
)
from .flops import KernelCall
from .perfmodel import KernelProfile, TableProfile, predict_algorithm_time
from .profile_store import (
    HardwareFingerprint,
    cache_base_dir,
    current_fingerprint,
    load_default_profile,
    save_profile,
)
from .runners import BlasRunner

# --------------------------------------------------- instance measurement ---

#: Kill-switch for the measurement fast path (arena + memo + pipelining).
#: An env var rather than plumbing so process-pool workers and nested
#: helpers inherit one decision; ``sweep --no-fastpath`` sets it.
FASTPATH_ENV = "REPRO_NO_FASTPATH"


def fastpath_enabled(flag: Optional[bool] = None) -> bool:
    """Whether the measurement fast path is on (explicit flag wins)."""
    if flag is not None:
        return bool(flag)
    return not os.environ.get(FASTPATH_ENV)


def _leaf_bases(alg: Algorithm) -> set:
    """Distinct operand base indices an algorithm's steps reference."""
    return {ref.base for step in alg.steps for ref in (step.lhs, step.rhs)
            if isinstance(ref, Leaf)}


@dataclasses.dataclass
class Instance:
    """One fully measured grid point: per-algorithm times/FLOPs + verdict."""

    point: Tuple[int, ...]
    times: Dict[str, float]
    flops: Dict[str, int]
    cls: Classification


def _measure_prepared(point, algos, operands, runner,
                      threshold: float) -> Instance:
    """Time + classify one point whose algorithms/operands are in hand."""
    times: Dict[str, float] = {}
    flops: Dict[str, int] = {}
    for a in algos:
        times[a.name] = runner.time_algorithm(a, operands)
        flops[a.name] = a.flops
    cls = classify(times, flops, threshold=threshold)
    return Instance(tuple(int(x) for x in point), times, flops, cls)


def measure_instance(
    spec: ExpressionSpec,
    point: Sequence[int],
    runner,
    threshold: float = 0.10,
    arena: Optional[OperandArena] = None,
) -> Instance:
    """Time every algorithm for one instance and classify it.

    ``runner`` is any object with ``make_operands(alg) -> dict`` and
    ``time_algorithm(alg, operands) -> seconds`` — every registered
    :class:`~repro.core.backends.ExecutionBackend` qualifies. With an
    ``arena``, operand synthesis is served from the shape-keyed pool
    (each distinct leaf buffer built once per arena lifetime); timing
    semantics are untouched — the cache-flush protocol runs per rep
    inside the backend either way.
    """
    algos = spec.algorithms(point)
    if arena is not None:
        return _measure_prepared(point, algos, arena.operands(algos),
                                 runner, threshold)
    times: Dict[str, float] = {}
    flops: Dict[str, int] = {}
    # Leaves are shared across algorithms: synthesize operands once, and
    # only fall back to make_operands for an algorithm referencing a base
    # the dict lacks — not per algorithm, which would generate (and mostly
    # discard) a full operand set each time.
    operands = runner.make_operands(algos[-1])
    for a in algos:
        if not _leaf_bases(a) <= operands.keys():
            for k, v in runner.make_operands(a).items():
                operands.setdefault(k, v)
        times[a.name] = runner.time_algorithm(a, operands)
        flops[a.name] = a.flops
    cls = classify(times, flops, threshold=threshold)
    return Instance(tuple(int(x) for x in point), times, flops, cls)


# ------------------------------------------------------------------ atlas ---

ATLAS_SCHEMA_VERSION = 1

_ENV_ATLAS_DIR = "REPRO_ATLAS_DIR"


class AtlasError(RuntimeError):
    """Atlas file exists but belongs to a different sweep configuration."""


def atlas_dir() -> Path:
    env = os.environ.get(_ENV_ATLAS_DIR)
    if env:
        return Path(env)
    return cache_base_dir() / "atlas"


def _slug(s: str) -> str:
    return re.sub(r"[^A-Za-z0-9._-]+", "_", s).lower()


def atlas_path(spec_name: str, fingerprint: HardwareFingerprint,
               threshold: float, directory: Optional[Path] = None) -> Path:
    d = Path(directory) if directory is not None else atlas_dir()
    t = f"{threshold:g}".replace(".", "p")
    return d / f"atlas-{_slug(spec_name)}-t{t}-{fingerprint.slug()}.jsonl"


def atlas_shard_path(spec_name: str, fingerprint: HardwareFingerprint,
                     threshold: float, shard_index: int,
                     directory: Optional[Path] = None) -> Path:
    """Per-host shard file of a fanned-out sweep: ``…-shardK.jsonl``.

    Same directory, naming scheme and header format as the canonical
    atlas, so every shard carries the full configuration and
    ``tools/atlas_merge.py`` can refuse to mix incompatible ones.
    """
    base = atlas_path(spec_name, fingerprint, threshold, directory)
    return base.with_name(f"{base.stem}-shard{int(shard_index)}{base.suffix}")


def _instance_to_json(inst: Instance) -> dict:
    return {
        "point": list(inst.point),
        "is_anomaly": inst.cls.is_anomaly,
        "time_score": inst.cls.time_score,
        "flop_score": inst.cls.flop_score,
        "cheapest": list(inst.cls.cheapest),
        "fastest": list(inst.cls.fastest),
        "times": inst.times,
        "flops": inst.flops,
    }


def _instance_from_json(d: dict) -> Instance:
    cls = Classification(
        is_anomaly=bool(d["is_anomaly"]),
        time_score=float(d["time_score"]),
        flop_score=float(d["flop_score"]),
        cheapest=tuple(d["cheapest"]),
        fastest=tuple(d["fastest"]),
    )
    return Instance(
        point=tuple(int(x) for x in d["point"]),
        times={str(k): float(v) for k, v in d["times"].items()},
        flops={str(k): int(v) for k, v in d["flops"].items()},
        cls=cls,
    )


class AnomalyAtlas:
    """Persistent, resumable JSONL store of swept classifications.

    One file per (expression, anomaly threshold, hardware fingerprint).
    Line 1 is a header record ``{"kind": "header", ...}``; every other line
    is one instance. Appends are buffered and flushed in chunks of
    ``chunk_size`` (with fsync), so a killed sweep loses at most one
    unflushed chunk and a restart resumes from the last chunk: points
    already on disk are skipped by :func:`sweep`.

    A torn final line (the kill landed mid-write) is tolerated on load;
    any undecodable line is skipped and counted in ``skipped_lines``.

    ``shard=(k, n)`` marks this file as host ``k``'s shard of an
    ``n``-way fanned-out sweep (see :mod:`repro.core.adaptive`): the
    header records it, and opening a shard file without the matching
    shard identity (or vice versa) is an :class:`AtlasError` — a shard
    must never silently resume as the canonical atlas before
    ``tools/atlas_merge.py`` has reconciled it.
    """

    def __init__(self, path: Path, fingerprint: HardwareFingerprint,
                 spec_name: str, threshold: float, chunk_size: int = 32,
                 shard: Optional[Tuple[int, int]] = None):
        if chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        if shard is not None:
            k, n = int(shard[0]), int(shard[1])
            if not 0 <= k < n:
                raise ValueError(f"shard must be (k, n) with 0 <= k < n; "
                                 f"got {shard}")
            shard = (k, n)
        self.path = Path(path)
        self.fingerprint = fingerprint
        self.spec_name = spec_name
        self.threshold = float(threshold)
        self.shard = shard
        self.chunk_size = chunk_size
        self.skipped_lines = 0
        self._records: Dict[Tuple[int, ...], Instance] = {}
        self._buffer: List[str] = []
        self._header_on_disk = False
        self._needs_newline = False
        self.recovered_from: Optional[Path] = None
        if self.path.is_file():
            self._load()

    @classmethod
    def open(cls, spec_name: str, fingerprint: HardwareFingerprint,
             threshold: float = 0.10, directory: Optional[Path] = None,
             chunk_size: int = 32) -> "AnomalyAtlas":
        """Open (resuming) or create the atlas for this configuration."""
        path = atlas_path(spec_name, fingerprint, threshold, directory)
        return cls(path, fingerprint, spec_name, threshold,
                   chunk_size=chunk_size)

    # -- persistence ------------------------------------------------------
    def _header(self) -> dict:
        head = {
            "kind": "header",
            "version": ATLAS_SCHEMA_VERSION,
            "spec": self.spec_name,
            "threshold": self.threshold,
            "fingerprint": self.fingerprint.to_dict(),
        }
        if self.shard is not None:
            head["shard"] = list(self.shard)
        return head

    def _load(self) -> None:
        with self.path.open() as f:
            first = f.readline()
            try:
                head = json.loads(first)
            except json.JSONDecodeError:
                # The kill landed mid-write of the header itself (it is the
                # first line of the first flushed chunk, so at most one
                # chunk existed). Resume must survive this: preserve the
                # torn file as a sidecar and start the atlas fresh.
                side = self.path.with_suffix(self.path.suffix + ".corrupt")
                self.path.replace(side)
                self.recovered_from = side
                return
            if head.get("kind") != "header":
                raise AtlasError(f"atlas {self.path} is missing its header")
            if head.get("version") != ATLAS_SCHEMA_VERSION:
                raise AtlasError(
                    f"atlas {self.path} has schema version "
                    f"{head.get('version')!r}; this build reads "
                    f"{ATLAS_SCHEMA_VERSION}")
            fp = HardwareFingerprint.from_dict(head["fingerprint"])
            if fp != self.fingerprint:
                raise AtlasError(
                    f"atlas {self.path} was swept on {fp}, but this "
                    f"process targets {self.fingerprint}")
            if head.get("spec") != self.spec_name or \
                    abs(head.get("threshold", -1) - self.threshold) > 1e-12:
                raise AtlasError(
                    f"atlas {self.path} records spec="
                    f"{head.get('spec')!r}/threshold="
                    f"{head.get('threshold')!r}, not "
                    f"{self.spec_name!r}/{self.threshold}")
            head_shard = head.get("shard")
            want_shard = list(self.shard) if self.shard is not None else None
            if head_shard != want_shard:
                raise AtlasError(
                    f"atlas {self.path} records shard={head_shard}, but "
                    f"this process opened it as shard={want_shard} — merge "
                    f"shards with tools/atlas_merge.py instead of mixing")
            self._header_on_disk = True
            raw = first
            for raw in f:
                line = raw.strip()
                if not line:
                    continue
                try:
                    inst = _instance_from_json(json.loads(line))
                except (json.JSONDecodeError, KeyError, TypeError, ValueError):
                    # Torn tail from a killed writer (or a corrupt line):
                    # drop it; the sweep will re-measure that point.
                    self.skipped_lines += 1
                    continue
                self._records[inst.point] = inst
            # A torn tail has no trailing newline; appending straight after
            # it would merge the next record into the garbage line and
            # silently lose it on the following load. Flush starts with a
            # newline instead (the blank line is skipped on load).
            self._needs_newline = not raw.endswith("\n")

    def append(self, inst: Instance) -> bool:
        """Add one instance; returns False (no write) for known points."""
        if inst.point in self._records:
            return False
        self._records[inst.point] = inst
        self._buffer.append(json.dumps(_instance_to_json(inst),
                                       sort_keys=True))
        if len(self._buffer) >= self.chunk_size:
            self.flush()
        return True

    def flush(self) -> None:
        """Durably write buffered records (chunk boundary for resume)."""
        if not self._buffer and self._header_on_disk:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with self.path.open("a") as f:
            if self._needs_newline:
                f.write("\n")
                self._needs_newline = False
            if not self._header_on_disk:
                f.write(json.dumps(self._header(), sort_keys=True) + "\n")
                self._header_on_disk = True
            for line in self._buffer:
                f.write(line + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._buffer.clear()

    def __enter__(self) -> "AnomalyAtlas":
        return self

    def __exit__(self, *exc) -> None:
        self.flush()

    # -- queries ----------------------------------------------------------
    def __contains__(self, point: Sequence[int]) -> bool:
        return tuple(int(x) for x in point) in self._records

    def __len__(self) -> int:
        return len(self._records)

    def get(self, point: Sequence[int]) -> Optional[Instance]:
        return self._records.get(tuple(int(x) for x in point))

    def records(self) -> List[Instance]:
        return list(self._records.values())

    def anomalies(self) -> List[Instance]:
        return [r for r in self._records.values() if r.cls.is_anomaly]


# ---------------------------------------------------------------- backends --


def _factory_key(factory) -> object:
    """Identity of a runner factory that survives pickling.

    ``functools.partial`` compares by object identity, and every chunk
    shipped to a worker unpickles to a *new* partial — so the worker-local
    runner cache keys on (func, args, kwargs) instead.
    """
    if isinstance(factory, functools.partial):
        return (factory.func, factory.args,
                tuple(sorted(factory.keywords.items())))
    return factory


_worker_runner: Optional[Tuple[object, object]] = None  # (key, runner)


def _measure_chunk(spec: ExpressionSpec, points: Sequence[Tuple[int, ...]],
                   runner_factory: Callable[[], object],
                   threshold: float, fastpath: bool = True,
                   ) -> Tuple[List[Instance], Dict[str, float]]:
    """Process-pool worker: measure one shard of points.

    Module-level (picklable); each worker builds its own runner — BLAS
    state, RNGs and cache-flush buffers are never shared across processes
    — and caches it for the worker's lifetime, so the 64 MB flush buffer
    is zeroed once per worker rather than once per chunk. With the fast
    path on, the runner's operand arena persists alongside it, so reuse
    compounds across every chunk the worker sees. Returns the measured
    instances plus this chunk's fast-path counter deltas.
    """
    global _worker_runner
    key = _factory_key(runner_factory)
    if _worker_runner is None or _worker_runner[0] != key:
        _worker_runner = (key, runner_factory())
    runner = _worker_runner[1]
    if not (fastpath and fastpath_enabled()):
        return ([measure_instance(spec, p, runner, threshold)
                 for p in points], {})
    arena = arena_for(runner)
    stats = FastPathStats()
    a0, m0 = arena.snapshot(), memo_counts(runner)
    out = [measure_instance(spec, p, runner, threshold, arena=arena)
           for p in order_points_for_locality(points)]
    stats.add_arena_delta(a0, arena.snapshot())
    stats.add_memo_delta(m0, memo_counts(runner))
    return out, stats.as_dict()


def _chunked(seq: Sequence, size: int) -> List[Sequence]:
    return [seq[i:i + size] for i in range(0, len(seq), size)]


def _run_serial(spec, points, runner, threshold, on_done) -> None:
    for p in points:
        on_done(measure_instance(spec, p, runner, threshold))


def _run_serial_fastpath(spec, points, runner, threshold, on_done,
                         stats: FastPathStats) -> None:
    """Arena + pipelined serial measurement (the ISSUE-10 fast path).

    Points are *measured* in locality order (lexicographic — identical to
    row-major grid order, so dense sweeps keep their legacy measurement
    order) while a single helper thread prepares point ``k+1``
    (enumeration + arena operand synthesis) during point ``k``'s
    GIL-releasing timed region. Instances are *emitted* strictly in
    request order through a small reorder buffer, so atlas bytes and
    progress callbacks are indistinguishable from the legacy path.
    """
    from collections import deque

    arena = arena_for(runner)
    order = order_points_for_locality(points)
    emit_q = deque(points)                       # request order
    ready: Dict[Tuple[int, ...], Instance] = {}
    memo0 = memo_counts(runner)
    a0 = arena.snapshot()

    def flush_ready() -> None:
        while emit_q and emit_q[0] in ready:
            on_done(ready.pop(emit_q.popleft()))

    def prepare(p):
        t0 = _time.perf_counter()
        algos = spec.algorithms(p)
        operands = arena.operands(algos)
        return p, algos, operands, _time.perf_counter() - t0

    with ThreadPoolExecutor(max_workers=1) as helper:
        nxt = helper.submit(prepare, order[0])
        for i in range(len(order)):
            t_wait = _time.perf_counter()
            p, algos, operands, prep_s = nxt.result()
            waited = _time.perf_counter() - t_wait
            stats.prep_s += prep_s
            # Preparation time not spent blocking here ran concurrently
            # with the previous point's timed region.
            stats.overlap_s += max(0.0, prep_s - waited)
            if i + 1 < len(order):
                nxt = helper.submit(prepare, order[i + 1])
                stats.points_pipelined += 1
            ready[p] = _measure_prepared(p, algos, operands, runner,
                                         threshold)
            flush_ready()
    flush_ready()
    stats.add_arena_delta(a0, arena.snapshot())
    stats.add_memo_delta(memo0, memo_counts(runner))


def _run_process_pool(spec, points, runner_factory, threshold, shards,
                      chunk_size, on_done, executor=None,
                      fastpath: bool = True,
                      stats: Optional[FastPathStats] = None) -> None:
    """Shard points over a process pool (the BLAS fallback path).

    Chunks are submitted eagerly but results are drained as they complete,
    so the atlas keeps filling (and flushing) while workers run — a kill
    mid-pool still leaves every completed chunk on disk. An ``executor``
    passed in is reused and left open (callers measuring many point sets,
    e.g. Experiment 1's sampling loop, pay process start-up once). With
    the fast path on, each chunk is measured in locality order inside its
    worker (whose arena persists across chunks) and per-chunk counter
    deltas are merged into ``stats``.
    """
    chunks = _chunked(points, chunk_size)
    own = executor is None
    pool = executor if executor is not None else ProcessPoolExecutor(
        max_workers=shards)
    try:
        pending = {
            pool.submit(_measure_chunk, spec, c, runner_factory, threshold,
                        fastpath)
            for c in chunks
        }
        while pending:
            done, pending = wait(pending, return_when=FIRST_COMPLETED)
            for fut in done:
                insts, chunk_stats = fut.result()
                for inst in insts:
                    on_done(inst)
                if stats is not None and chunk_stats:
                    stats.merge(FastPathStats.from_dict(chunk_stats))
    finally:
        if own:
            pool.shutdown()


def _run_jax_devices(spec, points, threshold, reps, exec_backend, dtype,
                     shards, on_done, seed=None, fastpath: bool = True,
                     stats: Optional[FastPathStats] = None) -> None:
    """Shard points across JAX devices, one pinned backend per device.

    Each device gets a round-robin shard and its own registry backend
    (``jax``/``pallas``/any device-sharded entry) whose operands are
    ``device_put`` to it; device shards run concurrently on threads (jit
    dispatch releases the GIL while devices execute). On a 1-device host
    this degrades to the serial path. Results stream to ``on_done`` per
    instance (serialized by a lock), so the atlas keeps flushing and a
    killed sweep still resumes from the last chunk. With the fast path
    on, each device runner gets its own operand arena and its shard's
    slice is measured in locality order; the executable memo lives on
    the runner, so each algorithm *structure* is built/jitted once per
    device instead of once per point.
    """
    import threading

    import jax

    devices = jax.devices()
    if shards:
        devices = devices[:shards]
    runners = [make_backend(exec_backend, device=d, reps=reps, dtype=dtype,
                            seed=seed)
               for d in devices]
    shards_pts = [points[i::len(devices)] for i in range(len(devices))]
    lock = threading.Lock()

    def work(runner, pts):
        arena = arena_for(runner) if fastpath else None
        if fastpath:
            pts = order_points_for_locality(pts)
        for p in pts:
            inst = measure_instance(spec, p, runner, threshold, arena=arena)
            with lock:
                on_done(inst)
        if stats is not None and arena is not None:
            with lock:
                stats.add_arena_delta((0, 0, 0), arena.snapshot())
                stats.add_memo_delta((0, 0), memo_counts(runner))

    with ThreadPoolExecutor(max_workers=len(devices)) as pool:
        futs = [pool.submit(work, r, pts)
                for r, pts in zip(runners, shards_pts) if pts]
        for fut in futs:
            fut.result()  # surface worker exceptions


# ------------------------------------------------------------------ sweep ---


@dataclasses.dataclass
class SweepResult:
    spec_name: str
    records: List[Instance]   # one per requested point (measured or cached)
    n_measured: int
    n_skipped: int            # points served from the atlas
    wall_s: float
    atlas_path: Optional[Path] = None
    #: Fast-path counters (arena/memo hits, pipeline overlap); ``None``
    #: when the legacy path ran (``--no-fastpath`` / REPRO_NO_FASTPATH).
    fastpath: Optional[FastPathStats] = None

    @property
    def n_points(self) -> int:
        return len(self.records)

    @property
    def anomalies(self) -> List[Instance]:
        return [r for r in self.records if r.cls.is_anomaly]

    @property
    def anomaly_rate(self) -> float:
        return len(self.anomalies) / len(self.records) if self.records \
            else 0.0

    @property
    def instances_per_s(self) -> float:
        return self.n_measured / self.wall_s if self.wall_s > 0 else 0.0


def sweep(
    spec: ExpressionSpec,
    points: Sequence[Sequence[int]],
    *,
    runner=None,
    runner_factory: Optional[Callable[[], object]] = None,
    threshold: float = 0.10,
    backend: str = "serial",
    shards: Optional[int] = None,
    atlas: Optional[AnomalyAtlas] = None,
    chunk_size: int = 8,
    max_instances: Optional[int] = None,
    reps: int = 3,
    exec_backend: Optional[str] = None,
    use_pallas: bool = False,
    dtype: str = "float32",
    executor=None,
    progress: Optional[Callable[[int, int, Instance], None]] = None,
    fastpath: Optional[bool] = None,
    seed: Optional[int] = None,
) -> SweepResult:
    """Measure + classify a set of instances — the one measurement path.

    ``backend`` picks the *sharding strategy*; ``exec_backend`` names the
    *execution backend* (a :mod:`repro.core.backends` registry key) the
    workers are built from when no explicit ``runner``/``runner_factory``
    is given:

    * ``backend="serial"``  — this process, ``runner`` (or a fresh
      instance of ``exec_backend``, default ``blas``) measuring point by
      point.
    * ``backend="process"`` — shard across ``shards`` worker processes;
      ``runner_factory`` must be a picklable zero-arg callable (e.g.
      ``functools.partial(make_backend, "numpy", reps=3)``) since runners
      hold unshippable state (cache-flush buffers, BLAS handles);
      defaults to ``exec_backend`` (default ``blas``).
    * ``backend="jax"``     — shard across JAX devices with device-pinned
      instances of ``exec_backend`` (default ``jax``; ``pallas`` routes
      through the Pallas kernels); ``reps``/``dtype`` configure them.
      ``use_pallas=True`` is the deprecated spelling of
      ``exec_backend="pallas"``.

    Points already present in ``atlas`` are *skipped* (served from disk) —
    that is what makes a restarted sweep resume instead of re-measuring.
    Newly measured instances stream into the atlas and are flushed in
    chunks. ``max_instances`` caps new measurements (budgeted/partial
    sweeps). Requested-point order is preserved in the result regardless
    of backend completion order. ``executor`` (process backend only) is an
    existing ``ProcessPoolExecutor`` to reuse across many sweep calls; it
    is left open for the caller.

    ``fastpath`` controls the measurement fast path (operand arena,
    executable memo, locality ordering, pipelined preparation): ``None``
    (default) follows the ``REPRO_NO_FASTPATH`` kill-switch, ``True``/
    ``False`` force it. Timing semantics are identical either way — only
    per-point fixed costs (allocation, RNG fill, enumeration, re-tracing)
    are amortised; the result's ``fastpath`` field carries the counters.
    ``seed`` makes operand synthesis reproducible (each leaf a pure
    function of ``(seed, base, shape)``) for runners the sweep builds
    itself; explicit ``runner``/``runner_factory`` carry their own.
    """
    if atlas is not None and abs(atlas.threshold - threshold) > 1e-12:
        raise ValueError(
            f"atlas {atlas.path} records threshold {atlas.threshold}, but "
            f"sweep() was called with threshold {threshold} — cached and "
            f"new classifications would silently disagree")
    if runner is not None and backend != "serial":
        raise ValueError(
            f"runner= only configures the serial backend; backend="
            f"{backend!r} builds its own workers (pass runner_factory for "
            f"'process', or exec_backend/reps/dtype for 'jax') — refusing "
            f"to silently measure with a different configuration")
    if use_pallas:
        # Deprecated spelling of exec_backend="pallas" (pre-registry API).
        if exec_backend not in (None, "pallas"):
            raise ValueError(
                f"use_pallas=True conflicts with exec_backend="
                f"{exec_backend!r}")
        exec_backend = "pallas"
    want = list(dict.fromkeys(tuple(int(x) for x in p) for p in points))
    for p in want:
        if len(p) != spec.ndims:
            raise ValueError(
                f"point {p} has {len(p)} dims but expression {spec.name} "
                f"takes {spec.ndims} — check the grid's ndims")
    cached: Dict[Tuple[int, ...], Instance] = {}
    todo: List[Tuple[int, ...]] = []
    for p in want:
        hit = atlas.get(p) if atlas is not None else None
        if hit is not None:
            cached[p] = hit
        else:
            todo.append(p)
    if max_instances is not None:
        todo = todo[:max_instances]

    measured: Dict[Tuple[int, ...], Instance] = {}
    n_total = len(todo)
    fp_on = fastpath_enabled(fastpath)
    stats = FastPathStats() if fp_on else None
    t0 = _time.perf_counter()

    def on_done(inst: Instance) -> None:
        measured[inst.point] = inst
        if atlas is not None:
            atlas.append(inst)
        if progress is not None:
            progress(len(measured), n_total, inst)

    try:
        if not todo:
            pass
        elif backend == "serial":
            r = runner
            if r is None:
                if runner_factory is not None:
                    r = runner_factory()
                elif exec_backend is not None:
                    # dtype is the device-backend knob (float32 default);
                    # fixed-dtype CPU backends keep their own default.
                    kw = {"reps": reps, "seed": seed}
                    if backend_shard_mode(exec_backend) == "device":
                        kw["dtype"] = dtype
                    r = make_backend(exec_backend, **kw)
                else:
                    r = BlasRunner(reps=reps, seed=seed)
            if fp_on:
                _run_serial_fastpath(spec, todo, r, threshold, on_done,
                                     stats)
            else:
                _run_serial(spec, todo, r, threshold, on_done)
        elif backend == "process":
            if runner_factory is None:
                runner_factory = functools.partial(
                    make_backend, exec_backend or "blas", reps=reps,
                    seed=seed)
            _run_process_pool(spec, todo, runner_factory, threshold,
                              shards or os.cpu_count() or 1, chunk_size,
                              on_done, executor=executor, fastpath=fp_on,
                              stats=stats)
        elif backend == "jax":
            _run_jax_devices(spec, todo, threshold, reps,
                             exec_backend or "jax", dtype, shards, on_done,
                             seed=seed, fastpath=fp_on, stats=stats)
        else:
            raise ValueError(
                f"unknown backend {backend!r}; expected serial|process|jax")
    finally:
        if atlas is not None:
            atlas.flush()

    records = [cached.get(p) or measured[p] for p in want
               if p in cached or p in measured]
    return SweepResult(
        spec_name=spec.name,
        records=records,
        n_measured=len(measured),
        n_skipped=len(cached),
        wall_s=_time.perf_counter() - t0,
        atlas_path=atlas.path if atlas is not None else None,
        fastpath=stats,
    )


# --------------------------------------------- batched kernel measurement ---


def collect_unique_calls(
    spec: ExpressionSpec, points: Iterable[Sequence[int]],
) -> List[KernelCall]:
    """Distinct kernel calls across every algorithm of every point.

    Across a grid, neighbouring instances' algorithms share most calls, so
    the unique set is far smaller than the naive call stream — this dedup
    is what makes predicted sweeps (and Experiment 3) cheap.
    """
    seen: Dict[KernelCall, None] = {}
    for p in points:
        for a in spec.algorithms(p):
            for call in a.calls:
                seen.setdefault(call)
    return list(seen)


def benchmark_unique_calls(
    runner,
    calls: Iterable[KernelCall],
    profile: Optional[TableProfile] = None,
    reps: Optional[int] = None,
    progress: Optional[Callable[[int, int, KernelCall], None]] = None,
    arena: Optional[OperandArena] = None,
    stats: Optional[FastPathStats] = None,
) -> Tuple[TableProfile, int, int]:
    """Benchmark the deduplicated call set, reusing ``profile`` entries.

    Returns ``(profile, n_measured, n_reused)``. Calls the profile already
    covers are never re-measured — so a persisted calibration makes repeat
    sweeps nearly free, and every new measurement lands in the profile for
    the *next* consumer (the calibration-cache feedback loop).

    With an ``arena``, each synthetic call's operands come from the
    shape-keyed pool (kernel calls across a grid share most shapes); pass
    ``stats`` to receive the arena/memo reuse counters so calibrate's
    progress lines show where the time went.
    """
    calls = list(dict.fromkeys(calls))
    if profile is None:
        profile = TableProfile(peak_flops=1.0)
    n_measured = n_reused = 0
    n_calls = len(calls)
    a0 = arena.snapshot() if arena is not None else None
    m0 = memo_counts(runner)
    for i, call in enumerate(calls):
        if call in profile:
            n_reused += 1
            continue
        # One signature across every backend: dtype/device/flush protocol
        # live on the runner instance (see ExecutionBackend.benchmark_call).
        if arena is not None:
            alg = synthetic_algorithm(call)
            seconds = runner.time_algorithm(alg, arena.operands([alg]),
                                            reps=reps)
        else:
            seconds = runner.benchmark_call(call, reps=reps)
        profile.record(call, seconds)
        n_measured += 1
        if seconds > 0 and call.flops:
            # cached profiles included: a newly observed best throughput
            # raises peak_flops so efficiency stays a true fraction
            profile.observe_peak(call.flops / seconds)
        if progress is not None:
            progress(i + 1, n_calls, call)
    if stats is not None:
        if arena is not None:
            stats.add_arena_delta(a0, arena.snapshot())
        stats.add_memo_delta(m0, memo_counts(runner))
    return profile, n_measured, n_reused


def predict_classifications(
    spec: ExpressionSpec,
    points: Iterable[Sequence[int]],
    profile: KernelProfile,
    threshold: float = 0.10,
    dtype_bytes: int = 8,
) -> Dict[Tuple[int, ...], Classification]:
    """Classify every point from the additive per-kernel model (no timing).

    This is the paper's Experiment-3 prediction generalized to arbitrary
    point sets: with a calibrated profile it maps anomaly regions at grid
    scale in milliseconds.
    """
    out: Dict[Tuple[int, ...], Classification] = {}
    for p in points:
        p = tuple(int(x) for x in p)
        algos = spec.algorithms(p)
        times = {a.name: predict_algorithm_time(a.calls, profile, dtype_bytes)
                 for a in algos}
        flops = {a.name: a.flops for a in algos}
        out[p] = classify(times, flops, threshold=threshold)
    return out


# ------------------------------------------------- cross-backend diffing ---


@dataclasses.dataclass
class BackendDisagreement:
    """One instance where two backends' verdicts differ."""

    point: Tuple[int, ...]
    fastest: Dict[str, Tuple[str, ...]]   # backend -> fastest set
    is_anomaly: Dict[str, bool]
    time_score: Dict[str, float]


@dataclasses.dataclass
class BackendComparison:
    """Diff of two per-backend atlases over one point set.

    ``fastest_differs`` lists instances whose fastest-algorithm sets are
    *disjoint* across the two backends — the same math, a different
    winning kernel sequence purely because the kernel implementations
    differ. This is the result class the paper could not measure on one
    library. ``anomaly_differs`` lists instances whose anomaly verdicts
    disagree (an instance can be an MKL-anomaly but not an XLA-anomaly).
    """

    spec_name: str
    backends: Tuple[str, str]
    n_points: int
    fastest_differs: List[BackendDisagreement]
    anomaly_differs: List[BackendDisagreement]
    results: Dict[str, SweepResult]

    @property
    def fastest_differs_rate(self) -> float:
        return len(self.fastest_differs) / self.n_points if self.n_points \
            else 0.0


def compare_backends(
    spec: ExpressionSpec,
    points: Sequence[Sequence[int]],
    sweeps: Mapping[str, SweepResult],
) -> BackendComparison:
    """Diff two (or more — pairwise over the first two) backend sweeps.

    ``sweeps`` maps backend name -> the :func:`sweep` result for *the
    same* spec and point set on that backend (each typically persisted in
    its own fingerprint-keyed atlas). Points missing from either result
    (e.g. budget-capped partial sweeps) are skipped.
    """
    names = list(sweeps)
    if len(names) < 2:
        raise ValueError("compare_backends needs at least two sweeps")
    a_name, b_name = names[0], names[1]
    by_point = {
        name: {r.point: r for r in res.records}
        for name, res in sweeps.items()
    }
    want = [tuple(int(x) for x in p) for p in points]
    fastest_differs: List[BackendDisagreement] = []
    anomaly_differs: List[BackendDisagreement] = []
    n = 0
    for p in want:
        ra = by_point[a_name].get(p)
        rb = by_point[b_name].get(p)
        if ra is None or rb is None:
            continue
        n += 1
        d = BackendDisagreement(
            point=p,
            fastest={a_name: ra.cls.fastest, b_name: rb.cls.fastest},
            is_anomaly={a_name: ra.cls.is_anomaly,
                        b_name: rb.cls.is_anomaly},
            time_score={a_name: ra.cls.time_score,
                        b_name: rb.cls.time_score},
        )
        if not (set(ra.cls.fastest) & set(rb.cls.fastest)):
            fastest_differs.append(d)
        if ra.cls.is_anomaly != rb.cls.is_anomaly:
            anomaly_differs.append(d)
    return BackendComparison(
        spec_name=spec.name,
        backends=(a_name, b_name),
        n_points=n,
        fastest_differs=fastest_differs,
        anomaly_differs=anomaly_differs,
        results=dict(sweeps),
    )


# ------------------------------------------------------------- clustering ---


def cluster_sweep(
    records: Iterable[Instance],
    grid: GridSpec,
) -> List[Region]:
    """Cluster a swept grid's anomalies into contiguous regions.

    Records off the grid (e.g. random-search points sharing the atlas) are
    ignored — adjacency is only defined on the grid's axes.
    """
    axes_sets = [set(ax) for ax in grid.axes]
    scores: Dict[Tuple[int, ...], Tuple[float, float]] = {}
    for r in records:
        if not r.cls.is_anomaly:
            continue
        if all(v in s for v, s in zip(r.point, axes_sets)):
            scores[r.point] = (r.cls.time_score, r.cls.flop_score)
    return cluster_regions(scores, grid.axes)


def cluster_predictions(
    predicted: Mapping[Tuple[int, ...], Classification],
    grid: GridSpec,
) -> List[Region]:
    """Cluster predicted (model-only) classifications over a grid."""
    scores = {p: (c.time_score, c.flop_score)
              for p, c in predicted.items() if c.is_anomaly}
    return cluster_regions(scores, grid.axes)


# -------------------------------------------------------------------- CLI ---


def _note(msg: str, quiet: bool) -> None:
    if not quiet:
        print(msg, file=sys.stderr)
        sys.stderr.flush()


def _registry_epilog() -> str:
    """All three registries, generated at parser-build time so the help
    text can never omit a registered entry (the hard-coded prose it
    replaced hid ``roofline``/``rankk`` and every user registration)."""
    from .cli_help import (analysis_rules_epilog, backends_epilog,
                           discriminants_epilog)

    lines = ["registered expression families (repro.core.expressions):"]
    for cli_name in registered_names():
        s = REGISTRY[cli_name]
        lines.append(f"  {cli_name:<7} {s.name:<6} ndims={s.ndims}  "
                     f"{s.description}")
    return "\n".join(lines) + "\n\n" + discriminants_epilog() \
        + "\n\n" + backends_epilog() + "\n\n" + analysis_rules_epilog()


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.sweep",
        description="Sharded anomaly sweep over a problem-size grid; "
                    "results persist in the resumable anomaly atlas.",
        epilog=_registry_epilog(),
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--expr", choices=registered_names(), default="aatb",
                    help="expression family to sweep (see the registry "
                         "listing below)")
    ap.add_argument("--list-exprs", action="store_true",
                    help="print the registered expression families (one "
                         "CLI name per line) and exit")
    ap.add_argument("--grid", default="small",
                    help=f"named grid {sorted(SWEEP_GRIDS)} (per-family "
                         "axis overrides apply) or comma-separated axis "
                         "values, e.g. 64,128,256")
    ap.add_argument("--mode",
                    choices=("measure", "predict", "evaluate", "adaptive"),
                    default="measure",
                    help="measure: time every algorithm per instance; "
                         "predict: classify from batched per-kernel "
                         "benchmarks (additive model, feeds the "
                         "calibration cache); evaluate: replay the "
                         "persisted atlas and score discriminants "
                         "(top-1 accuracy, time regret, anomaly "
                         "recall/precision) without re-measuring; "
                         "adaptive: coarse seed + boundary-refinement "
                         "rounds under --budget (resumable; shardable "
                         "across hosts with --shard)")
    ap.add_argument("--budget", type=int, default=None,
                    help="adaptive mode: total trajectory budget in grid "
                         "points (seed + refinement, global across "
                         "--shard hosts); resumed runs honor what "
                         "remains of it")
    ap.add_argument("--rounds", type=int, default=None,
                    help="adaptive mode: max refinement rounds (default: "
                         "until the budget runs out or a round finds no "
                         "new frontier)")
    ap.add_argument("--seed-stride", type=int, default=4,
                    help="adaptive mode: seed lattice stride in grid "
                         "indices (endpoints always included); regions "
                         "narrower than this can be missed")
    ap.add_argument("--shard", default=None, metavar="K/N",
                    help="adaptive mode: run host K of an N-way fan-out "
                         "— measures every N-th refinement candidate "
                         "into its own atlas-…-shardK.jsonl, reading "
                         "sibling shards back each round; merge with "
                         "tools/atlas_merge.py (exit 3 = waiting on "
                         "siblings, rerun after they advance)")
    ap.add_argument("--discriminants", default=None, metavar="A,B,C",
                    help="comma-separated repro.core.discriminants "
                         "registry keys to score in --mode evaluate "
                         "(default: every registered discriminant)")
    ap.add_argument("--backend", choices=registered_backends(),
                    default="blas",
                    help="execution backend (repro.core.backends registry); "
                         "each backend gets its own fingerprint-keyed atlas")
    ap.add_argument("--compare-backends", default=None, metavar="A,B",
                    help="sweep the grid on two backends and report "
                         "instances where the fastest algorithm differs "
                         "by backend (overrides --backend)")
    ap.add_argument("--shards", type=int, default=1,
                    help="worker shards: process-sharded backends "
                         "(blas/numpy) fan out over a process pool; "
                         "device-sharded backends (jax/pallas) use this "
                         "many JAX devices (0 = all devices)")
    ap.add_argument("--threshold", type=float, default=0.10)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--no-flush", action="store_true",
                    help="skip the per-rep cache flush (faster, noisier; "
                         "smoke/CI use)")
    ap.add_argument("--no-tuning", action="store_true",
                    help="pallas: ignore any cached TuningTable (sets "
                         "REPRO_NO_TUNING) — sweep the hard-coded 128 "
                         "tiles, e.g. to diff the tuned vs default "
                         "anomaly map")
    ap.add_argument("--no-fusion", action="store_true",
                    help="pallas: disable fused adjacent-step dispatch "
                         "(sets REPRO_NO_FUSION) — every step launches "
                         "its own kernel")
    ap.add_argument("--no-fastpath", action="store_true",
                    help="disable the measurement fast path (operand "
                         "arena, executable memo, pipelined preparation; "
                         "sets REPRO_NO_FASTPATH) — timing semantics are "
                         "identical either way, this is the paranoid "
                         "bisect switch")
    ap.add_argument("--seed", type=int, default=None,
                    help="operand-synthesis seed: every leaf buffer "
                         "becomes a pure function of (seed, base, shape), "
                         "so reruns and shards draw identical operands")
    ap.add_argument("--limit", type=int, default=None,
                    help="measure at most N new instances this run "
                         "(budgeted partial sweep; resume later)")
    ap.add_argument("--atlas-dir", type=Path, default=None,
                    help="atlas directory (default: $REPRO_ATLAS_DIR or "
                         "the shared cache under ~/.cache/repro/atlas)")
    ap.add_argument("--fresh", action="store_true",
                    help="delete any existing atlas file first")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.list_exprs:
        for cli_name in registered_names():
            print(cli_name)
        return 0

    # Process-wide on purpose: the sweep fans out through jitted closures
    # and (for process-sharded backends) worker processes that inherit the
    # environment — a constructor flag could not reach either.
    if args.no_tuning:
        os.environ["REPRO_NO_TUNING"] = "1"
    if args.no_fusion:
        os.environ["REPRO_NO_FUSION"] = "1"
    if args.no_fastpath:
        os.environ[FASTPATH_ENV] = "1"

    spec = get_spec(args.expr)
    if args.grid in SWEEP_GRIDS or args.grid in spec.grids:
        grid = spec.grid(args.grid)
    else:
        try:
            values = [int(v) for v in args.grid.split(",") if v.strip()]
        except ValueError:
            ap.error(f"--grid must name one of {sorted(SWEEP_GRIDS)} or "
                     f"be comma-separated ints; got {args.grid!r}")
        grid = GridSpec.uniform(values, spec.ndims)
    points = grid.points()

    if args.discriminants and args.mode != "evaluate":
        # Scoring is a replay-only concern; silently accepting the flag
        # on a measured sweep would imply the sweep was somehow filtered.
        ap.error("--discriminants only applies to --mode evaluate")

    if args.mode == "adaptive":
        if args.budget is None:
            ap.error("--mode adaptive requires --budget (the point of "
                     "the mode is a bounded measurement budget)")
        if args.limit is not None:
            ap.error("--limit is the dense-sweep budget knob; adaptive "
                     "mode budgets via --budget")
        if args.compare_backends:
            ap.error("--compare-backends diffs dense atlases; run "
                     "adaptive sweeps per backend and merge/compare "
                     "their atlases instead")
    else:
        for flag, val in (("--budget", args.budget),
                          ("--rounds", args.rounds),
                          ("--shard", args.shard)):
            if val is not None:
                ap.error(f"{flag} only applies to --mode adaptive")

    if args.compare_backends:
        if args.mode != "measure":
            # Comparison diffs *measured* atlases; silently degrading an
            # explicit --mode predict into two full measured sweeps could
            # cost hours of unrequested wall time on a dense grid.
            ap.error("--compare-backends runs measured sweeps; it cannot "
                     "be combined with --mode predict")
        return _main_compare(args, spec, grid, points)

    name = args.backend

    if args.mode == "evaluate":
        return _main_evaluate(args, spec, grid, points)

    if args.mode == "adaptive":
        return _main_adaptive(args, spec, grid, name)

    atlas = _open_backend_atlas(spec, name, args)

    _note(f"sweep {spec.name} grid={grid.name} "
          f"({grid.n_points} instances over {spec.ndims} dims), "
          f"backend={name} shards={args.shards}", args.quiet)
    _note(f"atlas: {atlas.path} ({len(atlas)} instances already recorded)",
          args.quiet)

    if args.mode == "predict":
        return _main_predict(args, spec, grid, points, atlas,
                             backend_default_dtype(name), atlas.fingerprint)

    res = _backend_sweep(spec, points, name, args, atlas)

    print(f"sweep {spec.name}/{grid.name} [{name}]: points={res.n_points} "
          f"measured={res.n_measured} skipped={res.n_skipped} "
          f"anomalies={len(res.anomalies)} "
          f"({res.anomaly_rate:.1%}) in {res.wall_s:.1f}s "
          f"[{res.instances_per_s:.1f} inst/s]")
    if res.fastpath is not None and res.n_measured:
        print(f"fastpath: {res.fastpath.summary()}")
    regions = cluster_sweep(res.records, grid)
    print(region_summary(regions, res.n_points))
    print(f"atlas written to {res.atlas_path}")
    return 0


def _open_backend_atlas(spec, name, args,
                        shard: Optional[Tuple[int, int]] = None
                        ) -> AnomalyAtlas:
    """The per-backend atlas: fingerprinted by the registry key + dtype.

    ``shard=(k, n)`` opens host k's shard file of an n-way adaptive
    fan-out instead of the canonical atlas.
    """
    fp = current_fingerprint(backend=name,
                             dtype=backend_default_dtype(name))
    if shard is not None:
        path = atlas_shard_path(spec.name, fp, args.threshold, shard[0],
                                args.atlas_dir)
    else:
        path = atlas_path(spec.name, fp, args.threshold, args.atlas_dir)
    if args.fresh and path.is_file():
        path.unlink()
    return AnomalyAtlas(path, fp, spec.name, args.threshold, shard=shard)


def _engine_config(name, args) -> dict:
    """Fan-out + runner kwargs for one registry backend, CLI-configured.

    Follows the backend's declared ``shard_mode``: device-sharded
    backends (jax/pallas) spread over JAX devices, process-sharded ones
    (blas/numpy — GIL- and cache-bound) over a worker pool when
    ``--shards`` asks for it. Shared verbatim by the dense sweep and the
    adaptive engine so both modes measure identically.
    """
    seed = getattr(args, "seed", None)
    if backend_shard_mode(name) == "device":
        return dict(backend="jax", exec_backend=name, reps=args.reps,
                    shards=args.shards or None,  # 0 = every device
                    seed=seed)
    if args.shards > 1:
        factory = functools.partial(make_backend, name, reps=args.reps,
                                    flush_cache=not args.no_flush,
                                    seed=seed)
        return dict(backend="process", shards=args.shards,
                    runner_factory=factory, reps=args.reps)
    return dict(runner=make_backend(name, reps=args.reps,
                                    flush_cache=not args.no_flush,
                                    seed=seed),
                reps=args.reps)


def _backend_sweep(spec, points, name, args, atlas) -> SweepResult:
    """One measured dense sweep on one registry backend, CLI-configured."""
    def progress(i, n, inst):
        if not args.quiet and (i % 25 == 0 or i == n):
            _note(f"  [{name} {i}/{n}] {inst.point} "
                  f"{'ANOMALY' if inst.cls.is_anomaly else 'ok'} "
                  f"ts={inst.cls.time_score:.1%}", args.quiet)

    return sweep(spec, points, threshold=args.threshold, atlas=atlas,
                 max_instances=args.limit, progress=progress,
                 **_engine_config(name, args))


def _parse_shard(text: str) -> Tuple[int, int]:
    try:
        k, n = (int(x) for x in text.split("/", 1))
    except ValueError:
        raise ValueError(f"--shard takes K/N (e.g. 0/4), got {text!r}")
    if not 0 <= k < n:
        raise ValueError(f"--shard needs 0 <= K < N, got {text!r}")
    return k, n


def _main_adaptive(args, spec, grid, name) -> int:
    """--mode adaptive: budgeted boundary refinement, optionally sharded.

    Exit 3 means a sharded host is waiting on sibling shard files —
    re-invoke once the other hosts advance; the trajectory replays from
    the shard atlas, so the retry costs no re-measurement.
    """
    from .adaptive import adaptive_sweep, boundary_cells

    try:
        shard = _parse_shard(args.shard) if args.shard else None
    except ValueError as e:
        print(str(e), file=sys.stderr)
        return 2
    atlas = _open_backend_atlas(spec, name, args, shard=shard)
    _note(f"adaptive sweep {spec.name} grid={grid.name} "
          f"({grid.n_points} grid points, budget={args.budget}, "
          f"seed stride={args.seed_stride}), backend={name}"
          + (f", shard {shard[0]}/{shard[1]}" if shard else ""),
          args.quiet)
    _note(f"atlas: {atlas.path} ({len(atlas)} instances already recorded)",
          args.quiet)
    res = adaptive_sweep(
        spec, grid, args.budget, args.rounds, threshold=args.threshold,
        atlas=atlas, shard=shard, seed_stride=args.seed_stride,
        **_engine_config(name, args))
    frontier = boundary_cells(res.verdicts(), grid)
    print(f"adaptive {spec.name}/{grid.name} [{name}]: "
          f"budget={res.budget} spent={res.spent} "
          f"measured={res.n_measured} rounds={res.n_refine_rounds} "
          f"stopped={res.stopped} "
          f"({res.spent / grid.n_points:.1%} of dense, "
          f"{len(frontier)} frontier cells) in {res.wall_s:.1f}s")
    print(region_summary(res.regions(), len(res.known)))
    print(f"atlas written to {res.atlas_path}")
    if res.stopped == "awaiting-siblings":
        _note("waiting on sibling shards — rerun this command after the "
              "other hosts advance, then merge with tools/atlas_merge.py",
              args.quiet)
        return 3
    return 0


def _main_compare(args, spec, grid, points) -> int:
    """--compare-backends A,B: sweep both, diff fastest sets + verdicts."""
    names = [n.strip() for n in args.compare_backends.split(",") if
             n.strip()]
    if len(names) != 2 or names[0] == names[1]:
        print(f"--compare-backends takes two distinct backend names, got "
              f"{args.compare_backends!r}", file=sys.stderr)
        return 2
    for n in names:
        if n not in registered_backends():
            print(f"unknown backend {n!r}; registered: "
                  f"{registered_backends()}", file=sys.stderr)
            return 2
    sweeps: Dict[str, SweepResult] = {}
    for n in names:
        atlas = _open_backend_atlas(spec, n, args)
        _note(f"sweep {spec.name} grid={grid.name} backend={n} "
              f"(atlas: {atlas.path}, {len(atlas)} recorded)", args.quiet)
        sweeps[n] = _backend_sweep(spec, points, n, args, atlas)
    cmp = compare_backends(spec, points, sweeps)
    a, b = cmp.backends
    print(f"compare {spec.name}/{grid.name} [{a} vs {b}]: "
          f"points={cmp.n_points} "
          f"fastest-differs={len(cmp.fastest_differs)} "
          f"({cmp.fastest_differs_rate:.1%}) "
          f"anomaly-verdict-differs={len(cmp.anomaly_differs)}")
    for d in cmp.fastest_differs:
        print(f"  {d.point}: {a} fastest={'/'.join(d.fastest[a])} "
              f"(ts={d.time_score[a]:.1%}) | "
              f"{b} fastest={'/'.join(d.fastest[b])} "
              f"(ts={d.time_score[b]:.1%})")
    for n in names:
        print(f"atlas[{n}] written to {sweeps[n].atlas_path}")
    return 0


def _main_predict(args, spec, grid, points, atlas, dtype, fp) -> int:
    """--mode predict: batched kernel benchmarks → model-only sweep."""
    runner = make_backend(args.backend, reps=args.reps, dtype=dtype,
                          flush_cache=not args.no_flush,
                          seed=getattr(args, "seed", None))
    cached = load_default_profile(backend=args.backend, dtype=dtype)
    calls = collect_unique_calls(spec, points)
    fp_on = fastpath_enabled()
    arena = arena_for(runner) if fp_on else None
    stats = FastPathStats() if fp_on else None
    t0 = _time.perf_counter()
    profile, n_meas, n_reused = benchmark_unique_calls(
        runner, calls, profile=cached, reps=args.reps, arena=arena,
        stats=stats)
    bench_s = _time.perf_counter() - t0
    save_profile(profile, fp, meta={"source": f"sweep:{spec.name}"})
    if stats is not None and n_meas:
        _note(f"fastpath: {stats.summary()}", args.quiet)
    predicted = predict_classifications(
        spec, points, profile, threshold=args.threshold,
        dtype_bytes=8 if dtype == "float64" else 4)
    n_anom = sum(1 for c in predicted.values() if c.is_anomaly)
    print(f"predict {spec.name}/{grid.name}: points={len(points)} "
          f"unique_kernels={len(calls)} measured={n_meas} "
          f"reused={n_reused} in {bench_s:.1f}s; "
          f"predicted anomalies={n_anom} ({n_anom / len(points):.1%})")
    regions = cluster_predictions(predicted, grid)
    print(region_summary(regions, len(points)))
    if len(atlas):
        # Confusion vs whatever ground truth the atlas already holds.
        from .anomaly import ConfusionMatrix
        cm = ConfusionMatrix()
        for p, c in predicted.items():
            actual = atlas.get(p)
            if actual is not None:
                cm.add(actual.cls.is_anomaly, c.is_anomaly)
        if cm.total:
            print(f"vs atlas ground truth ({cm.total} instances): "
                  f"recall={cm.recall:.1%} precision={cm.precision:.1%}")
    return 0


def _main_evaluate(args, spec, grid, points) -> int:
    """--mode evaluate: replay the atlas, score discriminants, no timing.

    The atlas is loaded through the *lenient* replay loader
    (:func:`repro.core.evaluate.load_atlas_records`): evaluation never
    appends, so fingerprints are not matched against this process and
    legacy pre-backend-registry headers are normalized instead of
    rejected. If the fingerprint-exact file is absent but exactly one
    atlas for this (spec, threshold) exists — e.g. ground truth swept on
    another machine, or under a legacy fingerprint — that one is used,
    with a note.
    """
    from .discriminants import registered_discriminants
    from .evaluate import evaluate_discriminants, load_atlas_records

    if args.discriminants:
        names = [n.strip() for n in args.discriminants.split(",")
                 if n.strip()]
        unknown = [n for n in names if n.lower()
                   not in registered_discriminants()]
        if unknown:
            print(f"unknown discriminant(s) {unknown}; registered: "
                  f"{registered_discriminants()}", file=sys.stderr)
            return 2
    else:
        names = registered_discriminants()

    fp = current_fingerprint(backend=args.backend,
                             dtype=backend_default_dtype(args.backend))
    path = atlas_path(spec.name, fp, args.threshold, args.atlas_dir)
    if not path.is_file():
        t = f"{args.threshold:g}".replace(".", "p")
        candidates = [
            c for c in sorted(path.parent.glob(
                f"atlas-{_slug(spec.name)}-t{t}-*.jsonl"))
            # Un-merged shard files are partial by construction; replay
            # the canonical atlas (tools/atlas_merge.py) instead.
            if not re.search(r"-shard\d+$", c.stem)
        ]
        if len(candidates) == 1:
            _note(f"no atlas for this fingerprint; evaluating the only "
                  f"match {candidates[0].name}", args.quiet)
            path = candidates[0]
        else:
            hint = (f"{len(candidates)} atlases match this spec/threshold"
                    if candidates else "none exist")
            print(f"no atlas at {path} ({hint}); sweep ground truth first: "
                  f"python -m repro.core.sweep --expr {args.expr} --grid "
                  f"{args.grid} --backend {args.backend}", file=sys.stderr)
            return 2

    replay = load_atlas_records(path)
    want = {tuple(int(x) for x in p) for p in points}
    records = [r for r in replay.records if r.point in want]
    if not records:
        # Grid mismatch (or a random-search atlas): score what exists
        # rather than erroring — the atlas is the ground truth we have.
        _note(f"no atlas records on grid {grid.name}; evaluating all "
              f"{len(replay.records)} recorded instances", args.quiet)
        records = replay.records
    if not records:
        print(f"atlas {path} holds no instances", file=sys.stderr)
        return 2

    dtype = backend_default_dtype(args.backend)
    profile = load_default_profile(backend=args.backend, dtype=dtype)
    try:
        res = evaluate_discriminants(
            spec, records, [n.lower() for n in names], profile=profile,
            threshold=args.threshold,
            dtype_bytes=8 if dtype == "float64" else 4)
    except ValueError as e:
        # Record-level defect (atlas swept under a different enumeration):
        # every row would be wrong, so the evaluation itself fails.
        print(f"evaluation failed: {e}", file=sys.stderr)
        return 1
    rows = []
    for score in res.scores.values():
        row = score.row()
        if score.error is not None and score.error.startswith("KeyError"):
            # The documented partial-calibration failure mode; other
            # errors get no hint — switching discriminants won't fix them.
            row += " (hint: `hybrid` tolerates partial calibrations)"
        rows.append(row)
    if all(s.error is not None for s in res.scores.values()):
        print("every requested discriminant failed to evaluate:",
              file=sys.stderr)
        for row in rows:
            print("  " + row, file=sys.stderr)
        return 1
    legacy = " legacy-fingerprint" if replay.legacy else ""
    print(f"evaluate {spec.name}/{grid.name} [{args.backend}]: "
          f"instances={res.n_instances} anomalies={res.n_anomalies} "
          f"profile={'cached' if profile is not None else 'analytical'}"
          f"{legacy}")
    for row in rows:
        print("  " + row)
    print(f"atlas read from {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
