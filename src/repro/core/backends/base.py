"""The execution-backend protocol, the generic step walker, and the registry.

The paper's anomalies are a property of the *kernel implementation*, not
the math: the same expression has different anomaly regions on MKL than on
XLA or a Pallas TPU kernel (Sankaran & Bientinesi 2022 argue discriminant
quality must be re-validated per backend). Asking "where do the backends
disagree?" therefore needs every executor to speak one interface. This
module defines it:

* :class:`KernelOps` — a backend's kernel vocabulary: one callable per
  :data:`~repro.core.flops.KERNEL_KINDS` entry (plus ``transpose``).
  Implementing these ~6 methods is the whole cost of a new backend.
* :func:`walk_steps` — the **one** DAG walker. Every executor in the
  repo used to reimplement the step loop (BLAS, numpy reference, jnp,
  Pallas); they now all walk here, parameterized by their
  :class:`KernelOps`.
* :class:`ExecutionBackend` — the protocol every backend satisfies:
  ``make_operands`` / ``execute`` / ``build`` / ``time_algorithm`` /
  ``benchmark_call`` / ``fingerprint_tags``. The base class implements
  all of them generically on top of :func:`walk_steps`; backends
  override only operand placement (``_asarray``), timing hooks
  (``_pre_rep`` for cache flushes, ``_sync`` for async dispatch) and, if
  they compile, ``_timed_callable``.
* :func:`register_backend` / :func:`get_backend` /
  :func:`registered_backends` — the registry ``calibrate``, ``sweep``,
  ``selector`` and ``planner`` resolve backends through. The registry
  key doubles as the profile/atlas fingerprint ``backend`` string, so a
  backend's measurements are never mixed with another's.

``benchmark_call`` is derived, not duplicated: a
:class:`~repro.core.flops.KernelCall` is wrapped into a one-step
:func:`synthetic_algorithm` and timed through the exact same path as
whole algorithms — the two parallel benchmark implementations the
pre-registry runners carried are gone.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..algorithms import Algorithm, Leaf, Step
from ..flops import KernelCall


class KernelOps:
    """Per-backend kernel vocabulary the generic walker dispatches to.

    ``symm``/``symm_r`` receive the symmetric operand as ``s`` (stored as
    its lower triangle — implementations must not read above the
    diagonal) and the dense operand as ``b``; ``syrk`` returns the lower
    triangle of ``a·aᵀ`` (``tri`` storage); ``tri2full`` mirrors a lower
    triangle into a full matrix.
    """

    def transpose(self, a):
        raise NotImplementedError

    def gemm(self, a, b):
        raise NotImplementedError

    def syrk(self, a):
        raise NotImplementedError

    def symm(self, s, b):
        """S·B with S symmetric (side L)."""
        raise NotImplementedError

    def symm_r(self, b, s):
        """B·S with S symmetric (side R)."""
        raise NotImplementedError

    def tri2full(self, t):
        raise NotImplementedError

    # -- fused adjacent-step dispatch (optional) ---------------------------
    def fused_kinds(self) -> frozenset:
        """Fused step patterns this vocabulary implements.

        The walker consults this before dispatching: when two adjacent
        steps match an advertised pattern (see :func:`fusable_pattern`),
        it calls the fused method instead of the two single-kind ones.
        Default: no fusion — CPU backends and plain jnp (where XLA does
        its own fusion) keep the one-step-one-kernel mapping.
        """
        return frozenset()

    def chain_gemm(self, a, b, c):
        """Fused ``(a·b)·c`` (pattern ``"gemm+gemm"``)."""
        raise NotImplementedError

    def gemm_syrk(self, a, b):
        """Fused lower triangle of ``(a·b)(a·b)ᵀ`` (``"gemm+syrk"``)."""
        raise NotImplementedError


def _fetched_refs(step: Step) -> tuple:
    """The operand refs ``walk_steps`` actually fetches for ``step``.

    syrk/tri2full fetch only ``lhs``; a syrk step's ``rhs`` may carry a
    provenance twin (the transposed factor the builder pruned) that is
    never materialized — counting it as a use would veto valid fusions.
    """
    if step.call.kind in ("gemm", "symm"):
        return (step.lhs, step.rhs)
    return (step.lhs,)


def fusable_pattern(first: Step, second: Step,
                    rest: Sequence[Step]) -> Optional[str]:
    """Which advertised fused pattern ``(first, second)`` matches, if any.

    ``first`` must be a gemm whose output ``X`` is consumed *only* as
    ``second``'s left operand and never fetched by any later step (its
    HBM materialization is what the fusion deletes):

    * ``"gemm+gemm"`` — ``second`` is a gemm with ``lhs == X``
      (``(A·B)·C``, the :mod:`repro.kernels.chain_gemm` shape);
    * ``"gemm+syrk"`` — ``second`` is a syrk on ``X``
      (``tril((A·B)(A·B)ᵀ)``, the epilogue fusion).
    """
    if first.call.kind != "gemm":
        return None
    x = first.out
    for later in rest:
        for ref in _fetched_refs(later):
            if not isinstance(ref, Leaf) and ref == x:
                return None
    second_lhs_is_x = not isinstance(second.lhs, Leaf) and second.lhs == x
    if second.call.kind == "gemm" and second_lhs_is_x and (
            isinstance(second.rhs, Leaf) or second.rhs != x):
        return "gemm+gemm"
    if second.call.kind == "syrk" and second_lhs_is_x:
        return "gemm+syrk"
    return None


def walk_steps(steps: Sequence[Step], leaf_fetch: Callable[[int], object],
               ops: KernelOps):
    """Execute/trace an algorithm's step DAG with one backend's kernels.

    ``leaf_fetch(base)`` returns the *untransposed* operand for a leaf
    base index; transposition is applied here via ``ops.transpose`` so
    callers hand over plain per-base arrays. Works eagerly (numpy, BLAS)
    and under tracing (jit/vmap of jnp/Pallas ops) alike — this is the
    single step walker the ISSUE-4 refactor collapsed the four previous
    executors into.

    When ``ops.fused_kinds()`` advertises fused patterns, adjacent steps
    matching :func:`fusable_pattern` dispatch to the fused method
    (``ops.chain_gemm`` / ``ops.gemm_syrk``) as one launch; the fused
    intermediate is provably dead (the pattern check rejects any later
    use), so only the second step's output id is bound.
    """
    inter: Dict[int, object] = {}

    def fetch(ref):
        if isinstance(ref, Leaf):
            a = leaf_fetch(ref.base)
            return ops.transpose(a) if ref.transposed else a
        return inter[ref]

    fused = ops.fused_kinds()
    out = None
    i = 0
    n = len(steps)
    while i < n:
        step = steps[i]
        if fused and i + 1 < n:
            pattern = fusable_pattern(step, steps[i + 1], steps[i + 2:])
            if pattern is not None and pattern in fused:
                nxt = steps[i + 1]
                if pattern == "gemm+gemm":
                    out = ops.chain_gemm(fetch(step.lhs), fetch(step.rhs),
                                         fetch(nxt.rhs))
                else:
                    out = ops.gemm_syrk(fetch(step.lhs), fetch(step.rhs))
                inter[nxt.out] = out
                i += 2
                continue
        kind = step.call.kind
        if kind == "gemm":
            out = ops.gemm(fetch(step.lhs), fetch(step.rhs))
        elif kind == "syrk":
            out = ops.syrk(fetch(step.lhs))
        elif kind == "symm":
            if step.symm_side == "R":
                out = ops.symm_r(fetch(step.lhs), fetch(step.rhs))
            else:
                out = ops.symm(fetch(step.lhs), fetch(step.rhs))
        elif kind == "tri2full":
            out = ops.tri2full(fetch(step.lhs))
        else:
            raise ValueError(kind)
        inter[step.out] = out
        i += 1
    return out


def num_inputs(alg: Algorithm) -> int:
    """Positional arity of a built callable: max leaf *index* + 1.

    The callable's signature follows chain positions; only *base*
    positions are ever read (a Gram pair's ``A`` and ``Aᵀ`` share one
    array), so callers may pass any placeholder at non-base slots.
    """
    mx = -1
    for step in alg.steps:
        for ref in (step.lhs, step.rhs):
            if isinstance(ref, Leaf):
                mx = max(mx, ref.index)
    return mx + 1


def synthetic_algorithm(call: KernelCall) -> Algorithm:
    """A one-step algorithm exercising exactly one kernel call.

    This is what makes ``benchmark_call`` generic: isolated kernel
    benchmarks run through the same ``make_operands`` →
    ``time_algorithm`` path as whole algorithms, so no backend carries a
    second, parallel per-kind benchmarking switch.
    """
    if call.kind == "gemm":
        m, n, k = call.dims
        a = Leaf(index=0, base=0, transposed=False, rows=m, cols=k)
        b = Leaf(index=1, base=1, transposed=False, rows=k, cols=n)
        step = Step(call=call, lhs=a, rhs=b, out=0, out_rows=m, out_cols=n,
                    out_storage="full", out_symmetric=False)
    elif call.kind == "syrk":
        m, k = call.dims
        a = Leaf(index=0, base=0, transposed=False, rows=m, cols=k)
        step = Step(call=call, lhs=a, rhs=None, out=0, out_rows=m,
                    out_cols=m, out_storage="tri", out_symmetric=True)
    elif call.kind == "symm":
        m, n = call.dims
        s = Leaf(index=0, base=0, transposed=False, rows=m, cols=m,
                 symmetric=True)
        b = Leaf(index=1, base=1, transposed=False, rows=m, cols=n)
        step = Step(call=call, lhs=s, rhs=b, out=0, out_rows=m, out_cols=n,
                    out_storage="full", out_symmetric=False)
    elif call.kind == "tri2full":
        (m,) = call.dims
        t = Leaf(index=0, base=0, transposed=False, rows=m, cols=m,
                 storage="tri")
        step = Step(call=call, lhs=t, rhs=None, out=0, out_rows=m,
                    out_cols=m, out_storage="full", out_symmetric=True)
    else:
        raise ValueError(call.kind)
    return Algorithm(name=f"bench_{call.kind}", steps=(step,))


def synthetic_fused_algorithm(kind: str, dims: Sequence[int]) -> Algorithm:
    """A two-step algorithm exercising exactly one fused pattern.

    The fused analogue of :func:`synthetic_algorithm`: the step pair is
    built so :func:`fusable_pattern` matches, and a fusion-advertising
    backend times the fused launch while any other backend times the
    two-kernel form — the same Algorithm measures both sides of the
    fusion trade.

    * ``"chain_gemm"``, dims ``(m, k, l, n)`` — ``(A·B)·C`` with
      A ``(m,k)``, B ``(k,l)``, C ``(l,n)``;
    * ``"gemm_syrk"``, dims ``(m, k, l)`` — ``tril((A·B)(A·B)ᵀ)`` with
      A ``(m,k)``, B ``(k,l)``.
    """
    if kind == "chain_gemm":
        m, k, l, n = dims
        a = Leaf(index=0, base=0, transposed=False, rows=m, cols=k)
        b = Leaf(index=1, base=1, transposed=False, rows=k, cols=l)
        c = Leaf(index=2, base=2, transposed=False, rows=l, cols=n)
        s1 = Step(call=KernelCall("gemm", (m, l, k)), lhs=a, rhs=b, out=0,
                  out_rows=m, out_cols=l, out_storage="full",
                  out_symmetric=False)
        s2 = Step(call=KernelCall("gemm", (m, n, l)), lhs=0, rhs=c, out=1,
                  out_rows=m, out_cols=n, out_storage="full",
                  out_symmetric=False)
    elif kind == "gemm_syrk":
        m, k, l = dims
        a = Leaf(index=0, base=0, transposed=False, rows=m, cols=k)
        b = Leaf(index=1, base=1, transposed=False, rows=k, cols=l)
        s1 = Step(call=KernelCall("gemm", (m, l, k)), lhs=a, rhs=b, out=0,
                  out_rows=m, out_cols=l, out_storage="full",
                  out_symmetric=False)
        s2 = Step(call=KernelCall("syrk", (m, l)), lhs=0, rhs=None, out=1,
                  out_rows=m, out_cols=m, out_storage="tri",
                  out_symmetric=True)
    else:
        raise ValueError(
            f"unknown fused pattern {kind!r}; expected 'chain_gemm' or "
            f"'gemm_syrk'")
    return Algorithm(name=f"bench_{kind}", steps=(s1, s2))


class ExecutionBackend:
    """Base class + protocol for one way of executing algorithms.

    Subclasses set ``name`` (the registry key — also the fingerprint
    ``backend`` string for profiles and atlases), ``default_dtype``,
    ``dtypes`` (``None`` = any) and ``shard_mode`` (``"process"`` for
    GIL/cache-bound CPU backends the sweep engine isolates in worker
    processes, ``"device"`` for backends the engine shards across JAX
    devices), then override the hooks they need:

    * ``ops()``            — the :class:`KernelOps` (required);
    * ``_asarray(a)``      — dtype/layout/device placement of operands;
    * ``_pre_rep()``       — per-repetition setup (BLAS cache flush);
    * ``_sync(out)``       — block on async dispatch (JAX);
    * ``_timed_callable()``— what ``time_algorithm`` times (JAX jits).
    """

    name: str = "abstract"
    default_dtype: str = "float64"
    #: Allowed dtype labels; ``None`` means any.
    dtypes: Optional[Tuple[str, ...]] = None
    shard_mode: str = "process"

    def __init__(self, reps: int = 3, dtype: Optional[str] = None,
                 rng: Optional[np.random.Generator] = None,
                 seed: Optional[int] = None):
        dtype = dtype or self.default_dtype
        if self.dtypes is not None and dtype not in self.dtypes:
            raise ValueError(
                f"backend {self.name!r} measures {'/'.join(self.dtypes)}; "
                f"got dtype={dtype!r} — a different label would stamp a "
                f"fingerprint the measurements don't match")
        self.reps = reps
        self.dtype = dtype
        #: With ``seed`` set, each leaf operand's content is a pure
        #: function of ``(seed, base, shape)`` — identical across reruns,
        #: shards, pool workers, and the arena fast path. With it unset
        #: (legacy default), draws come from ``rng`` in call order.
        self.seed = seed
        self.rng = rng or np.random.default_rng(0)

    # -- subclass hooks ---------------------------------------------------
    def ops(self) -> KernelOps:
        raise NotImplementedError

    def _asarray(self, a: np.ndarray):
        """Place one freshly synthesized operand (dtype/layout/device)."""
        return a

    def _pre_rep(self) -> None:
        """Per-repetition setup before the timer starts (cache flush)."""

    def _sync(self, out):
        """Block until ``out`` is materialized (async-dispatch backends)."""
        return out

    def _timed_callable(self, alg: Algorithm, operands: Dict[int, object]
                        ) -> Callable[[], object]:
        """The zero-arg callable ``time_algorithm`` times per repetition."""
        return lambda: self.execute(alg, operands)

    # -- the protocol ------------------------------------------------------
    def fingerprint_tags(self) -> Tuple[str, str]:
        """(backend, dtype) labels profiles/atlases are keyed by."""
        return (self.name, self.dtype)

    def make_operands(self, alg: Algorithm,
                      leading: Tuple[int, ...] = ()) -> Dict[int, object]:
        """Fresh random inputs for every distinct leaf *base* of ``alg``.

        Leaves are stored untransposed (transposition is applied at fetch
        by the walker); symmetric leaves are symmetrized, since SYMM-based
        algorithms read only a triangle and would otherwise disagree with
        their GEMM-based siblings. ``leading`` prefixes every operand's
        shape (the vmap-batched path passes ``(batch,)``), so batched and
        per-instance synthesis can never diverge.
        """
        out: Dict[int, object] = {}
        for step in alg.steps:
            for ref in (step.lhs, step.rhs):
                if isinstance(ref, Leaf) and ref.base not in out:
                    out[ref.base] = self.make_leaf_operand(ref, leading)
        return out

    def make_leaf_operand(self, ref: Leaf,
                          leading: Tuple[int, ...] = ()) -> object:
        """One leaf's operand buffer (untransposed, symmetrized, placed).

        This is the unit the operand arena pools: with ``seed`` set the
        buffer depends only on ``(seed, base, shape)``, so arena-served
        and freshly synthesized operands are bit-identical and sharded
        reruns replay exactly.
        """
        r, c = (ref.cols, ref.rows) if ref.transposed else (
            ref.rows, ref.cols)
        rng = self.rng if self.seed is None else np.random.default_rng(
            (self.seed, ref.base, r, c))
        a = rng.standard_normal((*leading, r, c))
        if ref.symmetric:
            a = (a + np.swapaxes(a, -1, -2)) / 2.0
        return self._asarray(a)

    def execute(self, alg: Algorithm,
                operands: Dict[int, object]):
        """Evaluate ``alg`` on base-indexed operands via the one walker."""
        return walk_steps(alg.steps, operands.__getitem__, self.ops())

    def build(self, alg: Algorithm) -> Callable:
        """A positional callable ``fn(*inputs)`` evaluating ``alg``.

        Inputs follow chain leaf order (see :func:`num_inputs`); for JAX
        backends the result is jit-able, for CPU backends it is a plain
        closure — either way the planner can embed it.
        """
        ops = self.ops()
        steps = alg.steps

        def fn(*inputs):
            return walk_steps(steps, inputs.__getitem__, ops)

        return fn

    def time_algorithm(self, alg: Algorithm,
                       operands: Optional[Dict[int, object]] = None,
                       reps: Optional[int] = None) -> float:
        """Median-of-reps wall seconds (warm-up excluded, dispatch synced).

        The protocol knobs live on the instance: BLAS-style backends flush
        the cache in ``_pre_rep`` (paper §3.4), JAX backends jit in
        ``_timed_callable`` and block in ``_sync``.
        """
        if operands is None:
            operands = self.make_operands(alg)
        reps = self.reps if reps is None else reps
        fn = self._timed_callable(alg, operands)
        self._sync(fn())  # warm-up: library init / compile / page-in
        ts: List[float] = []
        for _ in range(reps):
            self._pre_rep()
            t0 = time.perf_counter()
            self._sync(fn())
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    def benchmark_call(self, call: KernelCall,
                       reps: Optional[int] = None) -> float:
        """Time one kernel call in isolation (synthetic one-step algorithm).

        Same repetition/flush/sync protocol as :meth:`time_algorithm` —
        by construction, since it *is* ``time_algorithm`` on a
        :func:`synthetic_algorithm`.
        """
        return self.time_algorithm(synthetic_algorithm(call), reps=reps)

    def num_inputs(self, alg: Algorithm) -> int:
        return num_inputs(alg)


# ---------------------------------------------------------------- registry --

_REGISTRY: Dict[str, Callable[..., ExecutionBackend]] = {}


def register_backend(name: str, factory: Callable[..., ExecutionBackend],
                     ) -> Callable[..., ExecutionBackend]:
    """Register a backend class/factory under ``name`` (the fingerprint key).

    Returns ``factory`` so it can be used as a decorator. Duplicate names
    are rejected: silently shadowing ``blas`` would re-key every cached
    profile and atlas on disk.
    """
    key = name.lower()
    if key in _REGISTRY:
        raise ValueError(f"execution backend {key!r} is already registered")
    _REGISTRY[key] = factory
    return factory


def get_backend_class(name: str) -> Callable[..., ExecutionBackend]:
    """Resolve a registry name to its backend class/factory."""
    try:
        return _REGISTRY[name.lower()]
    except KeyError:
        raise KeyError(
            f"unknown execution backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def get_backend(name: str, **options) -> ExecutionBackend:
    """Instantiate a registered backend (strict: unknown options raise)."""
    return get_backend_class(name)(**options)


def make_backend(name: str, **options) -> ExecutionBackend:
    """CLI-lenient :func:`get_backend`: drops options the backend lacks.

    Generic front-ends (sweep/calibrate CLIs) pass one option superset —
    ``reps``/``flush_cache``/``dtype``/``device`` — and each backend takes
    what its constructor declares; e.g. ``flush_cache`` reaches BLAS but
    not JAX. Module-level (and so picklable inside ``functools.partial``)
    for the process-pool sweep path.
    """
    import inspect

    cls = get_backend_class(name)
    try:
        sig = inspect.signature(cls)
    except (TypeError, ValueError):  # pragma: no cover - exotic factory
        return cls(**options)
    params = sig.parameters
    if not any(p.kind is inspect.Parameter.VAR_KEYWORD
               for p in params.values()):
        options = {k: v for k, v in options.items() if k in params}
    return cls(**options)


def registered_backends() -> List[str]:
    return sorted(_REGISTRY)


def backend_default_dtype(name: str) -> str:
    """Default fingerprint dtype of a registered backend."""
    return getattr(get_backend_class(name), "default_dtype", "float32")


def backend_shard_mode(name: str) -> str:
    """How the sweep engine fans this backend out: process | device."""
    return getattr(get_backend_class(name), "shard_mode", "process")


def measure_seconds(fn: Callable, *args) -> tuple:
    """Run ``fn(*args)``, blocking on JAX async dispatch; (result, secs).

    Used by the planner's online refinement so the recorded time reflects
    device completion rather than dispatch-queue insertion. Deferred
    device errors surfaced by the block propagate — recording the
    dispatch-only time of a failed computation would poison the profile.
    """
    try:
        import jax
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        jax = None
    t0 = time.perf_counter()
    out = fn(*args)
    if jax is not None:
        jax.block_until_ready(out)  # no-op for non-JAX leaves
    return out, time.perf_counter() - t0
