"""The ``blas`` backend: scipy BLAS kernels, paper measurement protocol.

This is the paper's methodology verbatim: double precision, Fortran-order
operands, dgemm/dsyrk/dsymm through :mod:`scipy.linalg.blas`, a cache
flush before every repetition (§3.4) and median-of-k timing. It is the
backend the reproduction experiments measure and the one whose anomaly
regions correspond to the paper's.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .base import ExecutionBackend, KernelOps

try:  # scipy is available in this container; keep import soft for docs envs
    from scipy.linalg import blas as _blas
except Exception:  # pragma: no cover
    _blas = None


_FLUSH_BYTES = 64 * 1024 * 1024  # > L3 on the container host


class CacheFlusher:
    """Paper §3.4: flush the cache prior to each repetition."""

    def __init__(self, nbytes: int = _FLUSH_BYTES):
        self._buf = np.zeros(nbytes // 8, dtype=np.float64)

    def flush(self) -> None:
        # Touch every cache line; the sum defeats dead-code elimination.
        self._buf += 1.0
        _ = float(self._buf[:: 4096].sum())


class BlasOps(KernelOps):
    """scipy BLAS kernel vocabulary (float64, triangle-aware)."""

    def transpose(self, a):
        return a.T

    def gemm(self, a, b):
        return _blas.dgemm(1.0, a, b)

    def syrk(self, a):
        # dsyrk computes one triangle of a·aᵀ (lower, given lower=1).
        return _blas.dsyrk(1.0, a, lower=1)

    def symm(self, s, b):
        return _blas.dsymm(1.0, s, b, side=0, lower=1)

    def symm_r(self, b, s):
        # dsymm(side=1) computes b·s with s the symmetric operand.
        return _blas.dsymm(1.0, s, b, side=1, lower=1)

    def tri2full(self, t):
        return np.asfortranarray(np.tril(t) + np.tril(t, -1).T)


_OPS = BlasOps()


class BlasBackend(ExecutionBackend):
    """Execute/time algorithms with real BLAS kernels (paper methodology)."""

    name = "blas"
    default_dtype = "float64"
    dtypes = ("float64",)
    shard_mode = "process"

    def __init__(self, reps: int = 10, flush_cache: bool = True,
                 rng: Optional[np.random.Generator] = None,
                 dtype: Optional[str] = None,
                 seed: Optional[int] = None):
        if _blas is None:  # pragma: no cover
            raise RuntimeError("scipy BLAS unavailable")
        super().__init__(reps=reps, dtype=dtype, rng=rng, seed=seed)
        self.flusher = CacheFlusher() if flush_cache else None

    def ops(self) -> KernelOps:
        return _OPS

    def _asarray(self, a: np.ndarray) -> np.ndarray:
        return np.asfortranarray(a)

    def _pre_rep(self) -> None:
        if self.flusher:
            self.flusher.flush()
