"""The ``numpy`` backend: the pure-numpy oracle, promoted to first class.

Semantically identical to the BLAS backend but with no scipy dependency —
``@``/``tril`` only. It is the ground truth every other backend's
numerics are gated against (tests/test_expressions.py), and as a timing
backend it measures what numpy's own matmul dispatch does with the same
step DAGs (its anomaly regions are *not* the paper's BLAS regions — that
difference is exactly what ``sweep --compare-backends`` reports).
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from ..algorithms import Algorithm
from .base import ExecutionBackend, KernelOps, walk_steps
from .blas import CacheFlusher


def _mirror_lower(t: np.ndarray) -> np.ndarray:
    return np.tril(t) + np.tril(t, -1).T


class NumpyOps(KernelOps):
    """numpy kernel vocabulary honoring triangle storage."""

    def transpose(self, a):
        return a.T

    def gemm(self, a, b):
        return a @ b

    def syrk(self, a):
        return np.tril(a @ a.T)

    def symm(self, s, b):
        return _mirror_lower(s) @ b

    def symm_r(self, b, s):
        return b @ _mirror_lower(s)

    def tri2full(self, t):
        return _mirror_lower(t)


_OPS = NumpyOps()


class NumpyBackend(ExecutionBackend):
    """The oracle executor (and a measurable backend in its own right)."""

    name = "numpy"
    default_dtype = "float64"
    dtypes = ("float64",)
    shard_mode = "process"

    def __init__(self, reps: int = 10, flush_cache: bool = True,
                 rng: Optional[np.random.Generator] = None,
                 dtype: Optional[str] = None,
                 seed: Optional[int] = None):
        super().__init__(reps=reps, dtype=dtype, rng=rng, seed=seed)
        self.flusher = CacheFlusher() if flush_cache else None

    def ops(self) -> KernelOps:
        return _OPS

    def _pre_rep(self) -> None:
        if self.flusher:
            self.flusher.flush()


def reference_execute(alg: Algorithm,
                      operands: Dict[int, np.ndarray]) -> np.ndarray:
    """Stateless oracle executor for an algorithm's step sequence.

    The numerical correctness gate every registered expression's
    algorithms — on every registered backend — are checked against.
    Honors triangle storage (SYRK output keeps only the lower triangle;
    SYMM/TRI2FULL read only the lower triangle of symmetric operands)
    and SYMM sides. Equivalent to ``NumpyBackend().execute`` but with no
    instance to construct.
    """
    return walk_steps(alg.steps,
                      lambda base: np.asarray(operands[base]), _OPS)
