"""repro.core.backends — one executor stack for BLAS, numpy, XLA, Pallas.

The pluggable execution-backend registry (ISSUE 4). Four entries ship:

=========  =====================================================  =========
registry   what it executes                                       fingerprint
key                                                               dtype
=========  =====================================================  =========
``blas``   scipy BLAS (paper protocol: cache flush, median-of-k)  float64
``numpy``  the pure-numpy oracle (correctness ground truth)       float64
``jax``    jnp under jit (XLA)                                    float32
``pallas`` the Pallas TPU kernels (interpret mode on CPU)         float32
=========  =====================================================  =========

Every entry satisfies the :class:`~repro.core.backends.base
.ExecutionBackend` protocol (``make_operands`` / ``execute`` / ``build``
/ ``time_algorithm`` / ``benchmark_call`` / ``fingerprint_tags``) on top
of the single generic step walker in :mod:`repro.core.backends.base`;
``calibrate --backend``, ``sweep --backend``/``--compare-backends``,
``selector`` and the planner all resolve executors here. Registering a
fifth backend is ~30 lines — see docs/architecture.md.
"""

from .base import (
    ExecutionBackend,
    KernelOps,
    backend_default_dtype,
    backend_shard_mode,
    get_backend,
    get_backend_class,
    make_backend,
    measure_seconds,
    num_inputs,
    register_backend,
    registered_backends,
    synthetic_algorithm,
    walk_steps,
)
from .blas import BlasBackend, CacheFlusher
from .jax_backend import JaxBackend, PallasBackend
from .numpy_backend import NumpyBackend, reference_execute

register_backend("blas", BlasBackend)
register_backend("numpy", NumpyBackend)
register_backend("jax", JaxBackend)
register_backend("pallas", PallasBackend)

__all__ = [
    "ExecutionBackend", "KernelOps", "walk_steps", "synthetic_algorithm",
    "num_inputs", "measure_seconds",
    "register_backend", "get_backend", "get_backend_class", "make_backend",
    "registered_backends", "backend_default_dtype", "backend_shard_mode",
    "BlasBackend", "NumpyBackend", "JaxBackend", "PallasBackend",
    "CacheFlusher", "reference_execute",
]
