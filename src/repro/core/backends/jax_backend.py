"""The ``jax`` and ``pallas`` backends: jit-able executors + vmap batching.

``jax`` lowers every kernel kind to plain jnp (XLA picks the fusion);
``pallas`` routes gemm/syrk/symm through the hand-written Pallas TPU
kernels in :mod:`repro.kernels` (Mosaic on TPU, interpret mode on CPU —
the two must agree, which tests/test_kernels.py and the backend-parity
gate assert). Both share the generic walker, so an algorithm's step DAG
is traced once and jit/vmap treat it like any other jnp program.

The batched path (:meth:`JaxBackend.build_batched` /
:meth:`execute_batch`) vmaps the built callable over a leading instance
axis — the many-instance serving shape: one algorithm, a batch of
operand sets, one device dispatch instead of ``batch`` of them.
"""

from __future__ import annotations

import collections
import contextlib
import os
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from ..algorithms import Algorithm
from ..arena import algorithm_structural_key
from .base import ExecutionBackend, KernelOps, num_inputs

#: Bound on each backend instance's executable memo. Far above any real
#: family's structure count (the zoo tops out at ~25 per family); evicting
#: oldest-first keeps autotuning runs (one generation per candidate) from
#: accumulating dead entries.
EXEC_MEMO_MAX = 512

#: Opt-in persistent XLA compilation cache (the process-pool / multi-host
#: story: workers inherit the env var and share compiled programs across
#: processes and reruns instead of re-compiling per worker).
XLA_CACHE_ENV = "REPRO_XLA_CACHE_DIR"

_xla_cache_wired = False


def _maybe_enable_compilation_cache() -> None:
    """Point XLA's persistent compilation cache at $REPRO_XLA_CACHE_DIR.

    Best-effort and once per process: older jax versions without the
    config knob simply keep the in-memory jit cache (the executable memo
    above it still works).
    """
    global _xla_cache_wired
    if _xla_cache_wired:
        return
    _xla_cache_wired = True
    path = os.environ.get(XLA_CACHE_ENV)
    if not path:
        return
    try:
        import jax
        jax.config.update("jax_compilation_cache_dir", path)
    except Exception:
        try:
            from jax.experimental.compilation_cache import (
                compilation_cache as cc)
            cc.set_cache_dir(path)  # type: ignore[attr-defined]
        except Exception:
            pass


def _swap(a):
    import jax.numpy as jnp
    return jnp.swapaxes(a, -1, -2)


def _mirror(t):
    import jax.numpy as jnp
    return jnp.tril(t) + _swap(jnp.tril(t, -1))


class JnpOps(KernelOps):
    """Plain-jnp kernel vocabulary (batch-dim friendly: last-two-axes ops)."""

    def transpose(self, a):
        return _swap(a)

    def gemm(self, a, b):
        return a @ b

    def syrk(self, a):
        import jax.numpy as jnp
        return jnp.tril(a @ _swap(a))

    def symm(self, s, b):
        return _mirror(s) @ b

    def symm_r(self, b, s):
        return b @ _mirror(s)

    def tri2full(self, t):
        return _mirror(t)


class PallasOps(JnpOps):
    """Pallas TPU kernels for the compute kinds; jnp for data movement.

    ``tri2full`` stays jnp on purpose: it is pure data movement and XLA's
    fused tril/transpose is already bandwidth-bound (see
    :func:`repro.kernels.ops.tri2full`).

    ``config_lookup(kind, dims) -> dict | None`` supplies tuned tile
    configs (from a :class:`~repro.core.tuning.TuningTable`, or the
    autotuner's per-candidate override); ``None``/missing keys fall back
    to the kernels' built-in 128-edge defaults. Dims are read from the
    operands' trailing axes, so the lookup sees per-example shapes under
    vmap/jit tracing too. Unknown keys from a foreign table are dropped
    via :data:`~repro.core.tuning.ALLOWED_KEYS` rather than crashing the
    kernel call.

    This vocabulary also advertises the two fused patterns
    (``gemm+gemm`` → :func:`repro.kernels.ops.chain_gemm`,
    ``gemm+syrk`` → :func:`repro.kernels.ops.gemm_syrk`) unless
    ``REPRO_NO_FUSION`` is set.
    """

    def __init__(self, config_lookup: Optional[
            Callable[[str, Tuple[int, ...]], Optional[dict]]] = None):
        self._lookup = config_lookup

    def _cfg(self, kind: str, dims: Tuple[int, ...]) -> dict:
        if self._lookup is None:
            return {}
        cfg = self._lookup(kind, dims)
        if not cfg:
            return {}
        from ..tuning import ALLOWED_KEYS
        allowed = ALLOWED_KEYS.get(kind, ())
        return {k: int(v) for k, v in cfg.items() if k in allowed}

    def fused_kinds(self) -> frozenset:
        if os.environ.get("REPRO_NO_FUSION"):
            return frozenset()
        return frozenset({"gemm+gemm", "gemm+syrk"})

    def gemm(self, a, b):
        from repro.kernels import ops as kops
        cfg = self._cfg("gemm", (a.shape[-2], b.shape[-1], a.shape[-1]))
        return kops.gemm(a, b, **cfg)

    def syrk(self, a):
        from repro.kernels import ops as kops
        cfg = self._cfg("syrk", (a.shape[-2], a.shape[-1]))
        return kops.syrk(a, **cfg)

    def symm(self, s, b):
        from repro.kernels import ops as kops
        cfg = self._cfg("symm", (s.shape[-2], b.shape[-1]))
        return kops.symm(s, b, **cfg)

    def symm_r(self, b, s):
        from repro.kernels import ops as kops
        # B·S with S symmetric: (S·Bᵀ)ᵀ via the side-L kernel.
        cfg = self._cfg("symm", (s.shape[-2], b.shape[-2]))
        return _swap(kops.symm(s, _swap(b), **cfg))

    def chain_gemm(self, a, b, c):
        from repro.kernels import ops as kops
        cfg = self._cfg("chain_gemm", (a.shape[-2], a.shape[-1],
                                       b.shape[-1], c.shape[-1]))
        return kops.chain_gemm(a, b, c, **cfg)

    def gemm_syrk(self, a, b):
        from repro.kernels import ops as kops
        cfg = self._cfg("gemm_syrk", (a.shape[-2], a.shape[-1],
                                      b.shape[-1]))
        return kops.gemm_syrk(a, b, **cfg)


_JNP_OPS = JnpOps()
_PALLAS_OPS = PallasOps()


class JaxBackend(ExecutionBackend):
    """Build/execute/time algorithms as jitted JAX callables.

    ``device`` pins every operand this backend synthesizes (and therefore
    the computation, which follows its inputs) to one JAX device — the
    sweep engine constructs one backend per device to shard a grid across
    all of them. ``None`` leaves placement to JAX's default.

    ``use_pallas=True`` makes this instance behave as the ``pallas``
    backend (kernel ops and fingerprint tag included) — kept so the
    legacy ``JaxRunner(use_pallas=...)`` constructor keeps working; new
    code asks the registry for ``"pallas"`` instead.
    """

    name = "jax"
    default_dtype = "float32"
    dtypes = None  # any dtype label jax can represent
    shard_mode = "device"

    def __init__(self, device=None, reps: int = 3,
                 dtype: Optional[str] = None,
                 rng: Optional[np.random.Generator] = None,
                 use_pallas: bool = False,
                 seed: Optional[int] = None):
        super().__init__(reps=reps, dtype=dtype, rng=rng, seed=seed)
        self.device = device
        self.use_pallas = bool(use_pallas)
        # Executable memo: structural key -> jitted callable. jax.jit's
        # own cache handles per-shape retraces under one wrapper; this
        # memo removes the per-point Python build + wrapper construction.
        self._exec_memo: "collections.OrderedDict[Tuple, Callable]" = (
            collections.OrderedDict())
        self.memo_hits = 0
        self.memo_misses = 0
        _maybe_enable_compilation_cache()

    # -- hooks -------------------------------------------------------------
    def ops(self) -> KernelOps:
        return _PALLAS_OPS if self.use_pallas else _JNP_OPS

    def fingerprint_tags(self):
        return ("pallas" if self.use_pallas else "jax", self.dtype)

    def _asarray(self, a: np.ndarray):
        import jax
        import jax.numpy as jnp

        out = jnp.asarray(a, dtype=self.dtype)
        if out.dtype != jnp.dtype(self.dtype):
            # e.g. float64 requested with jax_enable_x64 off: JAX silently
            # downcasts, which would stamp a fingerprint the measurements
            # don't match.
            raise ValueError(
                f"jax produced dtype {out.dtype} for requested "
                f"{self.dtype!r} (for float64, enable jax_enable_x64)")
        if self.device is not None:
            out = jax.device_put(out, self.device)
        return out

    def _sync(self, out):
        import jax
        return jax.block_until_ready(out)

    def _memo_generation(self) -> Tuple:
        """Environment the traced program bakes in beyond its structure.

        The fusion kill-switch is read at trace time by
        :meth:`PallasOps.fused_kinds`; folding it into the memo key means
        flipping ``REPRO_NO_FUSION`` mid-process (tests, the
        ``--compare-backends`` A/B path) never serves a stale executable.
        """
        if self.use_pallas:
            return (bool(os.environ.get("REPRO_NO_FUSION")),)
        return ()

    def _jitted(self, alg: Algorithm) -> Callable:
        """The memoised jitted callable for ``alg``'s structure."""
        import jax

        key = (algorithm_structural_key(alg), self._memo_generation())
        fn = self._exec_memo.get(key)
        if fn is not None:
            self.memo_hits += 1
            self._exec_memo.move_to_end(key)
            return fn
        self.memo_misses += 1
        fn = jax.jit(self.build(alg))
        self._exec_memo[key] = fn
        while len(self._exec_memo) > EXEC_MEMO_MAX:
            self._exec_memo.popitem(last=False)
        return fn

    def _timed_callable(self, alg: Algorithm,
                        operands: Dict[int, object]) -> Callable[[], object]:
        """Memoised jit of the built callable; any remaining compile time
        lands in the warm-up call.

        There is no cache flush on this backend — operands live in HBM
        and the measured quantity is steady-state device time, not the
        paper's cold-cache CPU protocol.
        """
        args = self._args(alg, operands)
        fn = self._jitted(alg)
        return lambda: fn(*args)

    def _args(self, alg: Algorithm, operands: Dict[int, object]) -> list:
        n = num_inputs(alg)
        some = next(iter(operands.values()))
        # fetch only ever reads base positions; fill the rest with any array
        return [operands.get(i, some) for i in range(n)]

    # -- batched (vmap) execution -----------------------------------------
    def make_batched_operands(self, alg: Algorithm,
                              batch: int) -> Dict[int, object]:
        """``batch`` independent operand sets, stacked on a leading axis."""
        return self.make_operands(alg, leading=(batch,))

    def build_batched(self, alg: Algorithm) -> Callable:
        """vmap of :meth:`build` over a leading instance axis on every leaf.

        One dispatch evaluates the algorithm for a whole batch of operand
        sets — the serving-sweep shape, where thousands of small instances
        would otherwise pay per-call dispatch each.
        """
        import jax
        return jax.vmap(self.build(alg))

    def execute_batch(self, alg: Algorithm,
                      operands: Dict[int, object]):
        """Evaluate ``alg`` over batched operands (one jitted vmap call)."""
        import jax
        fn = jax.jit(self.build_batched(alg))
        return fn(*self._args(alg, operands))

    def time_algorithm_batched(self, alg: Algorithm, batch: int = 32,
                               operands: Optional[Dict[int, object]] = None,
                               reps: Optional[int] = None) -> float:
        """Median wall seconds for one *batched* evaluation of ``alg``.

        Divide by ``batch`` for per-instance amortized time; contrast with
        ``batch ×`` :meth:`time_algorithm` to see the dispatch amortization
        the vmap path buys.
        """
        import jax
        import time as _t

        if operands is None:
            operands = self.make_batched_operands(alg, batch)
        args = self._args(alg, operands)
        fn = jax.jit(self.build_batched(alg))
        self._sync(fn(*args))  # warm-up: compile + page-in
        ts = []
        for _ in range(reps if reps is not None else self.reps):
            t0 = _t.perf_counter()
            self._sync(fn(*args))
            ts.append(_t.perf_counter() - t0)
        return float(np.median(ts))


class PallasBackend(JaxBackend):
    """The ``pallas`` registry entry: Pallas kernels as a first-class backend.

    Interpret mode on CPU, Mosaic on TPU — same call sites either way
    (see :mod:`repro.kernels.ops`).

    Tuning: with ``tuning="auto"`` (the default) the backend lazily loads
    the :class:`~repro.core.tuning.TuningTable` cached for this machine's
    hardware fingerprint (written by ``calibrate --tune``) on first
    kernel dispatch; every gemm/syrk/symm/fused call then runs at the
    tuned tile config for its shape (nearest same-kind entry for unseen
    shapes). Pass an explicit table, or ``tuning=None`` to pin the
    built-in 128-edge defaults; ``REPRO_NO_TUNING=1`` kills lookup at
    dispatch time regardless.
    """

    name = "pallas"
    supports_tuning = True

    def __init__(self, device=None, reps: int = 3,
                 dtype: Optional[str] = None,
                 rng: Optional[np.random.Generator] = None,
                 use_pallas: bool = True, tuning="auto",
                 seed: Optional[int] = None):
        super().__init__(device=device, reps=reps, dtype=dtype, rng=rng,
                         use_pallas=use_pallas, seed=seed)
        self._tuning = tuning          # "auto" | TuningTable | None
        self._tuning_resolved = tuning != "auto"
        self._override: Optional[Callable[
            [str, Tuple[int, ...]], Optional[dict]]] = None
        #: Bumped whenever the effective config lookup changes (table
        #: swap, autotuner override enter/exit) — tile configs are baked
        #: into traced programs, so the executable memo keys on this.
        self._tuning_generation = 0

    def set_tuning(self, table) -> None:
        """Pin a :class:`~repro.core.tuning.TuningTable` (or ``None``)."""
        self._tuning = table
        self._tuning_resolved = True
        self._tuning_generation += 1

    def tuning_table(self):
        """The resolved table (auto-load happens here), or ``None``."""
        if not self._tuning_resolved:
            from ..tuning import load_default_tuning_table
            self._tuning = load_default_tuning_table(
                backend=self.name, dtype=self.dtype)
            self._tuning_resolved = True
        return self._tuning

    @contextlib.contextmanager
    def tuning_override(self, entries: Dict[Tuple[str, Tuple[int, ...]],
                                            dict]):
        """Force exact per-``(kind, dims)`` configs for the duration.

        The autotuner's measurement hook: candidate configs are applied
        through the same lookup path production dispatch uses, bypassing
        the table and the kill-switch (a tuning run must be able to
        measure while ``REPRO_NO_TUNING`` protects production traffic).
        """
        prev = self._override
        self._override = lambda kind, dims: entries.get((kind, dims))
        self._tuning_generation += 1
        try:
            yield self
        finally:
            self._override = prev
            self._tuning_generation += 1

    def _config_lookup(self, kind: str,
                       dims: Tuple[int, ...]) -> Optional[dict]:
        if self._override is not None:
            return self._override(kind, dims)
        if os.environ.get("REPRO_NO_TUNING"):
            return None
        table = self.tuning_table()
        if table is None:
            return None
        return table.config(kind, dims)

    def _memo_generation(self) -> Tuple:
        """Fusion + tuning state a traced Pallas program bakes in."""
        return (bool(os.environ.get("REPRO_NO_FUSION")),
                bool(os.environ.get("REPRO_NO_TUNING")),
                self._tuning_generation)

    def ops(self) -> KernelOps:
        return PallasOps(self._config_lookup)
