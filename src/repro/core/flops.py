"""FLOP counts for the BLAS kernels used by the paper's algorithms.

These are the paper's §3.1 conventions, verbatim:

* GEMM  (A: m×k, B: k×n)           → 2·m·n·k
* SYRK  (A: m×k, computes A·Aᵀ)    → (m+1)·m·k
* SYMM  (S: m×m symmetric, B: m×n) → 2·m²·n
* TRI2FULL (copy triangle to full m×m) → 0 FLOPs (pure data movement;
  the paper charges it no FLOPs, which is itself part of why FLOPs
  mislead — the copy costs time but not FLOPs).

SYMM dims are always ``(s_dim, other_dim)`` regardless of which side the
symmetric operand multiplies from (``S·B`` vs ``B·S`` cost the same
2·s²·o FLOPs and share calibration-table entries); the side lives on the
enumeration :class:`~repro.core.algorithms.Step` (``symm_side``), which
is what executors consult.

The counts are exposed both as python ints (for the selector) and as a
per-call dataclass so the perf-model layer can attach time estimates.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Tuple

#: The kernel vocabulary of the enumeration layer. Profiles, runners and
#: the calibration sweep all branch over exactly these kinds.
KERNEL_KINDS: Tuple[str, ...] = ("gemm", "syrk", "symm", "tri2full")


@dataclasses.dataclass(frozen=True)
class KernelCall:
    """One kernel invocation in an algorithm.

    ``kind``  ∈ {gemm, syrk, symm, tri2full}
    ``dims``  kernel-specific:
        gemm:     (m, n, k)   C[m,n] += A[m,k] B[k,n]
        syrk:     (m, k)      C[m,m] = A[m,k] A[m,k]ᵀ (one triangle)
        symm:     (m, n)      C[m,n] = S[m,m] B[m,n], S symmetric
        tri2full: (m,)        mirror triangle of an m×m matrix
    ``operands`` free-form labels for provenance/debugging.
    """

    kind: str
    dims: Tuple[int, ...]
    operands: Tuple[str, ...] = ()

    @property
    def flops(self) -> int:
        return kernel_flops(self.kind, self.dims)

    @property
    def bytes_moved(self) -> int:
        """Minimum HBM/memory traffic in elements (reads + writes).

        Used by the perf-model discriminant; dtype width is applied there.
        """
        if self.kind == "gemm":
            m, n, k = self.dims
            return m * k + k * n + m * n
        if self.kind == "syrk":
            m, k = self.dims
            return m * k + m * (m + 1) // 2
        if self.kind == "symm":
            m, n = self.dims
            return m * (m + 1) // 2 + 2 * m * n
        if self.kind == "tri2full":
            (m,) = self.dims
            return m * m  # read triangle + write other triangle ≈ m²
        raise ValueError(f"unknown kernel kind {self.kind!r}")

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        d = "x".join(str(x) for x in self.dims)
        ops = ",".join(self.operands)
        return f"{self.kind}({d}{'; ' + ops if ops else ''})"


def kernel_flops(kind: str, dims: Tuple[int, ...]) -> int:
    if kind == "gemm":
        m, n, k = dims
        return 2 * m * n * k
    if kind == "syrk":
        m, k = dims
        return (m + 1) * m * k
    if kind == "symm":
        m, n = dims
        return 2 * m * m * n
    if kind == "tri2full":
        return 0
    raise ValueError(
        f"unknown kernel kind {kind!r}; expected one of {KERNEL_KINDS}")


def gemm(m: int, n: int, k: int, *ops: str) -> KernelCall:
    return KernelCall("gemm", (m, n, k), tuple(ops))


def syrk(m: int, k: int, *ops: str) -> KernelCall:
    return KernelCall("syrk", (m, k), tuple(ops))


def symm(m: int, n: int, *ops: str) -> KernelCall:
    return KernelCall("symm", (m, n), tuple(ops))


def tri2full(m: int, *ops: str) -> KernelCall:
    return KernelCall("tri2full", (m,), tuple(ops))


def total_flops(calls: Iterable[KernelCall]) -> int:
    return sum(c.flops for c in calls)
