"""Planted-mask oracles for the adaptive sweep engine (ISSUE 7).

The adaptive engine's headline claim — ≥ 0.9 frontier recall at ≤ 40 % of
the dense measurement budget — is only checkable against ground truth that
is *known by construction*. This module plants it: a mask function decides
which grid points are anomalies, a duck-typed expression spec + runner pair
turns that mask into deterministic measurements the sweep engine consumes
unchanged, and the dense grid evaluated through the mask is the oracle the
property tests (``tests/test_adaptive.py``), ``benchmarks/sweep_bench.py``
and the ``adaptive-smoke`` CI job all compare against.

Everything here is a frozen top-level dataclass so specs, masks and runner
factories pickle across the process-pool sweep backend, and two masks with
equal parameters compare equal (the worker-local runner cache keys on the
factory's arguments).

Masks operate on grid *values* (the same tuples the sweep engine
measures), not axis indices; on the uniform grids the harnesses use the
two coincide up to spacing.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, Iterable, List, Tuple

from .expressions import GridSpec

Point = Tuple[int, ...]
MaskFn = Callable[[Point], bool]


# ------------------------------------------------------------------ masks ---


@dataclasses.dataclass(frozen=True)
class BlobMask:
    """Euclidean ball: one convex contiguous anomaly region."""

    center: Tuple[int, ...]
    radius: float

    def __call__(self, point: Point) -> bool:
        return sum((float(v) - c) ** 2
                   for v, c in zip(point, self.center)) <= self.radius ** 2


@dataclasses.dataclass(frozen=True)
class StripeMask:
    """Axis-aligned slab spanning the full grid along every other axis."""

    axis: int
    lo: int
    hi: int

    def __call__(self, point: Point) -> bool:
        return self.lo <= point[self.axis] <= self.hi


@dataclasses.dataclass(frozen=True)
class BoxMask:
    """Axis-aligned box, inclusive bounds per dimension."""

    lo: Tuple[int, ...]
    hi: Tuple[int, ...]

    def __call__(self, point: Point) -> bool:
        return all(a <= v <= b for v, a, b in zip(point, self.lo, self.hi))


@dataclasses.dataclass(frozen=True)
class UnionMask:
    """Union of member masks: multi-region and L-shaped plants."""

    masks: Tuple[MaskFn, ...]

    def __call__(self, point: Point) -> bool:
        return any(m(point) for m in self.masks)


@dataclasses.dataclass(frozen=True)
class EmptyMask:
    """No anomalies anywhere — the adaptive sweep must stop at the seed."""

    def __call__(self, point: Point) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class FullMask:
    """Everything anomalous — a region with no frontier to refine."""

    def __call__(self, point: Point) -> bool:
        return True


# ------------------------------------------------- planted spec + runner ---


@dataclasses.dataclass(frozen=True)
class PlantedAlg:
    """Minimal Algorithm stand-in: name + FLOPs + the instance it is for.

    Carrying the point lets :class:`MaskRunner` time by mask lookup —
    real ``Algorithm`` objects only expose dims through their kernel
    calls, which planted masks have no use for.
    """

    name: str
    flops: int
    point: Point
    calls: Tuple = ()
    steps: Tuple = ()


@dataclasses.dataclass(frozen=True)
class PlantedSpec:
    """Duck-typed :class:`~repro.core.expressions.ExpressionSpec`.

    Two algorithms per instance: ``cheap`` (fewest FLOPs) and ``fast``.
    Which one *times* fastest is the mask's call — see
    :class:`MaskRunner`. Satisfies everything ``sweep()`` touches
    (``name``/``ndims``/``algorithms``) and pickles across process pools.
    """

    name: str = "PLANTED"
    ndims: int = 2

    def algorithms(self, point: Iterable[int]) -> List[PlantedAlg]:
        p = tuple(int(x) for x in point)
        if len(p) != self.ndims:
            raise ValueError(
                f"point {p} has {len(p)} dims; {self.name} takes "
                f"{self.ndims}")
        return [PlantedAlg("cheap", 100, p), PlantedAlg("fast", 200, p)]


@dataclasses.dataclass(frozen=True)
class MaskRunner:
    """Deterministic timer that makes ``mask(point)`` the anomaly verdict.

    On masked points the FLOP-cheapest algorithm is slow (disjoint
    cheapest/fastest sets, time score 0.5 ≫ any sane threshold); elsewhere
    the cheapest algorithm is also fastest. Zero noise, so dense and
    adaptive sweeps classify identically and sharded runs replay exactly.
    """

    mask: MaskFn
    slow: float = 2.0
    fast: float = 1.0

    def make_operands(self, alg) -> Dict:
        return {}

    def time_algorithm(self, alg, operands=None) -> float:
        anomalous = bool(self.mask(alg.point))
        if alg.name == "cheap":
            return self.slow if anomalous else self.fast
        return self.fast if anomalous else self.slow


# ----------------------------------------------------------------- oracle ---


def dense_oracle(mask: MaskFn, grid: GridSpec) -> Dict[Point, bool]:
    """Ground truth the dense sweep would measure: every point's verdict."""
    return {p: bool(mask(p)) for p in grid.points()}


def true_frontier(mask: MaskFn, grid: GridSpec) -> FrozenSet[Point]:
    """Both-sided region frontier of the planted mask.

    A grid point is a frontier cell when any grid-positional neighbour
    (adjacent index along exactly one axis) has the opposite verdict —
    the cells :func:`repro.core.adaptive.boundary_cells` converges on
    when the whole frontier has been measured.
    """
    verdicts = dense_oracle(mask, grid)
    axes = [tuple(int(v) for v in ax) for ax in grid.axes]
    index = [{v: i for i, v in enumerate(ax)} for ax in axes]
    out = set()
    for p, v in verdicts.items():
        c = tuple(index[d][x] for d, x in enumerate(p))
        for d in range(len(axes)):
            for step in (-1, 1):
                j = c[d] + step
                if not 0 <= j < len(axes[d]):
                    continue
                q = p[:d] + (axes[d][j],) + p[d + 1:]
                if verdicts[q] != v:
                    out.add(p)
                    break
    return frozenset(out)


def frontier_recall(measured: Iterable[Point],
                    frontier: Iterable[Point]) -> float:
    """Fraction of oracle frontier cells the sweep measured (1.0 if the
    mask has no frontier — nothing to find is fully found)."""
    frontier = set(frontier)
    if not frontier:
        return 1.0
    return len(frontier & set(measured)) / len(frontier)


#: The planted family the property tests and the CI smoke job sweep — name
#: -> (mask builder taking the grid, human description). Builders derive
#: geometry from the grid so one family covers any uniform grid size.
def _mid(ax) -> int:
    return int(ax[len(ax) // 2])


def planted_masks(grid: GridSpec) -> Dict[str, MaskFn]:
    """The six planted ground-truth families of ISSUE 7, sized to ``grid``.

    Regions are planted wide enough (≥ the default seed stride of 4 index
    steps) that the coarse seed lattice intersects every region — the
    standard active-learning caveat: a region smaller than the seed spacing
    can be missed entirely, by design.
    """
    axes = grid.axes
    spacing = [int(ax[1]) - int(ax[0]) if len(ax) > 1 else 1 for ax in axes]
    lo = [int(ax[0]) for ax in axes]
    hi = [int(ax[-1]) for ax in axes]
    span = [h - x for h, x in zip(hi, lo)]
    center = tuple(_mid(ax) for ax in axes)
    radius = min(span) * 0.28
    third = [x + s // 3 for x, s in zip(lo, span)]
    two_thirds = [x + 2 * s // 3 for x, s in zip(lo, span)]
    quarter_r = min(span) * 0.18
    c_lo = tuple(x + s // 4 for x, s in zip(lo, span))
    c_hi = tuple(x + 3 * s // 4 for x, s in zip(lo, span))
    return {
        "blob": BlobMask(center=center, radius=radius),
        "stripe": StripeMask(axis=0, lo=third[0], hi=two_thirds[0]),
        "lshape": UnionMask((
            BoxMask(lo=tuple(lo), hi=(two_thirds[0],) + tuple(
                x + 2 * s for x, s in zip(lo[1:], spacing[1:]))),
            BoxMask(lo=tuple(lo), hi=(lo[0] + 2 * spacing[0],)
                    + tuple(two_thirds[1:])),
        )),
        "multi": UnionMask((
            BlobMask(center=c_lo, radius=quarter_r),
            BlobMask(center=c_hi, radius=quarter_r),
        )),
        "empty": EmptyMask(),
        "full": FullMask(),
    }
