"""Persistent kernel-profile storage — calibrate once per machine.

Peise & Bientinesi (arXiv:1209.2364) make the case that kernel performance
models must be *measured on the target hardware*; this module makes those
measurements durable. A :class:`~repro.core.perfmodel.TableProfile` is
serialized to versioned JSON together with a :class:`HardwareFingerprint`
(backend, device kind, dtype) so a profile calibrated on one machine is
never silently applied to another.

Layout on disk (one file per fingerprint)::

    <cache dir>/profile-<backend>-<device>-<dtype>.json

where ``<cache dir>`` is, in order of precedence:

1. the explicit ``path``/``directory`` argument,
2. ``$REPRO_PROFILE_DIR``,
3. ``$XDG_CACHE_HOME/repro/profiles`` or ``~/.cache/repro/profiles``.

Set ``REPRO_NO_PROFILE_CACHE=1`` to make :func:`load_default_profile`
return ``None`` unconditionally (used by tests and cold-start debugging).
"""

from __future__ import annotations

import dataclasses
import json
import os
import platform
import re
from pathlib import Path
from typing import Optional, Tuple

from .perfmodel import TableProfile

SCHEMA_VERSION = 1

_ENV_DIR = "REPRO_PROFILE_DIR"
_ENV_DISABLE = "REPRO_NO_PROFILE_CACHE"


class ProfileStoreError(RuntimeError):
    """Base class for profile persistence failures."""


class FingerprintMismatchError(ProfileStoreError):
    """A stored profile was calibrated on different hardware/backend/dtype."""


class SchemaVersionError(ProfileStoreError):
    """A stored profile uses a schema this build cannot read."""


@dataclasses.dataclass(frozen=True)
class HardwareFingerprint:
    """What a calibration is valid for: backend × device kind × dtype."""

    backend: str   # "blas" | "jax"
    device: str    # e.g. "x86_64", "TPU v5e", "cpu"
    dtype: str     # e.g. "float64", "float32", "bfloat16"

    def slug(self) -> str:
        """Filesystem-safe identifier used in the cache filename."""
        raw = f"{self.backend}-{self.device}-{self.dtype}"
        return re.sub(r"[^A-Za-z0-9._-]+", "_", raw).lower()

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "HardwareFingerprint":
        return cls(backend=str(d["backend"]), device=str(d["device"]),
                   dtype=str(d["dtype"]))


def current_fingerprint(backend: str = "blas",
                        dtype: str = "float64") -> HardwareFingerprint:
    """Fingerprint of *this* process's execution target.

    For CPU backends (blas/numpy) the device is the host ISA (profiles
    transfer across same-ISA hosts only approximately, but that is the
    right granularity for a cache key). For device-sharded backends
    (jax/pallas — consulted via the execution-backend registry) it is the
    first JAX device's kind.
    """
    try:
        from .backends import backend_shard_mode
        on_device = backend_shard_mode(backend) == "device"
    except KeyError:  # unregistered label (tests, foreign caches)
        on_device = backend in ("jax", "pallas")
    if on_device:
        try:
            import jax
            device = jax.devices()[0].device_kind
        except Exception:  # jax missing or no devices configured
            device = "unknown"
    else:
        device = platform.machine() or "unknown"
    return HardwareFingerprint(backend=backend, device=device, dtype=dtype)


def cache_base_dir() -> Path:
    """Root of this package's on-disk caches (``…/repro``).

    Shared by the profile cache (``<base>/profiles``) and the sweep
    engine's anomaly atlas (``<base>/atlas`` — see
    :mod:`repro.core.sweep`), so every per-machine artifact lives under
    one directory keyed by the same hardware fingerprints.
    """
    xdg = os.environ.get("XDG_CACHE_HOME")
    base = Path(xdg) if xdg else Path.home() / ".cache"
    return base / "repro"


def cache_dir() -> Path:
    env = os.environ.get(_ENV_DIR)
    if env:
        return Path(env)
    return cache_base_dir() / "profiles"


def profile_path(fingerprint: HardwareFingerprint,
                 directory: Optional[Path] = None) -> Path:
    d = Path(directory) if directory is not None else cache_dir()
    return d / f"profile-{fingerprint.slug()}.json"


def save_profile(
    profile: TableProfile,
    fingerprint: HardwareFingerprint,
    path: Optional[Path] = None,
    directory: Optional[Path] = None,
    meta: Optional[dict] = None,
) -> Path:
    """Write ``profile`` as versioned JSON; returns the file written.

    ``path`` wins over ``directory``; with neither, the default cache dir
    is used. Parent directories are created. The write is atomic (tmp file
    + rename) so a crashed calibration never leaves a torn cache.
    """
    out = Path(path) if path is not None else profile_path(
        fingerprint, directory)
    out.parent.mkdir(parents=True, exist_ok=True)
    doc = {
        "version": SCHEMA_VERSION,
        "fingerprint": fingerprint.to_dict(),
        "peak_flops": profile.peak(),
        "entries": [
            {"kind": kind, "dims": list(dims), "seconds": t}
            for (kind, dims), t in sorted(profile.table.items())
        ],
        "meta": dict(meta or {}),
    }
    # Unique per writer: concurrent saves (benchmarks + a live planner,
    # parallel CI shards) must not interleave in a shared tmp file.
    tmp = out.with_suffix(
        f"{out.suffix}.{os.getpid()}.{os.urandom(4).hex()}.tmp")
    tmp.write_text(json.dumps(doc, indent=1, sort_keys=True))
    tmp.replace(out)
    return out


def load_profile(
    path: Path,
    expected_fingerprint: Optional[HardwareFingerprint] = None,
) -> Tuple[TableProfile, HardwareFingerprint]:
    """Read a profile; reject schema/fingerprint mismatches loudly."""
    try:
        doc = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise ProfileStoreError(f"unreadable profile {path}: {e}") from e
    version = doc.get("version")
    if version != SCHEMA_VERSION:
        raise SchemaVersionError(
            f"profile {path} has schema version {version!r}; "
            f"this build reads version {SCHEMA_VERSION}")
    fp = HardwareFingerprint.from_dict(doc["fingerprint"])
    if expected_fingerprint is not None and fp != expected_fingerprint:
        raise FingerprintMismatchError(
            f"profile {path} was calibrated for {fp}, "
            f"but this process targets {expected_fingerprint}")
    table = {
        (str(e["kind"]), tuple(int(d) for d in e["dims"])): float(e["seconds"])
        for e in doc["entries"]
    }
    return TableProfile(peak_flops=float(doc["peak_flops"]),
                        table=table), fp


def load_default_profile(
    backend: str = "blas",
    dtype: str = "float64",
) -> Optional[TableProfile]:
    """Auto-load the cached profile matching this machine, if any.

    Returns ``None`` (never raises) when no valid cache exists — callers
    fall back to the analytical model, so a corrupt or stale cache degrades
    to the uncalibrated behaviour instead of crashing the planner.
    """
    if os.environ.get(_ENV_DISABLE):
        return None
    fp = current_fingerprint(backend=backend, dtype=dtype)
    path = profile_path(fp)
    if not path.is_file():
        return None
    try:
        profile, _ = load_profile(path, expected_fingerprint=fp)
    except ProfileStoreError:
        return None
    return profile
