"""Operand arena and fast-path accounting for measured sweeps (ISSUE 10).

Measured sweeps pay three per-point fixed costs that have nothing to do
with the paper's timed region: operand allocation + RNG fill, algorithm
enumeration, and (on jit backends) executable re-tracing. The paper's
methodology (§3.4) only constrains what happens *inside* a timed rep —
the cache-flush protocol — so everything around it is fair game to
amortise.

This module provides the pieces the sweep fast path composes:

* :class:`OperandArena` — a shape-keyed buffer pool bound to one runner.
  Each distinct ``(base, rows, cols, symmetric, storage)`` leaf is
  synthesized once and reused across points and algorithms. Cache-flush
  buffers are *not* pooled here: flushing stays inside the backend's
  ``_pre_rep`` per the BLAS protocol; only allocation and RNG fill are
  amortised.
* :func:`arena_for` — one arena per runner instance (weakly keyed, so a
  process-pool worker's cached runner keeps its arena across chunks and
  adaptive rounds, and a released runner releases its buffers).
* :func:`order_points_for_locality` — the measurement order that
  maximises arena/memo hits: stable lexicographic, i.e. exactly the
  row-major order grids are enumerated in, so dense sweeps keep their
  request order while arbitrary admitted sets (adaptive refinement
  rounds, shard slices) get grouped by shared leading dimensions.
* :func:`algorithm_structural_key` — a dims-free structural identity for
  an :class:`~repro.core.algorithms.Algorithm`, used by the jit-backend
  executable memo: two algorithms at different grid points that differ
  only in dimensions share one jitted callable (XLA re-traces per shape
  signature internally; the Python-side build + jit wrapper is reused).
* :class:`FastPathStats` — the counter block surfaced by ``sweep()``
  results, CLI progress, and ``benchmarks/sweep_bench.py``.

Everything degrades gracefully for duck-typed runners (the planted-mask
oracles in :mod:`repro.core.synthetic`, deterministic test runners): a
runner without ``make_leaf_operand`` is probed through its legacy
``make_operands(alg)`` once per distinct leaf shape, and algorithms
without real steps simply contribute no buffers — identical to the
legacy path's ``setdefault`` merge semantics.
"""

from __future__ import annotations

import dataclasses
import weakref
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .algorithms import Algorithm, Leaf

Point = Tuple[int, ...]

#: Sentinel stored for leaf keys the runner cannot synthesize (duck-typed
#: runners returning ``{}``) so they are probed once, not once per point.
_ABSENT = object()


# ------------------------------------------------------------------ stats ---


@dataclasses.dataclass
class FastPathStats:
    """Counters for one fast-path run (mergeable across shards/rounds).

    ``overlap_s`` is the portion of preparation work (enumeration +
    operand synthesis) that executed concurrently with a GIL-releasing
    timed region instead of serially before it; ``prep_s`` is the total
    preparation time, so ``overlap_fraction`` is the share of fixed cost
    the pipeline actually hid.
    """

    arena_hits: int = 0
    arena_misses: int = 0
    arena_bytes: int = 0
    memo_hits: int = 0
    memo_misses: int = 0
    points_pipelined: int = 0
    prep_s: float = 0.0
    overlap_s: float = 0.0

    @property
    def overlap_fraction(self) -> float:
        return self.overlap_s / self.prep_s if self.prep_s > 0 else 0.0

    def merge(self, other: "FastPathStats") -> "FastPathStats":
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        return self

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, float]) -> "FastPathStats":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def add_arena_delta(self, before: Tuple[int, int, int],
                        after: Tuple[int, int, int]) -> None:
        self.arena_hits += after[0] - before[0]
        self.arena_misses += after[1] - before[1]
        self.arena_bytes += after[2] - before[2]

    def add_memo_delta(self, before: Tuple[int, int],
                       after: Tuple[int, int]) -> None:
        self.memo_hits += after[0] - before[0]
        self.memo_misses += after[1] - before[1]

    def summary(self) -> str:
        mb = self.arena_bytes / 1e6
        return (f"arena {self.arena_hits}h/{self.arena_misses}m "
                f"({mb:.1f} MB), memo {self.memo_hits}h/{self.memo_misses}m, "
                f"pipelined {self.points_pipelined} "
                f"(overlap {self.overlap_fraction:.0%})")


def memo_counts(runner: object) -> Tuple[int, int]:
    """(hits, misses) of the runner's executable memo; zeros if it has
    none (CPU backends, duck-typed runners)."""
    return (int(getattr(runner, "memo_hits", 0)),
            int(getattr(runner, "memo_misses", 0)))


# ------------------------------------------------------------------ arena ---


def _leaf_key(ref: Leaf) -> Tuple:
    """Shape-keyed identity of a leaf's *backing buffer* (untransposed:
    a transposed view and the plain operand share one array)."""
    r, c = (ref.cols, ref.rows) if ref.transposed else (ref.rows, ref.cols)
    return (ref.base, r, c, ref.symmetric, ref.storage)


def _iter_leaves(alg: Algorithm) -> Iterable[Leaf]:
    for step in getattr(alg, "steps", ()):
        for ref in (step.lhs, step.rhs):
            if isinstance(ref, Leaf):
                yield ref


class OperandArena:
    """Shape-keyed operand buffers, bound to one runner.

    ``operands(algos)`` returns a ``{base: buffer}`` dict covering every
    leaf of every algorithm — the union the legacy path built through
    per-algorithm ``make_operands`` + ``setdefault`` merging — but each
    distinct leaf shape is synthesized at most once for the arena's
    lifetime. Buffers are handed to timed kernels read-only by
    convention (no repro kernel writes its inputs); the cache-flush
    protocol is untouched because flushing happens inside the backend's
    per-rep hook, not at allocation time.
    """

    def __init__(self, runner: object) -> None:
        self.runner = runner
        self._buffers: Dict[Tuple, object] = {}
        self.hits = 0
        self.misses = 0
        self.nbytes = 0

    def __len__(self) -> int:
        return sum(1 for v in self._buffers.values() if v is not _ABSENT)

    def snapshot(self) -> Tuple[int, int, int]:
        return (self.hits, self.misses, self.nbytes)

    def clear(self) -> None:
        self._buffers.clear()

    def _store(self, key: Tuple, buf: object) -> None:
        self._buffers[key] = buf
        if buf is not _ABSENT:
            self.misses += 1
            self.nbytes += int(getattr(buf, "nbytes", 0))

    def _synthesize(self, ref: Leaf, alg: Algorithm) -> None:
        """Fill the cache entry for ``ref`` (and, via the legacy
        whole-algorithm fallback, any sibling leaves that come for free)."""
        make_leaf = getattr(self.runner, "make_leaf_operand", None)
        if make_leaf is not None:
            self._store(_leaf_key(ref), make_leaf(ref))
            return
        # Duck-typed runner: probe through the legacy whole-algorithm
        # entry point and harvest whatever it produced.
        produced = self.runner.make_operands(alg)
        for leaf in _iter_leaves(alg):
            key = _leaf_key(leaf)
            if key not in self._buffers:
                buf = produced.get(leaf.base, _ABSENT)
                self._store(key, buf)
        if _leaf_key(ref) not in self._buffers:  # alg had no matching leaf
            self._store(_leaf_key(ref), produced.get(ref.base, _ABSENT))

    def operands(self, algos: Sequence[Algorithm]) -> Dict[int, object]:
        """Union operand dict for ``algos``, served from the pool."""
        out: Dict[int, object] = {}
        for alg in algos:
            for ref in _iter_leaves(alg):
                if ref.base in out:
                    continue
                key = _leaf_key(ref)
                buf = self._buffers.get(key)
                if buf is None:
                    self._synthesize(ref, alg)
                    buf = self._buffers[key]
                else:
                    self.hits += 1
                if buf is not _ABSENT:
                    out[ref.base] = buf
        return out


_ARENAS: "weakref.WeakKeyDictionary[object, OperandArena]" = (
    weakref.WeakKeyDictionary())


def arena_for(runner: object) -> OperandArena:
    """The arena bound to ``runner`` (created on first use).

    Weakly keyed: a process-pool worker's cached runner keeps one arena
    across chunks; dropping the runner drops its buffers. Runners that
    cannot be weakly referenced or hashed get a fresh (unpooled) arena —
    correct, just without cross-call reuse.
    """
    try:
        arena = _ARENAS.get(runner)
    except TypeError:
        return OperandArena(runner)
    if arena is None:
        arena = OperandArena(runner)
        try:
            _ARENAS[runner] = arena
        except TypeError:
            pass
    return arena


# ------------------------------------------------------------- scheduling ---


def order_points_for_locality(points: Iterable[Point]) -> List[Point]:
    """Measurement order maximising arena/memo reuse between neighbours.

    Stable lexicographic sort: identical to row-major grid enumeration
    (so a dense sweep's measurement order — and therefore its atlas byte
    stream — is unchanged), and arbitrary admitted sets (adaptive
    refinement rounds, shard slices) get consecutive points sharing
    leading dimensions, i.e. sharing operand shapes.
    """
    return sorted(points)


# -------------------------------------------------------- structural keys ---


def algorithm_structural_key(alg: Algorithm) -> Tuple:
    """Dims-free structural identity of an algorithm's step DAG.

    Captures everything the backend step-walker dispatches on — kernel
    kind, SYMM side, operand refs (leaf base/index/transposed/symmetric/
    storage, renumbered intermediate ids), output storage — and nothing
    shape-dependent. Two algorithms with the same key trace to the same
    XLA program *per operand-shape signature*, which ``jax.jit``'s own
    cache already handles; memoising the wrapper on this key means the
    Python-side build happens once per structure, not once per point.
    """
    renum = {s.out: i for i, s in enumerate(alg.steps)}

    def ref_key(r: object) -> Optional[Tuple]:
        if r is None:
            return None
        if isinstance(r, Leaf):
            return ("l", r.index, r.base, r.transposed, r.symmetric,
                    r.storage)
        i = renum.get(r)  # type: ignore[arg-type]
        # Provenance-only ids (e.g. a pruned SYRK twin) are never fetched
        # by the walker; collapse them so they don't split the memo.
        return ("s", i) if i is not None else ("dead",)

    return tuple(
        (s.call.kind, s.symm_side, ref_key(s.lhs), ref_key(s.rhs),
         s.out_storage, s.out_symmetric)
        for s in alg.steps)
