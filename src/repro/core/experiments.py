"""The paper's three experiments (§3.4), as thin harnesses over the engine.

* Experiment 1 — random search over an instance box: abundance + severity.
* Experiment 2 — axis-aligned line traversal around found anomalies: region
  thickness per dimension.
* Experiment 3 — predict anomalies from *isolated* kernel benchmarks
  (additive model), confusion matrix vs measured ground truth.

All measurement goes through :func:`repro.core.sweep.sweep` — the one
measurement path shared with grid sweeps and the benchmarks — so every
experiment can shard across workers (``backend``/``shards``/
``runner_factory``) and stream results into a persistent
:class:`~repro.core.sweep.AnomalyAtlas` (``atlas=``), making repeated runs
resume instead of re-measure. Experiment 3's isolated kernel benchmarks are
deduplicated and fed through the calibration cache
(:mod:`repro.core.profile_store`).

Scaled-down defaults: the paper used boxes up to 1200 with 10–23k samples on
a 10-core Xeon with MKL; the benchmarks here default to smaller boxes and
sample counts to finish in CI time, with flags to run the full study.

The expression specs (:data:`MATRIX_CHAIN_ABCD`, :data:`GRAM_AATB` and
the rest of the registry in :mod:`repro.core.expressions`),
:class:`Instance` and :func:`measure_instance` are re-exported here for
backwards compatibility; every harness takes *any* registered
:class:`ExpressionSpec`, so the zoo families run through Experiments 1–3
unchanged.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .anomaly import Classification, ConfusionMatrix, RegionScan, scan_line
from .backends import make_backend
from .perfmodel import TableProfile
from .runners import BlasRunner
from .sweep import (
    GRAM_AATB,
    MATRIX_CHAIN_ABCD,
    REGISTRY,
    AnomalyAtlas,
    ExpressionSpec,
    Instance,
    benchmark_unique_calls,
    collect_unique_calls,
    get_spec,
    measure_instance,
    registered_names,
    sweep,
)

__all__ = [
    "ExpressionSpec", "Instance", "measure_instance",
    "MATRIX_CHAIN_ABCD", "GRAM_AATB", "REGISTRY", "get_spec",
    "registered_names",
    "Experiment1Result", "Experiment2Result", "Experiment3Result",
    "experiment1_random_search", "experiment2_regions",
    "experiment3_predict_from_benchmarks",
]


@dataclasses.dataclass
class Experiment1Result:
    spec_name: str
    samples: int
    anomalies: List[Instance]
    wall_s: float

    @property
    def abundance(self) -> float:
        return len(self.anomalies) / self.samples if self.samples else 0.0


def experiment1_random_search(
    spec: ExpressionSpec,
    runner: Optional[BlasRunner] = None,
    box: Tuple[int, int] = (20, 1200),
    n_anomalies: int = 20,
    max_samples: int = 2000,
    threshold: float = 0.10,
    seed: int = 0,
    verbose: bool = False,
    atlas: Optional[AnomalyAtlas] = None,
    backend: str = "serial",
    shards: Optional[int] = None,
    runner_factory: Optional[Callable[[], object]] = None,
    batch: int = 25,
    exec_backend: Optional[str] = None,
) -> Experiment1Result:
    """Paper §3.4.1: sample instances u.a.r. until n anomalies are found.

    Sampling proceeds in batches of ``batch`` points so the engine can
    shard each batch across workers; the search stops at the end of the
    batch that reaches ``n_anomalies`` (it may slightly overshoot). Points
    already in ``atlas`` count as samples but are served from disk. With
    ``backend="process"`` one worker pool serves the entire search;
    ``runner`` configures only the serial backend — sharded backends build
    their workers from ``runner_factory``. ``exec_backend`` names a
    :mod:`repro.core.backends` registry entry to build default workers
    from (so the harness runs unchanged on blas/numpy/jax/pallas).
    """
    rng = np.random.default_rng(seed)
    if runner is not None and backend != "serial":
        # same guard sweep() enforces: a runner's protocol (reps, cache
        # flushing) must not be silently swapped for worker defaults
        raise ValueError(
            f"runner= only configures the serial backend; backend="
            f"{backend!r} builds workers from runner_factory")
    if runner is None and runner_factory is None and backend == "serial":
        # one instance (and so one flush buffer) for the whole search
        runner = make_backend(exec_backend) if exec_backend \
            else BlasRunner()
    executor = None
    if backend == "process":
        from concurrent.futures import ProcessPoolExecutor
        executor = ProcessPoolExecutor(
            max_workers=shards or os.cpu_count() or 1)
    found: List[Instance] = []
    samples = 0
    wall = 0.0
    try:
        while len(found) < n_anomalies and samples < max_samples:
            n = min(batch, max_samples - samples)
            pts = [tuple(int(x) for x in
                         rng.integers(box[0], box[1] + 1, size=spec.ndims))
                   for _ in range(n)]
            res = sweep(spec, pts, runner=runner,
                        runner_factory=runner_factory, threshold=threshold,
                        backend=backend, shards=shards, atlas=atlas,
                        executor=executor, exec_backend=exec_backend)
            samples += res.n_points
            wall += res.wall_s
            for inst in res.records:
                if inst.cls.is_anomaly:
                    found.append(inst)
                    if verbose:
                        print(f"  anomaly #{len(found)} at {inst.point} "
                              f"ts={inst.cls.time_score:.1%} "
                              f"fs={inst.cls.flop_score:.1%}")
    finally:
        if executor is not None:
            executor.shutdown()
    return Experiment1Result(spec.name, samples, found, wall)


@dataclasses.dataclass
class Experiment2Result:
    spec_name: str
    scans: List[RegionScan]
    # All classified points, reusable by Experiment 3:
    classified: Dict[Tuple[int, ...], Instance]


def experiment2_regions(
    spec: ExpressionSpec,
    runner: Optional[BlasRunner] = None,
    anomalies: Sequence[Instance] = (),
    box: Tuple[int, int] = (20, 1200),
    step: int = 10,
    threshold: float = 0.05,
    atlas: Optional[AnomalyAtlas] = None,
    exec_backend: Optional[str] = None,
) -> Experiment2Result:
    """Paper §3.4.2: intersect regions with axis-aligned lines.

    Line traversal is inherently sequential (each probe decides the next),
    so this harness probes point by point with the engine's measurement
    primitive; with an ``atlas`` every probe is served from / buffered
    into it (chunk-flushed by the atlas, once more on return), so repeat
    traversals resume. ``exec_backend`` names the registry backend to
    probe with when no ``runner`` is given.
    """
    if runner is None:
        # one instance (one flush buffer) for every probe
        runner = make_backend(exec_backend) if exec_backend \
            else BlasRunner()
    classified: Dict[Tuple[int, ...], Instance] = {}

    def classify_at(point: Tuple[int, ...]) -> Classification:
        if point not in classified:
            hit = atlas.get(point) if atlas is not None else None
            if hit is None:
                hit = measure_instance(spec, point, runner, threshold)
                if atlas is not None:
                    atlas.append(hit)  # buffered: fsync per chunk, not probe
            classified[point] = hit
        return classified[point].cls

    scans: List[RegionScan] = []
    try:
        for inst in anomalies:
            for dim in range(spec.ndims):
                scans.append(scan_line(
                    classify_at, inst.point, dim, box[0], box[1],
                    step=step))
    finally:
        if atlas is not None:
            atlas.flush()
    return Experiment2Result(spec.name, scans, classified)


@dataclasses.dataclass
class Experiment3Result:
    spec_name: str
    confusion: ConfusionMatrix
    profile: TableProfile
    n_calls_measured: int = 0
    n_calls_reused: int = 0


def experiment3_predict_from_benchmarks(
    spec: ExpressionSpec,
    runner,
    classified: Dict[Tuple[int, ...], Instance],
    threshold: float = 0.05,
    peak_flops: float = 1e11,
    profile: Optional[TableProfile] = None,
) -> Experiment3Result:
    """Paper §3.4.3: benchmark each distinct kernel call in isolation, then
    predict each instance's fastest/cheapest sets from the additive model and
    compare against measured ground truth.

    ``runner`` is an execution-backend instance, or a registry name
    (``"blas"``/``"jax"``/…) resolved through the backend registry — the
    prediction pipeline is backend-generic.

    The distinct-call set is collected across *all* instances up front and
    deduplicated (:func:`~repro.core.sweep.benchmark_unique_calls`), so
    each (kind, dims) is timed at most once per machine. Pass a persisted
    ``profile`` (see :mod:`repro.core.profile_store`) to reuse prior
    calibrations: only calls it lacks are measured, and the entries added
    here flow back to the caller through the result.

    Scoring is a thin configuration of the discriminant scoreboard
    (:func:`repro.core.evaluate.evaluate_discriminants`): the experiment
    *is* "evaluate the ``perfmodel`` discriminant, armed with the benched
    table, against measured ground truth" — the confusion matrix returned
    here is that evaluation's, so the paper harness and ``--mode
    evaluate`` can never disagree about what recall/precision mean.
    """
    from .evaluate import evaluate_discriminants

    if isinstance(runner, str):
        runner = make_backend(runner)
    if profile is None:
        profile = TableProfile(peak_flops=peak_flops)

    # 1. Benchmark the deduplicated call set (batched; reuses the cache).
    calls = collect_unique_calls(spec, classified)
    profile, n_meas, n_reused = benchmark_unique_calls(
        runner, calls, profile=profile)

    # 2. Score the additive model through the shared evaluation path.
    res = evaluate_discriminants(
        spec, list(classified.values()), ["perfmodel"],
        profile=profile, threshold=threshold, dtype_bytes=8)
    cm = res.scores["perfmodel"].confusion

    return Experiment3Result(spec.name, cm, profile,
                             n_calls_measured=n_meas,
                             n_calls_reused=n_reused)
