"""The paper's three experiments (§3.4), as reusable harnesses.

* Experiment 1 — random search over an instance box: abundance + severity.
* Experiment 2 — axis-aligned line traversal around found anomalies: region
  thickness per dimension.
* Experiment 3 — predict anomalies from *isolated* kernel benchmarks
  (additive model), confusion matrix vs measured ground truth.

Each harness takes an ``ExpressionSpec`` (how to build the chain for an
instance tuple) and a :class:`~repro.core.runners.BlasRunner`, so the same
code reproduces both paper expressions and extends to new ones.

Scaled-down defaults: the paper used boxes up to 1200 with 10–23k samples on
a 10-core Xeon with MKL; the benchmarks here default to smaller boxes and
sample counts to finish in CI time, with flags to run the full study.
"""

from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .algorithms import Algorithm, enumerate_algorithms
from .anomaly import Classification, ConfusionMatrix, RegionScan, classify, scan_line
from .expr import Chain, gram_times, matrix_chain
from .perfmodel import TableProfile, predict_algorithm_time
from .runners import BlasRunner


@dataclasses.dataclass(frozen=True)
class ExpressionSpec:
    """A family of instances: tuple of dims -> Chain."""

    name: str
    ndims: int
    build: Callable[[Sequence[int]], Chain]

    def algorithms(self, point: Sequence[int]) -> List[Algorithm]:
        return enumerate_algorithms(self.build(tuple(int(x) for x in point)))


MATRIX_CHAIN_ABCD = ExpressionSpec(
    name="ABCD", ndims=5, build=lambda d: matrix_chain(*d))

GRAM_AATB = ExpressionSpec(
    name="AATB", ndims=3, build=lambda d: gram_times(*d))


@dataclasses.dataclass
class Instance:
    point: Tuple[int, ...]
    times: Dict[str, float]
    flops: Dict[str, int]
    cls: Classification


def measure_instance(
    spec: ExpressionSpec,
    point: Sequence[int],
    runner: BlasRunner,
    threshold: float = 0.10,
) -> Instance:
    """Time every algorithm for one instance and classify it."""
    algos = spec.algorithms(point)
    times: Dict[str, float] = {}
    flops: Dict[str, int] = {}
    operands = runner.make_operands(algos[-1])  # leaves shared across algos
    for a in algos:
        # ensure operand dict covers this algorithm's leaves too
        for k, v in runner.make_operands(a).items():
            operands.setdefault(k, v)
        times[a.name] = runner.time_algorithm(a, operands)
        flops[a.name] = a.flops
    cls = classify(times, flops, threshold=threshold)
    return Instance(tuple(int(x) for x in point), times, flops, cls)


@dataclasses.dataclass
class Experiment1Result:
    spec_name: str
    samples: int
    anomalies: List[Instance]
    wall_s: float

    @property
    def abundance(self) -> float:
        return len(self.anomalies) / self.samples if self.samples else 0.0


def experiment1_random_search(
    spec: ExpressionSpec,
    runner: BlasRunner,
    box: Tuple[int, int] = (20, 1200),
    n_anomalies: int = 20,
    max_samples: int = 2000,
    threshold: float = 0.10,
    seed: int = 0,
    verbose: bool = False,
) -> Experiment1Result:
    """Paper §3.4.1: sample instances u.a.r. until n anomalies are found."""
    rng = np.random.default_rng(seed)
    found: List[Instance] = []
    t0 = _time.perf_counter()
    samples = 0
    while len(found) < n_anomalies and samples < max_samples:
        point = tuple(int(x) for x in
                      rng.integers(box[0], box[1] + 1, size=spec.ndims))
        inst = measure_instance(spec, point, runner, threshold)
        samples += 1
        if inst.cls.is_anomaly:
            found.append(inst)
            if verbose:
                print(f"  anomaly #{len(found)} at {point} "
                      f"ts={inst.cls.time_score:.1%} "
                      f"fs={inst.cls.flop_score:.1%}")
    return Experiment1Result(spec.name, samples, found,
                             _time.perf_counter() - t0)


@dataclasses.dataclass
class Experiment2Result:
    spec_name: str
    scans: List[RegionScan]
    # All classified points, reusable by Experiment 3:
    classified: Dict[Tuple[int, ...], Instance]


def experiment2_regions(
    spec: ExpressionSpec,
    runner: BlasRunner,
    anomalies: Sequence[Instance],
    box: Tuple[int, int] = (20, 1200),
    step: int = 10,
    threshold: float = 0.05,
) -> Experiment2Result:
    """Paper §3.4.2: intersect regions with axis-aligned lines."""
    classified: Dict[Tuple[int, ...], Instance] = {}

    def classify_at_factory(origin: Tuple[int, ...], dim: int):
        def classify_at(point: Tuple[int, ...]) -> Classification:
            if point not in classified:
                classified[point] = measure_instance(
                    spec, point, runner, threshold)
            return classified[point].cls
        return classify_at

    scans: List[RegionScan] = []
    for inst in anomalies:
        for dim in range(spec.ndims):
            scans.append(scan_line(
                classify_at_factory(inst.point, dim),
                inst.point, dim, box[0], box[1], step=step))
    return Experiment2Result(spec.name, scans, classified)


@dataclasses.dataclass
class Experiment3Result:
    spec_name: str
    confusion: ConfusionMatrix
    profile: TableProfile


def experiment3_predict_from_benchmarks(
    spec: ExpressionSpec,
    runner: BlasRunner,
    classified: Dict[Tuple[int, ...], Instance],
    threshold: float = 0.05,
    peak_flops: float = 1e11,
    profile: Optional[TableProfile] = None,
) -> Experiment3Result:
    """Paper §3.4.3: benchmark each distinct kernel call in isolation, then
    predict each instance's fastest/cheapest sets from the additive model and
    compare against measured ground truth.

    Pass a persisted ``profile`` (see :mod:`repro.core.profile_store`) to
    reuse prior calibrations: only calls it lacks are measured, and the
    entries added here flow back to the caller through the result."""
    if profile is None:
        profile = TableProfile(peak_flops=peak_flops)
    cm = ConfusionMatrix()

    # 1. Collect + benchmark every distinct call across all instances.
    for point in classified:
        for a in spec.algorithms(point):
            for call in a.calls:
                if call not in profile:
                    profile.record(call, runner.benchmark_call(call))

    # 2. Predict per instance; compare with measured classification.
    for point, inst in classified.items():
        algos = spec.algorithms(point)
        pred_times = {a.name: predict_algorithm_time(a.calls, profile)
                      for a in algos}
        flops = {a.name: a.flops for a in algos}
        predicted = classify(pred_times, flops, threshold=threshold)
        actual = classify(inst.times, flops, threshold=threshold)
        cm.add(actual.is_anomaly, predicted.is_anomaly)

    return Experiment3Result(spec.name, cm, profile)
