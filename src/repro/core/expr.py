"""Expression IR for dense linear algebra expressions.

The paper (López, Karlsson, Bientinesi, ICPP'22) studies the Linear Algebra
Mapping Problem (LAMP): one expression, many mathematically equivalent
*algorithms* (sequences of kernel calls). This module gives the minimal
symbolic layer needed to describe the paper's expressions — matrix chains
``A·B·C·D`` and Gram products ``A·Aᵀ·B`` — with enough structure (symmetry
tags, transpose) for the enumeration layer to generate every algorithm the
paper considers.

Dims are either concrete ints or symbolic names (str); symbolic dims are what
makes runtime selection (the productized version of the paper) necessary:
when sizes are unknown at trace time the planner must be consulted per
instance.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple, Union

Dim = Union[int, str]


def _fmt_dim(d: Dim) -> str:
    return str(d)


@dataclasses.dataclass(frozen=True)
class Matrix:
    """A leaf operand: a dense matrix with (possibly symbolic) dims.

    ``symmetric`` marks operands known symmetric (enables SYMM).
    """

    name: str
    rows: Dim
    cols: Dim
    symmetric: bool = False

    def T(self) -> "Transpose":
        return Transpose(self)

    @property
    def shape(self) -> Tuple[Dim, Dim]:
        return (self.rows, self.cols)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        s = f"{self.name}[{_fmt_dim(self.rows)}x{_fmt_dim(self.cols)}]"
        return s + ("ˢ" if self.symmetric else "")


@dataclasses.dataclass(frozen=True)
class Transpose:
    """Transpose view of a leaf. Only leaves need transposition here."""

    operand: Matrix

    @property
    def rows(self) -> Dim:
        return self.operand.cols

    @property
    def cols(self) -> Dim:
        return self.operand.rows

    @property
    def shape(self) -> Tuple[Dim, Dim]:
        return (self.rows, self.cols)

    @property
    def symmetric(self) -> bool:
        return self.operand.symmetric

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.operand.name}ᵀ"


Operand = Union[Matrix, Transpose]


@dataclasses.dataclass(frozen=True)
class Chain:
    """A product of operands ``ops[0] @ ops[1] @ ... @ ops[-1]``.

    The *expression*; the set of algorithms evaluating it is produced by
    :mod:`repro.core.algorithms`.
    """

    ops: Tuple[Operand, ...]

    def __post_init__(self) -> None:
        if len(self.ops) < 2:
            raise ValueError("Chain needs at least two operands")
        for lhs, rhs in zip(self.ops, self.ops[1:]):
            # Symbolic dims compare by name; mismatch of concrete dims is an
            # immediate error, symbolic-vs-concrete is deferred to bind time.
            a, b = lhs.cols, rhs.rows
            if isinstance(a, int) and isinstance(b, int) and a != b:
                raise ValueError(f"dim mismatch: {lhs} @ {rhs}")

    @property
    def rows(self) -> Dim:
        return self.ops[0].rows

    @property
    def cols(self) -> Dim:
        return self.ops[-1].cols

    def dims(self) -> Tuple[Dim, ...]:
        """The n+1 boundary dims d0..dn of an n-operand chain."""
        ds = [self.ops[0].rows]
        for op in self.ops:
            ds.append(op.cols)
        return tuple(ds)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return " @ ".join(repr(o) for o in self.ops)


def chain(*ops: Operand) -> Chain:
    return Chain(tuple(ops))


def matrix_chain(*dims: Dim, prefix: str = "M") -> Chain:
    """Build the paper's matrix-chain expression from boundary dims d0..dn.

    ``matrix_chain(d0, d1, d2, d3, d4)`` is the paper's ``ABCD`` instance
    ``(d0, d1, d2, d3, d4)``.
    """
    if len(dims) < 3:
        raise ValueError("need at least 3 boundary dims (2 matrices)")
    names = [chr(ord("A") + i) for i in range(len(dims) - 1)]
    mats = [Matrix(n, r, c) for n, r, c in zip(names, dims[:-1], dims[1:])]
    return Chain(tuple(mats))


def gram_times(d0: Dim, d1: Dim, d2: Dim) -> Chain:
    """The paper's second expression ``A·Aᵀ·B`` with A: d0×d1, B: d0×d2."""
    A = Matrix("A", d0, d1)
    B = Matrix("B", d0, d2)
    return Chain((A, A.T(), B))


def gram_right_times(d0: Dim, d1: Dim, d2: Dim) -> Chain:
    """Right-sided Gram product ``A·Bᵀ·B`` with A: d0×d1, B: d2×d1.

    The mirrored companion of :func:`gram_times`: the SYRK-able pair
    ``Bᵀ·B`` sits on the *right*, so the symmetric intermediate flows into
    the chain as a right operand (exercising SYMM side R).
    """
    A = Matrix("A", d0, d1)
    B = Matrix("B", d2, d1)
    return Chain((A, B.T(), B))


def gram_left_times(d0: Dim, d1: Dim, d2: Dim) -> Chain:
    """Tall-skinny Gram chain ``Aᵀ·A·B`` with A: d0×d1, B: d1×d2.

    For d0 ≫ d1 this is the normal-equations shape: SYRK on ``Aᵀ·A``
    produces a triangle-stored d1×d1 intermediate whose storage choice
    (SYMM vs TRI2FULL+GEMM) propagates into the tail of the chain.
    """
    A = Matrix("A", d0, d1)
    B = Matrix("B", d1, d2)
    return Chain((A.T(), A, B))


def symmetric_sandwich(d0: Dim, d1: Dim) -> Chain:
    """Symmetric sandwich ``Bᵀ·S·B`` with S: d0×d0 symmetric, B: d0×d1.

    The congruence-transform shape (covariance projection, FEM assembly):
    the symmetric operand sits mid-chain, so SYMM fires on either side
    depending on the multiplication order.
    """
    S = Matrix("S", d0, d0, symmetric=True)
    B = Matrix("B", d0, d1)
    return Chain((B.T(), S, B))


def gram_of_product(d0: Dim, d1: Dim, d2: Dim) -> Chain:
    """Gram of a product ``(A·B)(A·B)ᵀ = A·B·Bᵀ·Aᵀ``, A: d0×d1, B: d1×d2.

    The stress case for enumeration: the SYRK-able pair is the
    *intermediate* ``(AB)(AB)ᵀ``, which leaf-adjacency inspection never
    sees — algorithm generation must recognize transpose-equal
    intermediates (see :mod:`repro.core.algorithms`).
    """
    A = Matrix("A", d0, d1)
    B = Matrix("B", d1, d2)
    return Chain((A, B, B.T(), A.T()))


def is_gram_pair(x: Operand, y: Operand) -> bool:
    """True iff ``x @ y`` is ``A @ Aᵀ`` (a SYRK-able product)."""
    return (
        isinstance(x, Matrix)
        and isinstance(y, Transpose)
        and y.operand is x
    ) or (
        isinstance(x, Transpose)
        and isinstance(y, Matrix)
        and x.operand is y
    )


def bind_dims(c: Chain, env: Dict[str, int]) -> Tuple[int, ...]:
    """Resolve a chain's boundary dims to concrete ints using ``env``."""
    out = []
    for d in c.dims():
        if isinstance(d, str):
            if d not in env:
                raise KeyError(f"unbound symbolic dim {d!r}")
            out.append(int(env[d]))
        else:
            out.append(int(d))
    return tuple(out)
