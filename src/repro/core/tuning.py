"""Tile/pipeline autotuning: search space, roofline pruning, persistence.

The paper closes by conjecturing that FLOP counts must be combined with
kernel performance models to pick optimal algorithms — but a performance
model is only as honest as the kernels it measures. Our Pallas backend
used to run every kernel at a hard-coded 128³ tile, so its profiles (and
its anomaly map) measured *our defaults*, not the hardware. Peise &
Bientinesi (arXiv 1209.2364) show kernel performance varies sharply with
blocking and must be measured, not assumed; Sankaran & Bientinesi
(arXiv 2209.03258) show a small measurement budget spent on the cheapest
candidates ranks reliably. This module is the search-space half of that
tuner (the measurement loop lives in :mod:`repro.kernels.autotune`):

* :func:`candidate_configs` — per-kernel-kind tile candidates
  (``bm``/``bn``/``bk``/``bl`` block edges over :data:`BLOCK_CHOICES`).
* :func:`prune_candidates` — the
  :class:`~repro.core.perfmodel.RooflineProfile`-driven pre-filter:
  candidates whose VMEM footprint exceeds the hardware budget
  (:func:`kernel_vmem_bytes`, ``chain_gemm_vmem_bytes``-style estimates)
  or whose roofline-modeled time — the arithmetic-intensity bound: padded
  MXU work vs. per-tiling HBM traffic — is more than ``slack×`` the best
  candidate's are rejected *before any timing is spent on them*.
* :class:`TuningTable` — the persisted winners, keyed ``(kind, dims)``
  with nearest-config fallback in log-dim space for unseen shapes, saved
  as versioned JSON under the same
  :class:`~repro.core.profile_store.HardwareFingerprint` scheme (and
  cache directory) as calibration profiles:
  ``<cache dir>/tuning-<backend>-<device>-<dtype>.json``.

``calibrate --tune --backend pallas`` writes the table;
:class:`~repro.core.backends.jax_backend.PallasBackend` auto-loads it.
Set ``REPRO_NO_TUNING=1`` to kill tuned-config lookup entirely (the
kernels fall back to their 128³ defaults).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .perfmodel import RooflineProfile
from .profile_store import (
    HardwareFingerprint,
    ProfileStoreError,
    FingerprintMismatchError,
    SchemaVersionError,
    cache_dir,
    current_fingerprint,
)

TUNING_SCHEMA_VERSION = 1

#: Env kill-switch: disables both TuningTable auto-load and tuned-config
#: lookup on the Pallas backend (kernels run at their built-in defaults).
ENV_NO_TUNING = "REPRO_NO_TUNING"

#: Block-edge candidates per tile axis. 128 is the MXU edge (the old
#: hard-coded default); larger powers of two trade VMEM residency for
#: fewer grid steps and less operand re-streaming.
BLOCK_CHOICES: Tuple[int, ...] = (128, 256, 512)

#: Per-kind default configs — the hard-coded tiles the kernels ship with.
#: The autotuner always times the default alongside the pruned survivors,
#: so a persisted winner is never slower than the default *as measured*.
DEFAULT_CONFIGS: Dict[str, Dict[str, int]] = {
    "gemm": {"bm": 128, "bn": 128, "bk": 128, "pipeline": 0},
    "syrk": {"bm": 128, "bk": 128},
    "symm": {"bm": 128, "bn": 128},
    "chain_gemm": {"bm": 128, "bn": 128, "bk": 128, "bl": 128},
    "gemm_syrk": {"bm": 128, "bk": 128},
}

#: Config keys each kernel wrapper accepts — lookups are sanitized
#: through this so a foreign/hand-edited table entry can never crash a
#: kernel call with an unexpected keyword.
ALLOWED_KEYS: Dict[str, Tuple[str, ...]] = {
    kind: tuple(cfg) for kind, cfg in DEFAULT_CONFIGS.items()
}

#: Kinds the tuner searches. ``tri2full`` is pure data movement with no
#: tile parameters — nothing to tune.
TUNABLE_KINDS: Tuple[str, ...] = tuple(DEFAULT_CONFIGS)


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def config_key(config: Dict[str, int]) -> Tuple[Tuple[str, int], ...]:
    """Hashable, order-independent identity of a candidate config."""
    return tuple(sorted(config.items()))


def candidate_configs(kind: str,
                      dims: Sequence[int]) -> List[Dict[str, int]]:
    """The tile search space for one ``(kind, dims)`` tuning request.

    Pure cross product of :data:`BLOCK_CHOICES` over the kind's tile
    axes; the roofline pre-filter (:func:`prune_candidates`) is what
    keeps this affordable. The gemm ``pipeline`` knob (Mosaic
    ``dimension_semantics`` grid annotation) is *not* enumerated here —
    it does not change the roofline model, so the measurement loop
    probes it on the winning tile only (see
    :func:`repro.kernels.autotune.autotune_request`).
    """
    if kind not in TUNABLE_KINDS:
        raise ValueError(
            f"kernel kind {kind!r} is not tunable; expected one of "
            f"{TUNABLE_KINDS}")
    c = BLOCK_CHOICES
    if kind == "gemm":
        return [{"bm": bm, "bn": bn, "bk": bk}
                for bm in c for bn in c for bk in c]
    if kind == "syrk":
        return [{"bm": bm, "bk": bk} for bm in c for bk in c]
    if kind == "symm":
        return [{"bm": bm, "bn": bn} for bm in c for bn in c]
    if kind == "chain_gemm":
        return [{"bm": bm, "bn": bn, "bk": bk, "bl": bl}
                for bm in c for bn in c for bk in c for bl in c]
    # gemm_syrk: the intermediate row-block and B stay fully VMEM-resident,
    # so only the output block edge and the K slab are free.
    return [{"bm": bm, "bk": bk} for bm in c for bk in c]


def padded_dims(kind: str, dims: Sequence[int],
                config: Dict[str, int]) -> Tuple[int, ...]:
    """Problem dims after the ``ops.*`` wrapper pads to block multiples.

    This is the work actually scheduled — the quantization the perf
    model charges for; a 129-row GEMM at ``bm=512`` pays for 512 rows.
    """
    d = dict(DEFAULT_CONFIGS[kind], **config)
    if kind == "gemm":
        m, n, k = dims
        return (_ceil_to(m, d["bm"]), _ceil_to(n, d["bn"]),
                _ceil_to(k, d["bk"]))
    if kind == "syrk":
        m, k = dims
        return (_ceil_to(m, d["bm"]), _ceil_to(k, d["bk"]))
    if kind == "symm":
        m, n = dims
        return (_ceil_to(m, d["bm"]), _ceil_to(n, d["bn"]))
    if kind == "chain_gemm":
        m, k, l, n = dims
        return (_ceil_to(m, d["bm"]), _ceil_to(k, d["bk"]),
                _ceil_to(l, d["bl"]), _ceil_to(n, d["bn"]))
    if kind == "gemm_syrk":
        m, k, l = dims
        return (_ceil_to(m, d["bm"]), _ceil_to(k, d["bk"]),
                _ceil_to(l, 128))
    raise ValueError(f"kernel kind {kind!r} is not tunable")


def kernel_vmem_bytes(kind: str, dims: Sequence[int],
                      config: Dict[str, int], *, dtype_bytes: int) -> int:
    """Estimated per-program VMEM residency of one candidate tiling.

    ``chain_gemm_vmem_bytes``-style accounting: streamed operand tiles
    are charged twice (Mosaic double-buffers the pipeline), fp32
    accumulator scratch is charged at 4 bytes regardless of the operand
    dtype. The chain kinds delegate to the estimators that live next to
    their kernels so the pre-filter and the wrapper fallback can never
    disagree.
    """
    d = dict(DEFAULT_CONFIGS[kind], **config)
    bm = d.get("bm", 128)
    if kind == "gemm":
        bn, bk = d["bn"], d["bk"]
        return 2 * (bm * bk + bk * bn + bm * bn) * dtype_bytes \
            + bm * bn * 4
    if kind == "syrk":
        bk = d["bk"]
        return 2 * (2 * bm * bk + bm * bm) * dtype_bytes + bm * bm * 4
    if kind == "symm":
        bn = d["bn"]
        return 2 * (bm * bm + 2 * bm * bn) * dtype_bytes + bm * bn * 4
    if kind == "chain_gemm":
        from repro.kernels.chain_gemm import chain_gemm_vmem_bytes
        mp, kp, lp, np_ = padded_dims(kind, dims, d)
        return chain_gemm_vmem_bytes(mp, kp, lp, np_, bm=bm, bn=d["bn"],
                                     dtype_bytes=dtype_bytes)
    if kind == "gemm_syrk":
        from repro.kernels.chain_gemm import gemm_syrk_vmem_bytes
        mp, kp, lp = padded_dims(kind, dims, d)
        return gemm_syrk_vmem_bytes(mp, kp, lp, bm=bm,
                                    dtype_bytes=dtype_bytes)
    raise ValueError(f"kernel kind {kind!r} is not tunable")


def padded_flops(kind: str, dims: Sequence[int],
                 config: Dict[str, int]) -> int:
    """MXU work actually scheduled under one tiling (block-quantized)."""
    if kind == "gemm":
        mp, np_, kp = padded_dims(kind, dims, config)
        return 2 * mp * np_ * kp
    if kind == "syrk":
        d = dict(DEFAULT_CONFIGS[kind], **config)
        mp, kp = padded_dims(kind, dims, config)
        mt = mp // d["bm"]
        return (mt * (mt + 1) // 2) * 2 * d["bm"] * d["bm"] * kp
    if kind == "symm":
        mp, np_ = padded_dims(kind, dims, config)
        return 2 * mp * mp * np_
    if kind == "chain_gemm":
        mp, kp, lp, np_ = padded_dims(kind, dims, config)
        return 2 * mp * kp * lp + 2 * mp * np_ * lp
    if kind == "gemm_syrk":
        d = dict(DEFAULT_CONFIGS[kind], **config)
        mp, kp, lp = padded_dims(kind, dims, config)
        mt = mp // d["bm"]
        t_blocks = mt * (mt + 1) // 2
        # Two intermediate row-blocks recomputed per output block + the
        # outer product itself — the fusion's recompute-vs-HBM trade.
        return t_blocks * (4 * d["bm"] * kp * lp + 2 * d["bm"] * d["bm"] * lp)
    raise ValueError(f"kernel kind {kind!r} is not tunable")


def traffic_elems(kind: str, dims: Sequence[int],
                  config: Dict[str, int]) -> int:
    """HBM traffic (elements) of one tiling: operand re-streaming + output.

    This is where tile size earns its keep: a GEMM A-panel is re-read
    once per N-block, so doubling ``bn`` halves A traffic — the
    arithmetic-intensity lever the pre-filter ranks candidates by.
    """
    d = dict(DEFAULT_CONFIGS[kind], **config)
    bm = d.get("bm", 128)
    if kind == "gemm":
        mp, np_, kp = padded_dims(kind, dims, d)
        return mp * kp * (np_ // d["bn"]) + kp * np_ * (mp // bm) + mp * np_
    if kind == "syrk":
        mp, kp = padded_dims(kind, dims, d)
        mt = mp // bm
        return (mt * (mt + 1) // 2) * 2 * bm * kp + mp * mp
    if kind == "symm":
        mp, np_ = padded_dims(kind, dims, d)
        mt, nt = mp // bm, np_ // d["bn"]
        return mp * mp * nt + mp * np_ * mt + mp * np_
    if kind == "chain_gemm":
        mp, kp, lp, np_ = padded_dims(kind, dims, d)
        mt, nt = mp // bm, np_ // d["bn"]
        return mp * kp * nt + kp * lp * mt * nt + lp * np_ * mt + mp * np_
    if kind == "gemm_syrk":
        mp, kp, lp = padded_dims(kind, dims, d)
        mt = mp // bm
        t_blocks = mt * (mt + 1) // 2
        return t_blocks * (2 * bm * kp + kp * lp) + mp * mp
    raise ValueError(f"kernel kind {kind!r} is not tunable")


def modeled_time(kind: str, dims: Sequence[int], config: Dict[str, int],
                 profile: RooflineProfile, *, dtype_bytes: int) -> float:
    """Roofline-modeled seconds for one candidate tiling."""
    return profile.raw_time(padded_flops(kind, dims, config),
                            traffic_elems(kind, dims, config),
                            dtype_bytes=dtype_bytes)


def arithmetic_intensity(kind: str, dims: Sequence[int],
                         config: Dict[str, int], *,
                         dtype_bytes: int) -> float:
    """FLOPs per HBM byte under one tiling (the roofline x-axis)."""
    return padded_flops(kind, dims, config) / max(
        1, traffic_elems(kind, dims, config) * dtype_bytes)


@dataclasses.dataclass(frozen=True)
class RejectedCandidate:
    """One pruned config and why it never reached the timer."""

    config: Dict[str, int]
    reason: str    # "vmem" | "padding" | "roofline"
    detail: str


@dataclasses.dataclass
class PruneReport:
    """What the pre-filter decided for one ``(kind, dims)`` request.

    ``survivors`` are ordered cheapest-modeled-first (the Sankaran
    measurement order) and always contain the kind's default config;
    ``modeled`` aligns with ``survivors``.
    """

    kind: str
    dims: Tuple[int, ...]
    survivors: List[Dict[str, int]]
    modeled: List[float]
    rejected: List[RejectedCandidate]


def prune_candidates(
    kind: str,
    dims: Sequence[int],
    candidates: Optional[Iterable[Dict[str, int]]] = None,
    profile: Optional[RooflineProfile] = None,
    *,
    dtype_bytes: int = 4,
    slack: float = 2.0,
    max_survivors: int = 8,
) -> PruneReport:
    """The roofline pre-filter: decide which candidates deserve timing.

    Three rejection rules, applied in order and all *before* any timing:

    1. **vmem** — :func:`kernel_vmem_bytes` above the hardware budget
       (``profile.hw.vmem_bytes``). Such a config would spill or fail to
       compile; timing it is a wasted measurement by construction.
    2. **padding** — a block edge strictly larger than the dim it tiles
       (after MXU-128 rounding). The extra work is pure zero-padding; the
       same-shape 128 block dominates it.
    3. **roofline** — modeled time (:func:`modeled_time`: block-quantized
       MXU work vs. tiling-dependent HBM traffic — the arithmetic-
       intensity bound) worse than ``slack ×`` the best candidate's.

    Survivors are sorted cheapest-modeled-first and capped at
    ``max_survivors`` — the measurement budget is spent on the
    candidates the model already likes, which Sankaran & Bientinesi show
    is enough to rank reliably. The kind's default config is always
    re-appended if the cap or the roofline rule dropped it, so the
    measured winner can never lose to the default silently.
    """
    profile = profile or RooflineProfile()
    dims = tuple(int(d) for d in dims)
    if candidates is None:
        candidates = candidate_configs(kind, dims)
    budget = profile.hw.vmem_bytes
    default = dict(DEFAULT_CONFIGS[kind])
    kept: List[Tuple[float, Tuple[Tuple[str, int], ...], Dict[str, int]]] = []
    rejected: List[RejectedCandidate] = []
    for cfg in candidates:
        need = kernel_vmem_bytes(kind, dims, cfg, dtype_bytes=dtype_bytes)
        if need > budget:
            rejected.append(RejectedCandidate(
                dict(cfg), "vmem",
                f"needs {need} B > budget {budget} B"))
            continue
        waste = _padding_waste(kind, dims, cfg)
        if waste is not None:
            rejected.append(RejectedCandidate(dict(cfg), "padding", waste))
            continue
        t = modeled_time(kind, dims, cfg, profile, dtype_bytes=dtype_bytes)
        kept.append((t, config_key(cfg), dict(cfg)))
    kept.sort(key=lambda e: (e[0], e[1]))
    survivors: List[Dict[str, int]] = []
    modeled: List[float] = []
    if kept:
        best = kept[0][0]
        for t, _, cfg in kept:
            if t > slack * best and not math.isclose(t, slack * best):
                rejected.append(RejectedCandidate(
                    cfg, "roofline",
                    f"modeled {t:.3g}s > {slack:g}x best {best:.3g}s"))
            elif len(survivors) < max_survivors:
                survivors.append(cfg)
                modeled.append(t)
            else:
                rejected.append(RejectedCandidate(
                    cfg, "roofline",
                    f"budget cap: {max_survivors} cheaper candidates"))
    if not any(_same_tiles(c, default) for c in survivors):
        # The default 128-edge tiles always fit VMEM and never over-pad;
        # only the roofline cap can have dropped them. Re-admit so the
        # winner is measured against the status quo.
        survivors.append(default)
        modeled.append(modeled_time(kind, dims, default, profile,
                                    dtype_bytes=dtype_bytes))
    return PruneReport(kind=kind, dims=dims, survivors=survivors,
                       modeled=modeled, rejected=rejected)


def _same_tiles(a: Dict[str, int], b: Dict[str, int]) -> bool:
    """Tile-axis equality, ignoring non-tile knobs like ``pipeline``."""
    keys = (set(a) | set(b)) - {"pipeline"}
    return all(a.get(k, 128) == b.get(k, 128) for k in keys)


def _padding_waste(kind: str, dims: Sequence[int],
                   config: Dict[str, int]) -> Optional[str]:
    """Reason string when a block edge exceeds its (128-rounded) dim."""
    d = dict(DEFAULT_CONFIGS[kind], **config)
    axes: Dict[str, Tuple[str, int]]
    if kind == "gemm":
        m, n, k = dims
        axes = {"bm": ("m", m), "bn": ("n", n), "bk": ("k", k)}
    elif kind == "syrk":
        m, k = dims
        axes = {"bm": ("m", m), "bk": ("k", k)}
    elif kind == "symm":
        m, n = dims
        axes = {"bm": ("m", m), "bn": ("n", n)}
    elif kind == "chain_gemm":
        m, k, l, n = dims
        axes = {"bm": ("m", m), "bk": ("k", k), "bl": ("l", l),
                "bn": ("n", n)}
    else:  # gemm_syrk
        m, k, _ = dims
        axes = {"bm": ("m", m), "bk": ("k", k)}
    for block_name, (dim_name, dim) in axes.items():
        blk = d[block_name]
        if blk > 128 and blk > _ceil_to(dim, 128):
            return (f"{block_name}={blk} > padded {dim_name}="
                    f"{_ceil_to(dim, 128)}: pure zero-padding")
    return None


# ------------------------------------------------------------ the table ---


@dataclasses.dataclass
class TunedEntry:
    """The persisted outcome of tuning one ``(kind, dims)`` request."""

    config: Dict[str, int]
    seconds: float          # measured time of the winning config
    default_seconds: float  # measured time of the default tiles
    timed: int              # candidates that reached the timer
    pruned: int             # candidates the pre-filter rejected


class TuningTable:
    """Winning tile configs per ``(kind, dims)``, with nearest fallback.

    The tuning analogue of :class:`~repro.core.perfmodel.TableProfile`:
    exact hits serve the calibrated shapes, and unseen shapes borrow the
    config of the nearest same-kind entry in log-dim space (tile
    preferences vary smoothly with aspect ratio, so the neighbour's
    blocking is a far better prior than the hard-coded default).
    """

    def __init__(self, entries: Optional[Dict[Tuple[str, Tuple[int, ...]],
                                              TunedEntry]] = None,
                 meta: Optional[dict] = None):
        self.entries: Dict[Tuple[str, Tuple[int, ...]], TunedEntry] = dict(
            entries or {})
        self.meta = dict(meta or {})

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key: Tuple[str, Tuple[int, ...]]) -> bool:
        return key in self.entries

    def set(self, kind: str, dims: Sequence[int],
            entry: TunedEntry) -> None:
        self.entries[(kind, tuple(int(d) for d in dims))] = entry

    def entry(self, kind: str, dims: Sequence[int]
              ) -> Optional[TunedEntry]:
        """Exact-match entry, or ``None``."""
        return self.entries.get((kind, tuple(int(d) for d in dims)))

    def config(self, kind: str, dims: Sequence[int]
               ) -> Optional[Dict[str, int]]:
        """Winning config for ``(kind, dims)`` — exact or nearest.

        Nearest = smallest squared log-dim distance among same-kind,
        same-arity entries (the :meth:`TableProfile.nearest` metric).
        Returns ``None`` when the table has no entry of this kind, so
        callers fall back to the kernel's built-in defaults.
        """
        dims = tuple(int(d) for d in dims)
        hit = self.entries.get((kind, dims))
        if hit is not None:
            return dict(hit.config)
        best: Optional[Tuple[float, Tuple[int, ...]]] = None
        for (ekind, edims), entry in self.entries.items():
            if ekind != kind or len(edims) != len(dims):
                continue
            dist = sum(
                (math.log(max(a, 2)) - math.log(max(b, 2))) ** 2
                for a, b in zip(dims, edims))
            if best is None or (dist, edims) < best:
                best = (dist, edims)
        if best is None:
            return None
        return dict(self.entries[(kind, best[1])].config)


# -------------------------------------------------------------- storage ---


def tuning_path(fingerprint: HardwareFingerprint,
                directory: Optional[Path] = None) -> Path:
    """Where this fingerprint's tuning table lives (profile cache dir)."""
    d = Path(directory) if directory is not None else cache_dir()
    return d / f"tuning-{fingerprint.slug()}.json"


def save_tuning_table(
    table: TuningTable,
    fingerprint: HardwareFingerprint,
    path: Optional[Path] = None,
    directory: Optional[Path] = None,
    meta: Optional[dict] = None,
) -> Path:
    """Write the table as versioned JSON (atomic tmp-file + rename)."""
    out = Path(path) if path is not None else tuning_path(fingerprint,
                                                          directory)
    out.parent.mkdir(parents=True, exist_ok=True)
    doc = {
        "version": TUNING_SCHEMA_VERSION,
        "fingerprint": fingerprint.to_dict(),
        "entries": [
            {"kind": kind, "dims": list(dims), "config": e.config,
             "seconds": e.seconds, "default_seconds": e.default_seconds,
             "timed": e.timed, "pruned": e.pruned}
            for (kind, dims), e in sorted(table.entries.items())
        ],
        "meta": {**table.meta, **(meta or {})},
    }
    tmp = out.with_suffix(
        f"{out.suffix}.{os.getpid()}.{os.urandom(4).hex()}.tmp")
    tmp.write_text(json.dumps(doc, indent=1, sort_keys=True))
    tmp.replace(out)
    return out


def load_tuning_table(
    path: Path,
    expected_fingerprint: Optional[HardwareFingerprint] = None,
) -> Tuple[TuningTable, HardwareFingerprint]:
    """Read a tuning table; reject schema/fingerprint mismatches loudly."""
    try:
        doc = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise ProfileStoreError(f"unreadable tuning table {path}: {e}") from e
    version = doc.get("version")
    if version != TUNING_SCHEMA_VERSION:
        raise SchemaVersionError(
            f"tuning table {path} has schema version {version!r}; "
            f"this build reads version {TUNING_SCHEMA_VERSION}")
    fp = HardwareFingerprint.from_dict(doc["fingerprint"])
    if expected_fingerprint is not None and fp != expected_fingerprint:
        raise FingerprintMismatchError(
            f"tuning table {path} was tuned for {fp}, "
            f"but this process targets {expected_fingerprint}")
    entries = {}
    for e in doc["entries"]:
        key = (str(e["kind"]), tuple(int(d) for d in e["dims"]))
        entries[key] = TunedEntry(
            config={str(k): int(v) for k, v in e["config"].items()},
            seconds=float(e["seconds"]),
            default_seconds=float(e.get("default_seconds", 0.0)),
            timed=int(e.get("timed", 0)),
            pruned=int(e.get("pruned", 0)))
    return TuningTable(entries=entries, meta=dict(doc.get("meta") or {})), fp


def load_default_tuning_table(
    backend: str = "pallas",
    dtype: str = "float32",
) -> Optional[TuningTable]:
    """Auto-load the cached tuning table matching this machine, if any.

    Mirrors :func:`~repro.core.profile_store.load_default_profile`:
    returns ``None`` (never raises) when tuning is killed via
    ``REPRO_NO_TUNING``, no table exists, or the cached one is
    unreadable/mismatched — the kernels then run at their defaults.
    """
    if os.environ.get(ENV_NO_TUNING):
        return None
    fp = current_fingerprint(backend=backend, dtype=dtype)
    path = tuning_path(fp)
    if not path.is_file():
        return None
    try:
        table, _ = load_tuning_table(path, expected_fingerprint=fp)
    except ProfileStoreError:
        return None
    return table
