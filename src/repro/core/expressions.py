"""The expression zoo: a registry of expression families + their grids.

The paper studies two expressions (``ABCD``, ``AAᵀB``) and finds that
anomaly abundance is *expression-dependent* — rare for the chain, abundant
for the Gram product. Stress-testing that conclusion needs many more
families (the LAMP survey, Psarras/Barthels/Bientinesi 2019, catalogues
them; Sankaran & Bientinesi 2022 argue discriminant testing needs many
expression instances). This module is the single place a family is
declared: an :class:`ExpressionSpec` registered here automatically flows
through enumeration, FLOP counting, the sweep CLI (``--expr``), the
anomaly atlas, calibration (``--expr``), and the benchmarks.

Registered families::

    abcd   A·B·C·D            paper §3.2.1 (6 algorithms)
    aatb   A·Aᵀ·B             paper §3.2.2 (5 algorithms)
    abcde  A·B·C·D·E          5-operand chain (4! = 24 orderings)
    abtb   A·Bᵀ·B             right-sided Gram (SYMM side R)
    btsb   Bᵀ·S·B             symmetric sandwich (SYMM either side)
    atab   Aᵀ·A·B             tall-skinny Gram, tri-storage propagation
    abab   (AB)(AB)ᵀ          Gram of a *product* (intermediate SYRK)

Serving families (the decode hot path, docs/serving.md)::

    decproj  X·W               decode projection GEMM (qkv / logits)
    decattn  P·V·Wo            attention value→output chain (2 orders)
    decmlp   X·Wup·Wdn         MLP up→down chain (2 orders)

Registering a new family (see docs/architecture.md)::

    def _build_myexpr(dims):          # module-level: pickles across pools
        return some_chain_builder(*dims)

    MY_EXPR = register(ExpressionSpec(
        name="MYEXPR", ndims=3, build=_build_myexpr,
        description="what the family is"), cli="myexpr")
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import os
import threading
from typing import Callable, Dict, Iterable, List, Mapping, Sequence, Tuple

from .algorithms import (VERIFY_ENUMERATION_ENV, Algorithm, chain_leaves,
                         enumerate_algorithms)
from .expr import (
    Chain,
    Matrix,
    gram_left_times,
    gram_of_product,
    gram_right_times,
    gram_times,
    matrix_chain,
    symmetric_sandwich,
)

# ------------------------------------------------------------------ grids ---

#: Named per-axis dim values; every axis of a grid uses the same values, so
#: an n-dim spec swept at grid g covers len(g)**n instances. Specs with
#: many dims override entries via ``ExpressionSpec.grids`` to keep named
#: sweeps tractable (see ``ABCDE``).
SWEEP_GRIDS: Dict[str, Tuple[int, ...]] = {
    "smoke": (32, 64),
    "small": (32, 64, 96, 128),
    "default": tuple(range(64, 513, 64)),
    "full": tuple(range(100, 1201, 100)),
}


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """A rectilinear grid of instances: one sorted value axis per dim."""

    name: str
    axes: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        for ax in self.axes:
            if list(ax) != sorted(set(int(v) for v in ax)):
                raise ValueError(f"grid axis must be sorted unique ints: {ax}")

    @classmethod
    def uniform(cls, values: Iterable[int], ndims: int,
                name: str = "custom") -> "GridSpec":
        vals = tuple(sorted(set(int(v) for v in values)))
        return cls(name=name, axes=(vals,) * ndims)

    @classmethod
    def named(cls, name: str, ndims: int) -> "GridSpec":
        if name not in SWEEP_GRIDS:
            raise ValueError(
                f"unknown grid {name!r}; expected {sorted(SWEEP_GRIDS)}")
        return cls.uniform(SWEEP_GRIDS[name], ndims, name=name)

    @property
    def ndims(self) -> int:
        return len(self.axes)

    @property
    def n_points(self) -> int:
        out = 1
        for ax in self.axes:
            out *= len(ax)
        return out

    def points(self) -> List[Tuple[int, ...]]:
        """All grid points in deterministic row-major order."""
        return [tuple(p) for p in itertools.product(*self.axes)]


# ------------------------------------------------------- expression specs ---

#: Bound on the enumeration LRU. 1024 point-entries comfortably covers the
#: default grids (6**3 = 216 points) times a handful of families in flight
#: while capping memory for million-instance adaptive campaigns.
ALGO_CACHE_MAX = 1024

_ALGO_CACHE: "collections.OrderedDict[Tuple, Tuple[Algorithm, ...]]" = (
    collections.OrderedDict())
_ALGO_CACHE_LOCK = threading.Lock()
_ALGO_CACHE_STATS = {"hits": 0, "misses": 0}


def algorithm_cache_stats() -> Tuple[int, int]:
    """(hits, misses) of the process-wide enumeration LRU."""
    with _ALGO_CACHE_LOCK:
        return (_ALGO_CACHE_STATS["hits"], _ALGO_CACHE_STATS["misses"])


def clear_algorithm_cache() -> None:
    """Drop all memoised enumerations (and reset the hit counters)."""
    with _ALGO_CACHE_LOCK:
        _ALGO_CACHE.clear()
        _ALGO_CACHE_STATS["hits"] = 0
        _ALGO_CACHE_STATS["misses"] = 0


@dataclasses.dataclass(frozen=True)
class ExpressionSpec:
    """A family of instances: tuple of ``ndims`` free dims -> Chain.

    ``build`` must be a module-level function (not a lambda/closure) so
    specs pickle across the process-pool backend. ``grids`` overrides
    named grids (``SWEEP_GRIDS``) for this family — high-``ndims`` specs
    trim axis values so ``len(values)**ndims`` stays tractable.
    """

    name: str
    ndims: int
    build: Callable[[Sequence[int]], Chain]
    description: str = ""
    grids: Mapping[str, Tuple[int, ...]] = dataclasses.field(
        default_factory=dict)

    def _check_point(self, point: Sequence[int]) -> Tuple[int, ...]:
        pt = tuple(int(x) for x in point)
        if len(pt) != self.ndims:
            raise ValueError(
                f"expression {self.name} takes {self.ndims} dims, got "
                f"{len(pt)}: {pt} — a mis-shaped grid would silently build "
                f"a different expression")
        return pt

    def chain(self, point: Sequence[int]) -> Chain:
        """The concrete Chain at one instance point (ndims-validated)."""
        return self.build(self._check_point(point))

    def algorithms(self, point: Sequence[int]) -> List[Algorithm]:
        """Enumerated algorithms at ``point``, served from a bounded LRU.

        ``measure_instance``, ``collect_unique_calls``,
        ``predict_classifications`` and the evaluate path all enumerate
        the same points; the cache makes re-enumeration free within and
        across those passes. Keyed by ``(name, build, point)`` — the
        spec itself is frozen-but-unhashable (its ``grids`` mapping), and
        ``build`` is a module-level function, so two registry lookups of
        the same family share entries. Bypassed entirely under
        ``REPRO_VERIFY_ENUMERATION``: callers opting into per-enumeration
        verification must not be served unverified cached results.
        """
        pt = self._check_point(point)
        if os.environ.get(VERIFY_ENUMERATION_ENV):
            return enumerate_algorithms(self.build(pt))
        key = (self.name, self.build, pt)
        with _ALGO_CACHE_LOCK:
            cached = _ALGO_CACHE.get(key)
            if cached is not None:
                _ALGO_CACHE.move_to_end(key)
                _ALGO_CACHE_STATS["hits"] += 1
                return list(cached)
        algos = enumerate_algorithms(self.build(pt))
        with _ALGO_CACHE_LOCK:
            _ALGO_CACHE_STATS["misses"] += 1
            _ALGO_CACHE[key] = tuple(algos)
            _ALGO_CACHE.move_to_end(key)
            while len(_ALGO_CACHE) > ALGO_CACHE_MAX:
                _ALGO_CACHE.popitem(last=False)
        return algos

    def verify(self, point: Sequence[int]):
        """Statically verify this family at ``point``; returns findings.

        Convenience front-end to
        :func:`repro.core.analysis.verify_family` (lazy import: analysis
        layers on top of this module). An empty list means every
        enumerated algorithm passed every analysis rule.
        """
        from .analysis import verify_family

        return verify_family(self, point)

    def grid(self, name: str) -> GridSpec:
        """Named grid for this family: per-spec override ∨ SWEEP_GRIDS."""
        values = self.grids.get(name) or SWEEP_GRIDS.get(name)
        if values is None:
            raise ValueError(
                f"unknown grid {name!r} for expression {self.name}; "
                f"expected one of {sorted(set(SWEEP_GRIDS) | set(self.grids))}")
        return GridSpec.uniform(values, self.ndims, name=name)

    def reference_value(self, point: Sequence[int],
                        operands: Mapping[int, object]):
        """Ground-truth product at ``point`` from base-indexed operands.

        ``operands`` maps leaf *base* index -> matrix (untransposed), the
        same contract as every runner's ``make_operands`` — this is the
        oracle the zoo's numerical correctness gate compares algorithms
        against.
        """
        import numpy as np

        c = self.chain(point)
        from .expr import bind_dims
        dims = bind_dims(c, {})
        out = None
        for leaf in chain_leaves(c, dims):
            a = np.asarray(operands[leaf.base])
            a = a.T if leaf.transposed else a
            out = a if out is None else out @ a
        return out


# --------------------------------------------------------------- registry ---

#: CLI-name -> spec. :func:`register` is the one way in; the sweep CLI,
#: calibration, experiments and benchmarks all iterate this mapping.
REGISTRY: Dict[str, ExpressionSpec] = {}


def register(spec: ExpressionSpec, cli: str) -> ExpressionSpec:
    """Add ``spec`` under CLI name ``cli``; returns the spec (decl style)."""
    key = cli.lower()
    if key in REGISTRY:
        raise ValueError(f"expression {key!r} is already registered")
    REGISTRY[key] = spec
    return spec


def get_spec(name: str) -> ExpressionSpec:
    """Resolve a CLI name (case-insensitive) to its spec."""
    try:
        return REGISTRY[name.lower()]
    except KeyError:
        raise KeyError(
            f"unknown expression {name!r}; registered: "
            f"{sorted(REGISTRY)}") from None


def registered_names() -> List[str]:
    return sorted(REGISTRY)


def find_spec(name: str) -> ExpressionSpec:
    """Resolve a CLI key *or* a spec's atlas label (``spec.name``).

    Atlas headers record ``spec.name`` (``"AATB"``), while the CLI speaks
    registry keys (``"aatb"``); replay tooling
    (:mod:`repro.core.evaluate`) accepts either spelling.
    """
    key = name.lower()
    if key in REGISTRY:
        return REGISTRY[key]
    for spec in REGISTRY.values():
        if spec.name.lower() == key:
            return spec
    raise KeyError(
        f"no registered expression matches {name!r} (by CLI key or spec "
        f"name); registered: {sorted(REGISTRY)}")


# ----------------------------------------------------- the shipped zoo ------
# Builders are module-level so specs pickle across the process pool.


def _build_abcd(dims: Sequence[int]) -> Chain:
    return matrix_chain(*dims)


def _build_aatb(dims: Sequence[int]) -> Chain:
    return gram_times(*dims)


def _build_decproj(dims: Sequence[int]) -> Chain:
    t, d, k = dims
    return Chain((Matrix("X", t, d), Matrix("W", d, k)))


def _build_decattn(dims: Sequence[int]) -> Chain:
    t, s, h, d = dims
    P = Matrix("P", t, s)
    V = Matrix("V", s, h)
    Wo = Matrix("Wo", h, d)
    return Chain((P, V, Wo))


def _build_decmlp(dims: Sequence[int]) -> Chain:
    t, d, f = dims
    X = Matrix("X", t, d)
    Wup = Matrix("Wu", d, f)
    Wdn = Matrix("Wd", f, d)
    return Chain((X, Wup, Wdn))


def _build_abcde(dims: Sequence[int]) -> Chain:
    return matrix_chain(*dims)


def _build_abtb(dims: Sequence[int]) -> Chain:
    return gram_right_times(*dims)


def _build_btsb(dims: Sequence[int]) -> Chain:
    return symmetric_sandwich(*dims)


def _build_atab(dims: Sequence[int]) -> Chain:
    return gram_left_times(*dims)


def _build_abab(dims: Sequence[int]) -> Chain:
    return gram_of_product(*dims)


MATRIX_CHAIN_ABCD = register(ExpressionSpec(
    name="ABCD", ndims=5, build=_build_abcd,
    description="paper §3.2.1 4-operand chain (d0..d4); 6 algorithms"),
    cli="abcd")

GRAM_AATB = register(ExpressionSpec(
    name="AATB", ndims=3, build=_build_aatb,
    description="paper §3.2.2 Gram product A·Aᵀ·B (A: d0×d1, B: d0×d2); "
                "5 algorithms"),
    cli="aatb")

MATRIX_CHAIN_ABCDE = register(ExpressionSpec(
    name="ABCDE", ndims=6, build=_build_abcde,
    description="5-operand chain (d0..d5); 4! = 24 orderings",
    # 6 free dims: trim named grids so len(values)**6 stays tractable.
    grids={"small": (32, 64, 96),
           "default": (64, 128, 256, 512),
           "full": (128, 256, 384, 512, 768, 1024)}),
    cli="abcde")

GRAM_ABTB = register(ExpressionSpec(
    name="ABTB", ndims=3, build=_build_abtb,
    description="right-sided Gram A·Bᵀ·B (A: d0×d1, B: d2×d1); SYRK + "
                "SYMM-from-the-right; 5 algorithms"),
    cli="abtb")

SANDWICH_BTSB = register(ExpressionSpec(
    name="BTSB", ndims=2, build=_build_btsb,
    description="symmetric sandwich Bᵀ·S·B (S: d0×d0 symmetric, B: d0×d1); "
                "SYMM on either side; 4 algorithms"),
    cli="btsb")

GRAM_ATAB = register(ExpressionSpec(
    name="ATAB", ndims=3, build=_build_atab,
    description="tall-skinny Gram chain Aᵀ·A·B (A: d0×d1, B: d1×d2); "
                "tri-storage propagation; 5 algorithms"),
    cli="atab")

GRAM_ABAB = register(ExpressionSpec(
    name="ABAB", ndims=3, build=_build_abab,
    description="Gram of a product (AB)(AB)ᵀ (A: d0×d1, B: d1×d2); "
                "intermediate-Gram SYRK; 13 algorithms"),
    cli="abab")

SERVE_DECPROJ = register(ExpressionSpec(
    name="DECPROJ", ndims=3, build=_build_decproj,
    description="serving projection GEMM X·W (X: d0×d1, W: d1×d2); the "
                "skinny decode regime where efficiency dwarfs FLOPs; "
                "1 algorithm"),
    cli="decproj")

SERVE_DECATTN = register(ExpressionSpec(
    name="DECATTN", ndims=4, build=_build_decattn,
    description="decode attention value→output chain P·V·Wo (P: d0×d1, "
                "V: d1×d2, Wo: d2×d3); 2 association orders",
    # 4 free dims: trim named grids so len(values)**4 stays tractable.
    grids={"small": (32, 64, 96),
           "default": (64, 128, 256, 512),
           "full": (128, 256, 512, 1024)}),
    cli="decattn")

SERVE_DECMLP = register(ExpressionSpec(
    name="DECMLP", ndims=3, build=_build_decmlp,
    description="decode MLP chain X·Wup·Wdn (X: d0×d1, Wup: d1×d2, "
                "Wdn: d2×d1); 2 association orders"),
    cli="decmlp")

#: Back-compat alias: the pre-registry name for the CLI mapping.
SPECS = REGISTRY
