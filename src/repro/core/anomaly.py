"""Anomaly classification and severity scores (paper §3.3).

An instance is an *anomaly* when the set of FLOP-cheapest algorithms and the
set of fastest algorithms are disjoint — i.e. minimising FLOPs (the
Linnea/Julia/Armadillo strategy) picks a non-fastest algorithm — and the
time score exceeds a threshold (paper uses 10 % for Experiment 1, 5 % for
Experiments 2–3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Classification:
    """Per-instance verdict plus the paper's two severity scores.

    ``time_score`` is (T_cheapest − T_fastest) / T_cheapest ∈ [0, 1): the
    fraction of runtime lost by minimising FLOPs instead of time.

    ``flop_score`` is (F_fastest − F_cheapest) / F_fastest ∈ [0, 1): the
    fraction of FLOPs that buying the *fastest* algorithm costs extra.
    **Convention:** ``F_fastest`` is the FLOP count of the FLOP-cheapest
    member of the fastest set — when several algorithms tie for fastest
    (within ``rel_tol``), the score charges only the cheapest way of being
    fastest, so ties never inflate severity. Both scores are 0 whenever
    their denominator is 0.
    """

    is_anomaly: bool
    time_score: float   # (T_cheapest − T_fastest) / T_cheapest ∈ [0, 1)
    flop_score: float   # (F_fastest − F_cheapest) / F_fastest ∈ [0, 1)
    cheapest: Tuple[str, ...]
    fastest: Tuple[str, ...]


def classify(
    times: Dict[str, float],
    flops: Dict[str, int],
    threshold: float = 0.10,
    rel_tol: float = 1e-9,
) -> Classification:
    """Classify one instance given per-algorithm times and FLOP counts.

    ``times``/``flops`` are keyed by algorithm name. Ties in FLOPs (paper's
    Algs 1/2 and 3/4 for AAᵀB, 2/5 for ABCD) put multiple algorithms in the
    cheapest set; ties in time are resolved with ``rel_tol``.
    """
    if set(times) != set(flops):
        raise ValueError("times and flops must cover the same algorithms")
    f_min = min(flops.values())
    cheapest = tuple(sorted(a for a, f in flops.items() if f == f_min))
    t_min = min(times.values())
    fastest = tuple(sorted(
        a for a, t in times.items() if t <= t_min * (1 + rel_tol)))

    t_cheapest = min(times[a] for a in cheapest)
    time_score = max(0.0, (t_cheapest - t_min) / t_cheapest) \
        if t_cheapest > 0 else 0.0

    # F_fastest: FLOP count of the cheapest among the fastest algorithms.
    f_fastest = min(flops[a] for a in fastest)
    flop_score = max(0.0, (f_fastest - f_min) / f_fastest) \
        if f_fastest > 0 else 0.0

    disjoint = not (set(cheapest) & set(fastest))
    return Classification(
        is_anomaly=bool(disjoint and time_score > threshold),
        time_score=float(time_score),
        flop_score=float(flop_score),
        cheapest=cheapest,
        fastest=fastest,
    )


def pick_regret(times: Mapping[str, float], pick: str) -> float:
    """Relative time lost by choosing ``pick``: (T_pick − T_min) / T_min.

    The per-instance unit of the evaluation scoreboard
    (:mod:`repro.core.evaluate`): 0 when the pick is (tied-)fastest, 0.5
    when it costs 50 % more wall time than the fastest algorithm. Returns
    0 when the fastest time is 0 (degenerate clock resolution) — the same
    zero-denominator convention as the severity scores above.
    """
    t_min = min(times.values())
    if t_min <= 0:
        return 0.0
    return max(0.0, (float(times[pick]) - t_min) / t_min)


@dataclasses.dataclass
class RegionScan:
    """Result of traversing one axis-aligned line (paper Experiment 2)."""

    dim: int                     # which dimension was traversed
    origin: Tuple[int, ...]      # the seed anomaly instance
    points: List[Tuple[int, bool, float, float]]  # (coord, is_anom, ts, fs)
    lo: int                      # region boundary (inclusive) low coord
    hi: int                      # region boundary (inclusive) high coord

    @property
    def thickness(self) -> int:
        # Paper: b − a − 1 with a,b the first non-anomalous boundary points;
        # with inclusive anomalous endpoints lo/hi this is hi − lo + 1.
        return self.hi - self.lo + 1


def scan_line(
    classify_at,
    origin: Sequence[int],
    dim: int,
    lo_bound: int,
    hi_bound: int,
    step: int = 10,
    hole_tolerance: int = 2,
) -> RegionScan:
    """Traverse an axis-aligned line through an anomaly (paper §3.4.2).

    ``classify_at(point) -> Classification``. The traversal walks both
    directions from ``origin`` in ``step`` strides; 1–2 consecutive
    non-anomalies are holes; ≥3 mark the region boundary.
    """
    origin = tuple(int(x) for x in origin)
    points: Dict[int, Classification] = {}

    def probe(coord: int) -> Classification:
        if coord not in points:
            p = list(origin)
            p[dim] = coord
            points[coord] = classify_at(tuple(p))
        return points[coord]

    def walk(direction: int) -> int:
        """Return the last anomalous coordinate in this direction."""
        last_anom = origin[dim]
        misses = 0
        coord = origin[dim]
        while True:
            coord += direction * step
            if coord < lo_bound or coord > hi_bound:
                break
            c = probe(coord)
            if c.is_anomaly:
                last_anom = coord
                misses = 0
            else:
                misses += 1
                if misses > hole_tolerance:
                    break
        return last_anom

    probe(origin[dim])
    hi = walk(+1)
    lo = walk(-1)
    pts = sorted(
        (coord, c.is_anomaly, c.time_score, c.flop_score)
        for coord, c in points.items()
    )
    return RegionScan(dim=dim, origin=origin, points=pts, lo=lo, hi=hi)


@dataclasses.dataclass(frozen=True)
class Region:
    """One contiguous anomalous region of the problem-size grid.

    The paper's central empirical claim (§3.4.2) is that anomalies are not
    isolated points but "cluster into large contiguous regions"; a Region
    is one connected component of anomalous grid points (adjacency =
    neighbouring grid coordinates along exactly one axis), with severity
    summaries over its members.
    """

    points: Tuple[Tuple[int, ...], ...]     # sorted member instances
    lo: Tuple[int, ...]                     # bounding box, inclusive
    hi: Tuple[int, ...]
    mean_time_score: float
    max_time_score: float
    mean_flop_score: float
    max_flop_score: float

    @property
    def size(self) -> int:
        return len(self.points)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"Region(size={self.size}, bbox={self.lo}..{self.hi}, "
                f"ts_max={self.max_time_score:.1%})")


def cluster_regions(
    scores: Mapping[Tuple[int, ...], Tuple[float, float]],
    axes: Sequence[Sequence[int]],
) -> List[Region]:
    """Connected components of anomalous grid points (paper's regions).

    ``scores`` maps each *anomalous* point to its ``(time_score,
    flop_score)``; ``axes`` gives the full grid (one sorted value sequence
    per dimension), which defines adjacency: two points are neighbours when
    they agree on all axes but one, and differ by exactly one grid position
    on that axis (so irregular spacings still cluster correctly — adjacency
    is positional, not metric). A point off the grid (wrong dimensionality,
    or a coordinate value not on its axis) raises ``ValueError`` naming the
    point and the offending axis — adaptive refinement and atlas replay make
    this user-reachable, so the error must say which input is bad. Callers
    that legitimately mix off-grid records (e.g. random-search points
    sharing an atlas) filter first, like :func:`repro.core.sweep.cluster_sweep`.

    Returns regions sorted by size (largest first), ties broken by the
    smallest member point, so output is deterministic.
    """
    index = [
        {int(v): i for i, v in enumerate(ax)} for ax in axes
    ]
    coords = {}
    for p in scores:
        if len(p) != len(index):
            raise ValueError(
                f"point {p} has {len(p)} dims but the grid has "
                f"{len(index)} axes")
        c = []
        for d, v in enumerate(p):
            pos = index[d].get(int(v))
            if pos is None:
                raise ValueError(
                    f"point {p} is off-grid: value {v} is not on axis {d} "
                    f"(axis values: {tuple(axes[d])})")
            c.append(pos)
        coords[p] = tuple(c)
    by_coord = {c: p for p, c in coords.items()}

    seen = set()
    regions: List[Region] = []
    for start in sorted(scores):
        if start in seen:
            continue
        members: List[Tuple[int, ...]] = []
        stack = [start]
        seen.add(start)
        while stack:
            p = stack.pop()
            members.append(p)
            c = coords[p]
            for d in range(len(c)):
                for step in (-1, +1):
                    nb = c[:d] + (c[d] + step,) + c[d + 1:]
                    q = by_coord.get(nb)
                    if q is not None and q not in seen:
                        seen.add(q)
                        stack.append(q)
        members.sort()
        ts = [scores[p][0] for p in members]
        fs = [scores[p][1] for p in members]
        regions.append(Region(
            points=tuple(members),
            lo=tuple(min(p[d] for p in members) for d in range(len(start))),
            hi=tuple(max(p[d] for p in members) for d in range(len(start))),
            mean_time_score=sum(ts) / len(ts),
            max_time_score=max(ts),
            mean_flop_score=sum(fs) / len(fs),
            max_flop_score=max(fs),
        ))
    regions.sort(key=lambda r: (-r.size, r.points[0]))
    return regions


def region_summary(regions: Iterable[Region], n_points: int) -> str:
    """Human-readable digest of a clustering pass (CLI / benchmarks)."""
    regions = list(regions)
    n_anom = sum(r.size for r in regions)
    rate = n_anom / n_points if n_points else 0.0
    lines = [f"anomalies: {n_anom}/{n_points} ({rate:.1%}) in "
             f"{len(regions)} region(s)"]
    for i, r in enumerate(regions[:10]):
        lines.append(
            f"  region {i + 1}: size={r.size} bbox={r.lo}..{r.hi} "
            f"ts mean={r.mean_time_score:.1%} max={r.max_time_score:.1%}")
    if len(regions) > 10:
        lines.append(f"  ... {len(regions) - 10} more")
    return "\n".join(lines)


@dataclasses.dataclass
class ConfusionMatrix:
    """Experiment 3 output: measured (actual) vs profile-predicted."""

    tp: int = 0
    fp: int = 0
    fn: int = 0
    tn: int = 0

    def add(self, actual: bool, predicted: bool) -> None:
        if actual and predicted:
            self.tp += 1
        elif actual and not predicted:
            self.fn += 1
        elif not actual and predicted:
            self.fp += 1
        else:
            self.tn += 1

    @property
    def recall(self) -> float:   # paper: "92 % of anomalies predicted"
        d = self.tp + self.fn
        return self.tp / d if d else 0.0

    @property
    def precision(self) -> float:  # paper: "96 % of predicted were actual"
        d = self.tp + self.fp
        return self.tp / d if d else 0.0

    @property
    def total(self) -> int:
        return self.tp + self.fp + self.fn + self.tn

    def as_table(self) -> str:
        return (
            "            Predicted\n"
            "             No      Yes\n"
            f"Actual No   {self.tn:<8d}{self.fp:<8d}\n"
            f"       Yes  {self.fn:<8d}{self.tp:<8d}\n"
            f"recall={self.recall:.1%} precision={self.precision:.1%}"
        )
