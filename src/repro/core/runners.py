"""Algorithm executors + timers.

Two backends:

* :class:`BlasRunner` — executes algorithms through *actual BLAS* kernels
  (``scipy.linalg.blas`` dgemm/dsyrk/dsymm), matching the paper's
  methodology: double precision, median-of-k timing, cache flush between
  repetitions. This is what the paper-reproduction experiments
  (benchmarks/experiment*.py) measure.
* :class:`JaxRunner` — builds a jit-able JAX callable for an algorithm, used
  where the planner is embedded in model code (Muon, SSD). On TPU the gemm/
  syrk/symm steps lower to the Pallas kernels in :mod:`repro.kernels`.

The executor walks :class:`~repro.core.algorithms.Algorithm` steps; operand
leaves reference the chain's input matrices, transposition handled at leaf
fetch (BLAS ``trans`` flags / ``jnp.swapaxes``).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from .algorithms import Algorithm, Leaf, Step
from .flops import KernelCall

try:  # scipy is available in this container; keep import soft for docs envs
    from scipy.linalg import blas as _blas
except Exception:  # pragma: no cover
    _blas = None


# ------------------------------------------------------------------ BLAS ---

_FLUSH_BYTES = 64 * 1024 * 1024  # > L3 on the container host


class CacheFlusher:
    """Paper §3.4: flush the cache prior to each repetition."""

    def __init__(self, nbytes: int = _FLUSH_BYTES):
        self._buf = np.zeros(nbytes // 8, dtype=np.float64)

    def flush(self) -> None:
        # Touch every cache line; the sum defeats dead-code elimination.
        self._buf += 1.0
        _ = float(self._buf[:: 4096].sum())


def _blas_step(step: Step, fetch: Callable[[object], np.ndarray]) -> np.ndarray:
    """Execute one kernel call with scipy BLAS (float64, Fortran order)."""
    call = step.call
    if call.kind == "gemm":
        a = fetch(step.lhs)
        b = fetch(step.rhs)
        return _blas.dgemm(1.0, a, b)
    if call.kind == "syrk":
        a = fetch(step.lhs)
        # dsyrk computes one triangle of a·aᵀ (lower, given lower=1).
        return _blas.dsyrk(1.0, a, lower=1)
    if call.kind == "symm":
        # The symmetric operand (read as its lower triangle) is lhs for
        # side L and rhs for side R; dsymm(side=1) computes b·s.
        if step.symm_side == "R":
            s = fetch(step.rhs)
            b = fetch(step.lhs)
            return _blas.dsymm(1.0, s, b, side=1, lower=1)
        s = fetch(step.lhs)
        b = fetch(step.rhs)
        return _blas.dsymm(1.0, s, b, side=0, lower=1)
    if call.kind == "tri2full":
        t = fetch(step.lhs)
        return np.asfortranarray(
            np.tril(t) + np.tril(t, -1).T
        )
    raise ValueError(call.kind)


# ----------------------------------------------------- numpy reference ------


def _mirror_lower(t: np.ndarray) -> np.ndarray:
    return np.tril(t) + np.tril(t, -1).T


def reference_execute(alg: Algorithm,
                      operands: Dict[int, np.ndarray]) -> np.ndarray:
    """Pure-numpy oracle executor for an algorithm's step sequence.

    Semantically identical to :meth:`BlasRunner.execute` but with no
    scipy dependency and no timing concerns — the numerical correctness
    gate every registered expression's algorithms are checked against
    (see tests/test_expressions.py). Honors triangle storage (SYRK output
    keeps only the lower triangle; SYMM/TRI2FULL read only the lower
    triangle of symmetric operands) and SYMM sides.
    """
    inter: Dict[int, np.ndarray] = {}

    def fetch(ref: object) -> np.ndarray:
        if isinstance(ref, Leaf):
            a = np.asarray(operands[ref.base])
            return a.T if ref.transposed else a
        return inter[ref]

    out = None
    for step in alg.steps:
        kind = step.call.kind
        if kind == "gemm":
            out = fetch(step.lhs) @ fetch(step.rhs)
        elif kind == "syrk":
            a = fetch(step.lhs)
            out = np.tril(a @ a.T)
        elif kind == "symm":
            if step.symm_side == "R":
                out = fetch(step.lhs) @ _mirror_lower(fetch(step.rhs))
            else:
                out = _mirror_lower(fetch(step.lhs)) @ fetch(step.rhs)
        elif kind == "tri2full":
            out = _mirror_lower(fetch(step.lhs))
        else:
            raise ValueError(kind)
        inter[step.out] = out
    return out


class BlasRunner:
    """Execute/time algorithms with real BLAS kernels (paper methodology)."""

    def __init__(self, reps: int = 10, flush_cache: bool = True,
                 rng: Optional[np.random.Generator] = None):
        if _blas is None:  # pragma: no cover
            raise RuntimeError("scipy BLAS unavailable")
        self.reps = reps
        self.flusher = CacheFlusher() if flush_cache else None
        self.rng = rng or np.random.default_rng(0)

    # -- operand synthesis ------------------------------------------------
    def make_operands(self, alg: Algorithm) -> Dict[int, np.ndarray]:
        """Fresh random inputs for every distinct leaf index of ``alg``.

        Leaves are stored untransposed; transposition applied at fetch.
        """
        ops: Dict[int, np.ndarray] = {}
        for step in alg.steps:
            for ref in (step.lhs, step.rhs):
                if isinstance(ref, Leaf) and ref.base not in ops:
                    # Underlying (untransposed) matrix shape.
                    r, c = (ref.cols, ref.rows) if ref.transposed else (
                        ref.rows, ref.cols)
                    a = self.rng.standard_normal((r, c))
                    if ref.symmetric:
                        # SYMM-based algorithms read only a triangle; a
                        # non-symmetric operand would make them disagree
                        # with the GEMM-based ones.
                        a = (a + a.T) / 2.0
                    ops[ref.base] = np.asfortranarray(a)
        return ops

    def _fetcher(self, operands: Dict[int, np.ndarray],
                 inter: Dict[int, np.ndarray]) -> Callable:
        def fetch(ref):
            if isinstance(ref, Leaf):
                a = operands[ref.base]
                return a.T if ref.transposed else a
            return inter[ref]
        return fetch

    def execute(self, alg: Algorithm,
                operands: Dict[int, np.ndarray]) -> np.ndarray:
        inter: Dict[int, np.ndarray] = {}
        out = None
        fetch = self._fetcher(operands, inter)
        for step in alg.steps:
            out = _blas_step(step, fetch)
            inter[step.out] = out
        return out

    def time_algorithm(self, alg: Algorithm,
                       operands: Optional[Dict[int, np.ndarray]] = None
                       ) -> float:
        """Median-of-reps wall time (paper §3.4), cache flushed per rep."""
        if operands is None:
            operands = self.make_operands(alg)
        # warm-up (library init, page faults)
        self.execute(alg, operands)
        ts: List[float] = []
        for _ in range(self.reps):
            if self.flusher:
                self.flusher.flush()
            t0 = time.perf_counter()
            self.execute(alg, operands)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    # -- Experiment 3: isolated kernel benchmarks -------------------------
    def benchmark_call(self, call: KernelCall,
                       reps: Optional[int] = None) -> float:
        """Time one kernel call in isolation with a flushed cache.

        ``reps`` overrides the runner default for this call (the
        calibration sweep passes it through explicitly).
        """
        reps = self.reps if reps is None else reps
        rng = self.rng
        if call.kind == "gemm":
            m, n, k = call.dims
            a = np.asfortranarray(rng.standard_normal((m, k)))
            b = np.asfortranarray(rng.standard_normal((k, n)))

            def fn():
                return _blas.dgemm(1.0, a, b)
        elif call.kind == "syrk":
            m, k = call.dims
            a = np.asfortranarray(rng.standard_normal((m, k)))

            def fn():
                return _blas.dsyrk(1.0, a, lower=1)
        elif call.kind == "symm":
            m, n = call.dims
            s = np.asfortranarray(rng.standard_normal((m, m)))
            s = np.asfortranarray(s + s.T)
            b = np.asfortranarray(rng.standard_normal((m, n)))

            def fn():
                return _blas.dsymm(1.0, s, b, side=0, lower=1)
        elif call.kind == "tri2full":
            (m,) = call.dims
            t = np.asfortranarray(np.tril(rng.standard_normal((m, m))))

            def fn():
                return np.asfortranarray(np.tril(t) + np.tril(t, -1).T)
        else:
            raise ValueError(call.kind)
        fn()  # warm-up
        ts = []
        for _ in range(reps):
            if self.flusher:
                self.flusher.flush()
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))


# ------------------------------------------------------------------- JAX ---


class JaxRunner:
    """Build a jit-able callable for an Algorithm.

    ``use_pallas=True`` routes gemm/syrk/symm through the Pallas TPU kernels
    (interpret mode on CPU); otherwise pure jnp — the two must agree, which
    tests/test_kernels.py asserts.

    ``device`` pins every operand this runner synthesizes (and therefore
    the computation, which follows its inputs) to one JAX device — the
    sweep engine constructs one runner per device to shard a grid across
    all of them. ``None`` leaves placement to JAX's default.
    """

    def __init__(self, use_pallas: bool = False, device=None,
                 reps: int = 3, dtype: str = "float32",
                 rng: Optional[np.random.Generator] = None):
        self.use_pallas = use_pallas
        self.device = device
        self.reps = reps
        self.dtype = dtype
        self.rng = rng or np.random.default_rng(0)

    def build(self, alg: Algorithm) -> Callable:
        import jax.numpy as jnp

        if self.use_pallas:
            from repro.kernels import ops as kops

        use_pallas = self.use_pallas

        def mirror(t):
            return jnp.tril(t) + jnp.swapaxes(jnp.tril(t, -1), -1, -2)

        def fn(*inputs):
            inter: Dict[int, object] = {}

            def fetch(ref):
                if isinstance(ref, Leaf):
                    a = inputs[ref.base]
                    return jnp.swapaxes(a, -1, -2) if ref.transposed else a
                return inter[ref]

            out = None
            for step in alg.steps:
                c = step.call
                if c.kind == "gemm":
                    a, b = fetch(step.lhs), fetch(step.rhs)
                    out = (kops.gemm(a, b) if use_pallas else a @ b)
                elif c.kind == "syrk":
                    a = fetch(step.lhs)
                    out = (kops.syrk(a) if use_pallas
                           else jnp.tril(a @ jnp.swapaxes(a, -1, -2)))
                elif c.kind == "symm":
                    if step.symm_side == "R":
                        # B·S with S symmetric: (S·Bᵀ)ᵀ via the side-L
                        # kernel, or mirror-and-matmul in plain jnp.
                        b, s = fetch(step.lhs), fetch(step.rhs)
                        if use_pallas:
                            out = jnp.swapaxes(
                                kops.symm(s, jnp.swapaxes(b, -1, -2)),
                                -1, -2)
                        else:
                            out = b @ mirror(s)
                    else:
                        s, b = fetch(step.lhs), fetch(step.rhs)
                        if use_pallas:
                            out = kops.symm(s, b)
                        else:
                            out = mirror(s) @ b
                elif c.kind == "tri2full":
                    out = mirror(fetch(step.lhs))
                else:
                    raise ValueError(c.kind)
                inter[step.out] = out
            return out

        return fn

    def num_inputs(self, alg: Algorithm) -> int:
        mx = -1
        for step in alg.steps:
            for ref in (step.lhs, step.rhs):
                if isinstance(ref, Leaf):
                    mx = max(mx, ref.index)
        return mx + 1

    # -- measure interface (mirrors BlasRunner) ----------------------------
    def make_operands(self, alg: Algorithm) -> Dict[int, object]:
        """Device-resident random inputs keyed by leaf *base* index.

        Same contract as :meth:`BlasRunner.make_operands`, so
        ``measure_instance``/the sweep engine treat both runners uniformly.
        """
        import jax
        import jax.numpy as jnp

        ops: Dict[int, object] = {}
        for step in alg.steps:
            for ref in (step.lhs, step.rhs):
                if isinstance(ref, Leaf) and ref.base not in ops:
                    r, c = (ref.cols, ref.rows) if ref.transposed else (
                        ref.rows, ref.cols)
                    arr = self.rng.standard_normal((r, c))
                    if ref.symmetric:
                        # symmetric leaves must be symmetric (SYMM reads
                        # only a triangle); mirrors BlasRunner.
                        arr = (arr + arr.T) / 2.0
                    a = jnp.asarray(arr, dtype=self.dtype)
                    if self.device is not None:
                        a = jax.device_put(a, self.device)
                    ops[ref.base] = a
        return ops

    def time_algorithm(self, alg: Algorithm,
                       operands: Optional[Dict[int, object]] = None
                       ) -> float:
        """Median-of-reps wall seconds, jitted and blocked on completion.

        Compile time is excluded by the warm-up call; blocking defeats
        async dispatch under-reporting. There is no cache flush here — on
        the JAX backend operands live in HBM and the measured quantity is
        steady-state device time, not the paper's cold-cache CPU protocol.
        """
        import jax

        if operands is None:
            operands = self.make_operands(alg)
        n = self.num_inputs(alg)
        some = next(iter(operands.values()))
        # fetch only ever reads base positions; fill the rest with any array
        args = [operands.get(i, some) for i in range(n)]
        fn = jax.jit(self.build(alg))
        jax.block_until_ready(fn(*args))  # warm-up: compile + page-in
        ts: List[float] = []
        for _ in range(self.reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    # -- calibration: isolated kernel benchmarks --------------------------
    def benchmark_call(self, call: KernelCall, reps: int = 5,
                       dtype: str = "float32",
                       seed: int = 0) -> float:
        """Median wall seconds for one kernel call on the JAX backend.

        Mirrors :meth:`BlasRunner.benchmark_call` so the calibration sweep
        (:mod:`repro.core.calibrate`) treats the two backends uniformly.
        Dispatch is jitted and the result blocked on, so compile time is
        excluded (warm-up) and async dispatch doesn't under-report.
        """
        import jax
        import jax.numpy as jnp

        rng = np.random.default_rng(seed)

        def arr(*shape):
            a = jnp.asarray(rng.standard_normal(shape), dtype=dtype)
            if a.dtype != jnp.dtype(dtype):
                # e.g. float64 requested with jax_enable_x64 off: JAX
                # silently downcasts, which would stamp a fingerprint the
                # measurements don't match.
                raise ValueError(
                    f"jax produced dtype {a.dtype} for requested {dtype!r} "
                    f"(for float64, enable jax_enable_x64)")
            return a

        if call.kind == "gemm":
            m, n, k = call.dims
            args = (arr(m, k), arr(k, n))
            op = jax.jit(lambda a, b: a @ b)
        elif call.kind == "syrk":
            m, k = call.dims
            args = (arr(m, k),)
            op = jax.jit(lambda a: jnp.tril(a @ jnp.swapaxes(a, -1, -2)))
        elif call.kind == "symm":
            m, n = call.dims
            s = arr(m, m)
            args = (s + jnp.swapaxes(s, -1, -2), arr(m, n))
            op = jax.jit(lambda s, b: s @ b)
        elif call.kind == "tri2full":
            (m,) = call.dims
            args = (jnp.tril(arr(m, m)),)
            op = jax.jit(lambda t: jnp.tril(t) + jnp.swapaxes(
                jnp.tril(t, -1), -1, -2))
        else:
            raise ValueError(call.kind)
        jax.block_until_ready(op(*args))  # warm-up: compile + page-in
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(op(*args))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))


def measure_seconds(fn: Callable, *args) -> tuple:
    """Run ``fn(*args)``, blocking on JAX async dispatch; (result, secs).

    Used by the planner's online refinement so the recorded time reflects
    device completion rather than dispatch-queue insertion. Deferred
    device errors surfaced by the block propagate — recording the
    dispatch-only time of a failed computation would poison the profile.
    """
    try:
        import jax
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        jax = None
    t0 = time.perf_counter()
    out = fn(*args)
    if jax is not None:
        jax.block_until_ready(out)  # no-op for non-JAX leaves
    return out, time.perf_counter() - t0
