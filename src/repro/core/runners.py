"""Back-compat runner names over :mod:`repro.core.backends`.

The executors live in the backend registry now (ISSUE 4): one generic
step walker plus per-backend kernel ops in ``repro.core.backends``,
resolved by name via ``get_backend``. This module keeps the pre-registry
import surface alive:

* :class:`BlasRunner`  — alias of :class:`~repro.core.backends.BlasBackend`
  (the ``blas`` registry entry).
* :class:`JaxRunner`   — :class:`~repro.core.backends.JaxBackend` with the
  legacy constructor order (``use_pallas`` first); ``use_pallas=True``
  behaves as the ``pallas`` registry entry.
* :func:`reference_execute` — the numpy oracle (``numpy`` entry).
* :func:`measure_seconds`, :class:`CacheFlusher` — re-exports.

New code should resolve executors through the registry instead::

    from repro.core.backends import get_backend
    runner = get_backend("pallas", reps=3)
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .backends import (  # noqa: F401  (re-exported back-compat surface)
    CacheFlusher,
    JaxBackend,
    measure_seconds,
    reference_execute,
)
from .backends import BlasBackend as BlasRunner  # noqa: F401


class JaxRunner(JaxBackend):
    """Legacy constructor order for the jax/pallas backends.

    ``JaxRunner(use_pallas=True)`` is the ``pallas`` registry entry's
    behaviour; prefer ``get_backend("pallas")`` in new code.
    """

    def __init__(self, use_pallas: bool = False, device=None, reps: int = 3,
                 dtype: str = "float32",
                 rng: Optional[np.random.Generator] = None):
        super().__init__(device=device, reps=reps, dtype=dtype, rng=rng,
                         use_pallas=use_pallas)


__all__ = [
    "BlasRunner", "JaxRunner", "CacheFlusher", "measure_seconds",
    "reference_execute",
]
