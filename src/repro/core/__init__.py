"""repro.core — the paper's contribution: the LAMP planner.

*FLOPs as a Discriminant for Dense Linear Algebra Algorithms*
(López, Karlsson, Bientinesi — ICPP '22) productized:

expression IR → algorithm enumeration → {flops | perfmodel | measured}
discriminant → executable plan, plus the paper's anomaly-study harnesses
(Experiments 1–3).
"""

from .algorithms import (
    Algorithm,
    enumerate_algorithms,
    optimal_chain_order,
)
from .backends import (
    ExecutionBackend,
    get_backend,
    make_backend,
    register_backend,
    registered_backends,
)
from .anomaly import (
    Classification,
    ConfusionMatrix,
    Region,
    classify,
    cluster_regions,
    scan_line,
)
from .expr import (
    Chain,
    Matrix,
    Transpose,
    chain,
    gram_left_times,
    gram_of_product,
    gram_right_times,
    gram_times,
    matrix_chain,
    symmetric_sandwich,
)
from .expressions import (
    GRAM_ABAB,
    GRAM_ABTB,
    GRAM_ATAB,
    MATRIX_CHAIN_ABCDE,
    REGISTRY,
    SANDWICH_BTSB,
    ExpressionSpec,
    GridSpec,
    get_spec,
    register,
    registered_names,
)
from .flops import KernelCall, gemm, kernel_flops, symm, syrk, total_flops, tri2full
from .perfmodel import (
    TPU_V5E,
    AnalyticalTPUProfile,
    HardwareSpec,
    HybridProfile,
    KernelProfile,
    RooflineProfile,
    TableProfile,
    predict_algorithm_time,
)
from .planner import (
    Plan,
    Planner,
    default_planner,
    plan,
    reset_default_planner,
    resolve_profile,
)
from .profile_store import (
    FingerprintMismatchError,
    HardwareFingerprint,
    ProfileStoreError,
    current_fingerprint,
    load_default_profile,
    load_profile,
    profile_path,
    save_profile,
)
from .discriminants import (
    Discriminant,
    DiscriminantContext,
    get_discriminant,
    register_discriminant,
    registered_discriminants,
)
from .runners import BlasRunner, JaxRunner, measure_seconds, reference_execute
from .selector import as_hybrid, select, select_expression

# Lazy (PEP 562) so `python -m repro.core.calibrate` / `python -m
# repro.core.sweep` don't import their CLI modules twice (runpy warns when
# the target is already in sys.modules). NB `repro.core.calibrate` /
# `repro.core.sweep` name the *submodules* (like os.path); the entry-point
# functions are `repro.core.calibrate.calibrate` / `repro.core.sweep.sweep`.
_LAZY_EXPORTS = {
    "GRIDS": ".calibrate",
    "CalibrationResult": ".calibrate",
    "TuneResult": ".calibrate",
    "expression_calls": ".calibrate",
    "sweep_kernels": ".calibrate",
    # autotuning (tuning.py lazily imports kernel VMEM estimators; lazy
    # here keeps the perfmodel/profile_store chain out of base import)
    "TunedEntry": ".tuning",
    "TuningTable": ".tuning",
    "candidate_configs": ".tuning",
    "load_default_tuning_table": ".tuning",
    "load_tuning_table": ".tuning",
    "prune_candidates": ".tuning",
    "save_tuning_table": ".tuning",
    "tuning_path": ".tuning",
    # measurement fast path (arena imports algorithms only; listed lazy to
    # keep symmetry with the sweep engine that consumes it)
    "FastPathStats": ".arena",
    "OperandArena": ".arena",
    "algorithm_structural_key": ".arena",
    "arena_for": ".arena",
    "order_points_for_locality": ".arena",
    "algorithm_cache_stats": ".expressions",
    "clear_algorithm_cache": ".expressions",
    "fastpath_enabled": ".sweep",
    # sweep engine (the `sweep` *function* stays module-scoped to keep the
    # submodule name unambiguous, mirroring calibrate)
    "SWEEP_GRIDS": ".expressions",
    "AnomalyAtlas": ".sweep",
    "AtlasError": ".sweep",
    "BackendComparison": ".sweep",
    "BackendDisagreement": ".sweep",
    "compare_backends": ".sweep",
    "Instance": ".sweep",
    "SweepResult": ".sweep",
    "atlas_path": ".sweep",
    "atlas_shard_path": ".sweep",
    "benchmark_unique_calls": ".sweep",
    "cluster_sweep": ".sweep",
    "collect_unique_calls": ".sweep",
    "predict_classifications": ".sweep",
    # adaptive boundary-refinement engine (imports sweep; lazy likewise —
    # the `adaptive_sweep` *function* mirrors `sweep`/`calibrate` naming)
    "AdaptiveResult": ".adaptive",
    "RoundStats": ".adaptive",
    "adaptive_sweep": ".adaptive",
    "boundary_cells": ".adaptive",
    "refinement_candidates": ".adaptive",
    "seed_points": ".adaptive",
    # paper harnesses (import scipy-backed runners; lazy keeps base import
    # light and keeps `sweep` out of sys.modules at package import)
    "GRAM_AATB": ".expressions",
    "MATRIX_CHAIN_ABCD": ".expressions",
    "experiment1_random_search": ".experiments",
    "experiment2_regions": ".experiments",
    "experiment3_predict_from_benchmarks": ".experiments",
    "measure_instance": ".experiments",
    # atlas-replay evaluation (imports sweep; lazy for the same reason)
    "AtlasReplay": ".evaluate",
    "DiscriminantScore": ".evaluate",
    "EvaluationResult": ".evaluate",
    "evaluate_atlas": ".evaluate",
    "evaluate_discriminants": ".evaluate",
    "load_atlas_records": ".evaluate",
    # static plan verifier (analysis imports algorithms/expressions; lazy
    # keeps the analysis passes out of the base import path)
    "AnalysisError": ".analysis",
    "Finding": ".analysis",
    "assert_algorithms_valid": ".analysis",
    "run_mutation_suite": ".analysis",
    "verify_algorithm": ".analysis",
    "verify_algorithms": ".analysis",
    "verify_family": ".analysis",
    "verify_zoo": ".analysis",
    # deprecated alias (selector.__getattr__ emits the DeprecationWarning
    # at first *use*, not at package import — and it is deliberately NOT
    # in __all__, so star-imports don't trigger it either)
    "DISCRIMINANTS": ".selector",
}


def __getattr__(name):
    target = _LAZY_EXPORTS.get(name)
    if target is not None:
        import importlib
        mod = importlib.import_module(target, __name__)
        value = getattr(mod, name)
        globals()[name] = value  # cache: later lookups skip __getattr__
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Algorithm", "enumerate_algorithms", "optimal_chain_order",
    "ExecutionBackend", "get_backend", "make_backend", "register_backend",
    "registered_backends",
    "BackendComparison", "BackendDisagreement", "compare_backends",
    "Classification", "ConfusionMatrix", "Region", "classify",
    "cluster_regions", "scan_line",
    "FastPathStats", "OperandArena", "algorithm_structural_key",
    "arena_for", "order_points_for_locality",
    "algorithm_cache_stats", "clear_algorithm_cache", "fastpath_enabled",
    "SWEEP_GRIDS", "AnomalyAtlas", "AtlasError", "GridSpec", "Instance",
    "SweepResult", "atlas_path", "atlas_shard_path",
    "benchmark_unique_calls", "cluster_sweep",
    "collect_unique_calls", "predict_classifications",
    "AdaptiveResult", "RoundStats", "adaptive_sweep", "boundary_cells",
    "refinement_candidates", "seed_points",
    "Chain", "Matrix", "Transpose", "chain", "gram_times", "matrix_chain",
    "gram_left_times", "gram_of_product", "gram_right_times",
    "symmetric_sandwich",
    "GRAM_AATB", "MATRIX_CHAIN_ABCD", "MATRIX_CHAIN_ABCDE", "GRAM_ABTB",
    "GRAM_ATAB", "GRAM_ABAB", "SANDWICH_BTSB", "REGISTRY",
    "ExpressionSpec", "get_spec", "register", "registered_names",
    "experiment1_random_search", "experiment2_regions",
    "experiment3_predict_from_benchmarks", "measure_instance",
    "expression_calls",
    "KernelCall", "gemm", "kernel_flops", "symm", "syrk", "total_flops",
    "tri2full",
    "TPU_V5E", "AnalyticalTPUProfile", "HardwareSpec", "HybridProfile",
    "KernelProfile", "RooflineProfile", "TableProfile",
    "predict_algorithm_time",
    "Plan", "Planner", "default_planner", "plan", "reset_default_planner",
    "resolve_profile",
    "GRIDS", "CalibrationResult", "TuneResult", "sweep_kernels",
    "TunedEntry", "TuningTable", "candidate_configs",
    "load_default_tuning_table", "load_tuning_table", "prune_candidates",
    "save_tuning_table", "tuning_path",
    "FingerprintMismatchError", "HardwareFingerprint", "ProfileStoreError",
    "current_fingerprint", "load_default_profile", "load_profile",
    "profile_path", "save_profile",
    "BlasRunner", "JaxRunner", "measure_seconds", "reference_execute",
    "as_hybrid", "select", "select_expression",
    "Discriminant", "DiscriminantContext", "get_discriminant",
    "register_discriminant", "registered_discriminants",
    "AtlasReplay", "DiscriminantScore", "EvaluationResult",
    "evaluate_atlas", "evaluate_discriminants", "load_atlas_records",
    "AnalysisError", "Finding", "assert_algorithms_valid",
    "run_mutation_suite", "verify_algorithm", "verify_algorithms",
    "verify_family", "verify_zoo",
]
