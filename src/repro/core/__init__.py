"""repro.core — the paper's contribution: the LAMP planner.

*FLOPs as a Discriminant for Dense Linear Algebra Algorithms*
(López, Karlsson, Bientinesi — ICPP '22) productized:

expression IR → algorithm enumeration → {flops | perfmodel | measured}
discriminant → executable plan, plus the paper's anomaly-study harnesses
(Experiments 1–3).
"""

from .algorithms import (
    Algorithm,
    enumerate_algorithms,
    optimal_chain_order,
)
from .anomaly import Classification, ConfusionMatrix, classify, scan_line
from .expr import Chain, Matrix, Transpose, chain, gram_times, matrix_chain
from .experiments import (
    GRAM_AATB,
    MATRIX_CHAIN_ABCD,
    ExpressionSpec,
    experiment1_random_search,
    experiment2_regions,
    experiment3_predict_from_benchmarks,
    measure_instance,
)
from .flops import KernelCall, gemm, kernel_flops, symm, syrk, total_flops, tri2full
from .perfmodel import (
    TPU_V5E,
    AnalyticalTPUProfile,
    HardwareSpec,
    KernelProfile,
    TableProfile,
    predict_algorithm_time,
)
from .planner import Plan, Planner, default_planner, plan
from .runners import BlasRunner, JaxRunner
from .selector import DISCRIMINANTS, select

__all__ = [
    "Algorithm", "enumerate_algorithms", "optimal_chain_order",
    "Classification", "ConfusionMatrix", "classify", "scan_line",
    "Chain", "Matrix", "Transpose", "chain", "gram_times", "matrix_chain",
    "GRAM_AATB", "MATRIX_CHAIN_ABCD", "ExpressionSpec",
    "experiment1_random_search", "experiment2_regions",
    "experiment3_predict_from_benchmarks", "measure_instance",
    "KernelCall", "gemm", "kernel_flops", "symm", "syrk", "total_flops",
    "tri2full",
    "TPU_V5E", "AnalyticalTPUProfile", "HardwareSpec", "KernelProfile",
    "TableProfile", "predict_algorithm_time",
    "Plan", "Planner", "default_planner", "plan",
    "BlasRunner", "JaxRunner",
    "DISCRIMINANTS", "select",
]
