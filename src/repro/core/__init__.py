"""repro.core — the paper's contribution: the LAMP planner.

*FLOPs as a Discriminant for Dense Linear Algebra Algorithms*
(López, Karlsson, Bientinesi — ICPP '22) productized:

expression IR → algorithm enumeration → {flops | perfmodel | measured}
discriminant → executable plan, plus the paper's anomaly-study harnesses
(Experiments 1–3).
"""

from .algorithms import (
    Algorithm,
    enumerate_algorithms,
    optimal_chain_order,
)
from .anomaly import Classification, ConfusionMatrix, classify, scan_line
from .expr import Chain, Matrix, Transpose, chain, gram_times, matrix_chain
from .experiments import (
    GRAM_AATB,
    MATRIX_CHAIN_ABCD,
    ExpressionSpec,
    experiment1_random_search,
    experiment2_regions,
    experiment3_predict_from_benchmarks,
    measure_instance,
)
from .flops import KernelCall, gemm, kernel_flops, symm, syrk, total_flops, tri2full
from .perfmodel import (
    TPU_V5E,
    AnalyticalTPUProfile,
    HardwareSpec,
    HybridProfile,
    KernelProfile,
    TableProfile,
    predict_algorithm_time,
)
from .planner import (
    Plan,
    Planner,
    default_planner,
    plan,
    reset_default_planner,
    resolve_profile,
)
from .profile_store import (
    FingerprintMismatchError,
    HardwareFingerprint,
    ProfileStoreError,
    current_fingerprint,
    load_default_profile,
    load_profile,
    profile_path,
    save_profile,
)
from .runners import BlasRunner, JaxRunner, measure_seconds
from .selector import DISCRIMINANTS, as_hybrid, select

# Lazy (PEP 562) so `python -m repro.core.calibrate` doesn't import the
# CLI module twice (runpy warns when the target is already in sys.modules).
# NB `repro.core.calibrate` names the *submodule* (like os.path); the
# function is `repro.core.calibrate.calibrate`.
_CALIBRATE_EXPORTS = ("GRIDS", "CalibrationResult", "sweep_kernels")


def __getattr__(name):
    if name in _CALIBRATE_EXPORTS:
        import importlib
        mod = importlib.import_module(".calibrate", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Algorithm", "enumerate_algorithms", "optimal_chain_order",
    "Classification", "ConfusionMatrix", "classify", "scan_line",
    "Chain", "Matrix", "Transpose", "chain", "gram_times", "matrix_chain",
    "GRAM_AATB", "MATRIX_CHAIN_ABCD", "ExpressionSpec",
    "experiment1_random_search", "experiment2_regions",
    "experiment3_predict_from_benchmarks", "measure_instance",
    "KernelCall", "gemm", "kernel_flops", "symm", "syrk", "total_flops",
    "tri2full",
    "TPU_V5E", "AnalyticalTPUProfile", "HardwareSpec", "HybridProfile",
    "KernelProfile", "TableProfile", "predict_algorithm_time",
    "Plan", "Planner", "default_planner", "plan", "reset_default_planner",
    "resolve_profile",
    "GRIDS", "CalibrationResult", "sweep_kernels",
    "FingerprintMismatchError", "HardwareFingerprint", "ProfileStoreError",
    "current_fingerprint", "load_default_profile", "load_profile",
    "profile_path", "save_profile",
    "BlasRunner", "JaxRunner", "measure_seconds",
    "DISCRIMINANTS", "as_hybrid", "select",
]
