"""Registry-generated ``--help`` epilogs for the sweep/calibrate CLIs.

The epilogs used to be prose that listed policies and backends by hand,
so anything added to a registry after the prose was written —
``roofline``, ``rankk``, user-registered entries — was invisible to
``--help``. These helpers are the fix: the listings are *generated* from
:func:`repro.core.discriminants.registered_discriminants` and
:func:`repro.core.backends.registered_backends` at parser-build time, so
the help text can never drift from what the registries accept
(pinned by ``tests/test_serve.py``).
"""

from __future__ import annotations


def _first_doc_line(obj: object) -> str:
    doc = (getattr(obj, "__doc__", None) or "").strip()
    return doc.splitlines()[0].rstrip(".") if doc else ""


def discriminants_epilog() -> str:
    """One line per registered selection policy, capability-flagged."""
    from .discriminants import get_discriminant, registered_discriminants

    lines = ["registered discriminants (repro.core.discriminants):"]
    for name in registered_discriminants():
        d = get_discriminant(name)
        flags = []
        if getattr(d, "requires_profile", False):
            flags.append("profile")
        if getattr(d, "requires_measurement", False):
            flags.append("measures")
        tag = f" [{', '.join(flags)}]" if flags else ""
        lines.append(f"  {name:<10} {_first_doc_line(type(d))}{tag}")
    return "\n".join(lines)


def analysis_rules_epilog() -> str:
    """One line per registered static-analysis rule, severity-flagged."""
    from .analysis import RULES

    lines = ["static analysis rules (repro.core.analysis; "
             "python -m repro.core.analysis):"]
    for rule_id in sorted(RULES):
        rule = RULES[rule_id]
        lines.append(f"  {rule_id:<18} [{rule.severity}] {rule.summary}")
    return "\n".join(lines)


def backends_epilog() -> str:
    """One line per registered execution backend + its fingerprint dtype."""
    from .backends import registered_backends
    from .backends.base import backend_default_dtype, get_backend_class

    lines = ["registered execution backends (repro.core.backends):"]
    for name in registered_backends():
        cls = get_backend_class(name)
        dtype = backend_default_dtype(name)
        lines.append(f"  {name:<8} {_first_doc_line(cls)} "
                     f"[dtype={dtype}]")
    return "\n".join(lines)
