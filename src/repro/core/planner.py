"""The LAMP planner: expression → selected algorithm → JAX callable.

This is the paper's contribution as a *runtime feature*: model code hands a
linear-algebra expression (chain, Gram product) plus concrete sizes to
:func:`plan`, and gets back a jit-able callable implementing the algorithm
chosen by the configured discriminant. Plans are memoised per
(expression-structure, sizes, discriminant, profile) so that planning cost
is paid once per shape — the common case in training where shapes are
static across steps.

The planner is consumed by:
  * ``repro.optim.muon``   — Gram-product chains (the paper's AAᵀB);
  * ``repro.models.ssm``   — SSD quadratic-vs-chunked dual selection;
  * ``repro.serve.decode`` — decode-step projection chains (1-token GEMMs).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, Optional, Tuple

from .algorithms import Algorithm, enumerate_algorithms
from .expr import Chain, bind_dims
from .perfmodel import AnalyticalTPUProfile, KernelProfile
from .runners import JaxRunner
from .selector import select


@dataclasses.dataclass
class Plan:
    algorithm: Algorithm
    fn: Callable            # jax callable: (*leaf_arrays) -> result
    ranked: Tuple[str, ...]  # algorithm names, best first (for logging)
    discriminant: str

    @property
    def flops(self) -> int:
        return self.algorithm.flops


class Planner:
    """Thread-safe, memoising planner."""

    def __init__(
        self,
        discriminant: str = "perfmodel",
        profile: Optional[KernelProfile] = None,
        use_pallas: bool = False,
        dtype_bytes: int = 2,
    ):
        self.discriminant = discriminant
        self.profile = profile or AnalyticalTPUProfile()
        self.runner = JaxRunner(use_pallas=use_pallas)
        self.dtype_bytes = dtype_bytes
        self._cache: Dict[Tuple, Plan] = {}
        self._lock = threading.Lock()

    def _key(self, c: Chain, env) -> Tuple:
        dims = bind_dims(c, env or {})
        struct = tuple(
            (type(op).__name__, getattr(op, "symmetric", False))
            for op in c.ops
        )
        return (struct, dims, self.discriminant)

    def plan(self, c: Chain, env: Optional[Dict[str, int]] = None) -> Plan:
        key = self._key(c, env)
        with self._lock:
            hit = self._cache.get(key)
        if hit is not None:
            return hit
        algos = enumerate_algorithms(c, env)
        ranked = select(algos, self.discriminant, profile=self.profile,
                        dtype_bytes=self.dtype_bytes)
        best = ranked[0]
        plan = Plan(
            algorithm=best,
            fn=self.runner.build(best),
            ranked=tuple(a.name for a in ranked),
            discriminant=self.discriminant,
        )
        with self._lock:
            self._cache[key] = plan
        return plan

    def __call__(self, c: Chain, *arrays, env=None):
        """Plan and evaluate in one call (arrays follow chain leaf order,
        with Gram-pair leaves deduplicated: pass each distinct matrix once
        per its first occurrence index)."""
        plan = self.plan(c, env)
        return plan.fn(*arrays)


_default_planner: Optional[Planner] = None
_default_lock = threading.Lock()


def default_planner() -> Planner:
    global _default_planner
    with _default_lock:
        if _default_planner is None:
            _default_planner = Planner()
        return _default_planner


def plan(c: Chain, env: Optional[Dict[str, int]] = None,
         discriminant: str = "perfmodel") -> Plan:
    """Module-level convenience using a per-discriminant default planner."""
    p = default_planner()
    if discriminant != p.discriminant:
        p = Planner(discriminant=discriminant)
    return p.plan(c, env)
