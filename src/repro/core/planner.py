"""The LAMP planner: expression → selected algorithm → JAX callable.

This is the paper's contribution as a *runtime feature*: model code hands a
linear-algebra expression (chain, Gram product) plus concrete sizes to
:func:`plan`, and gets back a jit-able callable implementing the algorithm
chosen by the configured discriminant. Plans are memoised per
(expression-structure, sizes, discriminant, profile) so that planning cost
is paid once per shape — the common case in training where shapes are
static across steps.

Profiles resolve in three tiers (see ISSUE: calibrated-profile subsystem):

1. an explicit ``profile=`` argument wins;
2. otherwise a persisted calibration for this machine is auto-loaded from
   the profile cache (:mod:`repro.core.profile_store`) and wrapped in the
   hybrid measured-∨-analytical policy;
3. otherwise the closed-form :class:`AnalyticalTPUProfile`.

With ``record=True`` the planner additionally *refines* the live profile
online: each ``planner(chain, *arrays)`` execution is timed (blocking on
JAX async dispatch) and the observed wall time is apportioned over the
plan's kernel calls and blended into the table — so production traffic
keeps sharpening the model the calibration seeded. ``planner.save()``
persists the refined table back to the cache.

The planner is consumed by:
  * ``repro.serve.plan_cache`` — the serving layer's concurrent shape→plan
    cache (lock-free hits, coalesced misses, async refinement);
  * ``repro.models.attention`` — decode-step P·V·Wo association order is
    chosen by the planner at trace time (the ``decattn`` zoo family);
  * ``repro.core.sweep`` / the benchmarks — batch enumeration+selection.

See docs/serving.md for the request-path view of this module.
"""

from __future__ import annotations

import dataclasses
import threading
import warnings
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

from .algorithms import Algorithm, enumerate_algorithms
from .backends import get_backend, measure_seconds
from .expr import Chain, bind_dims
from .perfmodel import AnalyticalTPUProfile, KernelProfile, TableProfile
from .profile_store import (
    current_fingerprint,
    load_default_profile,
    save_profile,
)
from .discriminants import as_hybrid, get_discriminant
from .selector import select


@dataclasses.dataclass
class Plan:
    algorithm: Algorithm
    fn: Callable            # jax callable: (*leaf_arrays) -> result
    ranked: Tuple[str, ...]  # algorithm names, best first (for logging)
    discriminant: str

    @property
    def flops(self) -> int:
        return self.algorithm.flops


def resolve_profile(
    profile: Optional[KernelProfile] = None,
    backend: str = "blas",
    dtype: str = "float64",
) -> KernelProfile:
    """Tiered profile resolution: explicit → cached calibration → analytical.

    A cached :class:`TableProfile` is wrapped into the hybrid policy so
    shapes the calibration never measured still get analytical estimates.
    """
    if profile is not None:
        return profile
    cached = load_default_profile(backend=backend, dtype=dtype)
    if cached is not None:
        return as_hybrid(cached)
    return AnalyticalTPUProfile()


class Planner:
    """Thread-safe, memoising planner with optional online refinement.

    ``record=True`` semantics (post-calibration-subsystem behaviour): every
    ``planner(chain, *arrays)`` execution is wall-timed with a block on JAX
    async dispatch, the observed seconds are apportioned over the plan's
    kernel calls in proportion to the *analytical* model's relative call
    costs (one consistent weight model — see :meth:`observe`), and each
    share is EMA-blended (``observation_blend``, default 0.25) into the
    live table profile. Refinement needs a table to write into: a pure
    analytical profile makes ``observe`` a silent no-op. ``planner.save()``
    persists the refined table under this planner's
    ``(profile_backend, profile_dtype)`` fingerprint — by default
    ``jax/float32`` when recording, so online JAX timings are never filed
    under the ``blas/float64`` calibration that Experiment 3 trusts as
    isolated BLAS benchmarks.

    Example (pure-arithmetic policy, no profile or hardware needed)::

        >>> from repro.core.expr import matrix_chain
        >>> from repro.core.planner import Planner
        >>> planner = Planner(discriminant="flops", backend="numpy")
        >>> plan = planner.plan(matrix_chain(8, 512, 8, 512))
        >>> plan.discriminant
        'flops'
        >>> plan.algorithm.name          # (8×512)·(512×8) first is cheapest
        'alg1[gemm+gemm]'
        >>> len(plan.ranked)             # 3-operand chain: 2 orders ranked
        2
        >>> planner.plan(matrix_chain(8, 512, 8, 512)) is plan  # memoised
        True
    """

    def __init__(
        self,
        discriminant: str = "perfmodel",
        profile: Optional[KernelProfile] = None,
        backend: Optional[str] = None,
        dtype_bytes: int = 2,
        record: bool = False,
        observation_blend: float = 0.25,
        profile_backend: Optional[str] = None,
        profile_dtype: Optional[str] = None,
        use_pallas: Optional[bool] = None,
    ):
        # ``backend`` is an execution-backend registry name; the planner
        # builds its callables with that backend's kernels. ``use_pallas``
        # is the pre-registry spelling, kept as a deprecation shim.
        if use_pallas is not None:
            warnings.warn(
                "Planner(use_pallas=...) is deprecated; pass "
                "backend='pallas' (or 'jax') instead",
                DeprecationWarning, stacklevel=2)
            if backend is None:
                backend = "pallas" if use_pallas else "jax"
        self.backend = backend or "jax"
        self.runner = get_backend(self.backend)
        # One (backend, dtype) key governs BOTH the cache load in
        # resolve_profile and save() below — asymmetric keys would persist
        # refinements to a file no future load ever reads. The default key
        # depends on `record`: a read-only planner consumes the BLAS
        # calibration (the CLI's default output), but a recording planner
        # produces timings via its own execution backend, and those must
        # never be filed under the blas/float64 fingerprint experiment3
        # trusts as isolated BLAS benchmarks — so the recording default is
        # the runner's own fingerprint tag (jax/float32, pallas/float32…).
        run_tag, run_dtype = self.runner.fingerprint_tags()
        if profile_backend is None:
            profile_backend = run_tag if record else "blas"
        if profile_dtype is None:
            profile_dtype = run_dtype if record else "float64"
        self.profile_backend = profile_backend
        self.profile_dtype = profile_dtype
        # Any repro.core.discriminants registry key works; resolving at
        # construction surfaces typos before the first plan() call, and
        # the policy's capability flags drive both which arguments select
        # receives and whether profile refinement invalidates memos.
        try:
            self._policy = get_discriminant(discriminant)
        except KeyError as e:
            raise ValueError(str(e)) from None
        self.discriminant = discriminant
        self.profile = resolve_profile(profile, backend=profile_backend,
                                       dtype=profile_dtype)
        self.dtype_bytes = dtype_bytes
        self.record = record
        self.observation_blend = observation_blend
        # One slot per (structure, dims, discriminant); the stored value
        # carries the profile generation it was ranked under, so online
        # refinement invalidates it without growing the cache.
        self._cache: Dict[Tuple, Tuple[int, Plan]] = {}
        self._lock = threading.Lock()

    def _key(self, c: Chain, env) -> Tuple:
        dims = bind_dims(c, env or {})
        struct = tuple(
            (type(op).__name__, getattr(op, "symmetric", False))
            for op in c.ops
        )
        # The policy's fingerprint (not just its registry key): a
        # parametrized discriminant (rankk's measurement budget k) folds
        # its parameters in, so two planners sharing a cache through the
        # module-level plan() helpers can never alias distinct policies.
        return (struct, dims, self._policy.fingerprint())

    def _profile_generation(self) -> int:
        """Mutation counter of the live table profile (−1: no table).

        Folding this into the memo slot is what lets a ``record=True``
        planner re-rank after online refinement: without it, the first
        plan per shape was frozen forever even when heavy refinement had
        flipped the ranking (ISSUE 4 satellite). Discriminants whose
        ranking does not read the profile (``requires_profile=False``:
        ``flops``/``roofline`` are pure arithmetic; ``measured`` re-times
        on hardware) pin the generation — otherwise every observe() would
        force a provably identical re-enumeration (or, for ``measured``,
        a fresh wall-clock timing sweep) per call.
        """
        if not self._policy.requires_profile:
            return -1
        table = self._recording_table()
        return table.generation if table is not None else -1

    def policy_fingerprint(self) -> Tuple:
        """Stable identity of the selection policy (registry key + params).

        Parametrized discriminants (``rankk``'s measurement budget) fold
        their parameters in, so two planners configured differently can
        never alias one cache slot. :mod:`repro.serve.plan_cache` folds
        this into its shape→plan key.
        """
        return self._policy.fingerprint()

    def profile_generation(self) -> int:
        """Current profile generation this planner would rank under.

        −1 when the policy never reads the profile (pure arithmetic) or
        there is no live table; otherwise the table's mutation counter.
        A bump means online refinement may have flipped rankings — plans
        memoised under an older generation are stale. This is the serving
        cache's invalidation signal (docs/serving.md).
        """
        return self._profile_generation()

    def plan(self, c: Chain, env: Optional[Dict[str, int]] = None) -> Plan:
        """Enumerate, rank, and memoise: chain + sizes → :class:`Plan`.

        Memoised per ``(structure, dims, policy fingerprint)`` and revali-
        dated against :meth:`profile_generation`, so the enumeration and
        ranking cost is paid once per shape until refinement moves the
        profile. Thread-safe; concurrent misses may race to enumerate but
        converge on one cached entry (the serving layer's
        :class:`repro.serve.plan_cache.PlanCache` adds request coalescing
        on top so same-shape misses do the work exactly once).
        """
        key = self._key(c, env)
        gen = self._profile_generation()
        with self._lock:
            hit = self._cache.get(key)
        if hit is not None and hit[0] == gen:
            return hit[1]
        algos = enumerate_algorithms(c, env)
        # Capability-gated arguments: a profile handed to a policy that
        # never reads one (flops/measured/roofline) is a select() error
        # now, and the planner always *has* a resolved profile — so only
        # forward it where it is meaningful.
        ranked = select(
            algos, self.discriminant,
            profile=self.profile if self._policy.requires_profile else None,
            dtype_bytes=self.dtype_bytes)
        best = ranked[0]
        plan = Plan(
            algorithm=best,
            fn=self.runner.build(best),
            ranked=tuple(a.name for a in ranked),
            discriminant=self.discriminant,
        )
        with self._lock:
            self._cache[key] = (gen, plan)
        return plan

    def __call__(self, c: Chain, *arrays, env=None):
        """Plan and evaluate in one call (arrays follow chain leaf order,
        with Gram-pair leaves deduplicated: pass each distinct matrix once
        per its first occurrence index). With ``record=True`` the execution
        is timed and fed back into the live profile."""
        plan = self.plan(c, env)
        if not self.record:
            return plan.fn(*arrays)
        out, seconds = measure_seconds(plan.fn, *arrays)
        self.observe(plan, seconds)
        return out

    # -- online refinement ------------------------------------------------
    def _recording_table(self) -> Optional[TableProfile]:
        prof = self.profile
        if isinstance(prof, TableProfile):
            return prof
        return getattr(prof, "table_profile", None)

    def observe(self, plan: Plan, seconds: float) -> None:
        """Fold one measured plan execution back into the live profile.

        The total wall time is apportioned over the plan's kernel calls in
        proportion to their current predicted times (the additive model run
        backwards), then EMA-blended into the table so noisy single
        observations don't thrash a calibrated entry. No-op when the
        profile has no table to record into (pure analytical).
        """
        table = self._recording_table()
        if table is None or seconds <= 0:
            return
        calls = plan.algorithm.calls
        if not calls:
            return
        # Apportioning weights must come from ONE model: a HybridProfile
        # mixes measured entries (this machine's scale) with analytical
        # fallbacks (TPU constants, often 100-1000× off), and proportions
        # across that mix would credit analytically-predicted calls with
        # near-zero shares — poisoning the table with "free" kernels. The
        # analytical member's *relative* kernel costs are internally
        # consistent, which is all apportioning needs.
        weight_model = getattr(self.profile, "analytical", self.profile)
        try:
            preds = [max(weight_model.time(c, self.dtype_bytes), 1e-12)
                     for c in calls]
        except KeyError:
            # Plain TableProfile with a kernel kind it has never seen
            # (e.g. an empty table being bootstrapped): weight by the
            # closed-form model instead of dying after the work is done.
            weight_model = AnalyticalTPUProfile()
            preds = [max(weight_model.time(c, self.dtype_bytes), 1e-12)
                     for c in calls]
        total = sum(preds)
        blend = self.observation_blend
        with self._lock:
            for call, pred in zip(calls, preds):
                share = seconds * pred / total
                old = table.table.get((call.kind, call.dims))
                new = share if old is None else (
                    (1.0 - blend) * old + blend * share)
                table.record(call, new)

    def save(self, directory: Optional[Path] = None) -> Optional[Path]:
        """Persist the (possibly refined) table profile to the cache.

        Uses the planner's (profile_backend, profile_dtype) key — the same
        key ``resolve_profile`` loads with, so the next process finds the
        refinements. NB a profile passed *explicitly* to the constructor is
        stamped with that key too: if you hand the planner a profile
        calibrated for a different backend/dtype, set
        ``profile_backend``/``profile_dtype`` to match its provenance or
        the cache entry will misattribute the timings.
        """
        table = self._recording_table()
        if table is None:
            return None
        fp = current_fingerprint(backend=self.profile_backend,
                                 dtype=self.profile_dtype)
        return save_profile(table, fp, directory=directory,
                            meta={"source": "planner.online_refinement"})


_default_planner: Optional[Planner] = None
_planners_by_discriminant: Dict[str, "Planner"] = {}
_default_lock = threading.Lock()


def default_planner() -> Planner:
    """Process-wide planner; auto-loads this machine's cached calibration.

    The profile tier is resolved lazily at first use (see
    :func:`resolve_profile`), so running ``python -m repro.core.calibrate``
    before process start is all it takes to upgrade every consumer from
    the analytical model to measured tables.
    """
    global _default_planner
    with _default_lock:
        if _default_planner is None:
            _default_planner = Planner()
        return _default_planner


def reset_default_planner() -> None:
    """Drop the cached process-wide planners (tests; post-calibration)."""
    global _default_planner
    with _default_lock:
        _default_planner = None
        _planners_by_discriminant.clear()


def plan(c: Chain, env: Optional[Dict[str, int]] = None,
         discriminant: str = "perfmodel") -> Plan:
    """Module-level convenience using a per-discriminant default planner.

    Planners (and their profile-cache read) are memoised per discriminant
    so repeated calls stay in-memory after the first.
    """
    p = default_planner()
    if discriminant != p.discriminant:
        with _default_lock:
            p = _planners_by_discriminant.get(discriminant)
            if p is None:
                p = Planner(discriminant=discriminant)
                _planners_by_discriminant[discriminant] = p
    return p.plan(c, env)
