"""CLI: lint the expression zoo / run the mutation-catch gate.

Usage::

    python -m repro.core.analysis                     # whole zoo, smoke+small
    python -m repro.core.analysis --grid full         # heavier grids
    python -m repro.core.analysis --expr atab,abtb    # a subset of families
    python -m repro.core.analysis --mutants           # 8-way mutation gate

Exit status is nonzero on any finding (zoo mode) or any uncaught mutant
(mutation mode) — this is what the ``analysis-smoke`` CI job gates on.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Sequence

from ..cli_help import analysis_rules_epilog
from ..expressions import registered_names
from .findings import format_findings
from .mutants import DEFAULT_SPEC, run_mutation_suite
from .verify import verify_zoo


def _csv(value: str) -> List[str]:
    return [part for part in (p.strip() for p in value.split(",")) if part]


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="python -m repro.core.analysis",
        description="Statically verify every algorithm DAG in the "
                    "expression zoo (shapes, storage, liveness, FLOPs).",
        epilog=analysis_rules_epilog(),
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    parser.add_argument(
        "--expr", default=None, metavar="NAME[,NAME...]",
        help="families to lint (default: every registered family: "
             f"{', '.join(registered_names())})")
    parser.add_argument(
        "--grid", default="smoke,small", metavar="GRID[,GRID...]",
        help="named dim grids to lint across (default: smoke,small)")
    parser.add_argument(
        "--mutants", action="store_true",
        help="run the mutation-testing harness instead of the zoo lint: "
             "corrupt a valid family 8 known ways and require the "
             "verifier to catch every class")
    parser.add_argument(
        "--mutant-spec", default=DEFAULT_SPEC, metavar="NAME",
        help=f"family the mutation harness corrupts "
             f"(default: {DEFAULT_SPEC})")
    return parser


def _run_mutants(spec_name: str) -> int:
    outcomes = run_mutation_suite(spec_name)
    caught = sum(1 for o in outcomes if o.caught)
    width = max(len(o.mutant) for o in outcomes)
    for o in outcomes:
        status = "caught" if o.caught else "MISSED"
        print(f"  {o.mutant:<{width}}  expected={o.expected_rule:<18} "
              f"fired={','.join(o.fired_rules) or '-':<30} {status}")
    print(f"mutation suite ({spec_name}): {caught}/{len(outcomes)} caught")
    return 0 if caught == len(outcomes) else 1


def _run_zoo(exprs: Optional[List[str]], grids: List[str]) -> int:
    lint = verify_zoo(grids=grids, exprs=exprs)
    for row in lint.rows:
        status = f"{len(row.findings)} finding(s)" if row.findings else "ok"
        print(f"  {row.family:<10} {row.grid:<8} "
              f"{row.instances:>4} instance(s) {row.algorithms:>5} "
              f"algorithm(s)  {status}")
    findings = lint.findings
    if findings:
        print()
        print(format_findings(findings))
    print(f"zoo lint: {lint.algorithms} algorithm(s) over "
          f"{lint.instances} instance(s), {lint.rules_run} rules, "
          f"{len(findings)} finding(s) in {lint.seconds:.2f}s")
    return 1 if findings else 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.mutants:
        return _run_mutants(args.mutant_spec)
    exprs = _csv(args.expr) if args.expr else None
    return _run_zoo(exprs, _csv(args.grid))


if __name__ == "__main__":
    sys.exit(main())
