"""Mutation-testing harness: prove the verifier catches known bug classes.

A static checker that has never seen a broken DAG is untested in the
only way that matters. This module corrupts *valid* enumerated families
in eight known ways — each modelled on a real or realistic enumeration
bug — and asserts that :func:`~repro.core.analysis.verify.
verify_algorithms` flags each one with the *expected* rule:

==========================  ====================  =========================
mutant                      expected rule         modelled failure
==========================  ====================  =========================
``swapped-dims``            ``shape-mismatch``    m/k transposed in a call
``dropped-tri2full``        ``raw-tri-read``      the PR 3 bug: raw reads
                                                  of a tri-stored SYRK out
``dangling-step-ref``       ``dangling-ref``      consumer wired to an id
                                                  that is never produced
``flop-off-by-one``         ``flop-mismatch``     a lying FLOP formula
``dead-step``               ``dead-step``         DCE failed to prune
``duplicate-canonical-key`` ``duplicate-key``     dedup let a twin survive
``wrong-symm-side``         ``wrong-symm-side``   side-L/R flag flipped
``stale-out-id``            ``stale-out-id``      output id collision
==========================  ====================  =========================

The harness mutates a family that exercises every kernel kind (default:
``aatb`` — SYRK, TRI2FULL, SYMM and GEMM all appear) at a point with
pairwise-distinct dims, so no mutation is accidentally a no-op. CI's
``analysis-smoke`` job gates on 8/8 caught
(``python -m repro.core.analysis --mutants``).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

from ..algorithms import Algorithm, Step
from ..expressions import get_spec
from ..flops import KernelCall
from .verify import verify_algorithms

#: Default mutation target: family exercising all four kernel kinds.
DEFAULT_SPEC = "aatb"
#: Pairwise-distinct dims so dim swaps can never be symmetric no-ops.
DEFAULT_POINT: Tuple[int, ...] = (96, 64, 48)


class _OffByOneFlops(KernelCall):
    """A KernelCall whose claimed FLOPs are off by one (a lying formula)."""

    @property
    def flops(self) -> int:
        return super().flops + 1


Mutator = Callable[[List[Algorithm]], List[Algorithm]]


@dataclasses.dataclass(frozen=True)
class MutantClass:
    """One named corruption + the rule the verifier must answer with."""

    name: str
    expected_rule: str
    description: str
    apply: Mutator


@dataclasses.dataclass(frozen=True)
class MutationOutcome:
    """Result of running one mutant through the verifier."""

    mutant: str
    expected_rule: str
    fired_rules: Tuple[str, ...]
    caught: bool


def _replace_step(algo: Algorithm, index: int, step: Step) -> Algorithm:
    steps = list(algo.steps)
    steps[index] = step
    return Algorithm(name=algo.name, steps=tuple(steps))


def _find_step(algos: Sequence[Algorithm],
               pred: Callable[[Algorithm, int, Step], bool]
               ) -> Tuple[int, int]:
    for ai, algo in enumerate(algos):
        for si, step in enumerate(algo.steps):
            if pred(algo, si, step):
                return ai, si
    raise LookupError(
        "no step in the family matches this mutant's precondition — "
        "choose a family that exercises the targeted kernel pattern")


def _mutate_swapped_dims(algos: List[Algorithm]) -> List[Algorithm]:
    """Transpose m and k of a GEMM whose m != k."""
    ai, si = _find_step(
        algos, lambda a, i, s: s.call.kind == "gemm"
        and s.call.dims[0] != s.call.dims[2])
    algo = algos[ai]
    step = algo.steps[si]
    m, n, k = step.call.dims
    bad = dataclasses.replace(step, call=dataclasses.replace(
        step.call, dims=(k, n, m)))
    out = list(algos)
    out[ai] = _replace_step(algo, si, bad)
    return out


def _mutate_dropped_tri2full(algos: List[Algorithm]) -> List[Algorithm]:
    """Delete a mid-DAG TRI2FULL and wire its consumers to the raw out."""
    ai, si = _find_step(
        algos, lambda a, i, s: s.call.kind == "tri2full"
        and any(isinstance(r, int) and r == s.out
                for later in a.steps[i + 1:] for r in (later.lhs, later.rhs)))
    algo = algos[ai]
    dropped = algo.steps[si]
    steps: List[Step] = []
    for step in algo.steps:
        if step is dropped:
            continue
        lhs = dropped.lhs if step.lhs == dropped.out else step.lhs
        rhs = dropped.lhs if step.rhs == dropped.out else step.rhs
        steps.append(dataclasses.replace(step, lhs=lhs, rhs=rhs))
    out = list(algos)
    out[ai] = Algorithm(name=algo.name, steps=tuple(steps))
    return out


def _mutate_dangling_ref(algos: List[Algorithm]) -> List[Algorithm]:
    """Point a consumer at a step output that is never produced."""
    ai, si = _find_step(algos, lambda a, i, s: isinstance(s.lhs, int))
    algo = algos[ai]
    step = algo.steps[si]
    bogus = max(s.out for s in algo.steps) + 1_000_000
    out = list(algos)
    out[ai] = _replace_step(algo, si,
                            dataclasses.replace(step, lhs=bogus))
    return out


def _mutate_flop_off_by_one(algos: List[Algorithm]) -> List[Algorithm]:
    """Swap one call for a subclass whose claimed FLOPs are +1."""
    ai, si = _find_step(algos, lambda a, i, s: s.call.kind != "tri2full")
    algo = algos[ai]
    step = algo.steps[si]
    lying = _OffByOneFlops(kind=step.call.kind, dims=step.call.dims,
                           operands=step.call.operands)
    out = list(algos)
    out[ai] = _replace_step(algo, si,
                            dataclasses.replace(step, call=lying))
    return out


def _mutate_dead_step(algos: List[Algorithm]) -> List[Algorithm]:
    """Insert an unconsumed duplicate of an early step before the result."""
    ai, si = _find_step(algos, lambda a, i, s: len(a.steps) >= 1)
    algo = algos[ai]
    donor = algo.steps[si]
    fresh = max(s.out for s in algo.steps) + 1
    steps = list(algo.steps)
    steps.insert(len(steps) - 1 if len(steps) > 1 else 0,
                 dataclasses.replace(donor, out=fresh))
    out = list(algos)
    out[ai] = Algorithm(name=algo.name, steps=tuple(steps))
    return out


def _mutate_duplicate_key(algos: List[Algorithm]) -> List[Algorithm]:
    """Append a renamed copy of the first algorithm (dedup escapee)."""
    first = algos[0]
    return list(algos) + [
        Algorithm(name=f"dup[{first.name}]", steps=first.steps)]


def _mutate_wrong_symm_side(algos: List[Algorithm]) -> List[Algorithm]:
    """Flip a SYMM step's side flag (executors would read the wrong op)."""
    ai, si = _find_step(algos, lambda a, i, s: s.call.kind == "symm")
    algo = algos[ai]
    step = algo.steps[si]
    flipped = "R" if step.symm_side == "L" else "L"
    out = list(algos)
    out[ai] = _replace_step(
        algo, si, dataclasses.replace(step, symm_side=flipped))
    return out


def _mutate_stale_out_id(algos: List[Algorithm]) -> List[Algorithm]:
    """Collide the final step's output id with an earlier step's."""
    ai, _ = _find_step(algos, lambda a, i, s: len(a.steps) >= 2)
    algo = algos[ai]
    steps = list(algo.steps)
    steps[-1] = dataclasses.replace(steps[-1], out=steps[0].out)
    out = list(algos)
    out[ai] = Algorithm(name=algo.name, steps=tuple(steps))
    return out


MUTANT_CLASSES: Tuple[MutantClass, ...] = (
    MutantClass("swapped-dims", "shape-mismatch",
                "GEMM call dims with m and k transposed",
                _mutate_swapped_dims),
    MutantClass("dropped-tri2full", "raw-tri-read",
                "tri-stored SYRK output consumed raw (the PR 3 bug)",
                _mutate_dropped_tri2full),
    MutantClass("dangling-step-ref", "dangling-ref",
                "consumer wired to a never-produced output id",
                _mutate_dangling_ref),
    MutantClass("flop-off-by-one", "flop-mismatch",
                "kernel call whose claimed FLOPs are off by one",
                _mutate_flop_off_by_one),
    MutantClass("dead-step", "dead-step",
                "unconsumed step the enumerator's DCE should have pruned",
                _mutate_dead_step),
    MutantClass("duplicate-canonical-key", "duplicate-key",
                "two family members sharing one canonical key",
                _mutate_duplicate_key),
    MutantClass("wrong-symm-side", "wrong-symm-side",
                "SYMM side flag flipped relative to its operands",
                _mutate_wrong_symm_side),
    MutantClass("stale-out-id", "stale-out-id",
                "final step redefining an earlier step's output id",
                _mutate_stale_out_id),
)


def mutant_names() -> List[str]:
    return [m.name for m in MUTANT_CLASSES]


def run_mutation_suite(
    spec_name: str = DEFAULT_SPEC,
    point: Optional[Sequence[int]] = None,
) -> List[MutationOutcome]:
    """Apply every mutant to a fresh valid family; report catch status.

    A mutant is *caught* iff its expected rule id is among the rules the
    verifier fired on the corrupted family (other rules may fire too —
    corruption cascades are fine; silence is not).
    """
    spec = get_spec(spec_name)
    pt: Tuple[int, ...] = tuple(point) if point is not None else DEFAULT_POINT
    chain = spec.chain(pt)
    outcomes: List[MutationOutcome] = []
    for mutant in MUTANT_CLASSES:
        algos = spec.algorithms(pt)
        baseline = verify_algorithms(algos, chain=chain)
        if baseline:
            raise AssertionError(
                f"mutation harness needs a clean baseline; {spec_name}@"
                f"{pt} already has findings: {baseline}")
        mutated = mutant.apply(algos)
        fired = tuple(sorted({
            f.rule_id for f in verify_algorithms(mutated, chain=chain)}))
        outcomes.append(MutationOutcome(
            mutant=mutant.name,
            expected_rule=mutant.expected_rule,
            fired_rules=fired,
            caught=mutant.expected_rule in fired))
    return outcomes


def mutation_catch_rate(
        outcomes: Sequence[MutationOutcome]) -> Tuple[int, int]:
    """(caught, total) over a suite run."""
    return sum(1 for o in outcomes if o.caught), len(outcomes)
