"""Shape inference over an algorithm step-DAG.

Walks the steps in execution order, resolving every operand reference
(:class:`~repro.core.algorithms.Leaf` or a previous step's output id) to
a :class:`ValueInfo` and checking, per kernel kind, that the
:class:`~repro.core.flops.KernelCall` dims, the operand shapes, and the
step's declared output shape/tags all agree. Conformance rules live in
an extensible registry (:func:`register_kernel_shape`), so ROADMAP-3
kernels (POTRF/TRSM/TRMM/GETRF/GEQRF) plug in without touching this
module — see docs/analysis.md for the recipe.

Emitted rules: ``dangling-ref``, ``stale-out-id``, ``unknown-kind``,
``shape-mismatch``, ``wrong-symm-side``, ``bad-storage-tag``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from ..algorithms import Algorithm, Leaf, Step
from .findings import Collector


@dataclasses.dataclass(frozen=True)
class ValueInfo:
    """Statically known facts about one value in the DAG."""

    rows: int
    cols: int
    storage: str        # 'full' | 'tri'
    symmetric: bool


@dataclasses.dataclass(frozen=True)
class StepView:
    """One step plus its resolved operands, handed to conformance rules.

    ``lhs``/``rhs`` are ``None`` when the reference was dangling (already
    reported) or absent; rules must tolerate that and check what they
    can.
    """

    step: Step
    index: int
    lhs: Optional[ValueInfo]
    rhs: Optional[ValueInfo]
    collector: Collector

    def emit(self, rule_id: str, message: str) -> None:
        self.collector.emit(rule_id, message, step_index=self.index,
                            step_out=self.step.out)


#: Conformance rule: validate dims/operands and return the output
#: :class:`ValueInfo` the kernel *would* produce (or ``None`` when the
#: inputs are too broken to say). The pass separately checks the
#: declared ``out_*`` fields against that return value.
ShapeRule = Callable[[StepView], Optional[ValueInfo]]

KERNEL_SHAPE_RULES: Dict[str, ShapeRule] = {}


def register_kernel_shape(kind: str, rule: ShapeRule) -> ShapeRule:
    """Register the conformance rule for one kernel kind."""
    if kind in KERNEL_SHAPE_RULES:
        raise ValueError(f"shape rule for kind {kind!r} already registered")
    KERNEL_SHAPE_RULES[kind] = rule
    return rule


def _leaf_info(leaf: Leaf) -> ValueInfo:
    return ValueInfo(rows=leaf.rows, cols=leaf.cols, storage=leaf.storage,
                     symmetric=leaf.symmetric)


def resolve(ref: object, env: Dict[int, ValueInfo]) -> Optional[ValueInfo]:
    """Operand reference -> ValueInfo (None: dangling or absent)."""
    if isinstance(ref, Leaf):
        return _leaf_info(ref)
    if isinstance(ref, int):
        return env.get(ref)
    return None


def infer_shapes(algo: Algorithm,
                 collector: Collector) -> Dict[int, ValueInfo]:
    """Run shape inference; returns the step-output environment.

    The environment maps each step's ``out`` id to the *declared* output
    info (so downstream passes agree with what executors would
    materialize), after checking the declaration against the inferred
    shape. Findings go to ``collector``.
    """
    env: Dict[int, ValueInfo] = {}
    for i, step in enumerate(algo.steps):
        for label, ref in (("lhs", step.lhs), ("rhs", step.rhs)):
            if isinstance(ref, int) and ref not in env:
                collector.emit(
                    "dangling-ref",
                    f"{step.call.kind} {label} references step output "
                    f"{ref}, which no earlier step produced",
                    step_index=i, step_out=step.out)
        if step.out in env:
            collector.emit(
                "stale-out-id",
                f"output id {step.out} was already produced by an earlier "
                f"step; downstream reads are ambiguous",
                step_index=i, step_out=step.out)
        view = StepView(step=step, index=i,
                        lhs=resolve(step.lhs, env),
                        rhs=resolve(step.rhs, env),
                        collector=collector)
        rule = KERNEL_SHAPE_RULES.get(step.call.kind)
        if rule is None:
            collector.emit(
                "unknown-kind",
                f"kernel kind {step.call.kind!r} has no registered shape "
                f"rule; register one via "
                f"repro.core.analysis.register_kernel_shape",
                step_index=i, step_out=step.out)
            inferred = None
        else:
            inferred = rule(view)
        declared = ValueInfo(rows=step.out_rows, cols=step.out_cols,
                             storage=step.out_storage,
                             symmetric=step.out_symmetric)
        if inferred is not None:
            if (declared.rows, declared.cols) != (inferred.rows,
                                                  inferred.cols):
                view.emit(
                    "shape-mismatch",
                    f"declared output {declared.rows}x{declared.cols} but "
                    f"{step.call!r} produces "
                    f"{inferred.rows}x{inferred.cols}")
            if declared.storage != inferred.storage:
                view.emit(
                    "bad-storage-tag",
                    f"declared out_storage={declared.storage!r} but "
                    f"{step.call.kind} produces {inferred.storage!r}")
            if inferred.symmetric and not declared.symmetric:
                view.emit(
                    "bad-storage-tag",
                    f"{step.call.kind} output is symmetric by construction "
                    f"but out_symmetric is False")
        if declared.storage == "tri" and not declared.symmetric:
            view.emit(
                "bad-storage-tag",
                "tri storage implies a symmetric value, but out_symmetric "
                "is False (executors would mirror garbage)")
        env[step.out] = declared
    return env


def _dims_ok(view: StepView, arity: int) -> Optional[Tuple[int, ...]]:
    dims = view.step.call.dims
    if len(dims) != arity or any(
            not isinstance(d, int) or d <= 0 for d in dims):
        view.emit(
            "shape-mismatch",
            f"{view.step.call.kind} expects {arity} positive int dim(s), "
            f"got {dims!r}")
        return None
    return dims


def _check_operand(view: StepView, label: str, info: Optional[ValueInfo],
                   rows: int, cols: int) -> None:
    if info is not None and (info.rows, info.cols) != (rows, cols):
        view.emit(
            "shape-mismatch",
            f"{view.step.call.kind} {label} must be {rows}x{cols}, got "
            f"{info.rows}x{info.cols}")


# ----------------------------------------------------- built-in kernels ----


def _gemm_shape(view: StepView) -> Optional[ValueInfo]:
    dims = _dims_ok(view, 3)
    if dims is None:
        return None
    m, n, k = dims
    _check_operand(view, "lhs", view.lhs, m, k)
    _check_operand(view, "rhs", view.rhs, k, n)
    # A gram GEMM (X·Xᵀ) legitimately tags its full output symmetric;
    # symmetry of a general product is not statically decidable here, so
    # the declared flag is trusted either way.
    return ValueInfo(rows=m, cols=n, storage="full",
                     symmetric=view.step.out_symmetric)


def _syrk_shape(view: StepView) -> Optional[ValueInfo]:
    dims = _dims_ok(view, 2)
    if dims is None:
        return None
    m, k = dims
    _check_operand(view, "lhs", view.lhs, m, k)
    # rhs, when recorded, is the transpose twin (provenance only).
    if view.step.rhs is not None:
        _check_operand(view, "rhs (transpose twin)", view.rhs, k, m)
    return ValueInfo(rows=m, cols=m, storage="tri", symmetric=True)


def _symm_shape(view: StepView) -> Optional[ValueInfo]:
    dims = _dims_ok(view, 2)
    if dims is None:
        return None
    s, o = dims
    side = view.step.symm_side
    if side not in ("L", "R"):
        view.emit("wrong-symm-side",
                  f"symm_side must be 'L' or 'R', got {side!r}")
        return None
    sym, gen = (view.lhs, view.rhs) if side == "L" else (view.rhs, view.lhs)
    sym_label = "lhs" if side == "L" else "rhs"
    if sym is not None and not (
            sym.symmetric and sym.rows == sym.cols == s):
        view.emit(
            "wrong-symm-side",
            f"SYMM(side={side}) requires a symmetric {s}x{s} {sym_label}, "
            f"got {sym.rows}x{sym.cols}"
            f"{'' if sym.symmetric else ' (not symmetric)'}")
    gen_label = "rhs" if side == "L" else "lhs"
    gen_rows, gen_cols = (s, o) if side == "L" else (o, s)
    _check_operand(view, gen_label, gen, gen_rows, gen_cols)
    out_rows, out_cols = (s, o) if side == "L" else (o, s)
    return ValueInfo(rows=out_rows, cols=out_cols, storage="full",
                     symmetric=False)


def _tri2full_shape(view: StepView) -> Optional[ValueInfo]:
    dims = _dims_ok(view, 1)
    if dims is None:
        return None
    (m,) = dims
    _check_operand(view, "lhs", view.lhs, m, m)
    if view.lhs is not None and not view.lhs.symmetric:
        view.emit(
            "shape-mismatch",
            "tri2full mirrors a triangle into a symmetric full matrix, "
            "but the operand is not symmetric")
    return ValueInfo(rows=m, cols=m, storage="full", symmetric=True)


register_kernel_shape("gemm", _gemm_shape)
register_kernel_shape("syrk", _syrk_shape)
register_kernel_shape("symm", _symm_shape)
register_kernel_shape("tri2full", _tri2full_shape)


def registered_shape_kinds() -> List[str]:
    return sorted(KERNEL_SHAPE_RULES)
