"""Findings and the static-analysis rule registry.

Every check the verifier can perform is declared here as a :class:`Rule`
with a stable kebab-case ``rule_id``, a severity, and a one-line summary.
The registry is the single source of truth consumed by:

* the passes (:mod:`.shapes`, :mod:`.storage`, :mod:`.liveness`,
  :mod:`.flopcheck`) — a pass can only emit findings for registered
  rules, so a typo'd rule id is an immediate ``KeyError``, not a silent
  un-catalogued finding;
* the CLI ``--help`` epilog (:func:`repro.core.cli_help.
  analysis_rules_epilog`) and the rule catalog in ``docs/analysis.md``
  (both pinned by tests, so the catalog can never drift);
* the mutation harness (:mod:`.mutants`), whose expected-rule contract
  is expressed in these ids.

A :class:`Finding` is one concrete violation: the rule, where it fired
(algorithm name, step index, step output id), and a human message. The
verifier never raises on findings — callers that want exceptions use
:class:`AnalysisError` via :func:`repro.core.analysis.verify.
assert_algorithms_valid`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

#: Severities, mildest last. ``error`` findings make a DAG invalid
#: (the serving guard and the enumeration hook raise on them);
#: ``warning`` findings are legal-but-wasteful constructs (a redundant
#: TRI2FULL) that the CLI still fails on, because a clean enumeration
#: produces neither.
SEVERITIES: Tuple[str, ...] = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Rule:
    """One statically checkable invariant over an algorithm step-DAG."""

    rule_id: str
    severity: str
    summary: str


#: rule_id -> Rule. Populated by :func:`register_rule` at import time
#: (built-ins below) and by ROADMAP-3 kernel packs at their import time.
RULES: Dict[str, Rule] = {}


def register_rule(rule_id: str, severity: str, summary: str) -> Rule:
    """Declare a rule; returns it (declaration style, like the zoo)."""
    if severity not in SEVERITIES:
        raise ValueError(
            f"unknown severity {severity!r}; expected one of {SEVERITIES}")
    if rule_id in RULES:
        raise ValueError(f"analysis rule {rule_id!r} is already registered")
    rule = Rule(rule_id=rule_id, severity=severity, summary=summary)
    RULES[rule_id] = rule
    return rule


def registered_rules() -> List[str]:
    """Sorted rule ids (the CLI epilog and docs catalog iterate this)."""
    return sorted(RULES)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One concrete rule violation, locatable in its algorithm."""

    rule_id: str
    severity: str
    message: str
    algorithm: Optional[str] = None
    step_index: Optional[int] = None
    step_out: Optional[int] = None

    def __str__(self) -> str:
        where = self.algorithm or "<algorithm>"
        if self.step_index is not None:
            where += f" step#{self.step_index}"
        if self.step_out is not None:
            where += f" (out={self.step_out})"
        return f"[{self.severity}] {self.rule_id} @ {where}: {self.message}"


class Collector:
    """Accumulates findings for one verification run.

    Passes call :meth:`emit` with a registered rule id; the severity is
    looked up from the registry so a pass can never misreport one.
    """

    def __init__(self, algorithm: Optional[str] = None) -> None:
        self.algorithm = algorithm
        self.findings: List[Finding] = []

    def emit(self, rule_id: str, message: str,
             step_index: Optional[int] = None,
             step_out: Optional[int] = None) -> Finding:
        rule = RULES[rule_id]  # KeyError on unregistered rule: a pass bug
        f = Finding(rule_id=rule_id, severity=rule.severity, message=message,
                    algorithm=self.algorithm, step_index=step_index,
                    step_out=step_out)
        self.findings.append(f)
        return f


def errors_only(findings: Sequence[Finding]) -> List[Finding]:
    return [f for f in findings if f.severity == "error"]


def format_findings(findings: Sequence[Finding]) -> str:
    return "\n".join(str(f) for f in findings)


class AnalysisError(ValueError):
    """An algorithm DAG failed static verification.

    Raised by the strict entry points (the ``enumerate_algorithms``
    debug hook, the :class:`~repro.serve.plan_cache.PlanService` publish
    guard, :func:`~repro.core.analysis.verify.assert_algorithms_valid`);
    carries the findings for programmatic consumption.
    """

    def __init__(self, message: str, findings: Sequence[Finding]) -> None:
        super().__init__(
            message + "\n" + format_findings(findings) if findings
            else message)
        self.findings: Tuple[Finding, ...] = tuple(findings)


# ----------------------------------------------------- built-in catalog ----
# Dataflow well-formedness.
DANGLING_REF = register_rule(
    "dangling-ref", "error",
    "operand references a step output never defined before use")
STALE_OUT_ID = register_rule(
    "stale-out-id", "error",
    "step redefines an output id an earlier step already produced")
UNKNOWN_KIND = register_rule(
    "unknown-kind", "error",
    "kernel kind has no registered shape/storage/FLOP rules")

# Shape inference.
SHAPE_MISMATCH = register_rule(
    "shape-mismatch", "error",
    "kernel dims are inconsistent with operand or output shapes")
WRONG_SYMM_SIDE = register_rule(
    "wrong-symm-side", "error",
    "SYMM's designated symmetric side is not a symmetric square operand")
BAD_STORAGE_TAG = register_rule(
    "bad-storage-tag", "error",
    "declared storage/symmetry tags are inconsistent with the kernel kind")

# Storage-state dataflow.
RAW_TRI_READ = register_rule(
    "raw-tri-read", "error",
    "general-matrix read of a triangle-stored value without TRI2FULL")
REDUNDANT_TRI2FULL = register_rule(
    "redundant-tri2full", "warning",
    "TRI2FULL applied to an operand that is already full-stored")

# Liveness.
DEAD_STEP = register_rule(
    "dead-step", "error",
    "step output never reaches the algorithm result")
PRUNE_DIVERGENCE = register_rule(
    "prune-divergence", "error",
    "liveness pass disagrees with algorithms._prune_dead_steps")

# FLOP accounting.
FLOP_MISMATCH = register_rule(
    "flop-mismatch", "error",
    "claimed FLOP count disagrees with the independent recount")

# Result contract.
BAD_RESULT = register_rule(
    "bad-result", "error",
    "final result has the wrong shape or is not full-stored")

# Family-level audits.
DUPLICATE_KEY = register_rule(
    "duplicate-key", "error",
    "two enumerated algorithms share a canonical key (dedup unsound)")
