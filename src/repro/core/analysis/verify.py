"""The verifier: run every analysis pass over algorithms and families.

Entry points, from narrowest to widest:

* :func:`verify_algorithm` — one :class:`~repro.core.algorithms.
  Algorithm`: shape inference → storage dataflow → liveness → FLOP
  recount → result contract. Pure; executes nothing.
* :func:`verify_algorithms` — a family's worth of algorithms (one
  expression instance): per-algorithm passes + the family-level
  canonical-key dedup audit + per-algorithm result-shape check against
  the expression's own dims.
* :func:`verify_family` — an :class:`~repro.core.expressions.
  ExpressionSpec` (or CLI name) at one instance point: enumerates, then
  :func:`verify_algorithms`.
* :func:`verify_zoo` — every registered family across named grids: the
  CLI (``python -m repro.core.analysis``) and the ``analysis-smoke`` CI
  job run this.

:func:`assert_algorithms_valid` is the raising wrapper used by the
``enumerate_algorithms`` debug hook and the serving publish guard
(:class:`repro.serve.plan_cache.PlanService`): any *error*-severity
finding raises :class:`~repro.core.analysis.findings.AnalysisError`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

from ..algorithms import Algorithm
from ..expr import Chain, bind_dims
from ..expressions import ExpressionSpec, get_spec, registered_names
from .findings import (
    AnalysisError,
    Collector,
    Finding,
    RULES,
    errors_only,
)
from .flopcheck import check_flops
from .liveness import check_family_dedup, check_liveness
from .shapes import infer_shapes
from .storage import check_storage


def verify_algorithm(
    algo: Algorithm,
    expect_rows: Optional[int] = None,
    expect_cols: Optional[int] = None,
) -> List[Finding]:
    """Statically verify one algorithm; returns all findings (may be []).

    ``expect_rows``/``expect_cols`` pin the result shape to the
    expression the algorithm claims to evaluate (pass both or neither).
    Nothing is executed: every check is over the step-DAG's declared
    structure.
    """
    collector = Collector(algorithm=algo.name)
    env = infer_shapes(algo, collector)
    check_storage(algo, env, collector)
    check_liveness(algo, collector)
    check_flops(algo, collector)
    del env  # passes that need the environment already consumed it
    _check_result(algo, collector, expect_rows, expect_cols)
    return collector.findings


def _check_result(algo: Algorithm, collector: Collector,
                  expect_rows: Optional[int],
                  expect_cols: Optional[int]) -> None:
    if not algo.steps:
        collector.emit("bad-result", "algorithm has no steps")
        return
    final = algo.steps[-1]
    idx = len(algo.steps) - 1
    if final.out_storage != "full":
        collector.emit(
            "bad-result",
            f"result is {final.out_storage!r}-stored; consumers expect a "
            f"full matrix (the enumerator appends a tri2full)",
            step_index=idx, step_out=final.out)
    if expect_rows is not None and expect_cols is not None and (
            (final.out_rows, final.out_cols) != (expect_rows, expect_cols)):
        collector.emit(
            "bad-result",
            f"result is {final.out_rows}x{final.out_cols}; the expression "
            f"evaluates to {expect_rows}x{expect_cols}",
            step_index=idx, step_out=final.out)


def verify_algorithms(
    algos: Sequence[Algorithm],
    chain: Optional[Chain] = None,
    env: Optional[Dict[str, int]] = None,
) -> List[Finding]:
    """Verify a family of algorithms for one expression instance.

    Runs every per-algorithm pass plus the family-level dedup audit.
    With ``chain`` given, each algorithm's result shape is checked
    against the expression's boundary dims (``env`` resolves any
    symbolic dims, as in :func:`repro.core.expr.bind_dims`).
    """
    expect_rows: Optional[int] = None
    expect_cols: Optional[int] = None
    if chain is not None:
        dims = bind_dims(chain, env or {})
        expect_rows, expect_cols = dims[0], dims[-1]
    findings: List[Finding] = []
    for algo in algos:
        findings.extend(verify_algorithm(algo, expect_rows=expect_rows,
                                         expect_cols=expect_cols))
    family_collector = Collector(algorithm=None)
    check_family_dedup(algos, family_collector)
    findings.extend(family_collector.findings)
    return findings


def verify_family(spec: Union[str, ExpressionSpec],
                  point: Sequence[int]) -> List[Finding]:
    """Enumerate one family instance and verify every algorithm of it."""
    if isinstance(spec, str):
        spec = get_spec(spec)
    chain = spec.chain(point)
    return verify_algorithms(spec.algorithms(point), chain=chain)


def assert_algorithms_valid(
    algos: Sequence[Algorithm],
    chain: Optional[Chain] = None,
    env: Optional[Dict[str, int]] = None,
    context: str = "",
) -> None:
    """Raise :class:`AnalysisError` on any error-severity finding."""
    errors = errors_only(verify_algorithms(algos, chain=chain, env=env))
    if errors:
        where = f" for {context}" if context else ""
        raise AnalysisError(
            f"static analysis rejected {len(errors)} error finding(s) in "
            f"{len(algos)} algorithm(s){where}:", errors)


# --------------------------------------------------------- zoo-wide lint ---


@dataclasses.dataclass(frozen=True)
class FamilyLint:
    """Per-(family, grid) lint summary for one zoo run."""

    family: str
    grid: str
    instances: int
    algorithms: int
    findings: Tuple[Finding, ...]


@dataclasses.dataclass(frozen=True)
class ZooLint:
    """Whole-zoo lint result (what the CLI prints and CI gates on)."""

    rows: Tuple[FamilyLint, ...]
    seconds: float

    @property
    def instances(self) -> int:
        return sum(r.instances for r in self.rows)

    @property
    def algorithms(self) -> int:
        return sum(r.algorithms for r in self.rows)

    @property
    def findings(self) -> List[Finding]:
        return [f for r in self.rows for f in r.findings]

    @property
    def rules_run(self) -> int:
        return len(RULES)


def verify_zoo(
    grids: Sequence[str] = ("smoke",),
    exprs: Optional[Sequence[str]] = None,
) -> ZooLint:
    """Lint every algorithm of every family across the named grids.

    ``exprs`` defaults to every registered family. Grids unknown to a
    family raise (same contract as ``ExpressionSpec.grid``); the
    standard named grids are defined for every family.
    """
    names = list(exprs) if exprs is not None else registered_names()
    rows: List[FamilyLint] = []
    t0 = time.perf_counter()
    for name in names:
        spec = get_spec(name)
        for grid_name in grids:
            grid = spec.grid(grid_name)
            instances = 0
            algorithms = 0
            found: List[Finding] = []
            for point in grid.points():
                algos = spec.algorithms(point)
                found.extend(verify_algorithms(
                    algos, chain=spec.chain(point)))
                instances += 1
                algorithms += len(algos)
            rows.append(FamilyLint(
                family=name, grid=grid_name, instances=instances,
                algorithms=algorithms, findings=tuple(found)))
    return ZooLint(rows=tuple(rows), seconds=time.perf_counter() - t0)
