"""Storage-state dataflow over an algorithm step-DAG.

Every value in a step-DAG sits at a point of a small storage lattice:

* ``full`` + general — an ordinary dense matrix;
* ``full`` + symmetric — logically symmetric, both triangles present
  (a mirrored SYRK output, a symmetric leaf);
* ``tri`` + symmetric — only one triangle physically written (a raw
  SYRK output); the other triangle is garbage.

``tri`` + general is unrepresentable (the enumeration invariant "tri
implies symmetric"; :mod:`.shapes` flags it as ``bad-storage-tag``).

This pass checks every *read* against what the kernel can legally
consume. The read modes per kernel kind live in an extensible registry
(:func:`register_kernel_reads`):

* ``general`` — the kernel reads the operand as plain dense data; a
  ``tri``-stored operand is the PR 3 bug class (upper-triangle zeros
  flowing into a GEMM/SYMM) → ``raw-tri-read``.
* ``symmetric`` — the kernel consumes the operand's triangle directly
  (SYMM's symmetric side); ``tri`` or ``full`` storage both legal.
* ``mirror`` — TRI2FULL's input: expected to be ``tri``; a ``full``
  input is legal but wasteful → ``redundant-tri2full`` (warning).

SYRK's recorded ``rhs`` (the transpose twin) is provenance, not a read,
and is not checked here.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from ..algorithms import Algorithm, Step
from .findings import Collector
from .shapes import ValueInfo, resolve

#: (operand label, reference, read mode) triples for one step.
Read = Tuple[str, object, str]

ReadsRule = Callable[[Step], Tuple[Read, ...]]

KERNEL_READS: Dict[str, ReadsRule] = {}

READ_MODES: Tuple[str, ...] = ("general", "symmetric", "mirror")


def register_kernel_reads(kind: str, rule: ReadsRule) -> ReadsRule:
    """Register the read-mode rule for one kernel kind."""
    if kind in KERNEL_READS:
        raise ValueError(f"reads rule for kind {kind!r} already registered")
    KERNEL_READS[kind] = rule
    return rule


def _gemm_reads(step: Step) -> Tuple[Read, ...]:
    return (("lhs", step.lhs, "general"), ("rhs", step.rhs, "general"))


def _syrk_reads(step: Step) -> Tuple[Read, ...]:
    return (("lhs", step.lhs, "general"),)


def _symm_reads(step: Step) -> Tuple[Read, ...]:
    if step.symm_side == "R":
        return (("lhs", step.lhs, "general"), ("rhs", step.rhs, "symmetric"))
    return (("lhs", step.lhs, "symmetric"), ("rhs", step.rhs, "general"))


def _tri2full_reads(step: Step) -> Tuple[Read, ...]:
    return (("lhs", step.lhs, "mirror"),)


register_kernel_reads("gemm", _gemm_reads)
register_kernel_reads("syrk", _syrk_reads)
register_kernel_reads("symm", _symm_reads)
register_kernel_reads("tri2full", _tri2full_reads)


def registered_read_kinds() -> List[str]:
    return sorted(KERNEL_READS)


def check_storage(algo: Algorithm, env: Dict[int, ValueInfo],
                  collector: Collector) -> None:
    """Check every operand read against the storage lattice.

    ``env`` is the step-output environment from
    :func:`repro.core.analysis.shapes.infer_shapes`; dangling references
    resolve to ``None`` and are skipped here (already reported).
    ``unknown-kind`` is likewise :mod:`.shapes`' report — a kind missing
    from this registry but present there is still surfaced, since both
    registries must be extended together.
    """
    for i, step in enumerate(algo.steps):
        rule = KERNEL_READS.get(step.call.kind)
        if rule is None:
            if step.call.kind in _shape_kinds():
                collector.emit(
                    "unknown-kind",
                    f"kernel kind {step.call.kind!r} has a shape rule but "
                    f"no reads rule; register one via "
                    f"repro.core.analysis.register_kernel_reads",
                    step_index=i, step_out=step.out)
            continue
        for label, ref, mode in rule(step):
            info = resolve(ref, env)
            if info is None:
                continue
            if mode == "general" and info.storage == "tri":
                collector.emit(
                    "raw-tri-read",
                    f"{step.call.kind} reads {label} as a general matrix "
                    f"but it is triangle-stored; a tri2full step must "
                    f"mirror it first (the PR 3 bug class)",
                    step_index=i, step_out=step.out)
            elif mode == "mirror" and info.storage == "full":
                collector.emit(
                    "redundant-tri2full",
                    f"tri2full {label} is already full-stored; the mirror "
                    f"is pure wasted traffic",
                    step_index=i, step_out=step.out)


def _shape_kinds() -> Tuple[str, ...]:
    from .shapes import KERNEL_SHAPE_RULES
    return tuple(KERNEL_SHAPE_RULES)
