"""Liveness and dedup soundness over enumerated algorithms.

Two checks:

* **Dead steps** — a step whose output never reaches the algorithm's
  result (the last step's output) is wasted work the enumerator's DCE
  (:func:`repro.core.algorithms._prune_dead_steps`) should have removed;
  one surviving is an enumeration bug → ``dead-step``. The pass computes
  its own live set with the same dependency convention (SYRK and
  TRI2FULL consume only ``lhs``; SYRK's ``rhs`` is the transpose twin,
  same data) and then *cross-checks* against ``_prune_dead_steps``
  itself: if the two disagree on which steps survive, the convention has
  drifted and every FLOP total downstream is suspect →
  ``prune-divergence``.

* **Family dedup** — :func:`repro.core.algorithms.canonical_key` is the
  identity enumeration dedups on; two algorithms in one family sharing a
  key means dedup is unsound (the PR 3 id-shift bug class) →
  ``duplicate-key``.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Set, Tuple

from ..algorithms import Algorithm, Step, _prune_dead_steps, canonical_key
from .findings import Collector


def _step_deps(step: Step) -> Tuple[object, ...]:
    """Data dependencies of a step (the _prune_dead_steps convention)."""
    if step.call.kind in ("syrk", "tri2full"):
        return (step.lhs,)
    return (step.lhs, step.rhs)


def live_out_ids(steps: Sequence[Step]) -> Set[int]:
    """Output ids reachable from the result (the last step's output)."""
    if not steps:
        return set()
    live: Set[int] = {steps[-1].out}
    for step in reversed(steps):
        if step.out not in live:
            continue
        live.update(d for d in _step_deps(step) if isinstance(d, int))
    return live


def check_liveness(algo: Algorithm, collector: Collector) -> None:
    """Emit ``dead-step`` per unreachable step + the DCE cross-check."""
    steps = algo.steps
    if not steps:
        return
    live = live_out_ids(steps)
    dead = [(i, s) for i, s in enumerate(steps) if s.out not in live]
    for i, step in dead:
        collector.emit(
            "dead-step",
            f"{step.call.kind} output {step.out} never reaches the result "
            f"(out={steps[-1].out}); the enumerator's DCE should have "
            f"pruned it",
            step_index=i, step_out=step.out)
    # Cross-check: the enumerator's own pruner must agree on the
    # surviving set, else the dependency convention has drifted between
    # enumeration and analysis.
    pruned = _prune_dead_steps(steps, steps[-1].out)
    pruned_ids = [s.out for s in pruned]
    expected_ids = [s.out for s in steps if s.out in live]
    if pruned_ids != expected_ids:
        collector.emit(
            "prune-divergence",
            f"liveness keeps outputs {expected_ids} but "
            f"_prune_dead_steps keeps {pruned_ids}")


def check_family_dedup(algos: Sequence[Algorithm],
                       collector: Collector) -> None:
    """Emit ``duplicate-key`` for every canonical-key collision."""
    seen: Dict[Tuple[object, ...], str] = {}
    for algo in algos:
        try:
            key = canonical_key(algo.steps)
        except KeyError:
            # Renumbering hit a dangling step ref; the per-algorithm
            # pass already reported it, and no key means no collision.
            continue
        first = seen.get(key)
        if first is not None:
            collector.emit(
                "duplicate-key",
                f"algorithms {first!r} and {algo.name!r} share a canonical "
                f"key: enumeration dedup is unsound for this family")
        else:
            seen[key] = algo.name


def duplicate_key_groups(
        algos: Sequence[Algorithm]) -> List[List[str]]:
    """Names of algorithms grouped by shared canonical key (audit API)."""
    groups: Dict[Tuple[object, ...], List[str]] = {}
    for algo in algos:
        try:
            key = canonical_key(algo.steps)
        except KeyError:
            continue
        groups.setdefault(key, []).append(algo.name)
    return [names for names in groups.values() if len(names) > 1]
