"""Static plan verifier: analysis passes over algorithm step-DAGs.

Nothing in this package executes a kernel. Every check is over the
*declared* structure of an :class:`~repro.core.algorithms.Algorithm` —
shapes, storage tags, step wiring, FLOP claims — so it can run over the
whole expression zoo in milliseconds, inside enumeration (debug hook),
inside serving (publish guard), and in CI (``analysis-smoke``).

Entry points::

    from repro.core.analysis import verify_algorithm, verify_family

    findings = verify_family("atab", (64, 96))
    assert not findings

CLI::

    python -m repro.core.analysis              # lint the whole zoo
    python -m repro.core.analysis --mutants    # mutation-catch gate

Extension points (ROADMAP-3 kernels plug in here): per-kind shape rules
(:func:`register_kernel_shape`), read modes
(:func:`register_kernel_reads`), FLOP nodes
(:func:`register_flop_node`), and lint rules (:func:`register_rule`).
See docs/analysis.md for the rule catalog and a worked custom-kernel
example.
"""

from __future__ import annotations

from .findings import (
    AnalysisError,
    Collector,
    Finding,
    Rule,
    RULES,
    errors_only,
    format_findings,
    register_rule,
    registered_rules,
)
from .flopcheck import (
    recount_call,
    register_flop_node,
    registered_flop_kinds,
)
from .liveness import duplicate_key_groups, live_out_ids
from .mutants import (
    MUTANT_CLASSES,
    MutantClass,
    MutationOutcome,
    mutant_names,
    mutation_catch_rate,
    run_mutation_suite,
)
from .shapes import ValueInfo, infer_shapes, register_kernel_shape
from .storage import register_kernel_reads, registered_read_kinds
from .verify import (
    FamilyLint,
    ZooLint,
    assert_algorithms_valid,
    verify_algorithm,
    verify_algorithms,
    verify_family,
    verify_zoo,
)

__all__ = [
    "AnalysisError",
    "Collector",
    "FamilyLint",
    "Finding",
    "MUTANT_CLASSES",
    "MutantClass",
    "MutationOutcome",
    "RULES",
    "Rule",
    "ValueInfo",
    "ZooLint",
    "assert_algorithms_valid",
    "duplicate_key_groups",
    "errors_only",
    "format_findings",
    "infer_shapes",
    "live_out_ids",
    "mutant_names",
    "mutation_catch_rate",
    "recount_call",
    "register_flop_node",
    "register_kernel_reads",
    "register_kernel_shape",
    "register_rule",
    "registered_flop_kinds",
    "registered_read_kinds",
    "registered_rules",
    "run_mutation_suite",
    "verify_algorithm",
    "verify_algorithms",
    "verify_family",
    "verify_zoo",
]
