"""Independent FLOP recount for every kernel call in a step-DAG.

The paper's whole argument rests on the FLOP number attached to each
algorithm being *right*; this pass re-derives it from first principles —
output-entry count × arithmetic per entry — through a
``functools.singledispatch`` walker over per-kind node types (the tsfc
``flop_count.py`` idiom), and fails on any disagreement with what the
production accounting (:meth:`repro.core.flops.KernelCall.flops` /
:func:`repro.core.flops.total_flops`) claims.

The derivations are deliberately *not* copies of the formulas in
:mod:`repro.core.flops`:

* GEMM: ``m·n`` output entries, each a length-``k`` dot product =
  ``k`` multiplies + ``k`` adds           → ``m·n·(2k)``  (≡ 2mnk)
* SYRK: one triangle of an ``m×m`` product = ``m(m+1)/2`` entries ×
  ``2k``                                  → ``k·m·(m+1)`` (≡ (m+1)mk)
* SYMM: an ``s×o`` product against an ``s×s`` operand = ``s·o``
  entries × ``2s``                        → ``s·o·(2s)``  (≡ 2s²o)
* TRI2FULL: data movement only            → 0

A drift in either formulation — a botched edit to ``flops.py``, or a
:class:`~repro.core.flops.KernelCall` subclass lying through its
``flops`` property — trips ``flop-mismatch`` on every affected
algorithm. New kernel kinds register a node type via
:func:`register_flop_node` plus a ``recount.register`` handler (see
docs/analysis.md).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

from ..algorithms import Algorithm
from ..flops import KernelCall, total_flops
from .findings import Collector

#: kind -> dims-tuple -> typed node for the singledispatch walker.
NodeBuilder = Callable[[Tuple[int, ...]], object]

FLOP_NODES: Dict[str, NodeBuilder] = {}


def register_flop_node(kind: str, builder: NodeBuilder) -> NodeBuilder:
    """Register the dims->node builder for one kernel kind."""
    if kind in FLOP_NODES:
        raise ValueError(f"flop node for kind {kind!r} already registered")
    FLOP_NODES[kind] = builder
    return builder


@dataclasses.dataclass(frozen=True)
class GemmFlops:
    m: int
    n: int
    k: int


@dataclasses.dataclass(frozen=True)
class SyrkFlops:
    m: int
    k: int


@dataclasses.dataclass(frozen=True)
class SymmFlops:
    s: int
    o: int


@dataclasses.dataclass(frozen=True)
class Tri2FullFlops:
    m: int


@functools.singledispatch
def recount(node: object) -> int:
    """First-principles FLOPs of one typed kernel node."""
    raise NotImplementedError(
        f"no recount handler for {type(node).__name__}; register one via "
        f"recount.register")


@recount.register
def _recount_gemm(node: GemmFlops) -> int:
    # m·n output entries, each a length-k dot: k multiplies + k adds.
    return node.m * node.n * (node.k + node.k)


@recount.register
def _recount_syrk(node: SyrkFlops) -> int:
    # One triangle (incl. diagonal): m(m+1)/2 entries × 2k each.
    return (node.m * (node.m + 1) // 2) * (node.k + node.k)


@recount.register
def _recount_symm(node: SymmFlops) -> int:
    # s·o output entries, each a length-s dot against the symmetric op.
    return node.s * node.o * (node.s + node.s)


@recount.register
def _recount_tri2full(node: Tri2FullFlops) -> int:
    # Pure data movement; the paper charges the copy zero FLOPs (which
    # is itself part of why FLOPs mislead).
    return 0


register_flop_node("gemm", lambda d: GemmFlops(*d))
register_flop_node("syrk", lambda d: SyrkFlops(*d))
register_flop_node("symm", lambda d: SymmFlops(*d))
register_flop_node("tri2full", lambda d: Tri2FullFlops(*d))


def recount_call(call: KernelCall) -> Optional[int]:
    """Independent FLOPs of one call (None: unregistered kind)."""
    builder = FLOP_NODES.get(call.kind)
    if builder is None:
        return None
    try:
        node = builder(call.dims)
    except TypeError:
        return None  # wrong arity: shapes pass already flagged it
    return recount(node)


def registered_flop_kinds() -> List[str]:
    return sorted(FLOP_NODES)


def check_flops(algo: Algorithm, collector: Collector) -> None:
    """Compare claimed per-call and total FLOPs against the recount."""
    recounted_total = 0
    all_counted = True
    for i, step in enumerate(algo.steps):
        call = step.call
        independent = recount_call(call)
        if independent is None:
            all_counted = False
            if call.kind in FLOP_NODES:
                continue  # arity error, already reported by shapes
            collector.emit(
                "unknown-kind",
                f"kernel kind {call.kind!r} has no registered FLOP node; "
                f"register one via repro.core.analysis.register_flop_node",
                step_index=i, step_out=step.out)
            continue
        recounted_total += independent
        claimed = call.flops
        if claimed != independent:
            collector.emit(
                "flop-mismatch",
                f"{call!r} claims {claimed} FLOPs; first-principles "
                f"recount says {independent}",
                step_index=i, step_out=step.out)
    if not all_counted:
        return
    for label, claimed_total in (("total_flops", total_flops(algo.calls)),
                                 ("Algorithm.flops", algo.flops)):
        if claimed_total != recounted_total:
            collector.emit(
                "flop-mismatch",
                f"{label} claims {claimed_total} for the whole algorithm; "
                f"recount sums to {recounted_total}")
