"""Atlas replay: score every discriminant against persisted ground truth.

The anomaly atlas (:mod:`repro.core.sweep`) records, per instance, the
measured time of *every* algorithm — which is exactly what is needed to
answer the question the paper leaves open: **which discriminant is best,
and by how much?** This module replays persisted atlases through the
discriminant registry (:mod:`repro.core.discriminants`) and scores each
policy without re-measuring anything:

* **top-1 accuracy** — fraction of instances where the policy's first
  pick is a member of the fastest set (time ties resolved with the same
  ``rel_tol`` as classification);
* **time regret** (mean and p95) — relative wall time lost by the pick
  vs. the fastest algorithm (:func:`repro.core.anomaly.pick_regret`);
* **anomaly recall / precision** — Experiment 3's confusion matrix
  (paper Tables 1–2: 75–92 % recall) generalized to *any* policy: each
  discriminant's ``predict_times`` yields a predicted classification that
  is scored against the ground-truth classification.

Measurement-backed policies (``measured``, ``rankk``) are replayed
through :class:`~repro.core.discriminants.DiscriminantContext.times` —
the atlas's recorded times stand in for live execution, so ``measured``
scores a regret of exactly 0 on its own atlas (a property the tests pin).

Entry points: :func:`evaluate_discriminants` (records in hand),
:func:`evaluate_atlas` (a path or :class:`~repro.core.sweep.AnomalyAtlas`),
``python -m repro.core.sweep --mode evaluate --discriminants a,b,c`` (the
CLI), and ``benchmarks/discriminant_bench.py`` (the perf-trajectory rows).

Atlases written before the execution-backend registry existed carry a
fingerprint without a ``backend`` key; :func:`load_atlas_records`
normalizes such legacy headers (``backend="blas"``, the only executor
that existed then) instead of crashing, so years of accumulated ground
truth stay usable as evaluation data.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .anomaly import ConfusionMatrix, classify, pick_regret
from .discriminants import (
    DiscriminantContext,
    get_discriminant,
    registered_discriminants,
)
from .expressions import ExpressionSpec, find_spec
from .perfmodel import KernelProfile
from .profile_store import HardwareFingerprint
from .sweep import (
    ATLAS_SCHEMA_VERSION,
    AnomalyAtlas,
    AtlasError,
    Instance,
    _instance_from_json,
)

# ------------------------------------------------------------------ scores --


@dataclasses.dataclass
class DiscriminantScore:
    """One policy's scoreboard row over one replayed record set.

    ``error`` is set (and every metric zeroed) when the policy raised
    while scoring — e.g. ``perfmodel`` handed a partially calibrated
    table that ``KeyError``s on an unmeasured kernel kind. Per-policy
    failures never abort the scoreboard; defects in the *records* (an
    atlas swept under a different enumeration) still raise from
    :func:`evaluate_discriminants`, since they invalidate every row.
    """

    discriminant: str
    n_instances: int
    top1_hits: int
    regrets: Tuple[float, ...]
    confusion: Optional[ConfusionMatrix]
    error: Optional[str] = None

    @property
    def top1_accuracy(self) -> float:
        """Fraction of instances whose pick is in the fastest set."""
        return self.top1_hits / self.n_instances if self.n_instances else 0.0

    @property
    def mean_regret(self) -> float:
        return float(np.mean(self.regrets)) if self.regrets else 0.0

    @property
    def p95_regret(self) -> float:
        return float(np.percentile(self.regrets, 95)) if self.regrets \
            else 0.0

    @property
    def recall(self) -> Optional[float]:
        """Anomaly recall of the predicted classifications (None: the
        policy exposes no predicted times, so no classification exists)."""
        return self.confusion.recall if self.confusion is not None else None

    @property
    def precision(self) -> Optional[float]:
        return self.confusion.precision if self.confusion is not None \
            else None

    def row(self) -> str:
        if self.error is not None:
            return f"{self.discriminant:<10} failed: {self.error}"
        rec = f"{self.recall:.3f}" if self.recall is not None else "n/a"
        pre = f"{self.precision:.3f}" if self.precision is not None \
            else "n/a"
        return (f"{self.discriminant:<10} top1={self.top1_accuracy:.3f} "
                f"mean_regret={self.mean_regret:.1%} "
                f"p95_regret={self.p95_regret:.1%} "
                f"recall={rec} precision={pre}")


@dataclasses.dataclass
class EvaluationResult:
    """The scoreboard: every requested policy scored on one record set."""

    spec_name: str
    threshold: float
    n_instances: int
    n_anomalies: int
    scores: Dict[str, DiscriminantScore]

    def summary(self) -> str:
        lines = [f"evaluated {len(self.scores)} discriminants on "
                 f"{self.n_instances} instances of {self.spec_name} "
                 f"({self.n_anomalies} anomalies at "
                 f"threshold={self.threshold:g})"]
        for name in self.scores:
            lines.append("  " + self.scores[name].row())
        return "\n".join(lines)


def evaluate_discriminants(
    spec: ExpressionSpec,
    records: Sequence[Instance],
    discriminants: Optional[Sequence[str]] = None,
    *,
    profile: Optional[KernelProfile] = None,
    threshold: float = 0.10,
    dtype_bytes: int = 8,
) -> EvaluationResult:
    """Score discriminants against fully measured records — the core loop.

    ``records`` come from an atlas (or any :func:`~repro.core.sweep.sweep`
    result): each carries every algorithm's measured time. Ground truth is
    re-classified from those raw times at ``threshold`` (so one atlas can
    be evaluated at a different threshold than it was swept with — the
    paper itself uses 10 % for Experiment 1 and 5 % for Experiment 3).
    ``profile`` feeds the profile-consuming policies; measurement-backed
    policies replay the recorded times instead of executing anything.

    Accuracy/regret score the pick of each policy's own :meth:`rank` —
    the ordering the planner would actually execute — while anomaly
    classification comes from its :meth:`predict_times`. A policy that
    raises while scoring (``perfmodel`` over a partial calibration) gets
    an ``error`` row instead of aborting the other policies; defects in
    the records themselves still raise, since every row would be wrong.
    """
    names = list(discriminants) if discriminants is not None \
        else registered_discriminants()
    # Dedupe, order-preserving: the per-name counters below are shared,
    # so a repeated name would double-count hits (top-1 accuracy > 1).
    names = list(dict.fromkeys(names))
    policies = dict(zip(names, (get_discriminant(n) for n in names)))
    hits = {n: 0 for n in names}
    regrets: Dict[str, List[float]] = {n: [] for n in names}
    confusion: Dict[str, Optional[ConfusionMatrix]] = {
        n: ConfusionMatrix() for n in names}
    failed: Dict[str, str] = {}
    n_anomalies = 0
    for inst in records:
        algos = spec.algorithms(inst.point)
        expected = {a.name for a in algos}
        got = set(inst.times)
        if expected != got:
            missing = sorted(expected - got)
            extra = sorted(got - expected)
            raise ValueError(
                f"record at {inst.point} "
                f"{'lacks times for ' + str(missing) if missing else ''}"
                f"{' and ' if missing and extra else ''}"
                f"{'has times for unknown ' + str(extra) if extra else ''} "
                f"— was the atlas swept with a different enumeration of "
                f"{spec.name}?")
        flops = {a.name: a.flops for a in algos}
        actual = classify(inst.times, flops, threshold=threshold)
        n_anomalies += actual.is_anomaly
        ctx = DiscriminantContext(profile=profile, dtype_bytes=dtype_bytes,
                                  times=inst.times)
        for name in names:
            if name in failed:
                continue
            d = policies[name]
            try:
                ranked = d.rank(algos, ctx)
                pred_times = d.predict_times(algos, ctx)
            except Exception as e:
                failed[name] = f"{type(e).__name__}: {e}"
                continue
            if pred_times is None:
                confusion[name] = None
            else:
                cm = confusion[name]
                if cm is not None:
                    predicted = classify(pred_times, flops,
                                         threshold=threshold)
                    cm.add(actual.is_anomaly, predicted.is_anomaly)
            pick = ranked[0].name
            hits[name] += pick in actual.fastest
            regrets[name].append(pick_regret(inst.times, pick))

    def _score(n: str) -> DiscriminantScore:
        if n in failed:
            return DiscriminantScore(
                discriminant=n, n_instances=len(records), top1_hits=0,
                regrets=(), confusion=None, error=failed[n])
        return DiscriminantScore(
            discriminant=n, n_instances=len(records),
            top1_hits=hits[n], regrets=tuple(regrets[n]),
            confusion=confusion[n])

    return EvaluationResult(
        spec_name=spec.name,
        threshold=float(threshold),
        n_instances=len(records),
        n_anomalies=n_anomalies,
        scores={n: _score(n) for n in names},
    )


# ----------------------------------------------------- atlas replay loading --


@dataclasses.dataclass
class AtlasReplay:
    """A persisted atlas loaded for evaluation (read-only, any machine's).

    Unlike :class:`~repro.core.sweep.AnomalyAtlas`, no fingerprint match
    against *this* process is enforced — evaluation replays recorded
    times, it never appends — and legacy pre-backend-registry headers are
    normalized rather than rejected (``legacy`` records that this
    happened).
    """

    path: Path
    spec_name: str
    threshold: float
    fingerprint: HardwareFingerprint
    records: List[Instance]
    skipped_lines: int = 0
    legacy: bool = False


def _normalize_fingerprint(d: Optional[dict]) -> Tuple[HardwareFingerprint,
                                                       bool]:
    """Fingerprint from a header dict, tolerating pre-registry layouts.

    Atlases written before the execution-backend registry have no
    ``backend`` key (every sweep ran the scipy BLAS protocol then), and
    the earliest ones lack ``dtype`` too. Defaults reconstruct what those
    sweeps actually measured.
    """
    d = dict(d or {})
    legacy = "backend" not in d
    d.setdefault("backend", "blas")
    d.setdefault("device", "unknown")
    d.setdefault("dtype", "float64")
    return HardwareFingerprint.from_dict(d), legacy


def load_atlas_records(path: Union[str, Path]) -> AtlasReplay:
    """Read any atlas file for replay — tolerant where appending is strict.

    Torn tails are skipped (and counted) exactly as the resumable loader
    does; header fingerprints are normalized via
    :func:`_normalize_fingerprint` instead of being matched against this
    machine.
    """
    path = Path(path)
    records: List[Instance] = []
    skipped = 0
    with path.open() as f:
        try:
            head = json.loads(f.readline())
        except json.JSONDecodeError:
            raise AtlasError(f"atlas {path} has an unreadable header")
        if head.get("kind") != "header":
            raise AtlasError(f"atlas {path} is missing its header")
        if head.get("version") != ATLAS_SCHEMA_VERSION:
            raise AtlasError(
                f"atlas {path} has schema version {head.get('version')!r}; "
                f"this build reads {ATLAS_SCHEMA_VERSION}")
        fp, legacy = _normalize_fingerprint(head.get("fingerprint"))
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(_instance_from_json(json.loads(line)))
            except (json.JSONDecodeError, KeyError, TypeError, ValueError):
                skipped += 1
    return AtlasReplay(
        path=path,
        spec_name=str(head.get("spec", "")),
        threshold=float(head.get("threshold", 0.10)),
        fingerprint=fp,
        records=records,
        skipped_lines=skipped,
        legacy=legacy,
    )


def evaluate_atlas(
    atlas: Union[str, Path, AnomalyAtlas, AtlasReplay],
    discriminants: Optional[Sequence[str]] = None,
    *,
    spec: Optional[ExpressionSpec] = None,
    profile: Optional[KernelProfile] = None,
    threshold: Optional[float] = None,
    dtype_bytes: int = 8,
    points: Optional[Sequence[Sequence[int]]] = None,
) -> EvaluationResult:
    """Replay one persisted atlas and score the requested discriminants.

    ``atlas`` is a path (loaded leniently — legacy headers normalize), an
    open :class:`AnomalyAtlas`, or a pre-loaded :class:`AtlasReplay`.
    ``spec`` defaults to resolving the atlas's recorded expression name
    through the zoo registry; ``threshold`` defaults to the atlas's own;
    ``points`` restricts evaluation to a subset (e.g. one grid) — points
    absent from the atlas are skipped.
    """
    if isinstance(atlas, (str, Path)):
        atlas = load_atlas_records(atlas)
    if isinstance(atlas, AnomalyAtlas):
        replay = AtlasReplay(
            path=atlas.path, spec_name=atlas.spec_name,
            threshold=atlas.threshold, fingerprint=atlas.fingerprint,
            records=atlas.records())
    else:
        replay = atlas
    if spec is None:
        spec = find_spec(replay.spec_name)
    records = replay.records
    if points is not None:
        want = {tuple(int(x) for x in p) for p in points}
        records = [r for r in records if r.point in want]
    return evaluate_discriminants(
        spec, records, discriminants,
        profile=profile,
        threshold=replay.threshold if threshold is None else threshold,
        dtype_bytes=dtype_bytes,
    )
