"""Algorithm enumeration for linear algebra expressions.

An *algorithm* (paper §3.2) is a sequence of kernel calls that evaluates an
expression. Two sources of multiplicity:

1. **Multiplication order** — the chain ``A@B@C@D`` can reduce any adjacent
   pair at each step: (n-1)! orderings for an n-operand chain (the paper's
   3! = 6 for ``ABCD``). Note this is *orderings*, not parenthesizations:
   ``(AB)(CD)`` computed AB-first and CD-first are distinct algorithms
   (paper's Algorithms 2 and 5) because inter-kernel cache effects differ.
2. **Kernel choice** — a Gram pair ``X·Xᵀ`` may use SYRK (triangle output) or
   GEMM; a symmetric operand may use SYMM (from either side) or GEMM; a
   triangle-stored operand used by GEMM needs a TRI2FULL copy first
   (paper's Algorithm 2 for ``AAᵀB``).

Gram pairs are detected by *structural fingerprint*, not leaf adjacency:
an intermediate that is the transpose of another (``(AB)`` next to
``(BᵀAᵀ)``) is a Gram pair too, enumerating the ``GEMM+SYRK`` algorithm
for ``(AB)(AB)ᵀ`` with the never-consumed transpose twin pruned from the
step DAG. Dedup keys are canonical over that DAG (renumbered step ids,
leaves by (base, transposed)), so identical sequences reached via
different search paths collapse.

The enumeration reproduces the paper's sets exactly: 6 algorithms for
``ABCD`` and 5 for ``AAᵀB`` (SYRK+SYMM, SYRK+TRI2FULL+GEMM, GEMM+SYMM,
GEMM+GEMM, GEMM(AᵀB)+GEMM).

For long chains full enumeration explodes as (n-1)!·kernel-choices, so
:func:`enumerate_algorithms` takes a cap, and :func:`optimal_chain_order`
provides the classic O(n³) dynamic program over parenthesizations for the
FLOPs-only discriminant (what Linnea/Julia do).
"""

from __future__ import annotations

import dataclasses
import itertools
import os
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from .expr import Chain, Transpose, bind_dims
from .flops import KernelCall, gemm, symm, syrk, total_flops, tri2full


@dataclasses.dataclass(frozen=True)
class Leaf:
    """Reference to an input operand.

    ``index`` — position in the chain; ``base`` — position of the first
    chain operand backed by the same underlying Matrix (a Gram pair's
    ``A`` and ``Aᵀ`` share a base, so executors materialize ONE array);
    ``transposed`` — whether this occurrence is the transposed view.
    """

    index: int
    base: int
    transposed: bool
    rows: int
    cols: int
    symmetric: bool = False
    storage: str = "full"


@dataclasses.dataclass(frozen=True)
class Step:
    """One kernel call producing intermediate ``out``.

    ``lhs``/``rhs`` reference either a Leaf or a previous Step's ``out`` id
    (int). ``call`` carries kind+dims+flops. For ``tri2full`` only ``lhs``
    is used; for ``syrk`` only ``lhs`` is *needed* (``rhs`` records the
    transpose twin for provenance and may be None when that operand was
    never materialized). ``symm_side`` disambiguates SYMM: 'L' multiplies
    the symmetric ``lhs`` from the left, 'R' the symmetric ``rhs`` from
    the right — the KernelCall dims are (s_dim, other_dim) either way, so
    calibration tables are side-agnostic while executors are not.
    """

    call: KernelCall
    lhs: object  # Leaf | int
    rhs: object  # Leaf | int | None
    out: int
    out_rows: int
    out_cols: int
    out_storage: str  # 'full' | 'tri'
    out_symmetric: bool
    symm_side: str = "L"


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """A complete kernel-call sequence evaluating the expression."""

    name: str
    steps: Tuple[Step, ...]

    @property
    def calls(self) -> Tuple[KernelCall, ...]:
        return tuple(s.call for s in self.steps)

    @property
    def flops(self) -> int:
        return total_flops(self.calls)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.name}: " + "; ".join(repr(c) for c in self.calls)


@dataclasses.dataclass(frozen=True)
class _Node:
    """Enumeration-time operand: either a leaf or an intermediate.

    ``fp``/``fpT`` are structural fingerprints of the value and of its
    transpose: a leaf is ``("L", base, transposed)`` and a product is
    ``("P", lhs.fp, rhs.fp)`` (with ``(X·Y)ᵀ = Yᵀ·Xᵀ``). Symmetric nodes
    normalize ``fp == fpT``, so ``rhs.fp == lhs.fpT`` detects *any* Gram
    pair ``X·Xᵀ`` — leaf or intermediate — in O(1) per pair.
    """

    ref: object  # Leaf | int (step out id)
    rows: int
    cols: int
    symmetric: bool
    storage: str  # 'full' | 'tri'
    fp: Tuple = ()
    fpT: Tuple = ()


def chain_leaves(c: Chain, dims: Sequence[int]) -> List[Leaf]:
    """The chain's operands as :class:`Leaf` references.

    Operands backed by the same underlying :class:`~repro.core.expr.Matrix`
    share a ``base`` (executors materialize one array per base).
    """
    leaves = []
    seen: Dict[int, int] = {}
    for i, op in enumerate(c.ops):
        mat = op.operand if isinstance(op, Transpose) else op
        base = seen.setdefault(id(mat), i)
        leaves.append(Leaf(index=i, base=base,
                           transposed=isinstance(op, Transpose),
                           rows=dims[i], cols=dims[i + 1],
                           symmetric=op.symmetric))
    return leaves


def _leaf_nodes(c: Chain, dims: Sequence[int]) -> List[_Node]:
    nodes = []
    for leaf in chain_leaves(c, dims):
        if leaf.symmetric:
            # Sᵀ = S: one canonical fingerprint for both views.
            fp = fpT = ("L", leaf.base, False)
        else:
            fp = ("L", leaf.base, leaf.transposed)
            fpT = ("L", leaf.base, not leaf.transposed)
        nodes.append(_Node(ref=leaf, rows=leaf.rows, cols=leaf.cols,
                           symmetric=leaf.symmetric, storage="full",
                           fp=fp, fpT=fpT))
    return nodes


def _is_gram(lhs: _Node, rhs: _Node) -> bool:
    """Is ``lhs @ rhs`` a Gram product ``X·Xᵀ`` (SYRK-able)?

    Fingerprint equality subsumes the adjacent-leaf case (``A·Aᵀ``,
    ``Aᵀ·A``) *and* transpose-equal intermediates (``(AB)·(BᵀAᵀ)``),
    which positional leaf inspection used to miss.
    """
    return rhs.fp == lhs.fpT


def _pair_kernels(
    lhs: _Node, rhs: _Node, gram: bool
) -> Iterator[Tuple[str, Tuple[str, ...], KernelCall, str, bool]]:
    """Yield (label, pres, call, out_storage, out_symmetric) for lhs@rhs.

    ``pres`` lists the sides ('L'/'R') whose triangle-stored operand must
    be mirrored to full (a tri2full step) before ``call`` runs. The rule:
    any operand a kernel reads as a *general* matrix must be full-stored —
    SYRK never touches its rhs, SYMM reads its symmetric side's triangle
    directly, everything else needs the mirror. This is per-operand, so a
    pair of two triangle-stored intermediates (a chain with two Gram
    pairs, e.g. ``A·Aᵀ·B·Bᵀ``) mirrors each side it consumes.
    """
    m, k, n = lhs.rows, lhs.cols, rhs.cols
    pre_l = ("L",) if lhs.storage == "tri" else ()
    pre_r = ("R",) if rhs.storage == "tri" else ()

    if gram:
        # SYRK reads lhs as general data; rhs (its transpose) is unused.
        yield "syrk", pre_l, syrk(m, k), "tri", True
        # GEMM computing the full symmetric product reads both sides.
        yield "gemm", pre_l + pre_r, gemm(m, n, k), "full", True
        return

    # Left operand symmetric → SYMM(side=L): lhs's triangle is read
    # directly (tri or full storage both fine); rhs is general.
    if lhs.symmetric and lhs.rows == lhs.cols:
        yield "symm", pre_r, symm(m, n), "full", False
        # tri2full then plain GEMM (paper's Algorithm 2 for AAᵀB).
        yield "gemm", pre_l + pre_r, gemm(m, n, k), "full", False
        return

    # Right operand symmetric → SYMM(side=R); lhs here is never tri
    # (tri storage implies a symmetric node, handled above).
    if rhs.symmetric and rhs.rows == rhs.cols:
        yield "symmR", (), symm(n, m), "full", False
        yield "gemm", pre_r, gemm(m, n, k), "full", False
        return

    # Plain product (tri implies symmetric, so both sides are full here).
    yield "gemm", (), gemm(m, n, k), "full", False


def _step_label(step: Step) -> str:
    if step.call.kind == "symm" and step.symm_side == "R":
        return "symmR"
    return step.call.kind


def _prune_dead_steps(steps: Tuple[Step, ...],
                      final: object) -> Tuple[Step, ...]:
    """Drop steps whose outputs never reach ``final`` (the result ref).

    A SYRK consumes only its ``lhs`` (the ``rhs`` is the same data,
    transposed), so an intermediate-Gram SYRK makes the step that
    materialized the transpose twin dead — removing it turns the wasteful
    "compute both then SYRK one" sequence into the intended
    "GEMM + SYRK" algorithm, and lets dedup collapse every search path
    that reaches it. Dead references surviving on a SYRK's ``rhs`` are
    rewritten to None.
    """
    live = {final} if isinstance(final, int) else set()
    for step in reversed(steps):
        if step.out not in live:
            continue
        deps = (step.lhs,) if step.call.kind in ("syrk", "tri2full") \
            else (step.lhs, step.rhs)
        live.update(d for d in deps if isinstance(d, int))
    kept = tuple(s for s in steps if s.out in live)
    out_ids = {s.out for s in kept}
    return tuple(
        dataclasses.replace(s, rhs=None)
        if s.call.kind == "syrk" and isinstance(s.rhs, int)
        and s.rhs not in out_ids else s
        for s in kept
    )


def canonical_key(steps: Sequence[Step]) -> Tuple:
    """Canonical identity of a kernel-call sequence over its step DAG.

    Step ``out`` ids come from a global counter, so the same sequence
    reached via different search paths carries different ids — keying on
    raw ``(lhs, rhs)`` refs lets such duplicates survive dedup. The
    canonical key renumbers intermediates by position and identifies
    leaves by ``(base, transposed)`` (occurrence index is cosmetic), so
    two sequences are equal iff they run the same kernels on the same
    data in the same order.
    """
    renum = {s.out: i for i, s in enumerate(steps)}

    def ref(r: object) -> object:
        if isinstance(r, int):
            return ("s", renum[r])
        if r is None:
            return None
        return ("l", r.base, r.transposed)

    return tuple((s.call, s.symm_side, ref(s.lhs), ref(s.rhs))
                 for s in steps)


#: Env var turning on post-enumeration static verification everywhere a
#: caller doesn't pass ``verify=`` explicitly (CI debug runs set this).
VERIFY_ENUMERATION_ENV = "REPRO_VERIFY_ENUMERATION"


def enumerate_algorithms(
    c: Chain,
    env: Optional[Dict[str, int]] = None,
    max_algorithms: int = 512,
    verify: Optional[bool] = None,
) -> List[Algorithm]:
    """Enumerate all kernel-call sequences evaluating chain ``c``.

    Reproduces the paper's algorithm sets: 6 for 4-operand chains, 5 for
    ``AAᵀB``. Enumeration is exhaustive in (ordering × kernel choice) up to
    ``max_algorithms``; Gram pairs are detected by structural fingerprint,
    so transpose-equal *intermediates* (``(AB)(AB)ᵀ``) enumerate their
    SYRK variant too, with dead transpose-twin steps pruned.

    ``verify=True`` runs the static plan verifier
    (:mod:`repro.core.analysis`) over the enumerated family and raises
    :class:`~repro.core.analysis.AnalysisError` on any error finding —
    a debug-mode self-check for enumeration changes. ``verify=None``
    (the default) defers to the ``REPRO_VERIFY_ENUMERATION`` env var so
    CI can switch the check on globally without touching call sites.
    """
    dims = bind_dims(c, env or {})
    leaves = _leaf_nodes(c, dims)

    out: List[Algorithm] = []
    seen: Dict[Tuple, None] = {}
    counter = itertools.count()

    def emit(steps: Tuple[Step, ...], final_ref: object) -> None:
        steps = _prune_dead_steps(steps, final_ref)
        key = canonical_key(steps)
        if key in seen:
            return
        seen[key] = None
        name = "+".join(_step_label(s) for s in steps)
        out.append(Algorithm(name=name, steps=steps))

    def rec(nodes: List[_Node], steps: Tuple[Step, ...]) -> None:
        if len(out) >= max_algorithms:
            return
        if len(nodes) == 1:
            final = nodes[0]
            steps_f = steps
            if final.storage == "tri":
                # Result must be materialized as a full matrix.
                sid = next(counter)
                steps_f = steps + (
                    Step(call=tri2full(final.rows), lhs=final.ref, rhs=None,
                         out=sid, out_rows=final.rows, out_cols=final.cols,
                         out_storage="full", out_symmetric=final.symmetric),
                )
                emit(steps_f, sid)
            else:
                emit(steps_f, final.ref)
            return
        for i in range(len(nodes) - 1):
            lhs, rhs = nodes[i], nodes[i + 1]
            gram = _is_gram(lhs, rhs)
            for label, pres, prod, ostore, osym in _pair_kernels(
                    lhs, rhs, gram):
                new_steps = list(steps)
                lref, rref = lhs.ref, rhs.ref
                # tri2full pre-calls mirror each consumed tri operand.
                for side in pres:
                    node = lhs if side == "L" else rhs
                    sid = next(counter)
                    new_steps.append(
                        Step(call=tri2full(node.rows),
                             lhs=lref if side == "L" else rref, rhs=None,
                             out=sid, out_rows=node.rows,
                             out_cols=node.cols, out_storage="full",
                             out_symmetric=True))
                    if side == "L":
                        lref = sid
                    else:
                        rref = sid
                oid = next(counter)
                new_steps.append(
                    Step(call=prod, lhs=lref, rhs=rref, out=oid,
                         out_rows=lhs.rows, out_cols=rhs.cols,
                         out_storage=ostore, out_symmetric=osym,
                         symm_side="R" if label == "symmR" else "L"))
                fp = ("P", lhs.fp, rhs.fp)
                fpT = ("P", rhs.fpT, lhs.fpT)
                if osym:
                    fp = fpT = min(fp, fpT)
                merged = _Node(ref=oid, rows=lhs.rows, cols=rhs.cols,
                               symmetric=osym, storage=ostore,
                               fp=fp, fpT=fpT)
                rec(nodes[:i] + [merged] + nodes[i + 2:], tuple(new_steps))

    rec(leaves, ())
    # Stable, human-auditable naming: ordinal + per-step kernel labels.
    named = [
        Algorithm(name=f"alg{i + 1}[{a.name}]", steps=a.steps)
        for i, a in enumerate(out)
    ]
    if verify is None:
        verify = bool(os.environ.get(VERIFY_ENUMERATION_ENV))
    if verify:
        # Lazy import: analysis depends on this module, not vice versa.
        from .analysis import assert_algorithms_valid

        assert_algorithms_valid(named, chain=c, env=env,
                                context=f"enumerate_algorithms({c!r})")
    return named


def optimal_chain_order(dims: Sequence[int]) -> Tuple[int, Tuple]:
    """Classic matrix-chain DP: min-FLOPs parenthesization.

    Returns (flops, tree) where tree is a nested tuple of operand indices.
    This is the FLOPs-only discriminant used by Linnea/Julia/Armadillo, i.e.
    the strategy whose reliability the paper interrogates. O(n³).
    """
    n = len(dims) - 1
    if n < 1:
        raise ValueError("empty chain")
    INF = float("inf")
    cost = [[0] * n for _ in range(n)]
    split = [[0] * n for _ in range(n)]
    for span in range(1, n):
        for i in range(n - span):
            j = i + span
            best, arg = INF, i
            for k in range(i, j):
                c = (cost[i][k] + cost[k + 1][j]
                     + 2 * dims[i] * dims[k + 1] * dims[j + 1])
                if c < best:
                    best, arg = c, k
            cost[i][j] = int(best)
            split[i][j] = arg

    def tree(i: int, j: int):
        if i == j:
            return i
        k = split[i][j]
        return (tree(i, k), tree(k + 1, j))

    return cost[0][n - 1], tree(0, n - 1)


def chain_flops_of_order(dims: Sequence[int], order: Sequence[int]) -> int:
    """FLOPs of reducing adjacent pairs in the given order.

    ``order`` lists, per step, the index of the left operand of the pair to
    merge, with indices referring to the *current* working list.
    """
    ds = list(dims)
    fl = 0
    for i in order:
        fl += 2 * ds[i] * ds[i + 1] * ds[i + 2]
        del ds[i + 1]
    return fl
