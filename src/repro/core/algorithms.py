"""Algorithm enumeration for linear algebra expressions.

An *algorithm* (paper §3.2) is a sequence of kernel calls that evaluates an
expression. Two sources of multiplicity:

1. **Multiplication order** — the chain ``A@B@C@D`` can reduce any adjacent
   pair at each step: (n-1)! orderings for an n-operand chain (the paper's
   3! = 6 for ``ABCD``). Note this is *orderings*, not parenthesizations:
   ``(AB)(CD)`` computed AB-first and CD-first are distinct algorithms
   (paper's Algorithms 2 and 5) because inter-kernel cache effects differ.
2. **Kernel choice** — a Gram pair ``A·Aᵀ`` may use SYRK (triangle output) or
   GEMM; a symmetric operand may use SYMM or GEMM; a triangle-stored operand
   used by GEMM needs a TRI2FULL copy first (paper's Algorithm 2 for
   ``AAᵀB``).

The enumeration reproduces the paper's sets exactly: 6 algorithms for
``ABCD`` and 5 for ``AAᵀB`` (SYRK+SYMM, SYRK+TRI2FULL+GEMM, GEMM+SYMM,
GEMM+GEMM, GEMM(AᵀB)+GEMM).

For long chains full enumeration explodes as (n-1)!·kernel-choices, so
:func:`enumerate_algorithms` takes a cap, and :func:`optimal_chain_order`
provides the classic O(n³) dynamic program over parenthesizations for the
FLOPs-only discriminant (what Linnea/Julia do).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from .expr import Chain, Matrix, Transpose, bind_dims, is_gram_pair
from .flops import KernelCall, gemm, symm, syrk, total_flops, tri2full


@dataclasses.dataclass(frozen=True)
class Leaf:
    """Reference to an input operand.

    ``index`` — position in the chain; ``base`` — position of the first
    chain operand backed by the same underlying Matrix (a Gram pair's
    ``A`` and ``Aᵀ`` share a base, so executors materialize ONE array);
    ``transposed`` — whether this occurrence is the transposed view.
    """

    index: int
    base: int
    transposed: bool
    rows: int
    cols: int
    symmetric: bool = False
    storage: str = "full"


@dataclasses.dataclass(frozen=True)
class Step:
    """One kernel call producing intermediate ``out``.

    ``lhs``/``rhs`` reference either a Leaf or a previous Step's ``out`` id
    (int). ``call`` carries kind+dims+flops. For ``tri2full`` only ``lhs`` is
    used.
    """

    call: KernelCall
    lhs: object  # Leaf | int
    rhs: object  # Leaf | int | None
    out: int
    out_rows: int
    out_cols: int
    out_storage: str  # 'full' | 'tri'
    out_symmetric: bool


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """A complete kernel-call sequence evaluating the expression."""

    name: str
    steps: Tuple[Step, ...]

    @property
    def calls(self) -> Tuple[KernelCall, ...]:
        return tuple(s.call for s in self.steps)

    @property
    def flops(self) -> int:
        return total_flops(self.calls)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"{self.name}: " + "; ".join(repr(c) for c in self.calls)


@dataclasses.dataclass(frozen=True)
class _Node:
    """Enumeration-time operand: either a leaf or an intermediate."""

    ref: object  # Leaf | int (step out id)
    rows: int
    cols: int
    symmetric: bool
    storage: str  # 'full' | 'tri'


def _leaf_nodes(c: Chain, dims: Sequence[int]) -> List[_Node]:
    nodes = []
    seen: Dict[int, int] = {}
    for i, op in enumerate(c.ops):
        r, co = dims[i], dims[i + 1]
        mat = op.operand if isinstance(op, Transpose) else op
        base = seen.setdefault(id(mat), i)
        leaf = Leaf(index=i, base=base,
                    transposed=isinstance(op, Transpose), rows=r, cols=co,
                    symmetric=op.symmetric)
        nodes.append(_Node(ref=leaf, rows=r, cols=co,
                           symmetric=leaf.symmetric, storage="full"))
    return nodes


def _same_leaf_gram(c: Chain, i: int) -> bool:
    """Is ops[i] @ ops[i+1] a Gram pair A·Aᵀ or Aᵀ·A of the same leaf?"""
    return is_gram_pair(c.ops[i], c.ops[i + 1])


def _pair_kernels(
    lhs: _Node, rhs: _Node, gram: bool
) -> Iterator[Tuple[str, Tuple[KernelCall, ...], str, bool]]:
    """Yield (tag, calls, out_storage, out_symmetric) choices for lhs@rhs.

    ``calls`` may include a tri2full preceding the product kernel.
    """
    m, k, n = lhs.rows, lhs.cols, rhs.cols

    if gram and lhs.storage == "full" and rhs.storage == "full":
        # SYRK: one triangle of the (symmetric) product.
        yield "syrk", (syrk(m, k),), "tri", True
        # GEMM computing the full symmetric product.
        yield "gemm", (gemm(m, n, k),), "full", True
        return

    pre: Tuple[KernelCall, ...]

    # Left operand symmetric → SYMM(side=L) without materializing storage.
    if lhs.symmetric and lhs.rows == lhs.cols:
        yield "symm", (symm(m, n),), "full", False
        if lhs.storage == "tri":
            # tri2full then plain GEMM (paper's Algorithm 2 for AAᵀB).
            yield "tri2full+gemm", (tri2full(m), gemm(m, n, k)), "full", False
        else:
            yield "gemm", (gemm(m, n, k),), "full", False
        return

    # Right operand symmetric → SYMM(side=R).
    if rhs.symmetric and rhs.rows == rhs.cols:
        yield "symmR", (symm(n, m),), "full", False
        if rhs.storage == "tri":
            yield "tri2full+gemm", (tri2full(n), gemm(m, n, k)), "full", False
        else:
            yield "gemm", (gemm(m, n, k),), "full", False
        return

    # Plain product.
    yield "gemm", (gemm(m, n, k),), "full", False


def enumerate_algorithms(
    c: Chain,
    env: Optional[Dict[str, int]] = None,
    max_algorithms: int = 512,
) -> List[Algorithm]:
    """Enumerate all kernel-call sequences evaluating chain ``c``.

    Reproduces the paper's algorithm sets: 6 for 4-operand chains, 5 for
    ``AAᵀB``. Enumeration is exhaustive in (ordering × kernel choice) up to
    ``max_algorithms``.
    """
    dims = bind_dims(c, env or {})
    leaves = _leaf_nodes(c, dims)
    gram_flags = [_same_leaf_gram(c, i) for i in range(len(c.ops) - 1)]

    out: List[Algorithm] = []
    counter = itertools.count()

    def rec(nodes: List[_Node], grams: List[bool], steps: Tuple[Step, ...],
            tags: Tuple[str, ...]) -> None:
        if len(out) >= max_algorithms:
            return
        if len(nodes) == 1:
            final = nodes[0]
            steps_f = steps
            if final.storage == "tri":
                # Result must be materialized as a full matrix.
                sid = next(counter)
                call = tri2full(final.rows)
                steps_f = steps + (
                    Step(call=call, lhs=final.ref, rhs=None, out=sid,
                         out_rows=final.rows, out_cols=final.cols,
                         out_storage="full", out_symmetric=final.symmetric),
                )
                tags = tags + ("tri2full",)
            out.append(Algorithm(name="+".join(tags), steps=steps_f))
            return
        for i in range(len(nodes) - 1):
            lhs, rhs = nodes[i], nodes[i + 1]
            for tag, calls, ostore, osym in _pair_kernels(lhs, rhs, grams[i]):
                new_steps = list(steps)
                new_tags = tags + (tag,)
                lref, rref = lhs.ref, rhs.ref
                # tri2full pre-call rewrites the tri operand in place.
                if len(calls) == 2:
                    pre, prod = calls
                    sid = next(counter)
                    tri_on_left = lhs.storage == "tri"
                    src = lref if tri_on_left else rref
                    rows = lhs.rows if tri_on_left else rhs.rows
                    new_steps.append(
                        Step(call=pre, lhs=src, rhs=None, out=sid,
                             out_rows=rows, out_cols=rows,
                             out_storage="full", out_symmetric=True))
                    if tri_on_left:
                        lref = sid
                    else:
                        rref = sid
                    calls = (prod,)
                (prod,) = calls
                oid = next(counter)
                new_steps.append(
                    Step(call=prod, lhs=lref, rhs=rref, out=oid,
                         out_rows=lhs.rows, out_cols=rhs.cols,
                         out_storage=ostore, out_symmetric=osym))
                merged = _Node(ref=oid, rows=lhs.rows, cols=rhs.cols,
                               symmetric=osym, storage=ostore)
                new_nodes = nodes[:i] + [merged] + nodes[i + 2:]
                # Rebuild pair flags positionally: pairs touching the merged
                # node are never Gram pairs; pairs right of the merge shift.
                new_grams = []
                for j in range(len(new_nodes) - 1):
                    if j < i - 1:
                        new_grams.append(grams[j])
                    elif j in (i - 1, i):
                        new_grams.append(False)
                    else:
                        new_grams.append(grams[j + 1])
                rec(new_nodes, new_grams, tuple(new_steps), new_tags)

    rec(leaves, gram_flags, (), ())
    # Dedup identical call sequences reached via different search paths.
    seen = {}
    for a in out:
        key = (a.calls, tuple((s.lhs, s.rhs) for s in a.steps))
        if key not in seen:
            seen[key] = a
    algos = list(seen.values())
    # Stable, human-auditable naming: ordinal + tags.
    return [
        Algorithm(name=f"alg{i + 1}[{a.name}]", steps=a.steps)
        for i, a in enumerate(algos)
    ]


def optimal_chain_order(dims: Sequence[int]) -> Tuple[int, Tuple]:
    """Classic matrix-chain DP: min-FLOPs parenthesization.

    Returns (flops, tree) where tree is a nested tuple of operand indices.
    This is the FLOPs-only discriminant used by Linnea/Julia/Armadillo, i.e.
    the strategy whose reliability the paper interrogates. O(n³).
    """
    n = len(dims) - 1
    if n < 1:
        raise ValueError("empty chain")
    INF = float("inf")
    cost = [[0] * n for _ in range(n)]
    split = [[0] * n for _ in range(n)]
    for span in range(1, n):
        for i in range(n - span):
            j = i + span
            best, arg = INF, i
            for k in range(i, j):
                c = (cost[i][k] + cost[k + 1][j]
                     + 2 * dims[i] * dims[k + 1] * dims[j + 1])
                if c < best:
                    best, arg = c, k
            cost[i][j] = int(best)
            split[i][j] = arg

    def tree(i: int, j: int):
        if i == j:
            return i
        k = split[i][j]
        return (tree(i, k), tree(k + 1, j))

    return cost[0][n - 1], tree(0, n - 1)


def chain_flops_of_order(dims: Sequence[int], order: Sequence[int]) -> int:
    """FLOPs of reducing adjacent pairs in the given order.

    ``order`` lists, per step, the index of the left operand of the pair to
    merge, with indices referring to the *current* working list.
    """
    ds = list(dims)
    fl = 0
    for i in order:
        fl += 2 * ds[i] * ds[i + 1] * ds[i + 2]
        del ds[i + 1]
    return fl
