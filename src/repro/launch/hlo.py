"""HLO-text analysis: collective traffic + roofline terms from a compiled
dry-run artifact.

``cost_analysis()`` reports per-device FLOPs/bytes but no collective bytes,
so we parse the (post-SPMD-partitioning) HLO: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op contributes its result-shape bytes (documented convention — for
all-reduce the wire traffic is ~2·(n−1)/n× that; we report raw result
bytes and keep the convention fixed across §Perf iterations so deltas are
meaningful).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# shapes like bf16[8,128]{1,0} or f32[] — capture dtype + dims.
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+fn?)?)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=]+?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_.values())

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())

    def summary(self) -> str:
        parts = [
            f"{k}: n={self.counts[k]} bytes={self.bytes_[k]:,}"
            for k in sorted(self.counts)
        ]
        return "; ".join(parts) if parts else "none"


def top_collectives(hlo_text: str, n: int = 20):
    """Rank individual collective ops by result bytes, with op metadata —
    the §Perf attribution tool (which tensor is being moved, from where
    in the program)."""
    out = []
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m or f"{m.group(2)}-done(" in line:
            continue
        b = _shape_bytes(m.group(1))
        meta = ""
        mm = re.search(r'op_name="([^"]+)"', line)
        if mm:
            meta = mm.group(1)[-110:]
        out.append((b, m.group(2), m.group(1).strip()[:60], meta))
    out.sort(reverse=True)
    return out[:n]


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    bytes_: Dict[str, int] = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        # async pairs: count the -start only (the -done carries same shape)
        if f"{op}-done(" in line:
            continue
        b = _shape_bytes(type_str)
        counts[op] = counts.get(op, 0) + 1
        bytes_[op] = bytes_.get(op, 0) + b
    return CollectiveStats(counts=counts, bytes_=bytes_)


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one (arch × shape × mesh) cell."""

    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    chips: int
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    link_bw: float = 50e9

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / self.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def model_flops_ratio(self, model_flops_total: float) -> float:
        """MODEL_FLOPS / HLO_FLOPs (total across chips)."""
        hlo_total = self.flops_per_device * self.chips
        return model_flops_total / hlo_total if hlo_total else 0.0

    def roofline_fraction(self, model_flops_total: float) -> float:
        """Useful-FLOPs throughput achievable vs chip peak, given the
        dominant term: (MODEL_FLOPS/chips/t_bound) / peak."""
        if self.t_bound <= 0:
            return 0.0
        ach = model_flops_total / self.chips / self.t_bound
        return ach / self.peak_flops
