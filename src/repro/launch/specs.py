"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

``abstract_state``/``abstract_batch``/``abstract_serve_state`` build the
exact pytrees the jitted steps take, as ShapeDtypeStructs (no allocation),
plus matching NamedShardings:

  * params/optimizer — logical-axis rules (TP on ``model``, FSDP on
    ``data``(+``pod``));
  * batch — batch dim over (pod, data);
  * KV caches — batch over (pod, data), **sequence over model**
    (flash-decoding-style sharded-KV softmax: GSPMD turns the masked
    softmax + PV contraction into partial reductions + tiny all-reduces);
  * SSM caches — batch over (pod, data), heads over model.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec
from repro.models import api
from repro.models.attention import KVCache
from repro.models.encdec import EncDecCaches
from repro.models.hybrid import HybridCaches
from repro.models.ssm import SSMCache
from repro.models.transformer import LayerCaches, ModelConfig
from repro.optim import adamw
from repro.sharding import rules as shrules
from repro.train.train_step import TrainState
from repro.serve.decode import ServeState


def _batch_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def _div(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    if isinstance(axis, tuple):
        import math
        size = math.prod(mesh.shape[a] for a in axis)
    else:
        size = mesh.shape[axis]
    return n % size == 0


# ------------------------------------------------------------- abstract ---

def abstract_init(cfg: ModelConfig, dtype=jnp.float32):
    """Returns (param ShapeDtypeStructs, logical-axes pytree), allocation-
    free: params are traced with eval_shape; the axes pytree is static
    (plain python tuples) so it is captured from the traced init call."""
    key = jax.random.PRNGKey(0)
    captured = {}

    def initf(k):
        p, a = api.init(k, cfg, dtype)
        captured["axes"] = a
        return p

    shapes = jax.eval_shape(initf, key)
    return shapes, captured["axes"]


def param_shardings(cfg: ModelConfig, mesh: Mesh, dtype=jnp.float32,
                    policy: str = "fsdp"):
    shapes, axes = abstract_init(cfg, dtype)
    from repro.sharding.rules import rules_for
    specs = shrules.params_specs(axes, shapes, mesh,
                                 rules=rules_for(policy))
    return shapes, specs, shrules.shardings_of(specs, mesh)


def abstract_train_state(cfg: ModelConfig, mesh: Mesh,
                         dtype=jnp.float32, policy: str = "auto"):
    """(TrainState structs, TrainState shardings).

    ``policy``: fsdp | zero1 | auto — parameter sharding across the data
    axes. Optimizer moments are always data-sharded (ZeRO); ``auto``
    selects by modeled per-device memory (rules.pick_param_policy).
    """
    from repro.sharding.rules import pick_param_policy
    if policy == "auto":
        policy = pick_param_policy(cfg.param_count(), mesh)
    pshapes, pspecs, pshard = param_shardings(cfg, mesh, dtype,
                                              policy=policy)
    # Moments: always ZeRO-sharded over data (DEFAULT_RULES).
    _, _, mshard = param_shardings(cfg, mesh, dtype, policy="fsdp")
    opt_shapes = jax.eval_shape(adamw.init, pshapes)
    scalar = NamedSharding(mesh, P())
    opt_shard = adamw.AdamWState(
        step=scalar,
        mu=jax.tree.map(lambda s: s, mshard),
        nu=jax.tree.map(lambda s: s, mshard),
    )
    state = TrainState(params=pshapes, opt=opt_shapes,
                       step=jax.ShapeDtypeStruct((), jnp.int32))
    shard = TrainState(params=pshard, opt=opt_shard, step=scalar)
    return state, shard


def abstract_batch(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh
                   ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    b, s = shape.global_batch, shape.seq_len
    ba = _batch_axes(mesh)
    bspec = NamedSharding(mesh, P(ba))
    # VLM: the stub vision prefix occupies the first positions of the
    # sequence budget, so token count shrinks to keep total == seq_len.
    s_tok = s - cfg.vision_tokens if cfg.family == "vlm" else s
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, s_tok), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s_tok), jnp.int32),
    }
    shards: Dict[str, Any] = {"tokens": bspec, "labels": bspec}
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        shards["frames"] = bspec
    if cfg.family == "vlm":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        shards["vision_embeds"] = bspec
    return specs, shards


# ------------------------------------------------------------- caches ----

def cache_specs(cfg: ModelConfig, caches_shapes: Any, mesh: Mesh) -> Any:
    """PartitionSpecs for a (stacked) cache pytree by structure."""
    bax = _batch_axes(mesh)

    def kv_spec(arr, dim_s=2, dim_h=3):
        # (L, B, S, H, D)
        entries = [None] * len(arr.shape)
        entries[1] = bax if _div(arr.shape[1], mesh, bax) else None
        if _div(arr.shape[dim_s], mesh, "model"):
            entries[dim_s] = "model"
        return P(*entries)

    def ssm_state_spec(arr):
        # (L, B, H, N, P)
        entries = [None] * len(arr.shape)
        entries[1] = bax if _div(arr.shape[1], mesh, bax) else None
        if _div(arr.shape[2], mesh, "model"):
            entries[2] = "model"
        return P(*entries)

    def conv_spec(arr):
        # (L, B, K, C)
        entries = [None] * len(arr.shape)
        entries[1] = bax if _div(arr.shape[1], mesh, bax) else None
        if _div(arr.shape[3], mesh, "model"):
            entries[3] = "model"
        return P(*entries)

    def walk(obj):
        if isinstance(obj, KVCache):
            return KVCache(k=kv_spec(obj.k), v=kv_spec(obj.v), length=P())
        if isinstance(obj, SSMCache):
            return SSMCache(conv=conv_spec(obj.conv),
                            state=ssm_state_spec(obj.state), length=P())
        if isinstance(obj, LayerCaches):
            return LayerCaches(
                kv=walk(obj.kv) if obj.kv is not None else None,
                ssm=walk(obj.ssm) if obj.ssm is not None else None)
        if isinstance(obj, EncDecCaches):
            return EncDecCaches(self_kv=walk(obj.self_kv),
                                cross_k=kv_spec(obj.cross_k),
                                cross_v=kv_spec(obj.cross_v))
        if isinstance(obj, HybridCaches):
            return HybridCaches(ssm=walk(obj.ssm),
                                shared_kv=walk(obj.shared_kv))
        raise TypeError(type(obj))

    return walk(caches_shapes)


def abstract_serve_state(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                         dtype=jnp.bfloat16):
    """(ServeState structs, shardings, param structs, param shardings)."""
    pshapes, pspecs, pshard = param_shardings(cfg, mesh, dtype)
    b, max_s = shape.global_batch, shape.seq_len
    bi_specs = {}
    if cfg.family == "encdec":
        bi_specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), dtype)

    def mk(params, bi):
        return api.init_caches(params, cfg, b, max_s,
                               batch_inputs=bi or None, dtype=dtype)

    caches_shapes = jax.eval_shape(mk, pshapes, bi_specs)
    cspecs = cache_specs(cfg, caches_shapes, mesh)
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                          is_leaf=lambda x: isinstance(x, P))
    bax = _batch_axes(mesh)
    tok_shard = NamedSharding(
        mesh, P(bax if b % _axsize(mesh, bax) == 0 else None))
    state = ServeState(
        caches=caches_shapes,
        last_tokens=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        rng=jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    shard = ServeState(caches=cshard, last_tokens=tok_shard,
                       rng=NamedSharding(mesh, P()))
    return state, shard, pshapes, pshard


def _axsize(mesh: Mesh, axis) -> int:
    import math
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]
