"""End-to-end training driver (runs on real local devices).

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m \
      --smoke --steps 50 --ckpt /tmp/ckpt

``--smoke`` uses the reduced config (CPU-feasible); without it the full
assigned config is used (requires TPU-scale memory). The driver wires the
synthetic data pipeline, mesh, sharding rules, checkpoint manager and
supervisor together — the same path the dry-run proves at 512 devices.
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import get, get_smoke, normalize
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh, set_mesh
from repro.runtime.supervisor import RestartPolicy, Supervisor
from repro.sharding.context import activation_sharding
from repro.train import loop as train_loop


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--optimizer", default="adamw",
                    choices=("adamw", "muon"))
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get(normalize(args.arch))
    mesh = make_host_mesh(model=args.model_parallel)

    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = ((cfg.encoder_seq, cfg.d_model), "float32")
    if cfg.family == "vlm":
        extra["vision_embeds"] = ((cfg.vision_tokens, cfg.d_model),
                                  "float32")
    source = SyntheticLM(cfg.vocab, args.seq, args.batch,
                         extra_specs=extra)

    def run(attempt: int):
        with set_mesh(mesh), activation_sharding(mesh):
            return train_loop.train(
                cfg, source, args.steps, ckpt_dir=args.ckpt,
                optimizer=args.optimizer, peak_lr=args.lr, mesh=mesh)

    sup = Supervisor(RestartPolicy(max_restarts=args.max_restarts,
                                   backoff_s=0.1))
    state = sup.run(run)
    print(f"[train] done at step {int(jax.device_get(state.step))}; "
          f"restarts={sup.restarts}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
