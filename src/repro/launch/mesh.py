"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state — required because
the dry-run must set XLA_FLAGS before any jax initialization.

Mesh shapes (assignment):
  single-pod: (16, 16)      axes (data, model)   — 256 chips
  multi-pod:  (2, 16, 16)   axes (pod, data, model) — 512 chips

Axis semantics: ``data`` carries DP + FSDP (param/optimizer ZeRO-3
sharding); ``model`` carries TP/EP; ``pod`` is the cross-DCN data-parallel
replica axis (gradient all-reduce crosses it once per step — the axis
gradient compression targets).
"""

from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; Auto is the pre-AxisType default.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def set_mesh(mesh):
    """Enter ``mesh`` as the ambient mesh, portable across jax versions.

    ``jax.set_mesh`` landed after jax 0.4.x; on older jax the Mesh object
    itself is the context manager that establishes the ambient mesh for
    sharding constraints. Use this everywhere instead of ``jax.set_mesh``
    (same class of gate as the ``AxisType`` import above).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (tests / examples)."""
    n = jax.device_count()
    model = min(model, n)
    return _make_mesh((n // model, model), ("data", "model"))
