import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init). 512 host-platform placeholder devices back both the
single-pod (16×16) and multi-pod (2×16×16) production meshes.

Per cell this driver:
  1. builds ShapeDtypeStruct inputs + NamedShardings (launch/specs.py),
     with sequence-parallel activation constraints active,
  2. ``jax.jit(step, in_shardings, out_shardings).lower(...).compile()``,
  3. records ``memory_analysis()`` (fits-per-device proof) and the HLO
     collective schedule (launch/hlo.py),
  4. **depth-corrects** FLOPs/bytes/collective-bytes: XLA cost analysis
     counts a ``while`` (lax.scan over layers) body ONCE, so the driver
     compiles two shallow depth variants of the same cell and linearly
     extrapolates to the full depth — exact for uniform stacks
     (hybrid's tail remainder ≈5% approximation, documented).

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out dryrun.json
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get, normalize, shape_applicable
from repro.launch import hlo as hlo_lib
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.models.transformer import ModelConfig
from repro.serve.decode import make_serve_step
from repro.sharding.context import activation_sharding
from repro.train.train_step import make_train_step


def _cfg_for_cell(arch: str, shape_name: str) -> ModelConfig:
    cfg = get(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        # Big-model training cells need per-layer remat to bound
        # activation memory at seq 4096 × batch 256.
        cfg = dataclasses.replace(cfg, remat="full")
    return cfg


def _depth_unit(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid" and cfg.attn_every:
        return cfg.attn_every
    if cfg.window_pattern:
        return len(cfg.window_pattern)
    return 1


def _depth_variant(cfg: ModelConfig, k: int) -> ModelConfig:
    kw: Dict[str, Any] = {"n_layers": k}
    if cfg.family == "encdec":
        kw["encoder_layers"] = max(1, min(cfg.encoder_layers, k))
    return dataclasses.replace(cfg, **kw)


def lower_cell(cfg: ModelConfig, shape_name: str, multi_pod: bool,
               donate: bool = True, policy: str = "auto"):
    """Lower+compile one cell; returns (compiled, meta dict)."""
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    with set_mesh(mesh), activation_sharding(
            mesh, heads=not multi_pod):
        if shape.kind == "train":
            state, state_shard = specs_lib.abstract_train_state(
                cfg, mesh, policy=policy)
            batch, batch_shard = specs_lib.abstract_batch(cfg, shape, mesh)
            step = make_train_step(cfg, accum_steps=1)
            jitted = jax.jit(
                step,
                in_shardings=(state_shard, batch_shard),
                out_shardings=(state_shard, None),
                donate_argnums=(0,) if donate else (),
            )
            lowered = jitted.lower(state, batch)
        elif shape.kind == "prefill":
            pshapes, _, pshard = specs_lib.param_shardings(
                cfg, mesh, jnp.bfloat16)
            batch, batch_shard = specs_lib.abstract_batch(cfg, shape, mesh)
            if cfg.family in ("encdec", "hybrid"):
                # prefill == teacher-forced forward for these families.
                from repro.models import api

                def step(params, b):
                    logits, _ = api.forward_train(params, cfg, b)
                    return logits

                jitted = jax.jit(step, in_shardings=(pshard, batch_shard))
                lowered = jitted.lower(pshapes, batch)
            else:
                serve_state, sshard, _, _ = specs_lib.abstract_serve_state(
                    cfg, shape, mesh)
                from repro.models import api

                def step(params, b, caches):
                    return api.prefill(params, cfg, b, caches)

                jitted = jax.jit(
                    step,
                    in_shardings=(pshard, batch_shard, sshard.caches),
                    out_shardings=(None, sshard.caches),
                    donate_argnums=(2,) if donate else (),
                )
                lowered = jitted.lower(pshapes, batch, serve_state.caches)
        else:  # decode
            serve_state, sshard, pshapes, pshard = \
                specs_lib.abstract_serve_state(cfg, shape, mesh)
            step = make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(sshard, pshard),
                out_shardings=(sshard, sshard.last_tokens),
                donate_argnums=(0,) if donate else (),
            )
            lowered = jitted.lower(serve_state, pshapes)

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    meta = {"compile_s": compile_s,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": 512 if multi_pod else 256,
            "kind": shape.kind}
    return compiled, meta


def _cell_costs(compiled) -> Tuple[float, float, float, Dict]:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    bts = float(ca.get("bytes accessed", 0.0))
    coll = hlo_lib.collective_stats(compiled.as_text())
    return flops, bts, float(coll.total_bytes), {
        "counts": coll.counts, "bytes": coll.bytes_}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             depth_correct: bool = True) -> Dict[str, Any]:
    cfg = _cfg_for_cell(arch, shape_name)
    shape = SHAPES[shape_name]
    run, why = shape_applicable(cfg, shape)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    if not run:
        return {"arch": cfg.name, "shape": shape_name, "mesh": mesh_tag,
                "skipped": True, "reason": why}

    compiled, meta = lower_cell(cfg, shape_name, multi_pod)
    mem = compiled.memory_analysis()
    flops_raw, bytes_raw, coll_raw, coll_detail = _cell_costs(compiled)

    # ---- depth correction --------------------------------------------
    # XLA cost analysis counts while bodies once, so FLOPs/bytes/collective
    # totals come from *unrolled* shallow variants (scan_util.unrolled):
    # two depths → exact per-layer increment → linear extrapolation.
    from repro.models import scan_util
    unit = _depth_unit(cfg)
    L = cfg.n_layers
    if depth_correct and L > 2 * unit:
        with scan_util.unrolled():
            c1, _ = lower_cell(_depth_variant(cfg, unit), shape_name,
                               multi_pod, donate=False)
            c2, _ = lower_cell(_depth_variant(cfg, 2 * unit), shape_name,
                               multi_pod, donate=False)
        f1, b1, l1, _ = _cell_costs(c1)
        f2, b2, l2, _ = _cell_costs(c2)
        scale = (L - unit) / unit
        flops = f1 + scale * max(0.0, f2 - f1)
        bts = b1 + scale * max(0.0, b2 - b1)
        coll = l1 + scale * max(0.0, l2 - l1)
        depth_note = (f"depth-corrected (unrolled L={unit},{2*unit} "
                      f"variants, linear in depth)")
    else:
        with scan_util.unrolled():
            cu, _ = lower_cell(cfg, shape_name, multi_pod, donate=False)
        flops, bts, coll, _ = _cell_costs(cu)
        depth_note = "direct (fully unrolled shallow model)"

    chips = meta["chips"]
    roof = hlo_lib.Roofline(
        flops_per_device=flops, bytes_per_device=bts,
        collective_bytes=coll, chips=chips)
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if meta["kind"] != "decode"
                                   else 1)
    mult = 6 if meta["kind"] == "train" else 2
    model_flops = mult * n_active * tokens
    return {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": meta["mesh"],
        "kind": meta["kind"],
        "compile_s": round(meta["compile_s"], 1),
        "params": n_params,
        "active_params": n_active,
        "depth_note": depth_note,
        "bytes_per_device": {
            "args": mem.argument_size_in_bytes,
            "out": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "alias": mem.alias_size_in_bytes,
            "peak_est": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bts,
        "collectives": coll_detail,
        "collective_bytes": coll,
        "t_compute": roof.t_compute,
        "t_memory": roof.t_memory,
        "t_collective": roof.t_collective,
        "bottleneck": roof.bottleneck,
        "model_flops": model_flops,
        "model_flops_ratio": roof.model_flops_ratio(model_flops),
        "roofline_fraction": roof.roofline_fraction(model_flops),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("off", "on", "both"),
                    default="off")
    ap.add_argument("--no-depth-correct", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.all or not args.arch else (
        normalize(args.arch),)
    shapes = tuple(SHAPES) if args.all or not args.shape else (args.shape,)
    pods = {"off": (False,), "on": (True,), "both": (False, True)}[
        args.multi_pod]

    results = []
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in pods:
                tag = f"{arch} × {shape_name} × {'2x16x16' if mp else '16x16'}"
                try:
                    r = run_cell(arch, shape_name, mp,
                                 depth_correct=not args.no_depth_correct)
                    results.append(r)
                    if r.get("skipped"):
                        print(f"[skip] {tag}: {r['reason']}", flush=True)
                    else:
                        print(
                            f"[ ok ] {tag}: compile={r['compile_s']}s "
                            f"peak={r['bytes_per_device']['peak_est']/2**30:.2f}GiB "
                            f"tc={r['t_compute']*1e3:.2f}ms "
                            f"tm={r['t_memory']*1e3:.2f}ms "
                            f"tl={r['t_collective']*1e3:.2f}ms "
                            f"→ {r['bottleneck']} "
                            f"roofline={r['roofline_fraction']:.2%}",
                            flush=True)
                except Exception as e:
                    failures += 1
                    traceback.print_exc()
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}",
                          flush=True)
                    results.append({"arch": arch, "shape": shape_name,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "error": f"{type(e).__name__}: {e}"})
                if args.out:
                    # incremental write: partial sweeps still produce
                    # a usable artifact (atomic rename).
                    with open(args.out + ".tmp", "w") as f:
                        json.dump(results, f, indent=1)
                    os.replace(args.out + ".tmp", args.out)
    if args.out:
        print(f"wrote {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
