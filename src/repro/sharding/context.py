"""Activation-sharding context: sequence parallelism without touching
model code signatures.

The launcher (dry-run / trainer) activates a context naming the mesh and
batch axes; model code calls :func:`shard_seq` / :func:`shard_logits` at
the residual stream and LM head. Inside the context these lower to
``with_sharding_constraint`` — GSPMD then keeps the carried activations
sequence-sharded over the ``model`` axis between blocks (Megatron-style
sequence parallelism: norms/residuals run S/model-sharded; the attention
and MLP projections transition via all-gather/reduce-scatter pairs that
GSPMD inserts). Outside the context they are identity, so single-host
tests and examples see no constraints.

Memory effect (glm4 train_4k cell): the per-device residual carried
through the layer scan drops model_axis-fold (16×) — the difference
between a 190 GiB and a <16 GiB HBM footprint.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


def _state():
    if not hasattr(_ctx, "mesh"):
        _ctx.mesh = None
        _ctx.batch_axes = None
        _ctx.heads_enabled = True
    return _ctx


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, batch_axes=None, heads: bool = True):
    """``heads=False`` disables the in-attention head constraints only:
    XLA's SPMD partitioner has a pathological compile-time path for the
    head-layout transitions on 3-axis (pod) meshes on the CPU backend —
    multi-pod dry-runs prove compilation with the propagated layout
    instead (single-pod keeps the optimized Megatron layout; documented
    in EXPERIMENTS.md §Dry-run)."""
    st = _state()
    prev = (st.mesh, st.batch_axes, getattr(st, "heads_enabled", True))
    if batch_axes is None:
        axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        batch_axes = axes if len(axes) > 1 else (axes[0] if axes else None)
    st.mesh, st.batch_axes = mesh, batch_axes
    st.heads_enabled = heads
    try:
        yield
    finally:
        st.mesh, st.batch_axes, st.heads_enabled = prev


def _axsize(mesh, axis) -> int:
    import math
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def _constrain(x, spec_entries):
    st = _state()
    if st.mesh is None:
        return x
    entries = []
    for dim, ax in zip(x.shape, spec_entries):
        size = _axsize(st.mesh, ax)
        entries.append(ax if (ax is not None and dim % size == 0
                              and dim >= size) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(st.mesh, P(*entries)))


def shard_seq(x):
    """(B, S, d) residual: batch over (pod,data), sequence over model."""
    st = _state()
    if st.mesh is None or x.ndim != 3:
        return x
    return _constrain(x, (st.batch_axes, "model", None))


def shard_logits(x):
    """(B, S, V) logits: batch over (pod,data), vocab over model."""
    st = _state()
    if st.mesh is None or x.ndim != 3:
        return x
    return _constrain(x, (st.batch_axes, None, "model"))


def shard_tokens_hidden(x):
    """(T, d) flattened token activations (MoE internals)."""
    st = _state()
    if st.mesh is None or x.ndim != 2:
        return x
    return _constrain(x, (st.batch_axes, None))


def shard_moe_groups(x):
    """(G, Tg, d) grouped MoE token blocks: groups over the batch axes."""
    st = _state()
    if st.mesh is None or x.ndim != 3:
        return x
    return _constrain(x, (st.batch_axes, None, None))


def shard_heads(x):
    """(B, S, H, Dh) attention tensors: heads over model, full sequence —
    the Megatron TP layout inside the attention block. Combined with the
    S-sharded residual (shard_seq), GSPMD inserts the canonical
    all-gather(S) on entry / reduce-scatter(S) on exit instead of
    full-activation all-reduces (§Perf hillclimb 2)."""
    st = _state()
    if st.mesh is None or x.ndim != 4 or not getattr(
            st, "heads_enabled", True):
        return x
    return _constrain(x, (st.batch_axes, None, "model", None))


def shard_ssd_chunks(x):
    """(B, nc, Q, ...) SSD chunk tensors: batch over (pod,data), chunk
    axis over model — keeps the O(nc·Q²·H) intra-chunk working set
    model-sharded through the SSD layer (mamba2 §Perf hillclimb)."""
    st = _state()
    if st.mesh is None or x.ndim < 3:
        return x
    spec = (st.batch_axes, "model") + (None,) * (x.ndim - 2)
    return _constrain(x, spec)


def shard_ssd_states(x, h_axis: int):
    """SSD inter-chunk states: shard the heads axis over model. The
    chunk-state tensors (B, nc, H, N, P) are the largest live set of the
    chunked SSD backward (≈ nc·H·N·P floats per sequence) and the
    associative scan over chunks is elementwise in H — head sharding is
    free parallelism there (mamba2 §Perf hillclimb, iteration 2)."""
    st = _state()
    if st.mesh is None:
        return x
    spec = [None] * x.ndim
    spec[h_axis] = "model"
    return _constrain(x, tuple(spec))
