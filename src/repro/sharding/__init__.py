"""Sharding policy: logical-axis rules -> PartitionSpecs (DP/TP/EP/FSDP)."""

from .rules import (
    DEFAULT_RULES,
    batch_spec,
    params_specs,
    replicated,
    shardings_of,
    spec_for,
)

__all__ = ["DEFAULT_RULES", "batch_spec", "params_specs", "replicated",
           "shardings_of", "spec_for"]
